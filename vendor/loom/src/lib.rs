//! Vendored, offline stand-in for the [`loom`] concurrency model checker.
//!
//! The build container has no network access, so this crate reimplements
//! the slice of loom's API that `mcprioq` uses — `loom::model` /
//! [`Builder`], [`thread`], [`sync::atomic`], [`sync::Mutex`] /
//! [`sync::Condvar`], [`cell::UnsafeCell`], [`hint::spin_loop`] — with the
//! same semantics contract, so `rust/src/sync/shim.rs` and the models in
//! `rust/tests/loom_models.rs` compile unchanged against the real crate.
//!
//! # What this implementation checks
//!
//! Each call to [`model`] runs the closure many times. Within one run,
//! every synchronization operation (atomic op, fence, mutex/condvar op,
//! spawn/join, yield/spin hint) is a *scheduling point*: exactly one thread
//! runs between two points, and a seeded RNG picks which thread runs next.
//! Randomized schedule exploration (in the style of shuttle / PCT) replaces
//! real loom's exhaustive DFS: the code under test has process-global state
//! (RCU registry, arena counters) that persists across runs, which breaks
//! the deterministic replay exhaustive search depends on — random seeds
//! per-iteration have no such requirement and still drive the probability
//! of missing a schedule-dependent bug toward zero as iterations grow.
//!
//! On top of the schedule, the runtime maintains vector clocks with
//! release/acquire transfer rules (including release sequences via RMWs and
//! release/acquire *fences*) and flags:
//!
//! - **data races**: `cell::UnsafeCell` accesses not ordered by
//!   happens-before panic with a race report;
//! - **deadlocks**: all live threads blocked panics with a state dump;
//! - **livelocks**: an execution exceeding its op budget panics;
//! - **lost wakeups / leaked threads**: a model that completes with a
//!   spawned thread never finished panics.
//!
//! # What it does not check
//!
//! Operations execute sequentially-consistently at their scheduling point;
//! weak-memory *value* outcomes (a relaxed load observing a stale value, as
//! on real ARM) are not simulated — `Relaxed` vs `Acquire` differences are
//! observed through the happens-before race detector, not through stale
//! reads. This is the same trade-off made by shuttle, and it still catches
//! ordering bugs whenever they manifest as an unsynchronized `UnsafeCell`
//! access or a broken protocol invariant asserted by the model.
//!
//! # Environment knobs
//!
//! - `LOOM_ITERATIONS`: override the iteration count (CI uses a larger
//!   value than the local default).
//! - `LOOM_SEED`: override the base seed to reproduce a reported failure
//!   (each iteration `i` runs with seed `base + i`; failures print both).

pub mod cell;
pub mod hint;
pub mod sync;
pub mod thread;

pub(crate) mod atomic;
pub(crate) mod rt;

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

/// Model-exploration configuration. Mirrors loom's `Builder` shape;
/// `iterations`/`seed`/`op_budget` are the knobs this implementation uses.
#[derive(Debug, Clone)]
pub struct Builder {
    /// Schedules to explore per model (`LOOM_ITERATIONS` overrides).
    pub iterations: usize,
    /// Base RNG seed; iteration `i` uses `seed + i` (`LOOM_SEED` overrides).
    pub seed: u64,
    /// Scheduling points allowed per execution before it is declared a
    /// livelock.
    pub op_budget: u64,
    /// Accepted for loom API compatibility; the scheduler has no intrinsic
    /// thread limit.
    pub max_threads: usize,
}

impl Default for Builder {
    fn default() -> Self {
        Self::new()
    }
}

impl Builder {
    pub fn new() -> Builder {
        Builder { iterations: 256, seed: 0x5EED_CAFE, op_budget: 1 << 20, max_threads: 8 }
    }

    fn env_u64(name: &str) -> Option<u64> {
        std::env::var(name).ok()?.trim().parse().ok()
    }

    /// Run `f` under the scheduler, once per iteration, each with a fresh
    /// execution (clocks, access histories) and a distinct seed.
    pub fn check<F>(&self, f: F)
    where
        F: Fn() + Sync + Send + 'static,
    {
        let iterations = Self::env_u64("LOOM_ITERATIONS")
            .map(|n| n as usize)
            .unwrap_or(self.iterations)
            .max(1);
        let base_seed = Self::env_u64("LOOM_SEED").unwrap_or(self.seed);

        for it in 0..iterations {
            let seed = base_seed.wrapping_add(it as u64);
            let exec = rt::Execution::new(seed, self.op_budget);
            let main = exec.register_thread(None);
            rt::set_ctx(std::sync::Arc::clone(&exec), main);

            let result = catch_unwind(AssertUnwindSafe(&f));

            // Leak check before finishing: every spawned thread must have
            // been joined (a parked leftover thread means the model lost a
            // wakeup or forgot a join — both bugs).
            let leaked: Vec<usize> = {
                let st = exec.lock();
                st.threads
                    .iter()
                    .enumerate()
                    .skip(1)
                    .filter(|(_, t)| t.run != rt::Run::Finished)
                    .map(|(i, _)| i)
                    .collect()
            };

            rt::clear_ctx();
            if let Err(payload) = result {
                eprintln!(
                    "loom: model failed at iteration {it} (seed {seed:#x}); rerun with \
                     LOOM_SEED={seed} LOOM_ITERATIONS=1 to reproduce"
                );
                resume_unwind(payload);
            }
            // Assert before `finish`: finishing main reschedules, and a
            // leaked runnable thread would start executing concurrently
            // with the next iteration.
            assert!(
                leaked.is_empty(),
                "loom: model completed but threads {leaked:?} were never joined \
                 (iteration {it}, seed {seed:#x})"
            );
            exec.finish(main);
        }
    }
}

/// Explore the interleavings of `f` with the default [`Builder`].
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    Builder::new().check(f)
}
