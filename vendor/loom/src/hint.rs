//! Spin hints. Under a model a spin hint is a scheduling point — a spin
//! loop that waits on another thread *must* deschedule, or the model would
//! burn its op budget without ever running the thread it waits for.

use crate::rt;

pub fn spin_loop() {
    if rt::yield_point().is_none() {
        std::hint::spin_loop();
    }
}
