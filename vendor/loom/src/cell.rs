//! Race-checked interior mutability. Under an active model every access is
//! recorded against the access history of the cell's address and checked
//! for happens-before with all concurrent accesses; a conflict panics with
//! a race report. Outside a model the wrappers are zero-cost.
//!
//! API note: like real loom, access is closure-scoped (`with`/`with_mut`)
//! instead of `get()` — the access is recorded exactly when it happens.

use crate::rt;

#[derive(Debug, Default)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

impl<T> UnsafeCell<T> {
    pub const fn new(v: T) -> Self {
        UnsafeCell(std::cell::UnsafeCell::new(v))
    }

    /// Immutable access: records a read, panics on a racing write.
    /// The closure receives a raw pointer; dereferencing it is the caller's
    /// unsafe obligation (the model only validates the synchronization).
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        let _ = rt::cell_access(self.0.get() as usize, false);
        f(self.0.get())
    }

    /// Mutable access: records a write, panics on any racing access.
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        let _ = rt::cell_access(self.0.get() as usize, true);
        f(self.0.get())
    }

    pub fn get_mut(&mut self) -> &mut T {
        // Exclusive borrow: statically race-free.
        self.0.get_mut()
    }

    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }
}
