//! Model-aware `Mutex`/`Condvar` (used by the shim for the bounded ingest
//! queue). Inside a model, blocking is cooperative: a contended `lock` or a
//! `wait` parks the thread in the scheduler, which then explores the other
//! threads; lost-wakeup and lock-ordering deadlocks surface as a model
//! panic instead of a hung test. Lock/unlock transfer happens-before via
//! the mutex's clock, like a release/acquire pair.
//!
//! `Arc` is re-exported from std: its internal synchronization is not under
//! test, and real `Arc` keeps the models allocation-faithful.

use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError};

use crate::rt::{self, vjoin, Blocked, Run};

pub use std::sync::Arc;

pub mod atomic {
    pub use crate::atomic::{
        fence, AtomicBool, AtomicI64, AtomicPtr, AtomicU32, AtomicU64, AtomicU8, AtomicUsize,
    };
    pub use std::sync::atomic::Ordering;
}

pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
}

pub struct MutexGuard<'a, T> {
    mx: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
    /// True when acquired under an active model (scheduler bookkeeping on
    /// drop); captured at acquisition so teardown stays consistent even if
    /// the model ends while a guard is alive.
    model: bool,
}

impl<T> Mutex<T> {
    pub const fn new(value: T) -> Self {
        Mutex { inner: std::sync::Mutex::new(value) }
    }

    fn addr(&self) -> usize {
        self as *const _ as usize
    }

    /// Acquire the scheduler-level ownership of this mutex (model path).
    fn model_acquire(&self, exec: &std::sync::Arc<rt::Execution>, me: usize) {
        let addr = self.addr();
        let st = exec.lock();
        let mut st = exec.schedule(st, me);
        loop {
            let meta = st.mutexes.entry(addr).or_default();
            if meta.held_by.is_none() {
                meta.held_by = Some(me);
                let sync = meta.sync.clone();
                vjoin(&mut st.threads[me].clock, &sync);
                return;
            }
            st = exec.block(st, me, Blocked::Mutex(addr));
        }
    }

    /// Release the scheduler-level ownership (model path). The real inner
    /// guard must already be dropped.
    fn model_release(&self, exec: &std::sync::Arc<rt::Execution>, me: usize) {
        let addr = self.addr();
        let mut st = exec.lock();
        let clock = st.threads[me].clock.clone();
        let meta = st.mutexes.entry(addr).or_default();
        meta.held_by = None;
        meta.sync = clock;
        rt::Execution::wake_mutex_waiters(&mut st, addr);
    }

    fn take_inner(&self) -> std::sync::MutexGuard<'_, T> {
        match self.inner.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => {
                unreachable!("loom: scheduler granted a mutex that is still held")
            }
        }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match rt::ctx() {
            Some((exec, me)) => {
                self.model_acquire(&exec, me);
                Ok(MutexGuard { mx: self, inner: Some(self.take_inner()), model: true })
            }
            None => {
                let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
                Ok(MutexGuard { mx: self, inner: Some(g), model: false })
            }
        }
    }

    pub fn try_lock(&self) -> Result<MutexGuard<'_, T>, std::sync::TryLockError<MutexGuard<'_, T>>>
    {
        match rt::ctx() {
            Some((exec, me)) => {
                let addr = self.addr();
                let st = exec.lock();
                let mut st = exec.schedule(st, me);
                let meta = st.mutexes.entry(addr).or_default();
                if meta.held_by.is_none() {
                    meta.held_by = Some(me);
                    let sync = meta.sync.clone();
                    vjoin(&mut st.threads[me].clock, &sync);
                    drop(st);
                    Ok(MutexGuard { mx: self, inner: Some(self.take_inner()), model: true })
                } else {
                    Err(std::sync::TryLockError::WouldBlock)
                }
            }
            None => match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard { mx: self, inner: Some(g), model: false }),
                Err(std::sync::TryLockError::Poisoned(p)) => {
                    Ok(MutexGuard { mx: self, inner: Some(p.into_inner()), model: false })
                }
                Err(std::sync::TryLockError::WouldBlock) => {
                    Err(std::sync::TryLockError::WouldBlock)
                }
            },
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        Ok(self.inner.get_mut().unwrap_or_else(PoisonError::into_inner))
    }

    pub fn into_inner(self) -> LockResult<T> {
        Ok(self.inner.into_inner().unwrap_or_else(PoisonError::into_inner))
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after release")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the real lock first, then the scheduler-level ownership:
        // the next model thread only touches the inner mutex after the
        // scheduler grants it, so this order can never produce WouldBlock.
        self.inner = None;
        if self.model {
            if let Some((exec, me)) = rt::ctx() {
                self.mx.model_release(&exec, me);
            }
        }
    }
}

/// Result of [`Condvar::wait_timeout`]. (Own type: std's cannot be
/// constructed outside std.)
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar { inner: std::sync::Condvar::new() }
    }

    fn addr(&self) -> usize {
        self as *const _ as usize
    }

    fn model_wait<'a, T>(
        &self,
        exec: std::sync::Arc<rt::Execution>,
        me: usize,
        mut guard: MutexGuard<'a, T>,
        timed: bool,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        let mx = guard.mx;
        // Atomically (w.r.t. the scheduler) release the mutex and park on
        // the condvar: both happen under one state lock, so a notify cannot
        // slip between them (no lost wakeups by construction).
        guard.inner = None;
        guard.model = false; // neutralize the guard's Drop bookkeeping
        {
            let mut st = exec.lock();
            let clock = st.threads[me].clock.clone();
            let addr = mx.addr();
            let meta = st.mutexes.entry(addr).or_default();
            meta.held_by = None;
            meta.sync = clock;
            rt::Execution::wake_mutex_waiters(&mut st, addr);
            st = exec.block(st, me, Blocked::Condvar { cv: self.addr(), timed });
            let t = &mut st.threads[me];
            let timed_out = std::mem::take(&mut t.timed_out);
            drop(st);
            drop(guard);
            // Re-acquire the mutex before returning, like std.
            mx.model_acquire(&exec, me);
            (
                MutexGuard { mx, inner: Some(mx.take_inner()), model: true },
                WaitTimeoutResult(timed_out),
            )
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match rt::ctx() {
            Some((exec, me)) => {
                let (g, _) = self.model_wait(exec, me, guard, false);
                Ok(g)
            }
            None => {
                let mut guard = guard;
                let inner = guard.inner.take().expect("guard accessed after release");
                let inner = self.inner.wait(inner).unwrap_or_else(PoisonError::into_inner);
                guard.inner = Some(inner);
                Ok(guard)
            }
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match rt::ctx() {
            Some((exec, me)) => {
                // Models have no clock: the timeout fires exactly when no
                // other thread can make progress (see rt::reschedule).
                Ok(self.model_wait(exec, me, guard, true))
            }
            None => {
                let mut guard = guard;
                let inner = guard.inner.take().expect("guard accessed after release");
                let (inner, tr) =
                    self.inner.wait_timeout(inner, dur).unwrap_or_else(PoisonError::into_inner);
                guard.inner = Some(inner);
                Ok((guard, WaitTimeoutResult(tr.timed_out())))
            }
        }
    }

    fn model_notify(&self, all: bool) -> Option<()> {
        let (exec, me) = rt::ctx()?;
        let st = exec.lock();
        let mut st = exec.schedule(st, me);
        let cv = self.addr();
        let mut waiters: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                t.run == Run::Blocked
                    && matches!(t.blocked_on, Blocked::Condvar { cv: c, .. } if c == cv)
            })
            .map(|(i, _)| i)
            .collect();
        if waiters.is_empty() {
            return Some(());
        }
        if !all {
            let pick = st.choose(waiters.len());
            waiters = vec![waiters[pick]];
        }
        for w in waiters {
            st.threads[w].run = Run::Runnable;
            st.threads[w].blocked_on = Blocked::None;
        }
        Some(())
    }

    pub fn notify_one(&self) {
        if self.model_notify(false).is_none() {
            self.inner.notify_one();
        }
    }

    pub fn notify_all(&self) {
        if self.model_notify(true).is_none() {
            self.inner.notify_all();
        }
    }
}
