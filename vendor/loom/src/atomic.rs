//! Model-checked atomic types. Each wraps the real `std` atomic (so `new`
//! stays `const` and statics in the code under test keep working); the
//! happens-before metadata lives in a side table keyed by address inside
//! the active execution. Outside a model (or on a thread the scheduler
//! does not know about, e.g. TLS destructors at thread exit) every
//! operation falls through to the plain `std` op.

use std::sync::atomic::Ordering;

use crate::rt;

/// Ops every atomic type supports (load/store/swap/CAS/fetch_update).
macro_rules! atomic_base {
    ($name:ident, $std:ty, $prim:ty) => {
        #[derive(Debug, Default)]
        pub struct $name {
            v: $std,
        }

        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self { v: <$std>::new(v) }
            }

            fn addr(&self) -> usize {
                self as *const _ as usize
            }

            pub fn load(&self, order: Ordering) -> $prim {
                rt::atomic_load(self.addr(), order, || self.v.load(Ordering::SeqCst))
                    .unwrap_or_else(|| self.v.load(order))
            }

            pub fn store(&self, val: $prim, order: Ordering) {
                rt::atomic_store(self.addr(), order, || self.v.store(val, Ordering::SeqCst))
                    .unwrap_or_else(|| self.v.store(val, order))
            }

            pub fn swap(&self, val: $prim, order: Ordering) -> $prim {
                rt::atomic_rmw(self.addr(), order, order, || {
                    (self.v.swap(val, Ordering::SeqCst), true)
                })
                .unwrap_or_else(|| self.v.swap(val, order))
            }

            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                rt::atomic_rmw(self.addr(), success, failure, || {
                    let r = self.v.compare_exchange(
                        current,
                        new,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    );
                    let ok = r.is_ok();
                    (r, ok)
                })
                .unwrap_or_else(|| self.v.compare_exchange(current, new, success, failure))
            }

            /// Under the model a weak CAS only fails on a real value
            /// mismatch (no spurious failures — a strict subset of the
            /// allowed behaviours, so models stay small).
            pub fn compare_exchange_weak(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                self.compare_exchange(current, new, success, failure)
            }

            /// `f` must be pure (no synchronization inside — it runs under
            /// the scheduler lock), matching loom's own restriction.
            pub fn fetch_update<F>(
                &self,
                set_order: Ordering,
                fetch_order: Ordering,
                mut f: F,
            ) -> Result<$prim, $prim>
            where
                F: FnMut($prim) -> Option<$prim>,
            {
                match rt::atomic_rmw(self.addr(), set_order, fetch_order, || {
                    // Serialized under the scheduler: load-compute-store is
                    // atomic here by construction.
                    let cur = self.v.load(Ordering::SeqCst);
                    match f(cur) {
                        Some(next) => {
                            self.v.store(next, Ordering::SeqCst);
                            (Ok(cur), true)
                        }
                        None => (Err(cur), false),
                    }
                }) {
                    Some(r) => r,
                    None => self.v.fetch_update(set_order, fetch_order, f),
                }
            }

            pub fn get_mut(&mut self) -> &mut $prim {
                self.v.get_mut()
            }

            pub fn into_inner(self) -> $prim {
                self.v.into_inner()
            }
        }
    };
}

/// Arithmetic / min-max RMWs (integer types only — std `AtomicBool` does
/// not have them).
macro_rules! atomic_int_ops {
    ($name:ident, $prim:ty, [$($method:ident),+ $(,)?]) => {
        impl $name {
            $(
                pub fn $method(&self, val: $prim, order: Ordering) -> $prim {
                    rt::atomic_rmw(self.addr(), order, order, || {
                        (self.v.$method(val, Ordering::SeqCst), true)
                    })
                    .unwrap_or_else(|| self.v.$method(val, order))
                }
            )+
        }
    };
}

macro_rules! atomic_int {
    ($name:ident, $std:ty, $prim:ty) => {
        atomic_base!($name, $std, $prim);
        atomic_int_ops!(
            $name,
            $prim,
            [fetch_add, fetch_sub, fetch_max, fetch_min, fetch_and, fetch_or, fetch_xor]
        );
    };
}

atomic_base!(AtomicBool, std::sync::atomic::AtomicBool, bool);
atomic_int_ops!(AtomicBool, bool, [fetch_and, fetch_or, fetch_xor]);

atomic_int!(AtomicU8, std::sync::atomic::AtomicU8, u8);
atomic_int!(AtomicU32, std::sync::atomic::AtomicU32, u32);
atomic_int!(AtomicU64, std::sync::atomic::AtomicU64, u64);
atomic_int!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
atomic_int!(AtomicI64, std::sync::atomic::AtomicI64, i64);

/// Model-checked `AtomicPtr<T>` (same side-table scheme).
#[derive(Debug)]
pub struct AtomicPtr<T> {
    v: std::sync::atomic::AtomicPtr<T>,
}

impl<T> Default for AtomicPtr<T> {
    fn default() -> Self {
        Self::new(std::ptr::null_mut())
    }
}

impl<T> AtomicPtr<T> {
    pub const fn new(p: *mut T) -> Self {
        Self { v: std::sync::atomic::AtomicPtr::new(p) }
    }

    fn addr(&self) -> usize {
        self as *const _ as usize
    }

    pub fn load(&self, order: Ordering) -> *mut T {
        rt::atomic_load(self.addr(), order, || self.v.load(Ordering::SeqCst))
            .unwrap_or_else(|| self.v.load(order))
    }

    pub fn store(&self, p: *mut T, order: Ordering) {
        rt::atomic_store(self.addr(), order, || self.v.store(p, Ordering::SeqCst))
            .unwrap_or_else(|| self.v.store(p, order))
    }

    pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
        rt::atomic_rmw(self.addr(), order, order, || (self.v.swap(p, Ordering::SeqCst), true))
            .unwrap_or_else(|| self.v.swap(p, order))
    }

    pub fn compare_exchange(
        &self,
        current: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        rt::atomic_rmw(self.addr(), success, failure, || {
            let r = self.v.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst);
            let ok = r.is_ok();
            (r, ok)
        })
        .unwrap_or_else(|| self.v.compare_exchange(current, new, success, failure))
    }

    pub fn compare_exchange_weak(
        &self,
        current: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        self.compare_exchange(current, new, success, failure)
    }

    pub fn get_mut(&mut self) -> &mut *mut T {
        self.v.get_mut()
    }

    pub fn into_inner(self) -> *mut T {
        self.v.into_inner()
    }
}

/// Model-checked memory fence.
pub fn fence(order: Ordering) {
    if rt::fence(order).is_none() {
        std::sync::atomic::fence(order);
    }
}
