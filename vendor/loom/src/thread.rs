//! Model-aware threads. Inside a model, `spawn` registers the child with
//! the scheduler (spawn happens-before everything the child does, and
//! `join` happens-after everything it did) and runs it on a real OS thread
//! so `thread_local!` state behaves as in production. Outside a model this
//! is plain `std::thread`.

use std::sync::{Arc, Mutex, PoisonError};

use crate::rt::{self, Blocked, Run};

enum Handle<T> {
    /// Spawned inside a model.
    Model {
        exec: Arc<crate::rt::Execution>,
        tid: usize,
        result: Arc<Mutex<Option<std::thread::Result<T>>>>,
        os: std::thread::JoinHandle<()>,
    },
    /// Spawned outside any model: plain std thread.
    Plain(std::thread::JoinHandle<T>),
}

pub struct JoinHandle<T> {
    inner: Handle<T>,
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let Some((exec, me)) = rt::ctx() else {
        return JoinHandle { inner: Handle::Plain(std::thread::spawn(f)) };
    };

    // Spawning is itself a scheduling point; tick the parent so the
    // child's inherited clock includes it.
    {
        let st = exec.lock();
        let mut st = exec.schedule(st, me);
        let t = &mut st.threads[me];
        if t.clock.len() <= me {
            t.clock.resize(me + 1, 0);
        }
        t.clock[me] += 1;
    }
    let tid = exec.register_thread(Some(me));

    let result: Arc<Mutex<Option<std::thread::Result<T>>>> = Arc::new(Mutex::new(None));
    let slot = Arc::clone(&result);
    let child_exec = Arc::clone(&exec);
    let os = std::thread::spawn(move || {
        rt::set_ctx(Arc::clone(&child_exec), tid);
        // Park until first scheduled.
        {
            let st = child_exec.lock();
            let _st = child_exec.wait_for_turn(st, tid);
        }
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        *slot.lock().unwrap_or_else(PoisonError::into_inner) = Some(r);
        // Clear the context *before* finishing so this thread's TLS
        // destructors (which run after) fall back to plain execution
        // instead of asking a scheduler that no longer tracks the thread.
        rt::clear_ctx();
        child_exec.finish(tid);
    });

    JoinHandle { inner: Handle::Model { exec, tid, result, os } }
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        match self.inner {
            Handle::Plain(h) => h.join(),
            Handle::Model { exec, tid, result, os } => {
                let me = rt::ctx().map(|(_, tid)| tid);
                if let Some(me) = me {
                    let st = exec.lock();
                    let mut st = exec.schedule(st, me);
                    if st.threads[tid].run != Run::Finished {
                        st = exec.block(st, me, Blocked::Join(tid));
                    }
                    // `finish(tid)` already joined the target's final clock
                    // into ours if we blocked; if it was already finished,
                    // join it here.
                    let target_clock = st.threads[tid].clock.clone();
                    crate::rt::vjoin(&mut st.threads[me].clock, &target_clock);
                }
                // The scheduler-level join happened; wait out OS-level
                // termination too so thread_local destructors (RCU
                // unregister, arena-block close) have fully run before the
                // model continues — mirrors std join semantics.
                let _ = os.join();
                result
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .take()
                    .expect("loom: joined thread produced no result")
            }
        }
    }
}

/// Scheduling point (and a plain yield outside a model).
pub fn yield_now() {
    if rt::yield_point().is_none() {
        std::thread::yield_now();
    }
}
