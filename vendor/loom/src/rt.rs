//! The execution runtime: a cooperative scheduler that serializes model
//! threads (exactly one runs between two scheduling points), a seeded RNG
//! that picks which thread runs next, and the vector-clock machinery that
//! tracks happens-before so `cell::UnsafeCell` accesses can be checked for
//! data races.
//!
//! Every synchronization operation (atomic op, fence, mutex op, condvar op,
//! spawn/join, yield) is a *scheduling point*: the running thread offers the
//! scheduler the chance to run somebody else first. Because the operations
//! themselves execute under the runtime's own lock, exploring all
//! interleavings of scheduling points explores all interleavings of the
//! operations.
//!
//! Threads are real OS threads, parked on a condvar while descheduled, so
//! `thread_local!` state in the code under test (RCU participant handles,
//! arena blocks) behaves exactly as in production.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

/// A vector clock: `clock[tid]` is the last tick of thread `tid` known to
/// happen-before the owner's current point.
pub(crate) type VClock = Vec<u64>;

pub(crate) fn vjoin(into: &mut VClock, from: &VClock) {
    if into.len() < from.len() {
        into.resize(from.len(), 0);
    }
    for (i, &v) in from.iter().enumerate() {
        if into[i] < v {
            into[i] = v;
        }
    }
}

/// Does the event `(tid, tick)` happen-before a thread whose clock is
/// `clock`?
pub(crate) fn happens_before(event: (usize, u64), clock: &VClock) -> bool {
    clock.get(event.0).copied().unwrap_or(0) >= event.1
}

// ---------------------------------------------------------------------------
// Per-thread / per-object runtime state
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Run {
    Runnable,
    Blocked,
    Finished,
}

#[derive(Clone, PartialEq, Eq, Debug)]
pub(crate) enum Blocked {
    None,
    /// Waiting for thread `tid` to finish.
    Join(usize),
    /// Waiting to acquire the mutex keyed by this address.
    Mutex(usize),
    /// Waiting on a condvar (keyed by address), holding nothing.
    Condvar { cv: usize, timed: bool },
}

pub(crate) struct ThreadCtl {
    pub run: Run,
    pub blocked_on: Blocked,
    pub clock: VClock,
    /// Sync clocks observed by relaxed loads since the last acquire fence
    /// (consumed by `fence(Acquire)`).
    pub pending_acquire: VClock,
    /// This thread's clock as of its last release fence (transferred by
    /// subsequent relaxed stores).
    pub release_fence: VClock,
    /// Set when a timed condvar wait was woken by the deadlock-avoidance
    /// timeout path rather than a notify.
    pub timed_out: bool,
}

impl ThreadCtl {
    fn new(clock: VClock) -> Self {
        ThreadCtl {
            run: Run::Runnable,
            blocked_on: Blocked::None,
            clock,
            pending_acquire: Vec::new(),
            release_fence: Vec::new(),
            timed_out: false,
        }
    }
}

/// Happens-before state of one atomic variable (keyed by address).
#[derive(Default)]
pub(crate) struct AtomicMeta {
    /// The clock transferred to acquiring loads (set by release stores,
    /// extended by RMWs — release sequences).
    pub sync: VClock,
}

/// Access history of one `cell::UnsafeCell` (keyed by address).
#[derive(Default)]
pub(crate) struct CellMeta {
    pub last_write: Option<(usize, u64)>,
    /// Reads since the last write (one entry per thread).
    pub reads: Vec<(usize, u64)>,
}

/// State of one `sync::Mutex` (keyed by address).
#[derive(Default)]
pub(crate) struct MutexMeta {
    pub held_by: Option<usize>,
    /// Clock of the last unlocker (transferred to the next locker).
    pub sync: VClock,
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

pub(crate) struct ExecState {
    rng: u64,
    pub threads: Vec<ThreadCtl>,
    pub active: usize,
    ops: u64,
    op_budget: u64,
    pub atomics: HashMap<usize, AtomicMeta>,
    pub cells: HashMap<usize, CellMeta>,
    pub mutexes: HashMap<usize, MutexMeta>,
}

impl ExecState {
    fn splitmix(&mut self) -> u64 {
        self.rng = self.rng.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform choice in `0..n` (n > 0).
    pub(crate) fn choose(&mut self, n: usize) -> usize {
        (self.splitmix() % n as u64) as usize
    }

    fn tick(&mut self, tid: usize) -> u64 {
        let clock = &mut self.threads[tid].clock;
        if clock.len() <= tid {
            clock.resize(tid + 1, 0);
        }
        clock[tid] += 1;
        clock[tid]
    }
}

/// One model execution: shared between all its threads.
pub(crate) struct Execution {
    state: Mutex<ExecState>,
    cv: Condvar,
}

impl Execution {
    pub(crate) fn new(seed: u64, op_budget: u64) -> Arc<Execution> {
        Arc::new(Execution {
            state: Mutex::new(ExecState {
                rng: seed,
                threads: Vec::new(),
                active: 0,
                ops: 0,
                op_budget,
                atomics: HashMap::new(),
                cells: HashMap::new(),
                mutexes: HashMap::new(),
            }),
            cv: Condvar::new(),
        })
    }

    /// Non-poisoning lock: a panic in one model thread (a failed assertion
    /// or a reported race) must not wedge the others while it unwinds.
    pub(crate) fn lock(&self) -> MutexGuard<'_, ExecState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Register a new thread; returns its tid. `parent` (if any) donates its
    /// clock — spawn happens-before everything the child does.
    pub(crate) fn register_thread(&self, parent: Option<usize>) -> usize {
        let mut st = self.lock();
        let clock = match parent {
            Some(p) => st.threads[p].clock.clone(),
            None => Vec::new(),
        };
        st.threads.push(ThreadCtl::new(clock));
        st.threads.len() - 1
    }

    /// Pick the next active thread among the runnable ones and wake it.
    /// Called with the state lock held, by a thread that is about to wait
    /// or exit. Panics on deadlock (live threads, none runnable).
    pub(crate) fn reschedule(&self, st: &mut ExecState) {
        loop {
            let runnable: Vec<usize> = st
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| t.run == Run::Runnable)
                .map(|(i, _)| i)
                .collect();
            if !runnable.is_empty() {
                let pick = runnable[st.choose(runnable.len())];
                st.active = pick;
                self.cv.notify_all();
                return;
            }
            // Nobody is runnable. Fire timed condvar waits (models a timeout
            // elapsing once nothing else can make progress), else deadlock.
            let mut woke = false;
            for t in st.threads.iter_mut() {
                if t.run == Run::Blocked {
                    if let Blocked::Condvar { timed: true, .. } = t.blocked_on {
                        t.run = Run::Runnable;
                        t.blocked_on = Blocked::None;
                        t.timed_out = true;
                        woke = true;
                    }
                }
            }
            if woke {
                continue;
            }
            let live = st.threads.iter().filter(|t| t.run != Run::Finished).count();
            if live == 0 {
                return; // execution fully drained
            }
            let states: Vec<String> = st
                .threads
                .iter()
                .enumerate()
                .map(|(i, t)| format!("t{}: {:?} {:?}", i, t.run, t.blocked_on))
                .collect();
            panic!("loom: deadlock — every live thread is blocked [{}]", states.join(", "));
        }
    }

    /// Park the calling thread until it is runnable *and* scheduled.
    pub(crate) fn wait_for_turn<'a>(
        &'a self,
        mut st: MutexGuard<'a, ExecState>,
        me: usize,
    ) -> MutexGuard<'a, ExecState> {
        while !(st.active == me && st.threads[me].run == Run::Runnable) {
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st
    }

    /// A scheduling point: offer the scheduler the chance to run another
    /// thread before the caller's next operation. Returns with the lock
    /// held and the caller active; callers then perform their operation
    /// under the lock (operations are therefore serialized — sequentially
    /// consistent — while interleavings are explored at these points).
    pub(crate) fn schedule<'a>(
        &'a self,
        mut st: MutexGuard<'a, ExecState>,
        me: usize,
    ) -> MutexGuard<'a, ExecState> {
        st.ops += 1;
        if st.ops > st.op_budget {
            panic!(
                "loom: op budget ({}) exceeded — livelock, or the model is too large \
                 (shrink it or raise Builder.op_budget)",
                st.op_budget
            );
        }
        self.reschedule(&mut st);
        self.wait_for_turn(st, me)
    }

    /// Block the calling thread on `why` until another thread makes it
    /// runnable again (unlock, notify, join target finishing).
    pub(crate) fn block<'a>(
        &'a self,
        mut st: MutexGuard<'a, ExecState>,
        me: usize,
        why: Blocked,
    ) -> MutexGuard<'a, ExecState> {
        st.threads[me].run = Run::Blocked;
        st.threads[me].blocked_on = why;
        self.reschedule(&mut st);
        self.wait_for_turn(st, me)
    }

    /// Mark `me` finished, wake joiners, and hand the schedule on.
    pub(crate) fn finish(&self, me: usize) {
        let mut st = self.lock();
        st.threads[me].run = Run::Finished;
        let final_clock = st.threads[me].clock.clone();
        for t in st.threads.iter_mut() {
            if t.run == Run::Blocked && t.blocked_on == Blocked::Join(me) {
                t.run = Run::Runnable;
                t.blocked_on = Blocked::None;
                // join(t) happens-after everything t did.
                vjoin(&mut t.clock, &final_clock);
            }
        }
        self.reschedule(&mut st);
    }

    /// Wake every thread blocked on the mutex at `addr`.
    pub(crate) fn wake_mutex_waiters(st: &mut ExecState, addr: usize) {
        for t in st.threads.iter_mut() {
            if t.run == Run::Blocked && t.blocked_on == Blocked::Mutex(addr) {
                t.run = Run::Runnable;
                t.blocked_on = Blocked::None;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Thread context (TLS)
// ---------------------------------------------------------------------------

thread_local! {
    static CTX: RefCell<Option<(Arc<Execution>, usize)>> = const { RefCell::new(None) };
}

pub(crate) fn set_ctx(exec: Arc<Execution>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((exec, tid)));
}

pub(crate) fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

pub(crate) fn ctx() -> Option<(Arc<Execution>, usize)> {
    // `try_with`: TLS destructors (RCU participant unregister, arena-block
    // close) may run loom-shimmed atomics after CTX is gone — they fall
    // back to plain execution, which is exactly right for teardown.
    CTX.try_with(|c| c.borrow().clone()).ok().flatten()
}

// ---------------------------------------------------------------------------
// Operation hooks used by atomic.rs / cell.rs / sync.rs / thread.rs
// ---------------------------------------------------------------------------

/// Memory-order effect classification for the clock transfer rules.
pub(crate) fn is_acquire(o: Ordering) -> bool {
    matches!(o, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

pub(crate) fn is_release(o: Ordering) -> bool {
    matches!(o, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

/// Run `op` as a scheduled atomic **load** of the variable at `addr`.
/// Returns `op()`'s result, or `None` if no model is active (caller falls
/// back to the plain operation).
pub(crate) fn atomic_load<R>(addr: usize, order: Ordering, op: impl FnOnce() -> R) -> Option<R> {
    let (exec, me) = ctx()?;
    let st = exec.lock();
    let mut st = exec.schedule(st, me);
    st.tick(me);
    let r = op();
    let sync = st.atomics.entry(addr).or_default().sync.clone();
    let t = &mut st.threads[me];
    if is_acquire(order) {
        vjoin(&mut t.clock, &sync);
    } else {
        vjoin(&mut t.pending_acquire, &sync);
    }
    Some(r)
}

/// Run `op` as a scheduled atomic **store**.
pub(crate) fn atomic_store<R>(addr: usize, order: Ordering, op: impl FnOnce() -> R) -> Option<R> {
    let (exec, me) = ctx()?;
    let st = exec.lock();
    let mut st = exec.schedule(st, me);
    st.tick(me);
    let r = op();
    let mut sync = st.threads[me].release_fence.clone();
    if is_release(order) {
        let clock = st.threads[me].clock.clone();
        vjoin(&mut sync, &clock);
    }
    // A pure store starts a fresh release sequence: replace, don't join.
    st.atomics.entry(addr).or_default().sync = sync;
    Some(r)
}

/// Run `op` as a scheduled atomic **read-modify-write**. `op` returns
/// `(result, wrote)`; when `wrote` is false (failed compare_exchange) only
/// the load side applies, with `failure_order`.
pub(crate) fn atomic_rmw<R>(
    addr: usize,
    success: Ordering,
    failure: Ordering,
    op: impl FnOnce() -> (R, bool),
) -> Option<R> {
    let (exec, me) = ctx()?;
    let st = exec.lock();
    let mut st = exec.schedule(st, me);
    st.tick(me);
    let (r, wrote) = op();
    let order = if wrote { success } else { failure };
    let sync = st.atomics.entry(addr).or_default().sync.clone();
    {
        let t = &mut st.threads[me];
        if is_acquire(order) {
            vjoin(&mut t.clock, &sync);
        } else {
            vjoin(&mut t.pending_acquire, &sync);
        }
    }
    if wrote {
        // RMWs extend the release sequence: join into the existing sync
        // clock (even a relaxed RMW preserves prior release heads).
        let mut contrib = st.threads[me].release_fence.clone();
        if is_release(success) {
            let clock = st.threads[me].clock.clone();
            vjoin(&mut contrib, &clock);
        }
        vjoin(&mut st.atomics.entry(addr).or_default().sync, &contrib);
    }
    Some(r)
}

/// Scheduled memory fence.
pub(crate) fn fence(order: Ordering) -> Option<()> {
    let (exec, me) = ctx()?;
    let st = exec.lock();
    let mut st = exec.schedule(st, me);
    st.tick(me);
    let t = &mut st.threads[me];
    if is_acquire(order) {
        let pending = std::mem::take(&mut t.pending_acquire);
        vjoin(&mut t.clock, &pending);
    }
    if is_release(order) {
        t.release_fence = t.clock.clone();
    }
    Some(())
}

/// Scheduled access to an `UnsafeCell`; checks for data races against the
/// recorded access history. Panics with a race report on conflict.
pub(crate) fn cell_access(addr: usize, write: bool) -> Option<()> {
    let (exec, me) = ctx()?;
    let st = exec.lock();
    let mut st = exec.schedule(st, me);
    let now = st.tick(me);
    let clock = st.threads[me].clock.clone();
    let meta = st.cells.entry(addr).or_default();
    if let Some(w) = meta.last_write {
        if w.0 != me && !happens_before(w, &clock) {
            panic!(
                "loom: data race on UnsafeCell {:#x}: {} by t{} is concurrent with write by t{}",
                addr,
                if write { "write" } else { "read" },
                me,
                w.0
            );
        }
    }
    if write {
        for &r in &meta.reads {
            if r.0 != me && !happens_before(r, &clock) {
                panic!(
                    "loom: data race on UnsafeCell {:#x}: write by t{} is concurrent with read by t{}",
                    addr, me, r.0
                );
            }
        }
        meta.last_write = Some((me, now));
        meta.reads.clear();
    } else {
        meta.reads.retain(|r| r.0 != me);
        meta.reads.push((me, now));
    }
    Some(())
}

/// Plain scheduling point (yield / spin hint).
pub(crate) fn yield_point() -> Option<()> {
    let (exec, me) = ctx()?;
    let st = exec.lock();
    let _st = exec.schedule(st, me);
    Some(())
}
