//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the real
//! `anyhow` cannot be fetched. This shim implements the subset the codebase
//! uses — `Result`, `Error`, the `Context` extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with matching semantics:
//!
//! * `Error` carries a cause chain of messages; `{}` prints the outermost,
//!   `{:#}` prints the whole chain joined with `": "` (the alternate-display
//!   convention callers rely on for `error: {e:#}` output).
//! * Any `std::error::Error` converts via `?` (its `source()` chain is
//!   captured), so existing `io::Error`/`ParseIntError` propagation works.
//! * `Error` deliberately does NOT implement `std::error::Error`, exactly
//!   like the real crate — that is what makes the blanket `From` legal.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn push_context(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// The cause-chain messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/here/xyz").context("reading config")?;
        Ok(())
    }

    #[test]
    fn question_mark_and_context_chain() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(err.chain.len() >= 2);
    }

    #[test]
    fn macros_and_option_context() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing")?;
            ensure!(v < 10, "too big: {v}");
            if v == 7 {
                bail!("unlucky");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", f(None).unwrap_err()), "missing");
        assert_eq!(format!("{}", f(Some(7)).unwrap_err()), "unlucky");
        assert_eq!(format!("{}", f(Some(12)).unwrap_err()), "too big: 12");
        let e: Error = anyhow!("code {}", 42);
        assert_eq!(e.root_cause(), "code 42");
        let msg = String::from("owned");
        let e: Error = anyhow!(msg);
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }
}
