//! END-TO-END DRIVER — the paper's motivating application (ref [1]):
//! paging in a cellular core network.
//!
//! Full-system composition: a synthetic mobility workload streams handover
//! events through the serving coordinator (bounded ingestion queue + ingest
//! workers + decay scheduler) into MCPrioQ, while a paging policy queries
//! `infer_threshold` concurrently to locate "idle" users. We report the
//! paper's headline quantities:
//!
//! * paging success probability vs cells paged (threshold sweep),
//! * inference scan depth — the measured O(CDF⁻¹(t)) cost,
//! * online update throughput while queries run,
//! * behaviour across a topology change with decay on (adaptation).
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example paging_sim`

use std::sync::Arc;
use std::time::{Duration, Instant};

use mcprioq::config::ServerConfig;
use mcprioq::coordinator::{DecayScheduler, Engine};
use mcprioq::sync::shim::{AtomicBool, AtomicU64, Ordering};
use mcprioq::testutil::Rng64;
use mcprioq::workload::{MobilityConfig, MobilityTrace, TransitionStream};

const WARMUP_EVENTS: usize = 200_000;
const PHASE_EVENTS: usize = 150_000;
const PAGE_PROBES: usize = 4_000;

fn main() {
    let mob_cfg = MobilityConfig {
        width: 24,
        height: 24,
        users: 400,
        skew: 1.1,
        explore: 0.05,
        seed: 42,
    };
    println!("== mcprioq paging simulation ==");
    println!(
        "topology: {}x{} cells, {} users, skew {}, explore {}",
        mob_cfg.width, mob_cfg.height, mob_cfg.users, mob_cfg.skew, mob_cfg.explore
    );

    let config = ServerConfig { shards: 1, queue_capacity: 65_536, ..Default::default() };
    let engine = Engine::new(&config, 2);
    let decay = DecayScheduler::start(Arc::clone(&engine), Duration::from_millis(400));

    let mut trace = MobilityTrace::new(mob_cfg);

    // ---- Phase 1: online learning under live queries ----
    let queries_done = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let engine = Arc::clone(&engine);
        let queries_done = Arc::clone(&queries_done);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut rng = Rng64::new(7);
            while !stop.load(Ordering::Relaxed) {
                let cell = rng.next_below(24 * 24);
                let _ = engine.infer_threshold(cell, 0.9);
                queries_done.fetch_add(1, Ordering::Relaxed);
            }
        })
    };

    let t0 = Instant::now();
    for _ in 0..WARMUP_EVENTS {
        let (from, to) = trace.next_transition();
        engine.observe(from, to); // through the bounded queue, like prod
    }
    engine.quiesce();
    let learn_dt = t0.elapsed();
    stop.store(true, Ordering::SeqCst);
    reader.join().unwrap();
    println!(
        "\nphase 1 — online learning: {} handovers in {:.2?} ({:.0} updates/s) \
         with {} concurrent queries served",
        WARMUP_EVENTS,
        learn_dt,
        WARMUP_EVENTS as f64 / learn_dt.as_secs_f64(),
        queries_done.load(Ordering::Relaxed),
    );
    let s = engine.stats();
    println!(
        "model: {} cells, {} edges, query p50={}ns p99={}ns",
        s.nodes, s.edges, s.query_ns_p50, s.query_ns_p99
    );

    // ---- Phase 2: paging accuracy sweep ----
    println!("\nphase 2 — paging policy sweep (true next cell vs paged set):");
    println!("{:>9} {:>10} {:>12} {:>12} {:>10}", "threshold", "success", "cells/page", "scan depth", "scan p99");
    for &t in &[0.5, 0.8, 0.9, 0.95, 0.99] {
        let (success, avg_cells, avg_scan, p99_scan) = paging_accuracy(&engine, &mut trace, t);
        println!(
            "{t:>9.2} {:>9.1}% {avg_cells:>12.2} {avg_scan:>12.2} {p99_scan:>10}",
            success * 100.0
        );
    }

    // ---- Phase 3: topology change + decay adaptation ----
    println!("\nphase 3 — topology flip (commuter corridors move), decay adapts:");
    trace.flip_topology();
    let (s0, _, _, _) = paging_accuracy(&engine, &mut trace, 0.9);
    println!("  success@0.9 immediately after flip: {:.1}%", s0 * 100.0);
    for round in 1..=4 {
        for _ in 0..PHASE_EVENTS {
            let (from, to) = trace.next_transition();
            engine.observe(from, to);
        }
        engine.quiesce();
        let (sr, _, _, _) = paging_accuracy(&engine, &mut trace, 0.9);
        println!(
            "  after {} more events (+decay every 400ms): {:.1}%",
            round * PHASE_EVENTS,
            sr * 100.0
        );
    }
    let s = engine.stats();
    println!("\nfinal: {} edges (decay pruned stale corridors), {} decay runs", s.edges, decay.runs());
    engine.shutdown();
    println!("\nOK — full stack (workload -> queue -> workers -> MCPrioQ -> inference) exercised.");
}

/// Simulate paging: a user's *true* next move is drawn from the mobility
/// model; the policy pages cells from `infer_threshold(from, t)` and
/// succeeds if the true destination is in the paged set.
fn paging_accuracy(
    engine: &Engine,
    trace: &mut MobilityTrace,
    t: f64,
) -> (f64, f64, f64, usize) {
    let mut hits = 0usize;
    let mut cells_paged = 0usize;
    let mut scans = Vec::with_capacity(PAGE_PROBES);
    for _ in 0..PAGE_PROBES {
        // Draw a real movement from the model (also advances the world).
        let (from, to) = trace.next_transition();
        let rec = engine.infer_threshold(from, t);
        if rec.items.iter().any(|&(cell, _)| cell == to) {
            hits += 1;
        }
        cells_paged += rec.items.len();
        scans.push(rec.scanned);
        // Feed the event back (the system keeps learning while paging).
        engine.observe_direct(from, to);
    }
    scans.sort_unstable();
    let p99 = scans[(scans.len() * 99) / 100];
    (
        hits as f64 / PAGE_PROBES as f64,
        cells_paged as f64 / PAGE_PROBES as f64,
        scans.iter().sum::<usize>() as f64 / scans.len() as f64,
        p99,
    )
}
