//! Serving example: boots the full coordinator (TCP server, ingest
//! workers, decay scheduler), drives it with a multi-threaded client load
//! generator over real sockets, and reports latency/throughput.
//!
//! Run: `cargo run --release --example serve`

use std::sync::Arc;
use std::time::{Duration, Instant};

use mcprioq::config::ServerConfig;
use mcprioq::coordinator::{Client, DecayScheduler, Engine, Server};
use mcprioq::sync::shim::{AtomicU64, Ordering};
use mcprioq::metrics::Histogram;
use mcprioq::testutil::Rng64;
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const CLIENTS: usize = 4;
const OPS_PER_CLIENT: usize = 20_000;
const READ_FRACTION: f64 = 0.2;
/// Updates buffered per `OBSERVEB` round trip.
const WRITE_BATCH: usize = 64;

fn main() {
    let config = ServerConfig { shards: 2, queue_capacity: 65_536, ..Default::default() };
    let engine = Engine::new(&config, 2);
    let _decay = DecayScheduler::start(Arc::clone(&engine), Duration::from_secs(1));
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let _handle = server.spawn();
    println!("== mcprioq serve example ==");
    println!("server on {addr}; {CLIENTS} clients x {OPS_PER_CLIENT} ops ({:.0}% reads)\n", READ_FRACTION * 100.0);

    let total_reads = Arc::new(AtomicU64::new(0));
    let read_lat = Arc::new(Histogram::new());
    let write_lat = Arc::new(Histogram::new());

    let t0 = Instant::now();
    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let total_reads = Arc::clone(&total_reads);
            let read_lat = Arc::clone(&read_lat);
            let write_lat = Arc::clone(&write_lat);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut stream = ZipfChainStream::new(2_000, 16, 1.1, c as u64 + 1);
                let mut rng = Rng64::new(c as u64 + 100);
                // Writes ride the batched wire path (`OBSERVEB`): buffer
                // locally, flush every WRITE_BATCH in one round trip.
                let mut buf: Vec<(u64, u64)> = Vec::with_capacity(WRITE_BATCH);
                for _ in 0..OPS_PER_CLIENT {
                    let (src, dst) = stream.next_transition();
                    if rng.next_bool(READ_FRACTION) {
                        let t = Instant::now();
                        let _ = client.topk(src, 8).expect("topk");
                        read_lat.record(t.elapsed().as_nanos() as u64);
                        total_reads.fetch_add(1, Ordering::Relaxed);
                    } else {
                        buf.push((src, dst));
                        if buf.len() == WRITE_BATCH {
                            let t = Instant::now();
                            let n = client.observe_batch(&buf).expect("observe_batch");
                            assert_eq!(n, buf.len());
                            // Per-update latency: one round trip / batch.
                            write_lat
                                .record(t.elapsed().as_nanos() as u64 / buf.len() as u64);
                            buf.clear();
                        }
                    }
                }
                if !buf.is_empty() {
                    client.observe_batch(&buf).expect("observe_batch");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let dt = t0.elapsed();
    engine.quiesce();

    let total_ops = (CLIENTS * OPS_PER_CLIENT) as f64;
    println!("drove {total_ops} requests in {dt:.2?} -> {:.0} req/s over TCP", total_ops / dt.as_secs_f64());
    let r = read_lat.snapshot();
    let w = write_lat.snapshot();
    println!("read  latency: p50={}µs p99={}µs (n={})", r.p50 / 1000, r.p99 / 1000, r.count);
    println!("write latency: p50={}µs p99={}µs (n={})", w.p50 / 1000, w.p99 / 1000, w.count);

    let s = engine.stats();
    println!(
        "\nengine: {} shards, {} nodes, {} edges, {} observes, {} queries",
        s.shards, s.nodes, s.edges, s.observes, s.queries
    );
    println!(
        "engine-side query latency: p50={}ns p99={}ns (TCP overhead dominates the client view)",
        s.query_ns_p50, s.query_ns_p99
    );
    engine.shutdown();
}
