//! Recommender-system example — the paper's introductory use case:
//! "recommend products such that the probability of a match is above a
//! threshold" over live user sessions, including the sparse-vs-dense
//! engine comparison when AOT artifacts are available.
//!
//! Run: `make artifacts && cargo run --release --example recsys`

use std::sync::Arc;
use std::time::Instant;

use mcprioq::baselines::MarkovModel;
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::runtime::{default_artifacts_dir, DenseXlaChain, XlaRuntime};
use mcprioq::workload::{RecsysConfig, SessionStream, TransitionStream};

const ITEMS: u64 = 1_000;
const TRAIN: usize = 300_000;
const EVAL: usize = 20_000;

fn main() {
    let cfg = RecsysConfig { items: ITEMS, fanout: 24, skew: 1.1, continue_p: 0.85, seed: 9 };
    let mut stream = SessionStream::new(cfg);
    let chain = McPrioQ::new(ChainConfig::default());

    // ---- online training ----
    let t0 = Instant::now();
    for _ in 0..TRAIN {
        let (prev, item) = stream.next_transition();
        chain.observe(prev, item);
    }
    let dt = t0.elapsed();
    println!("== mcprioq recsys ==");
    println!(
        "trained on {TRAIN} session transitions in {dt:.2?} ({:.2}M updates/s)",
        TRAIN as f64 / dt.as_secs_f64() / 1e6
    );
    let s = chain.stats();
    println!("catalog: {} items with behaviour, {} co-view edges, ~{} KiB\n", s.nodes, s.edges, s.approx_bytes / 1024);

    // ---- hit-rate evaluation: does the next real view appear in the
    //      recommended set? ----
    println!("{:>10} {:>10} {:>12} {:>12}", "threshold", "hit-rate", "items/rec", "scan depth");
    for &t in &[0.3, 0.5, 0.7, 0.9] {
        let mut hits = 0;
        let mut shown = 0;
        let mut scanned = 0;
        for _ in 0..EVAL {
            let (prev, actual) = stream.next_transition();
            let rec = chain.infer_threshold(prev, t);
            if rec.items.iter().any(|&(i, _)| i == actual) {
                hits += 1;
            }
            shown += rec.items.len();
            scanned += rec.scanned;
            chain.observe(prev, actual); // keep learning online
        }
        println!(
            "{t:>10.1} {:>9.1}% {:>12.2} {:>12.2}",
            100.0 * hits as f64 / EVAL as f64,
            shown as f64 / EVAL as f64,
            scanned as f64 / EVAL as f64
        );
    }

    // ---- sparse vs dense engine (three-layer path) ----
    match XlaRuntime::new(&default_artifacts_dir()) {
        Ok(rt) => {
            println!("\nsparse vs dense (XLA/PJRT on {}):", rt.platform());
            let dense = DenseXlaChain::new(Arc::new(rt), 512).expect("dense engine");
            // Train the dense engine on the same distribution (smaller id
            // space: dense capacity is compiled in).
            let cfg = RecsysConfig { items: 500, fanout: 24, skew: 1.1, continue_p: 0.85, seed: 9 };
            let mut stream = SessionStream::new(cfg);
            let sparse = McPrioQ::new(ChainConfig::default());
            let pairs: Vec<(u64, u64)> = (0..50_000).map(|_| stream.next_transition()).collect();
            let t0 = Instant::now();
            for &(a, b) in &pairs {
                sparse.observe(a, b);
            }
            let sparse_dt = t0.elapsed();
            let t0 = Instant::now();
            for &(a, b) in &pairs {
                dense.observe(a, b);
            }
            let dense_dt = t0.elapsed();
            let t0 = Instant::now();
            for i in 0..2_000u64 {
                let _ = sparse.infer_topk(pairs[i as usize % pairs.len()].0, 8);
            }
            let sparse_q = t0.elapsed();
            let t0 = Instant::now();
            for i in 0..2_000u64 {
                let _ = dense.infer_topk(pairs[i as usize % pairs.len()].0, 8);
            }
            let dense_q = t0.elapsed();
            println!(
                "  updates: sparse {:.2?} vs dense {:.2?} ({:.0}x)",
                sparse_dt,
                dense_dt,
                dense_dt.as_secs_f64() / sparse_dt.as_secs_f64()
            );
            println!(
                "  queries: sparse {:.2?} vs dense {:.2?} ({:.0}x) for 2000 top-8",
                sparse_q,
                dense_q,
                dense_q.as_secs_f64() / sparse_q.as_secs_f64()
            );
            println!(
                "  memory:  sparse ~{} KiB vs dense {} KiB (capacity {})",
                sparse.stats().approx_bytes / 1024,
                dense.resident_bytes() / 1024,
                dense.capacity()
            );
            // Answers agree.
            let a = sparse.infer_topk(pairs[0].0, 4);
            let b = dense.infer_topk(pairs[0].0, 4);
            assert_eq!(a.items.len(), b.items.len());
            println!("  answers agree on spot-check (src {}): {:?}", pairs[0].0, a.items);
        }
        Err(e) => println!("\n(dense comparison skipped: {e:#})"),
    }
}
