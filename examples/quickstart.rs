//! Quickstart: build a small markov chain online, query it while it
//! learns, and run a decay cycle — the whole public API in 80 lines.
//!
//! Run: `cargo run --release --example quickstart`

use mcprioq::chain::{ChainConfig, McPrioQ};

fn main() {
    // A chain with default settings (dst hash table on, decay 1/2).
    let chain = McPrioQ::new(ChainConfig::default());

    // Feed transitions: user journeys through a tiny site.
    // home(0) -> search(1) mostly; search -> product(2); product -> cart(3).
    let journeys: &[&[u64]] = &[
        &[0, 1, 2, 3],
        &[0, 1, 2, 0],
        &[0, 1, 2, 3],
        &[0, 2, 3],
        &[0, 1, 0],
        &[0, 1, 2, 3],
    ];
    for j in journeys {
        for w in j.windows(2) {
            chain.observe(w[0], w[1]);
        }
    }

    // "Which pages follow home(0), with 90% confidence?"
    let rec = chain.infer_threshold(0, 0.9);
    println!("after home(0), 90% of the time users go to:");
    for (page, p) in &rec.items {
        println!("  page {page}  p={p:.2}");
    }
    println!("(scanned {} of {} edges; cum={:.2})\n", rec.scanned, chain.edge_count(), rec.cumulative);

    // Top-1 from search(1).
    let top = chain.infer_topk(1, 1);
    println!("most likely after search(1): page {} (p={:.2})", top.items[0].0, top.items[0].1);

    // Single-edge probability.
    println!("P(2 -> 3) = {:.2}", chain.probability(2, 3).unwrap());

    // Model decay (§II.C): halve all counters, prune dead edges.
    let before = chain.edge_count();
    let (surviving, pruned) = chain.decay();
    println!("\ndecay: {before} edges -> {} (pruned {pruned}, surviving mass {surviving})", chain.edge_count());

    // The distribution shape survives decay.
    let rec = chain.infer_threshold(0, 0.9);
    println!("after decay, home(0) still recommends {:?}", rec.items.iter().map(|&(d, _)| d).collect::<Vec<_>>());

    // Structure invariants hold whenever quiesced.
    chain.check_invariants().expect("invariants");
    let stats = chain.stats();
    println!(
        "\nstats: {} nodes, {} edges, {} observations, {} swaps ({} skipped), ~{} KiB",
        stats.nodes,
        stats.edges,
        stats.observes,
        stats.swaps,
        stats.swap_skips,
        stats.approx_bytes / 1024
    );
}
