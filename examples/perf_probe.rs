//! §Perf probe: observe/inference cost vs working-set size and the
//! component breakdown (stream, rcu pin, full observe). Regenerates the
//! EXPERIMENTS.md §Perf table.
//!
//! Run: `cargo run --release --example perf_probe`

use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::workload::{TransitionStream, ZipfChainStream};
use std::time::Instant;

fn main() {
    // Component breakdown at a converged, cache-resident size.
    let chain = McPrioQ::new(ChainConfig::default());
    let mut s = ZipfChainStream::new(1_000, 24, 1.1, 99);
    for _ in 0..1_000_000 {
        let (a, b) = s.next_transition();
        chain.observe(a, b);
    }
    let n = 2_000_000u64;
    let t0 = Instant::now();
    let mut acc = 0u64;
    for _ in 0..n {
        let (a, b) = s.next_transition();
        acc = acc.wrapping_add(a ^ b);
    }
    println!("stream only:  {:>4.0} ns", t0.elapsed().as_nanos() as f64 / n as f64);
    std::hint::black_box(acc);
    let t0 = Instant::now();
    for _ in 0..n {
        std::hint::black_box(mcprioq::rcu::pin());
    }
    println!("rcu pin:      {:>4.0} ns", t0.elapsed().as_nanos() as f64 / n as f64);
    let t0 = Instant::now();
    for _ in 0..n {
        let (a, b) = s.next_transition();
        chain.observe(a, b);
    }
    println!("full observe: {:>4.0} ns (converged, cache-resident)", t0.elapsed().as_nanos() as f64 / n as f64);
    let t0 = Instant::now();
    for i in 0..n {
        std::hint::black_box(chain.infer_threshold(i % 1_000, 0.9));
    }
    println!("infer t=0.9:  {:>4.0} ns", t0.elapsed().as_nanos() as f64 / n as f64);

    // Working-set sweep: the memory wall, not the structure, dominates at
    // large graphs on this host.
    println!("\nobserve vs working set:");
    for &(nodes, fanout) in &[(100u64, 16u64), (1_000, 24), (10_000, 32), (50_000, 32)] {
        let chain = McPrioQ::new(ChainConfig::default());
        let mut s = ZipfChainStream::new(nodes, fanout, 1.1, 99);
        let warm = (nodes * 400).max(1_000_000);
        for _ in 0..warm {
            let (a, b) = s.next_transition();
            chain.observe(a, b);
        }
        let n = 2_000_000u64;
        let t0 = Instant::now();
        for _ in 0..n {
            let (a, b) = s.next_transition();
            chain.observe(a, b);
        }
        let ns = t0.elapsed().as_nanos() as f64 / n as f64;
        println!(
            "  nodes={nodes:>6} edges={:>8} ~{:>7} KiB: {ns:>4.0} ns/observe",
            chain.edge_count(),
            chain.stats().approx_bytes / 1024
        );
    }
}
