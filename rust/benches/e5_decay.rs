//! E5 — model decay (§II.C): after a topology change, a decaying model
//! re-converges to the new distribution and prunes dead edges, while a
//! non-decaying model is stuck averaging both worlds and grows forever
//! (DESIGN.md §3).
//!
//! Claim shape to reproduce: with decay, top-1 accuracy on the *new*
//! distribution recovers within a few decay cycles and the edge count
//! stays bounded; without decay, recovery is much slower (old mass must
//! be out-voted) and edges accumulate.

use mcprioq::bench_harness::{bench_mode_from_env, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const NODES: u64 = 400;
const FANOUT: u64 = 16;
const PHASE: usize = 400_000;
const ROUNDS: usize = 8;

/// Top-1 accuracy against the stream's true rank-0 successor.
fn top1_accuracy(chain: &McPrioQ, stream: &ZipfChainStream) -> f64 {
    let mut hits = 0;
    for src in 0..NODES {
        let rec = chain.infer_topk(src, 1);
        if let Some(&(dst, _)) = rec.items.first() {
            if dst == stream.dst_at_rank(src, 0) {
                hits += 1;
            }
        }
    }
    hits as f64 / NODES as f64
}

fn main() {
    let bench = bench_mode_from_env();
    let phase = if bench.samples <= 3 { PHASE / 10 } else { PHASE };

    let mut table = Table::new(
        "e5_decay",
        &["round", "variant", "top1_acc_new_world", "edges", "total_mass"],
    );

    for (variant, decay_on) in [("decay", true), ("no-decay", false)] {
        let chain = McPrioQ::new(ChainConfig::default());
        // World A: seed 1. Train to convergence.
        let mut world_a = ZipfChainStream::new(NODES, FANOUT, 1.1, 1);
        for _ in 0..phase * 2 {
            let (a, b) = world_a.next_transition();
            chain.observe(a, b);
        }
        // World B: same nodes, different successor mapping (seed change
        // re-permutes `dst_at_rank` via the stream's internal mixing).
        let world_b = ZipfChainStream::new(NODES, FANOUT, 1.1, 0xB0B);
        let mut world_b_run = ZipfChainStream::new(NODES, FANOUT, 1.1, 0xB0B);

        let acc0 = top1_accuracy(&chain, &world_b);
        table.row(&[
            "0".into(),
            variant.into(),
            format!("{acc0:.3}"),
            chain.edge_count().to_string(),
            chain.stats().observes.to_string(),
        ]);
        for round in 1..=ROUNDS {
            for _ in 0..phase / 2 {
                let (a, b) = world_b_run.next_transition();
                chain.observe(a, b);
            }
            if decay_on {
                chain.decay();
            }
            let acc = top1_accuracy(&chain, &world_b);
            let mass: u64 = chain.export().iter().map(|(_, t, _)| *t).sum();
            table.row(&[
                round.to_string(),
                variant.into(),
                format!("{acc:.3}"),
                chain.edge_count().to_string(),
                mass.to_string(),
            ]);
            println!(
                "  {variant} round {round}: top1(new)={acc:.3} edges={} mass={mass}",
                chain.edge_count()
            );
        }
    }
    table.finish();
}
