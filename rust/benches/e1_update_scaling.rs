//! E1 — "O(1), lock-free updates": update-only throughput vs thread count,
//! MCPrioQ against every baseline (DESIGN.md §3).
//!
//! Claim shape to reproduce: MCPrioQ scales near-linearly with threads
//! (wait-free increments on disjoint cache lines), the coarse mutex
//! collapses, sharded/rwlock sits in between, skip-list pays pop-insert.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::baselines::{HeapChain, MarkovModel, MutexChain, ShardedChain, SkipListChain};
use mcprioq::bench_harness::{bench_mode_from_env, fmt_rate, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::workload::{TransitionStream, ZipfChainStream};

// Cache-resident working set (~3 MiB): measures the *structures*, not
// DRAM latency. The DRAM-bound regime is characterized separately in
// EXPERIMENTS.md §Perf (observe cost vs working-set size).
const NODES: u64 = 1_000;
const FANOUT: u64 = 24;
const SKEW: f64 = 1.1;

fn main() {
    let bench = bench_mode_from_env();
    let duration = if bench.samples <= 3 { Duration::from_millis(150) } else { Duration::from_millis(500) };
    let threads_list = [1usize, 2, 4, 8];

    let mut table = Table::new("e1_update_scaling", &["model", "threads", "updates_per_s", "speedup_vs_1t"]);
    let models: Vec<(&str, Box<dyn Fn() -> Arc<dyn MarkovModel>>)> = vec![
        ("mcprioq", Box::new(|| Arc::new(McPrioQ::new(ChainConfig::default())))),
        ("mutex", Box::new(|| Arc::new(MutexChain::new()))),
        ("sharded-rwlock", Box::new(|| Arc::new(ShardedChain::new(64)))),
        ("skiplist", Box::new(|| Arc::new(SkipListChain::new()))),
        ("heap-lazy", Box::new(|| Arc::new(HeapChain::new()))),
    ];

    for (name, make) in &models {
        let mut base = 0.0;
        for &threads in &threads_list {
            let model = make();
            // Pre-warm the graph so steady-state is existing-edge updates
            // (the paper's normal case).
            {
                let mut s = ZipfChainStream::new(NODES, FANOUT, SKEW, 99);
                for _ in 0..1_000_000 {
                    let (a, b) = s.next_transition();
                    model.observe(a, b);
                }
            }
            let rate = bench.run_threads(threads, duration, |t| {
                let model = Arc::clone(&model);
                let mut stream =
                    ZipfChainStream::with_topology(NODES, FANOUT, SKEW, t as u64 + 1, 99);
                move || {
                    let (a, b) = stream.next_transition();
                    model.observe(a, b);
                    1
                }
            });
            if threads == 1 {
                base = rate;
            }
            table.row(&[
                name.to_string(),
                threads.to_string(),
                format!("{rate:.0}"),
                format!("{:.2}", rate / base),
            ]);
            println!("  {name:>15} {threads}t: {}", fmt_rate(rate));
        }
    }

    // Batch-first path: the same update stream applied through
    // `observe_batch` (one RCU pin + cached src lookup per batch) at each
    // swept batch size. Batch 1 approximates `observe` plus slice overhead;
    // larger batches amortize pin/lookup cost.
    for &batch in &mcprioq::bench_harness::batch_sizes_from_env() {
        let name = format!("mcprioq-batch{batch}");
        let mut base = 0.0;
        for &threads in &threads_list {
            let chain = Arc::new(McPrioQ::new(ChainConfig::default()));
            {
                let mut s = ZipfChainStream::new(NODES, FANOUT, SKEW, 99);
                for _ in 0..1_000_000 {
                    let (a, b) = s.next_transition();
                    chain.observe(a, b);
                }
            }
            let rate = bench.run_threads(threads, duration, |t| {
                let chain = Arc::clone(&chain);
                let mut stream =
                    ZipfChainStream::with_topology(NODES, FANOUT, SKEW, t as u64 + 1, 99);
                let mut buf = Vec::with_capacity(batch);
                move || {
                    buf.clear();
                    for _ in 0..batch {
                        buf.push(stream.next_transition());
                    }
                    chain.observe_batch(&buf);
                    batch as u64
                }
            });
            if threads == 1 {
                base = rate;
            }
            table.row(&[
                name.clone(),
                threads.to_string(),
                format!("{rate:.0}"),
                format!("{:.2}", rate / base),
            ]);
            println!("  {name:>15} {threads}t: {}", fmt_rate(rate));
        }
    }
    table.finish();
}
