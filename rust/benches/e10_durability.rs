//! E10 — durability overhead and recovery throughput (DESIGN.md §4).
//!
//! Claim shape: write-ahead logging on the shard-affine ingest path is a
//! bounded tax — steady-state queued ingest with `fsync = batch` (group
//! commit) stays within 15% of the WAL-off rate (the PR's acceptance
//! bound), `never` is nearly free, `always` shows the per-batch fsync
//! cost, and cold recovery replays the log at memory-ingest speeds.

use std::time::Duration;

use mcprioq::bench_harness::{bench_mode_from_env, durability_sweep, fmt_rate, Table};
use mcprioq::testutil::TempDir;

fn main() {
    let bench = bench_mode_from_env();
    let duration = if bench.samples <= 3 {
        Duration::from_millis(200)
    } else {
        Duration::from_millis(600)
    };
    let threads = 4;
    let shards = 4;
    let scratch = TempDir::new("e10-durability");

    let mut table =
        Table::new("e10_durability", &["mode", "threads", "updates_per_s", "vs_memory"]);
    let (rows, probe) =
        durability_sweep(&bench, duration, threads, shards, 256, scratch.path())
            .expect("durability sweep");
    for row in &rows {
        table.row(&[
            row.mode.to_string(),
            threads.to_string(),
            format!("{:.0}", row.updates_per_s),
            format!("{:.2}", row.vs_memory),
        ]);
        println!(
            "  fsync {:>7}: {} ({:.2}x vs memory)",
            row.mode,
            fmt_rate(row.updates_per_s),
            row.vs_memory
        );
        if row.mode == "batch" && row.vs_memory < 0.85 {
            println!("  !! fsync=batch below the 0.85x acceptance bound");
        }
    }
    table.row(&[
        "recover".to_string(),
        "1".to_string(),
        format!("{:.0}", probe.updates_per_s),
        "-".to_string(),
    ]);
    println!(
        "  recovery: {} batches / {} updates in {:.3}s ({})",
        probe.batches,
        probe.updates,
        probe.secs,
        fmt_rate(probe.updates_per_s)
    );
    table.finish();
}
