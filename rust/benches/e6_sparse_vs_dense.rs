//! E6 — the introduction's motivation: "in high performance systems it is
//! sometimes hard to build very large graphs that are efficient both with
//! respect to memory and compute." Sparse MCPrioQ vs the dense-matrix
//! XLA engine (the full three-layer artifact path) across graph size and
//! fill factor (DESIGN.md §3).
//!
//! Claim shape to reproduce: dense update/query cost and memory grow with
//! the *capacity* n (O(n²) state, O(n) per row) regardless of how sparse
//! the real graph is; MCPrioQ costs grow only with live edges. Requires
//! `make artifacts`; skips gracefully otherwise.

use std::sync::Arc;
use std::time::Instant;

use mcprioq::baselines::MarkovModel;
use mcprioq::bench_harness::Table;
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::runtime::{default_artifacts_dir, DenseXlaChain, XlaRuntime};
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const QUERIES: usize = 500;

fn main() {
    let rt = match XlaRuntime::new(&default_artifacts_dir()) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("e6 skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    println!("PJRT platform: {}", rt.platform());

    let mut table = Table::new(
        "e6_sparse_vs_dense",
        &[
            "nodes", "fanout", "live_edges",
            "sparse_update_ns", "dense_update_ns",
            "sparse_query_ns", "dense_query_ns",
            "sparse_kib", "dense_kib",
        ],
    );

    for &(nodes, fanout) in &[(48u64, 4u64), (48, 16), (240, 4), (240, 16), (1000, 8), (1000, 32)] {
        let sparse = McPrioQ::new(ChainConfig::default());
        let dense = DenseXlaChain::new(Arc::clone(&rt), nodes as usize).expect("dense");
        let mut stream = ZipfChainStream::new(nodes, fanout, 1.1, 6);
        let train = 40_000usize;
        let pairs: Vec<(u64, u64)> = (0..train).map(|_| stream.next_transition()).collect();

        let t0 = Instant::now();
        for &(a, b) in &pairs {
            sparse.observe(a, b);
        }
        let sparse_up = t0.elapsed().as_nanos() as f64 / train as f64;
        let t0 = Instant::now();
        for &(a, b) in &pairs {
            dense.observe(a, b);
        }
        let dense_up = t0.elapsed().as_nanos() as f64 / train as f64;

        let t0 = Instant::now();
        for i in 0..QUERIES {
            std::hint::black_box(sparse.infer_topk(pairs[i].0, 8));
        }
        let sparse_q = t0.elapsed().as_nanos() as f64 / QUERIES as f64;
        let t0 = Instant::now();
        for i in 0..QUERIES {
            std::hint::black_box(dense.infer_topk(pairs[i].0, 8));
        }
        let dense_q = t0.elapsed().as_nanos() as f64 / QUERIES as f64;

        // Same answers (sanity before trusting the numbers).
        let a = sparse.infer_topk(pairs[0].0, 4);
        let b = dense.infer_topk(pairs[0].0, 4);
        assert_eq!(a.items.len(), b.items.len(), "engines disagree");

        let row = [
            nodes.to_string(),
            fanout.to_string(),
            sparse.edge_count().to_string(),
            format!("{sparse_up:.0}"),
            format!("{dense_up:.0}"),
            format!("{sparse_q:.0}"),
            format!("{dense_q:.0}"),
            (sparse.stats().approx_bytes / 1024).to_string(),
            (dense.resident_bytes() / 1024).to_string(),
        ];
        println!(
            "  n={nodes} f={fanout}: update {sparse_up:.0}ns vs {dense_up:.0}ns, \
             query {sparse_q:.0}ns vs {dense_q:.0}ns, mem {}KiB vs {}KiB",
            sparse.stats().approx_bytes / 1024,
            dense.resident_bytes() / 1024
        );
        table.row(&row);
    }
    table.finish();
}
