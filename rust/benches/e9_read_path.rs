//! E9 — read-path overhaul acceptance sweep: hot-node `infer_topk` /
//! `infer_threshold` throughput vs reader thread count, with the
//! RCU-published prefix-sum snapshots ON vs OFF (the plain list walk —
//! the paper's read path and the PR-2 ablation baseline).
//!
//! Claim shape to reproduce: the list walk pays a dependent-load cache
//! miss per item, so its per-reader cost grows with the scan depth and its
//! aggregate throughput saturates as readers contend for the same chain of
//! lines; the snapshot path is a bounded copy of a contiguous prefix
//! (topk) or a binary search (threshold) over an immutable array that
//! scales near-linearly with readers. Acceptance: >= 2x topk throughput
//! at 8 threads on a hot node (fanout 256, Zipf 1.0, k = 10).
//!
//! Fixture and topk sweep come from `bench_harness::hot_node_chain` /
//! `read_topk_sweep`, shared with `mcprioq bench` (which emits
//! `BENCH_read.json`), so the CLI artifact and this bench cannot diverge.
//!
//! Also reported: the quiescent equivalence check (snapshot answers must
//! be byte-identical to the list walk) so a perf run doubles as a
//! correctness probe.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::bench_harness::{bench_mode_from_env, fmt_rate, hot_node_chain, read_topk_sweep, Table};
use mcprioq::chain::{ChainConfig, McPrioQ, Recommendation};

const FANOUT: usize = 256;
const TRAIN: usize = 400_000;
const K: usize = 10;
const SRC: u64 = 0;
const THREADS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let bench = bench_mode_from_env();
    let train = if bench.samples <= 3 { TRAIN / 10 } else { TRAIN };
    let window = Duration::from_millis(if bench.samples <= 3 { 80 } else { 300 });

    let without = hot_node_chain(
        ChainConfig { snap_enabled: false, ..Default::default() },
        FANOUT,
        train,
        0xE9,
    );
    let with_snap = hot_node_chain(ChainConfig::default(), FANOUT, train, 0xE9);

    // Quiescent equivalence: same stream, snapshots on vs off, answers
    // must match byte-for-byte (items, cumulative, scanned, total).
    for k in [1, K, FANOUT + 10] {
        // Query twice: the first read builds the snapshot, the second
        // serves from it.
        with_snap.infer_topk(SRC, k);
        assert_eq!(with_snap.infer_topk(SRC, k), without.infer_topk(SRC, k), "topk {k}");
    }
    for t in [0.5, 0.9, 0.99, 1.0] {
        with_snap.infer_threshold(SRC, t);
        assert_eq!(
            with_snap.infer_threshold(SRC, t),
            without.infer_threshold(SRC, t),
            "threshold {t}"
        );
    }
    println!("quiescent equivalence: snapshot answers identical to list walk");

    let mut table = Table::new(
        "e9_read_path",
        &["mode", "threads", "topk_per_s", "threshold_per_s", "topk_vs_list"],
    );
    let mut speedup_at_max = 0.0;
    for row in read_topk_sweep(&bench, window, &THREADS, K, &without, &with_snap) {
        // The threshold sweep rides on the same chains: snapshots turn the
        // O(CDF⁻¹(t)) walk into a binary search.
        let chain: &Arc<McPrioQ> =
            if row.mode == "snapshot" { &with_snap } else { &without };
        let thr_rate = bench.run_threads(row.threads, window, |_| {
            let chain = Arc::clone(chain);
            let mut out = Recommendation::default();
            move || {
                chain.infer_threshold_into(SRC, 0.9, &mut out);
                1
            }
        });
        if row.mode == "snapshot" && row.threads == 8 {
            speedup_at_max = row.vs_list_walk;
        }
        table.row(&[
            row.mode.to_string(),
            row.threads.to_string(),
            format!("{:.0}", row.topk_per_s),
            format!("{thr_rate:.0}"),
            format!("{:.2}", row.vs_list_walk),
        ]);
        println!(
            "  {:>9} x{}: topk {}, threshold {} ({:.2}x)",
            row.mode,
            row.threads,
            fmt_rate(row.topk_per_s),
            fmt_rate(thr_rate),
            row.vs_list_walk
        );
    }
    table.finish();
    println!("topk speedup at 8 threads: {speedup_at_max:.2}x (target >= 2.0x)");
}
