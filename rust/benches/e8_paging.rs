//! E8 — the application benchmark (ref [1]): cellular paging. Success
//! probability and paging cost vs threshold, online MCPrioQ vs a frozen
//! offline model under topology drift (DESIGN.md §3).
//!
//! Claim shape to reproduce: success@t tracks t (the model is calibrated);
//! the paged-set size is far below the topology degree (skew exploited);
//! after drift, the online model recovers while the frozen model's
//! success collapses toward the exploration floor.

use mcprioq::bench_harness::{bench_mode_from_env, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::workload::{MobilityConfig, MobilityTrace, TransitionStream};

const PROBES: usize = 5_000;

fn accuracy(chain: &McPrioQ, trace: &mut MobilityTrace, t: f64, learn: Option<&McPrioQ>) -> (f64, f64) {
    let mut hits = 0;
    let mut paged = 0usize;
    for _ in 0..PROBES {
        let (from, to) = trace.next_transition();
        let rec = chain.infer_threshold(from, t);
        if rec.items.iter().any(|&(c, _)| c == to) {
            hits += 1;
        }
        paged += rec.items.len();
        if let Some(l) = learn {
            l.observe(from, to);
        }
    }
    (hits as f64 / PROBES as f64, paged as f64 / PROBES as f64)
}

fn main() {
    let bench = bench_mode_from_env();
    let train = if bench.samples <= 3 { 60_000 } else { 600_000 };

    let cfg = MobilityConfig { width: 20, height: 20, users: 300, skew: 1.1, explore: 0.05, seed: 13 };
    let mut trace = MobilityTrace::new(cfg);

    // Train the online model.
    let online = McPrioQ::new(ChainConfig::default());
    for _ in 0..train {
        let (a, b) = trace.next_transition();
        online.observe(a, b);
    }
    // Freeze a copy (the "retrained offline, deployed statically" model).
    let frozen = McPrioQ::import(ChainConfig::default(), &online.export());

    let mut table = Table::new(
        "e8_paging",
        &["phase", "threshold", "online_success", "online_cells", "frozen_success", "frozen_cells"],
    );

    println!("-- converged world --");
    for &t in &[0.5, 0.8, 0.9, 0.95, 0.99] {
        let (so, co) = accuracy(&online, &mut trace, t, Some(&online));
        let (sf, cf) = accuracy(&frozen, &mut trace, t, None);
        table.row(&[
            "stable".into(),
            format!("{t}"),
            format!("{so:.3}"),
            format!("{co:.2}"),
            format!("{sf:.3}"),
            format!("{cf:.2}"),
        ]);
        println!("  t={t}: online {so:.3} ({co:.2} cells) vs frozen {sf:.3} ({cf:.2} cells)");
    }

    // Drift: corridors move. Online keeps learning (with decay); frozen
    // does not. Measured at t = 0.5, where the paged set is small (~2
    // cells) so getting the *order* right matters — at t ≥ 0.9 the paged
    // set covers most of the ≤ 6 neighbours and hides the damage.
    println!("-- after topology drift (t = 0.5) --");
    trace.flip_topology();
    for round in 0..6 {
        for _ in 0..train / 6 {
            let (a, b) = trace.next_transition();
            online.observe(a, b);
        }
        online.decay();
        let (so, co) = accuracy(&online, &mut trace, 0.5, Some(&online));
        let (sf, cf) = accuracy(&frozen, &mut trace, 0.5, None);
        table.row(&[
            format!("drift+{round}"),
            "0.5".into(),
            format!("{so:.3}"),
            format!("{co:.2}"),
            format!("{sf:.3}"),
            format!("{cf:.2}"),
        ]);
        println!("  round {round}: online {so:.3} ({co:.2} cells) vs frozen {sf:.3} ({cf:.2} cells)");
    }
    table.finish();
}
