//! E7 — "approximately correct results even during concurrent updates":
//! quantify the approximation. Readers scan while writers update; recall,
//! order inversions, and complete-scan rate are measured against the
//! quiesced ground truth (DESIGN.md §3).
//!
//! Claim shape to reproduce: recall stays ≈ 1 and inversions per scan
//! stay O(1) even under maximal churn (uniform counts); the skewed
//! normal case is essentially indistinguishable from quiesced reads.
//! This is the measured counterpart of the swap design in Fig. 2.

use std::collections::HashSet;
use std::sync::Arc;

use mcprioq::bench_harness::{bench_mode_from_env, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::sync::shim::{AtomicBool, Ordering};
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const FANOUT: u64 = 64;
const SCANS: usize = 5_000;

fn main() {
    let bench = bench_mode_from_env();
    let scans = if bench.samples <= 3 { SCANS / 5 } else { SCANS };

    let mut table = Table::new(
        "e7_concurrent_recall",
        &["writers", "skew", "mean_recall", "min_recall", "complete_scan_pct", "mean_inversions", "phantom_keys"],
    );

    for &writers in &[0usize, 2, 4] {
        for &skew in &[0.0, 1.1] {
            let chain = Arc::new(McPrioQ::new(ChainConfig::default()));
            // One hot src node with FANOUT edges — the worst case is all
            // the churn concentrated in one queue.
            const SRC: u64 = 0;
            {
                let mut s = ZipfChainStream::new(FANOUT + 1, FANOUT, skew, 2);
                for _ in 0..50_000 {
                    let (_, b) = s.next_transition();
                    chain.observe(SRC, b);
                }
            }
            // Ground truth membership while quiesced.
            chain.repair();
            let truth: HashSet<u64> =
                chain.infer_topk(SRC, usize::MAX).items.iter().map(|&(d, _)| d).collect();

            let stop = Arc::new(AtomicBool::new(false));
            let handles: Vec<_> = (0..writers)
                .map(|w| {
                    let chain = Arc::clone(&chain);
                    let stop = Arc::clone(&stop);
                    std::thread::spawn(move || {
                        let mut s = ZipfChainStream::new(FANOUT + 1, FANOUT, skew, w as u64 + 3);
                        while !stop.load(Ordering::Relaxed) {
                            let (_, b) = s.next_transition();
                            chain.observe(SRC, b);
                        }
                    })
                })
                .collect();

            let mut recall_sum = 0.0;
            let mut min_recall = 1.0f64;
            let mut complete = 0usize;
            let mut inversions = 0u64;
            let mut phantoms = 0u64;
            for _ in 0..scans {
                let rec = chain.infer_topk(SRC, usize::MAX);
                let seen: HashSet<u64> = rec.items.iter().map(|&(d, _)| d).collect();
                let recall = seen.intersection(&truth).count() as f64 / truth.len() as f64;
                recall_sum += recall;
                min_recall = min_recall.min(recall);
                if recall >= 1.0 {
                    complete += 1;
                }
                phantoms += seen.difference(&truth).count() as u64;
                // Order inversions in the returned snapshot.
                inversions += rec
                    .items
                    .windows(2)
                    .filter(|w| w[0].1 < w[1].1 - 1e-12)
                    .count() as u64;
            }
            stop.store(true, Ordering::SeqCst);
            for h in handles {
                h.join().unwrap();
            }
            table.row(&[
                writers.to_string(),
                format!("{skew}"),
                format!("{:.5}", recall_sum / scans as f64),
                format!("{min_recall:.5}"),
                format!("{:.2}", 100.0 * complete as f64 / scans as f64),
                format!("{:.4}", inversions as f64 / scans as f64),
                phantoms.to_string(),
            ]);
            println!(
                "  {writers} writers s={skew}: recall mean {:.4} min {:.4}, {:.1}% complete, {:.3} inversions/scan",
                recall_sum / scans as f64,
                min_recall,
                100.0 * complete as f64 / scans as f64,
                inversions as f64 / scans as f64
            );
            chain.repair();
            chain.check_invariants().expect("invariants");
        }
    }
    table.finish();
}
