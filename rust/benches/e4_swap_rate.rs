//! E4 — "the normal case would likely be no-swap and in rare cases a
//! single-swap" (§II.A.2): swap-count distribution per update vs the edge
//! distribution's skew and the arrival order (DESIGN.md §3).
//!
//! Claim shape to reproduce: for skewed (Zipf) streams, the overwhelming
//! majority of updates perform zero swaps and almost all the rest exactly
//! one; the uniform distribution (counts stay tied) and shuffled bulk
//! loads are the adversarial cases. Also measures ticket-skip rate under
//! concurrency (the price of never blocking).

use std::sync::Arc;
use std::time::Duration;

use mcprioq::bench_harness::{bench_mode_from_env, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::sync::shim::{AtomicU64, Ordering};
use mcprioq::testutil::Rng64;
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const UPDATES: usize = 1_000_000;

fn main() {
    let bench = bench_mode_from_env();
    let updates = if bench.samples <= 3 { UPDATES / 10 } else { UPDATES };

    let mut table = Table::new(
        "e4_swap_rate",
        &["skew", "arrival", "swap0_pct", "swap1_pct", "swap2plus_pct", "swaps_per_update", "max_bubble"],
    );

    for &skew in &[0.0, 0.5, 1.0, 1.5] {
        for arrival in ["stream", "shuffled"] {
            let chain = McPrioQ::new(ChainConfig::default());
            let mut hist = [0u64; 3];
            let mut total_swaps = 0u64;
            let mut max_bubble = 0u32;

            if arrival == "stream" {
                let mut s = ZipfChainStream::new(500, 64, skew, 11);
                // Steady state: the paper's assumption is a converged queue
                // whose counts reflect the edge probabilities; warm up
                // first, then measure.
                for _ in 0..updates {
                    let (a, b) = s.next_transition();
                    chain.observe(a, b);
                }
                for _ in 0..updates {
                    let (a, b) = s.next_transition();
                    let o = chain.observe(a, b);
                    hist[(o.increment.swaps as usize).min(2)] += 1;
                    total_swaps += o.increment.swaps as u64;
                    max_bubble = max_bubble.max(o.increment.swaps);
                }
            } else {
                // Shuffled bulk load: all (src, dst, repeat) triples
                // pre-generated then randomly permuted — breaks the
                // "increments arrive in probability order" assumption.
                let mut s = ZipfChainStream::new(500, 64, skew, 11);
                let mut events: Vec<(u64, u64)> =
                    (0..updates).map(|_| s.next_transition()).collect();
                Rng64::new(3).shuffle(&mut events);
                for (a, b) in events {
                    let o = chain.observe(a, b);
                    hist[(o.increment.swaps as usize).min(2)] += 1;
                    total_swaps += o.increment.swaps as u64;
                    max_bubble = max_bubble.max(o.increment.swaps);
                }
            }
            let n = updates as f64;
            table.row(&[
                format!("{skew}"),
                arrival.to_string(),
                format!("{:.3}", 100.0 * hist[0] as f64 / n),
                format!("{:.3}", 100.0 * hist[1] as f64 / n),
                format!("{:.3}", 100.0 * hist[2] as f64 / n),
                format!("{:.5}", total_swaps as f64 / n),
                max_bubble.to_string(),
            ]);
            println!(
                "  s={skew} {arrival}: no-swap {:.2}%, 1-swap {:.2}%, 2+ {:.2}% (max bubble {max_bubble})",
                100.0 * hist[0] as f64 / n,
                100.0 * hist[1] as f64 / n,
                100.0 * hist[2] as f64 / n
            );
        }
    }
    table.finish();

    // Concurrency: how often is the reorder ticket busy (skip rate)?
    let mut skips = Table::new("e4b_swap_skips", &["threads", "skew", "skips_per_million"]);
    for &threads in &[2usize, 4, 8] {
        for &skew in &[0.0, 1.1] {
            let chain = Arc::new(McPrioQ::new(ChainConfig::default()));
            let skipped = Arc::new(AtomicU64::new(0));
            let done = Arc::new(AtomicU64::new(0));
            let per = (updates / threads).max(10_000);
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let chain = Arc::clone(&chain);
                    let skipped = Arc::clone(&skipped);
                    let done = Arc::clone(&done);
                    scope.spawn(move || {
                        let mut s = ZipfChainStream::new(64, 32, skew, t as u64);
                        for _ in 0..per {
                            let (a, b) = s.next_transition();
                            let o = chain.observe(a, b);
                            if o.increment.skipped {
                                skipped.fetch_add(1, Ordering::Relaxed);
                            }
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            });
            let rate = 1e6 * skipped.load(Ordering::Relaxed) as f64 / done.load(Ordering::Relaxed) as f64;
            skips.row(&[threads.to_string(), format!("{skew}"), format!("{rate:.1}")]);
            println!("  {threads}t s={skew}: {rate:.1} skipped reorders per million updates");
            // After a repair sweep the structure is exactly sorted again.
            chain.repair();
            chain.check_invariants().expect("invariants after concurrent run");
        }
    }
    skips.finish();
    let _ = Duration::from_secs(0);
}
