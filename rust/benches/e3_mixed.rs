//! E3 — "construct the graph while simultaneously querying it": mixed
//! read/write throughput vs thread count (DESIGN.md §3).
//!
//! Claim shape to reproduce: MCPrioQ's throughput is insensitive to the
//! read fraction (reads are wait-free RCU scans) and scales with threads;
//! lock-based baselines degrade as writers serialize readers.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::baselines::{MarkovModel, MutexChain, ShardedChain, SkipListChain};
use mcprioq::bench_harness::{bench_mode_from_env, fmt_rate, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::testutil::Rng64;
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const NODES: u64 = 1_000;
const FANOUT: u64 = 24;

fn main() {
    let bench = bench_mode_from_env();
    let duration = if bench.samples <= 3 { Duration::from_millis(150) } else { Duration::from_millis(500) };

    let mut table = Table::new("e3_mixed", &["model", "read_frac", "threads", "ops_per_s"]);
    let models: Vec<(&str, Box<dyn Fn() -> Arc<dyn MarkovModel>>)> = vec![
        ("mcprioq", Box::new(|| Arc::new(McPrioQ::new(ChainConfig::default())))),
        ("mutex", Box::new(|| Arc::new(MutexChain::new()))),
        ("sharded-rwlock", Box::new(|| Arc::new(ShardedChain::new(64)))),
        ("skiplist", Box::new(|| Arc::new(SkipListChain::new()))),
    ];

    for (name, make) in &models {
        for &read_frac in &[0.5f64, 0.9, 0.99] {
            for &threads in &[1usize, 4, 8] {
                let model = make();
                {
                    let mut s = ZipfChainStream::new(NODES, FANOUT, 1.1, 5);
                    for _ in 0..1_000_000 {
                        let (a, b) = s.next_transition();
                        model.observe(a, b);
                    }
                }
                let rate = bench.run_threads(threads, duration, |t| {
                    let model = Arc::clone(&model);
                    let mut stream =
                        ZipfChainStream::with_topology(NODES, FANOUT, 1.1, t as u64 + 10, 5);
                    let mut rng = Rng64::new(t as u64 + 77);
                    move || {
                        let (a, b) = stream.next_transition();
                        if rng.next_bool(read_frac) {
                            std::hint::black_box(model.infer_threshold(a, 0.9));
                        } else {
                            model.observe(a, b);
                        }
                        1
                    }
                });
                table.row(&[
                    name.to_string(),
                    format!("{read_frac}"),
                    threads.to_string(),
                    format!("{rate:.0}"),
                ]);
                println!("  {name:>15} r={read_frac} {threads}t: {}", fmt_rate(rate));
            }
        }
    }
    table.finish();
}
