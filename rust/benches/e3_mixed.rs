//! E3 — "construct the graph while simultaneously querying it": mixed
//! read/write throughput vs thread count (DESIGN.md §3).
//!
//! Claim shape to reproduce: MCPrioQ's throughput is insensitive to the
//! read fraction (reads are wait-free RCU scans) and scales with threads;
//! lock-based baselines degrade as writers serialize readers.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::baselines::{MarkovModel, MutexChain, ShardedChain, SkipListChain};
use mcprioq::bench_harness::{batch_sizes_from_env, bench_mode_from_env, fmt_rate, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::config::ServerConfig;
use mcprioq::coordinator::Engine;
use mcprioq::testutil::Rng64;
use mcprioq::workload::{TransitionStream, ZipfChainStream};

const NODES: u64 = 1_000;
const FANOUT: u64 = 24;

fn main() {
    let bench = bench_mode_from_env();
    let duration = if bench.samples <= 3 { Duration::from_millis(150) } else { Duration::from_millis(500) };

    let mut table = Table::new("e3_mixed", &["model", "read_frac", "threads", "ops_per_s"]);
    let models: Vec<(&str, Box<dyn Fn() -> Arc<dyn MarkovModel>>)> = vec![
        ("mcprioq", Box::new(|| Arc::new(McPrioQ::new(ChainConfig::default())))),
        ("mutex", Box::new(|| Arc::new(MutexChain::new()))),
        ("sharded-rwlock", Box::new(|| Arc::new(ShardedChain::new(64)))),
        ("skiplist", Box::new(|| Arc::new(SkipListChain::new()))),
    ];

    for (name, make) in &models {
        for &read_frac in &[0.5f64, 0.9, 0.99] {
            for &threads in &[1usize, 4, 8] {
                let model = make();
                {
                    let mut s = ZipfChainStream::new(NODES, FANOUT, 1.1, 5);
                    for _ in 0..1_000_000 {
                        let (a, b) = s.next_transition();
                        model.observe(a, b);
                    }
                }
                let rate = bench.run_threads(threads, duration, |t| {
                    let model = Arc::clone(&model);
                    let mut stream =
                        ZipfChainStream::with_topology(NODES, FANOUT, 1.1, t as u64 + 10, 5);
                    let mut rng = Rng64::new(t as u64 + 77);
                    move || {
                        let (a, b) = stream.next_transition();
                        if rng.next_bool(read_frac) {
                            std::hint::black_box(model.infer_threshold(a, 0.9));
                        } else {
                            model.observe(a, b);
                        }
                        1
                    }
                });
                table.row(&[
                    name.to_string(),
                    format!("{read_frac}"),
                    threads.to_string(),
                    format!("{rate:.0}"),
                ]);
                println!("  {name:>15} r={read_frac} {threads}t: {}", fmt_rate(rate));
            }
        }
    }
    table.finish();

    batch_sweep(&bench, duration);
}

/// Batch-first acceptance sweep: mixed read/write throughput vs batch size
/// on (a) the chain's `observe_batch` path and (b) the engine's queued
/// shard-affine path (`Engine::observe_batch` -> per-shard queues ->
/// worker `observe_batch`). Batch 1 is the single-item baseline; the
/// refactor targets >= 1.5x at batch 256 on >= 4 threads.
fn batch_sweep(bench: &mcprioq::bench_harness::Bench, duration: Duration) {
    let mut sizes = batch_sizes_from_env();
    if !sizes.contains(&1) {
        sizes.insert(0, 1);
    }
    let mut table = Table::new(
        "e3_batch_sweep",
        &["path", "read_frac", "threads", "batch", "ops_per_s", "vs_batch1"],
    );
    for &read_frac in &[0.0f64, 0.5] {
        for &threads in &[1usize, 4, 8] {
            for path in ["chain", "engine"] {
                let mut base = 0.0;
                for &batch in &sizes {
                    let rate = match path {
                        "chain" => chain_point(bench, duration, threads, batch, read_frac),
                        _ => engine_point(bench, duration, threads, batch, read_frac),
                    };
                    if batch == sizes[0] {
                        base = rate;
                    }
                    let vs_batch1 =
                        if base > 0.0 { format!("{:.2}", rate / base) } else { "-".to_string() };
                    table.row(&[
                        path.to_string(),
                        format!("{read_frac}"),
                        threads.to_string(),
                        batch.to_string(),
                        format!("{rate:.0}"),
                        vs_batch1,
                    ]);
                    println!(
                        "  {path:>6} r={read_frac} {threads}t b={batch}: {}",
                        fmt_rate(rate)
                    );
                }
            }
        }
    }
    table.finish();
}

const SWEEP_PREFILL: usize = 200_000;

/// Mixed ops/sec straight on the chain: writes apply synchronously, so the
/// thunk's op count is the applied count.
fn chain_point(
    bench: &mcprioq::bench_harness::Bench,
    duration: Duration,
    threads: usize,
    batch: usize,
    read_frac: f64,
) -> f64 {
    let chain = Arc::new(McPrioQ::new(ChainConfig::default()));
    {
        let mut s = ZipfChainStream::new(NODES, FANOUT, 1.1, 5);
        for _ in 0..SWEEP_PREFILL {
            let (a, b) = s.next_transition();
            chain.observe(a, b);
        }
    }
    bench.run_threads(threads, duration, |t| {
        let chain = Arc::clone(&chain);
        let mut stream = ZipfChainStream::with_topology(NODES, FANOUT, 1.1, t as u64 + 10, 5);
        let mut rng = Rng64::new(t as u64 + 77);
        let mut buf: Vec<(u64, u64)> = Vec::with_capacity(batch);
        move || {
            let (a, b) = stream.next_transition();
            if rng.next_bool(read_frac) {
                std::hint::black_box(chain.infer_threshold(a, 0.9));
                return 1;
            }
            // batch == 1 exercises the true single-item entry point.
            if batch == 1 {
                chain.observe(a, b);
                return 1;
            }
            buf.push((a, b));
            if buf.len() < batch {
                return 0;
            }
            chain.observe_batch(&buf);
            let n = buf.len() as u64;
            buf.clear();
            n
        }
    })
}

/// Mixed ops/sec through the queued pipeline. Writes are asynchronous, so
/// the thunks count only reads; write throughput is taken from the
/// engine's applied-update counter over the same window — counting
/// enqueues would credit backlog that shutdown then discards.
fn engine_point(
    bench: &mcprioq::bench_harness::Bench,
    duration: Duration,
    threads: usize,
    batch: usize,
    read_frac: f64,
) -> f64 {
    let engine = Engine::new(
        &ServerConfig { shards: 4, queue_capacity: 65_536, ..Default::default() },
        4,
    );
    {
        let mut s = ZipfChainStream::new(NODES, FANOUT, 1.1, 5);
        for _ in 0..SWEEP_PREFILL {
            let (a, b) = s.next_transition();
            engine.observe_direct(a, b);
        }
    }
    let applied_before = engine.stats().applied_updates;
    let read_rate = bench.run_threads(threads, duration, |t| {
        let engine = Arc::clone(&engine);
        let mut stream = ZipfChainStream::with_topology(NODES, FANOUT, 1.1, t as u64 + 10, 5);
        let mut rng = Rng64::new(t as u64 + 77);
        let mut buf: Vec<(u64, u64)> = Vec::with_capacity(batch);
        move || {
            let (a, b) = stream.next_transition();
            if rng.next_bool(read_frac) {
                std::hint::black_box(engine.infer_threshold(a, 0.9));
                return 1;
            }
            if batch == 1 {
                engine.observe(a, b);
                return 0;
            }
            buf.push((a, b));
            if buf.len() == batch {
                engine.observe_batch(&buf);
                buf.clear();
            }
            0
        }
    });
    // Snapshot immediately at window end: still-queued backlog is excluded.
    let applied_after = engine.stats().applied_updates;
    engine.shutdown();
    read_rate + (applied_after - applied_before) as f64 / duration.as_secs_f64()
}
