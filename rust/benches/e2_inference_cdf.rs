//! E2 — "O(CDF⁻¹(t)) inference": measured scan depth + latency vs the
//! threshold t, across edge-distribution skews, against the analytic
//! quantile function of the generating Zipf (DESIGN.md §3).
//!
//! Claim shape to reproduce: scan depth ≈ Zipf quantile(t); tiny for
//! skewed distributions, ≈ fanout·t for the uniform worst case (s = 0).
//! Includes the skip-list and heap comparison (§II.2's structure debate)
//! and the no-dst-table ablation for update cost.

use std::time::Instant;

use mcprioq::baselines::{HeapChain, MarkovModel, SkipListChain};
use mcprioq::bench_harness::{bench_mode_from_env, fmt_ns, Table};
use mcprioq::chain::{ChainConfig, McPrioQ};
use mcprioq::workload::{TransitionStream, Zipf, ZipfChainStream};

const NODES: u64 = 2_000;
const FANOUT: u64 = 64;
const TRAIN: usize = 2_000_000;

fn main() {
    let bench = bench_mode_from_env();
    let train = if bench.samples <= 3 { TRAIN / 10 } else { TRAIN };

    let mut table = Table::new(
        "e2_inference_cdf",
        &["skew", "threshold", "predicted_cdf_inv", "measured_scan", "latency_ns", "skiplist_scan", "heap_latency_ns"],
    );

    for &skew in &[0.0, 0.8, 1.2] {
        let chain = McPrioQ::new(ChainConfig::default());
        let skiplist = SkipListChain::new();
        let heap = HeapChain::new();
        let mut stream = ZipfChainStream::new(NODES, FANOUT, skew, 7);
        for _ in 0..train {
            let (a, b) = stream.next_transition();
            chain.observe(a, b);
            skiplist.observe(a, b);
            heap.observe(a, b);
        }
        chain.repair();
        let zipf = Zipf::new(FANOUT as usize, skew);
        // Query the busiest sources for stable statistics.
        let hot_srcs: Vec<u64> = (0..NODES).filter(|&s| chain.node_stats(s).map_or(0, |st| st.total) > (train as u64 / NODES as u64) / 2).take(256).collect();
        assert!(!hot_srcs.is_empty());

        for &t in &[0.5, 0.8, 0.9, 0.95, 0.99] {
            let mut scans = 0usize;
            let mut sl_scans = 0usize;
            let t0 = Instant::now();
            for &s in &hot_srcs {
                scans += chain.infer_threshold(s, t).scanned;
            }
            let lat = t0.elapsed().as_nanos() as f64 / hot_srcs.len() as f64;
            for &s in &hot_srcs {
                sl_scans += skiplist.infer_threshold(s, t).scanned;
            }
            let t0 = Instant::now();
            for &s in &hot_srcs {
                let _ = heap.infer_threshold(s, t);
                // Touch the counts so the lazy sort re-dirties: emulate the
                // online setting where every query pays the sort.
                heap.observe(s, s % FANOUT);
            }
            let heap_lat = t0.elapsed().as_nanos() as f64 / hot_srcs.len() as f64;

            let measured = scans as f64 / hot_srcs.len() as f64;
            let predicted = zipf.quantile(t);
            table.row(&[
                format!("{skew}"),
                format!("{t}"),
                predicted.to_string(),
                format!("{measured:.2}"),
                format!("{lat:.0}"),
                format!("{:.2}", sl_scans as f64 / hot_srcs.len() as f64),
                format!("{heap_lat:.0}"),
            ]);
            println!(
                "  s={skew} t={t}: predicted {predicted}, measured {measured:.1}, {} per query",
                fmt_ns(lat)
            );
        }
    }
    table.finish();

    // Ablation: update cost with vs without the dst hash table (§II.2).
    let mut ab = Table::new("e2b_dst_table_ablation", &["variant", "skew", "update_ns"]);
    for &skew in &[0.0, 1.2] {
        for (variant, use_dst) in [("with-dst-table", true), ("list-only", false)] {
            let chain = McPrioQ::new(ChainConfig { use_dst_table: use_dst, ..Default::default() });
            let mut stream = ZipfChainStream::new(64, FANOUT, skew, 3);
            for _ in 0..100_000 {
                let (a, b) = stream.next_transition();
                chain.observe(a, b);
            }
            let m = bench.run("update", 1, || {
                let (a, b) = stream.next_transition();
                chain.observe(a, b);
            });
            ab.row(&[variant.to_string(), format!("{skew}"), format!("{:.0}", m.median_ns())]);
            println!("  {variant} s={skew}: {} per update", fmt_ns(m.median_ns()));
        }
    }
    ab.finish();
}
