//! Baseline markov-chain implementations the paper argues against or
//! discusses as alternatives (§II.2), all behind one trait so every
//! benchmark can sweep implementations:
//!
//! * [`MutexChain`] — coarse global `Mutex` around a plain map-of-maps; the
//!   textbook non-lock-free construction.
//! * [`ShardedChain`] — `RwLock`-per-shard map with per-node sorted edge
//!   vectors; the "just shard it" industry default.
//! * [`SkipListChain`] — per-node *skip-list* priority queue (Sundell &
//!   Tsigas [3] is the paper's cited alternative; ours is the structural
//!   equivalent guarded by a per-node `RwLock`, so E2 compares *search
//!   depth/structure*, while E1/E3 compare against its locking overhead).
//! * [`HeapChain`] — heap-style "fast insert, pay at query": O(1) updates
//!   into a hash map, full sort on (dirty) inference — the §II.2 point that
//!   heaps optimize top-1 insert, not cumulative-probability scans.
//! * `DenseXlaChain` (in [`crate::runtime`]) — the dense-matrix engine the
//!   introduction motivates against, running on the AOT-compiled JAX/Pallas
//!   artifact.
//!
//! All baselines implement *the same semantics* (two-counter probabilities,
//! halving decay with zero-pruning) so experiment outputs are comparable.

mod heap;
mod locked;
mod skiplist;

pub use heap::HeapChain;
pub use locked::{MutexChain, ShardedChain};
pub use skiplist::{SkipList, SkipListChain};

use crate::chain::{McPrioQ, Recommendation};

/// The common surface of every markov-chain implementation in this crate.
pub trait MarkovModel: Send + Sync {
    fn name(&self) -> &'static str;
    fn observe(&self, src: u64, dst: u64);
    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation;
    fn infer_topk(&self, src: u64, k: usize) -> Recommendation;
    /// Halve all counters, prune zeros. Returns (surviving mass, pruned).
    fn decay(&self) -> (u64, usize);
    fn edge_count(&self) -> usize;
}

impl MarkovModel for McPrioQ {
    fn name(&self) -> &'static str {
        "mcprioq"
    }

    fn observe(&self, src: u64, dst: u64) {
        McPrioQ::observe(self, src, dst);
    }

    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        McPrioQ::infer_threshold(self, src, threshold)
    }

    fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        McPrioQ::infer_topk(self, src, k)
    }

    fn decay(&self) -> (u64, usize) {
        McPrioQ::decay(self)
    }

    fn edge_count(&self) -> usize {
        McPrioQ::edge_count(self)
    }
}

/// Shared helper: build a `Recommendation` from a descending-sorted slice
/// of `(dst, count)` with a cumulative-probability threshold.
pub(crate) fn recommend_threshold(
    sorted: &[(u64, u64)],
    total: u64,
    threshold: f64,
) -> Recommendation {
    if total == 0 {
        return Recommendation { items: Vec::new(), cumulative: 0.0, scanned: 0, total: 0 };
    }
    let threshold = threshold.clamp(0.0, 1.0);
    let totf = total as f64;
    let mut items = Vec::new();
    let mut cum = 0u64;
    let mut scanned = 0;
    if threshold > 0.0 {
        for &(dst, count) in sorted {
            scanned += 1;
            cum += count;
            items.push((dst, count as f64 / totf));
            if cum as f64 >= threshold * totf {
                break;
            }
        }
    }
    Recommendation { items, cumulative: cum as f64 / totf, scanned, total }
}

/// Shared helper: top-k version.
pub(crate) fn recommend_topk(sorted: &[(u64, u64)], total: u64, k: usize) -> Recommendation {
    if total == 0 || k == 0 {
        return Recommendation { items: Vec::new(), cumulative: 0.0, scanned: 0, total };
    }
    let totf = total as f64;
    let mut cum = 0u64;
    let items: Vec<(u64, f64)> = sorted
        .iter()
        .take(k)
        .map(|&(dst, count)| {
            cum += count;
            (dst, count as f64 / totf)
        })
        .collect();
    let scanned = items.len();
    Recommendation { items, cumulative: cum as f64 / totf, scanned, total }
}

#[cfg(test)]
mod tests;
