//! Skip-list priority queue baseline (§II.2 discusses Sundell & Tsigas [3]
//! as the natural lock-free alternative).
//!
//! [`SkipList`] is a textbook multi-level list ordered by
//! `(count desc, dst asc)` with deterministic pseudo-random tower heights.
//! [`SkipListChain`] wraps one skip-list + dst→count map per src node
//! behind a per-node `RwLock`: counter updates are remove+reinsert (the
//! pop-insert scheme the paper's swap replaces), so E2/E4 compare the
//! *structural* costs and E1/E3 the locking overhead.

use std::collections::HashMap;
use std::sync::RwLock;

use super::{recommend_threshold, recommend_topk, MarkovModel};
use crate::chain::Recommendation;
use crate::sync::shim::{AtomicUsize, Ordering};

const MAX_LEVEL: usize = 12;

/// Key ordering: higher count first, then dst ascending (total order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Key {
    count: u64,
    dst: u64,
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.count.cmp(&self.count).then(self.dst.cmp(&other.dst))
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct SkipNode {
    key: Key,
    next: Vec<usize>, // arena indices; usize::MAX = null
}

const NIL: usize = usize::MAX;

/// Arena-backed skip list (indices instead of pointers: cache-friendly and
/// no unsafe).
pub struct SkipList {
    arena: Vec<SkipNode>,
    head: Vec<usize>, // per-level first node
    free: Vec<usize>,
    len: usize,
    rng_state: u64,
}

impl Default for SkipList {
    fn default() -> Self {
        Self::new()
    }
}

impl SkipList {
    pub fn new() -> Self {
        SkipList {
            arena: Vec::new(),
            head: vec![NIL; MAX_LEVEL],
            free: Vec::new(),
            len: 0,
            rng_state: 0x853C_49E6_748F_EA9B,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn random_level(&mut self) -> usize {
        // xorshift; geometric(1/2) heights capped at MAX_LEVEL.
        self.rng_state ^= self.rng_state << 13;
        self.rng_state ^= self.rng_state >> 7;
        self.rng_state ^= self.rng_state << 17;
        ((self.rng_state.trailing_ones() as usize) + 1).min(MAX_LEVEL)
    }

    /// Find per-level predecessors of `key` (NIL = head).
    fn predecessors(&self, key: Key) -> [usize; MAX_LEVEL] {
        let mut preds = [NIL; MAX_LEVEL];
        let mut cur = NIL; // virtual head
        for level in (0..MAX_LEVEL).rev() {
            loop {
                let next = if cur == NIL { self.head[level] } else { self.arena[cur].next[level] };
                if next != NIL && self.arena[next].key < key {
                    cur = next;
                } else {
                    break;
                }
            }
            preds[level] = cur;
        }
        preds
    }

    pub fn insert(&mut self, count: u64, dst: u64) {
        let key = Key { count, dst };
        let preds = self.predecessors(key);
        let level = self.random_level();
        let idx = match self.free.pop() {
            Some(i) => {
                self.arena[i] = SkipNode { key, next: vec![NIL; level] };
                i
            }
            None => {
                self.arena.push(SkipNode { key, next: vec![NIL; level] });
                self.arena.len() - 1
            }
        };
        for l in 0..level {
            let succ = if preds[l] == NIL { self.head[l] } else { self.arena[preds[l]].next[l] };
            self.arena[idx].next[l] = succ;
            if preds[l] == NIL {
                self.head[l] = idx;
            } else {
                self.arena[preds[l]].next[l] = idx;
            }
        }
        self.len += 1;
    }

    /// Remove the exact `(count, dst)` entry; true if present.
    pub fn remove(&mut self, count: u64, dst: u64) -> bool {
        let key = Key { count, dst };
        let preds = self.predecessors(key);
        let target = if preds[0] == NIL { self.head[0] } else { self.arena[preds[0]].next[0] };
        if target == NIL || self.arena[target].key != key {
            return false;
        }
        let height = self.arena[target].next.len();
        for l in 0..height {
            let pred_next =
                if preds[l] == NIL { self.head[l] } else { self.arena[preds[l]].next[l] };
            if pred_next == target {
                let succ = self.arena[target].next[l];
                if preds[l] == NIL {
                    self.head[l] = succ;
                } else {
                    self.arena[preds[l]].next[l] = succ;
                }
            }
        }
        self.free.push(target);
        self.len -= 1;
        true
    }

    /// Iterate `(dst, count)` in priority order; `f` returns false to stop.
    /// Returns nodes visited (comparable to EdgeList::scan).
    pub fn scan<F: FnMut(u64, u64) -> bool>(&self, mut f: F) -> usize {
        let mut cur = self.head[0];
        let mut visited = 0;
        while cur != NIL {
            let n = &self.arena[cur];
            visited += 1;
            if !f(n.key.dst, n.key.count) {
                break;
            }
            cur = n.next[0];
        }
        visited
    }

    /// Comparison-depth of locating `key`'s position (search cost metric
    /// for E2 structure comparisons).
    pub fn search_depth(&self, count: u64, dst: u64) -> usize {
        let key = Key { count, dst };
        let mut depth = 0;
        let mut cur = NIL;
        for level in (0..MAX_LEVEL).rev() {
            loop {
                let next = if cur == NIL { self.head[level] } else { self.arena[cur].next[level] };
                depth += 1;
                if next != NIL && self.arena[next].key < key {
                    cur = next;
                } else {
                    break;
                }
            }
        }
        depth
    }

    /// Verify ordering and level monotonicity (test helper).
    pub fn check(&self) -> Result<(), String> {
        let mut cur = self.head[0];
        let mut last: Option<Key> = None;
        let mut n = 0;
        while cur != NIL {
            let k = self.arena[cur].key;
            if let Some(l) = last {
                if k < l {
                    return Err(format!("order violation at dst {}", k.dst));
                }
            }
            last = Some(k);
            cur = self.arena[cur].next[0];
            n += 1;
            if n > self.len {
                return Err("cycle".to_string());
            }
        }
        if n != self.len {
            return Err(format!("len {} but saw {n}", self.len));
        }
        Ok(())
    }
}

struct SkipNodeState {
    total: u64,
    counts: HashMap<u64, u64>,
    list: SkipList,
}

/// Markov chain over per-node skip-lists (see module docs).
pub struct SkipListChain {
    nodes: RwLock<HashMap<u64, RwLock<SkipNodeState>>>,
    edges: AtomicUsize,
}

impl Default for SkipListChain {
    fn default() -> Self {
        Self::new()
    }
}

impl SkipListChain {
    pub fn new() -> Self {
        SkipListChain { nodes: RwLock::new(HashMap::new()), edges: AtomicUsize::new(0) }
    }

    fn with_node<R>(&self, src: u64, f: impl FnOnce(&mut SkipNodeState) -> R) -> Option<R> {
        let map = self.nodes.read().unwrap();
        map.get(&src).map(|n| f(&mut n.write().unwrap()))
    }
}

impl MarkovModel for SkipListChain {
    fn name(&self) -> &'static str {
        "skiplist"
    }

    fn observe(&self, src: u64, dst: u64) {
        // Fast path: node exists.
        let updated = self.with_node(src, |state| {
            let old = state.counts.get(&dst).copied();
            match old {
                Some(c) => {
                    // Pop-insert: the scheme the paper's swap avoids.
                    state.list.remove(c, dst);
                    state.list.insert(c + 1, dst);
                    state.counts.insert(dst, c + 1);
                }
                None => {
                    state.counts.insert(dst, 1);
                    state.list.insert(1, dst);
                    self.edges.fetch_add(1, Ordering::Relaxed);
                }
            }
            state.total += 1;
        });
        if updated.is_none() {
            // Slow path: create the node, then retry.
            {
                let mut map = self.nodes.write().unwrap();
                map.entry(src).or_insert_with(|| {
                    RwLock::new(SkipNodeState {
                        total: 0,
                        counts: HashMap::new(),
                        list: SkipList::new(),
                    })
                });
            }
            self.observe(src, dst);
        }
    }

    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        self.with_node(src, |state| {
            let mut sorted = Vec::new();
            state.list.scan(|d, c| {
                sorted.push((d, c));
                true
            });
            recommend_threshold(&sorted, state.total, threshold)
        })
        .unwrap_or_else(|| recommend_threshold(&[], 0, threshold))
    }

    fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        self.with_node(src, |state| {
            let mut sorted = Vec::new();
            state.list.scan(|d, c| {
                sorted.push((d, c));
                sorted.len() < k
            });
            recommend_topk(&sorted, state.total, k)
        })
        .unwrap_or_else(|| recommend_topk(&[], 0, k))
    }

    fn decay(&self) -> (u64, usize) {
        let map = self.nodes.read().unwrap();
        let mut total = 0;
        let mut pruned = 0;
        for node in map.values() {
            let mut state = node.write().unwrap();
            let old: Vec<(u64, u64)> = state.counts.iter().map(|(&d, &c)| (d, c)).collect();
            for (dst, c) in old {
                state.list.remove(c, dst);
                let nc = c / 2;
                if nc == 0 {
                    state.counts.remove(&dst);
                    pruned += 1;
                } else {
                    state.counts.insert(dst, nc);
                    state.list.insert(nc, dst);
                }
            }
            state.total = state.counts.values().sum();
            total += state.total;
        }
        self.edges.fetch_sub(pruned, Ordering::Relaxed);
        (total, pruned)
    }

    fn edge_count(&self) -> usize {
        self.edges.load(Ordering::Relaxed)
    }
}
