//! Baseline tests: each implementation individually, plus differential
//! tests proving all models agree with MCPrioQ on deterministic workloads.

use super::*;
use crate::chain::{ChainConfig, McPrioQ};
use crate::testutil::Rng64;
use std::sync::Arc;

fn all_models() -> Vec<Box<dyn MarkovModel>> {
    vec![
        Box::new(McPrioQ::new(ChainConfig::default())),
        Box::new(MutexChain::new()),
        Box::new(ShardedChain::new(8)),
        Box::new(SkipListChain::new()),
        Box::new(HeapChain::new()),
    ]
}

#[test]
fn skiplist_insert_scan_ordered() {
    let mut sl = SkipList::new();
    for (c, d) in [(5u64, 1u64), (9, 2), (1, 3), (7, 4), (5, 5)] {
        sl.insert(c, d);
    }
    sl.check().unwrap();
    let mut out = Vec::new();
    sl.scan(|d, c| {
        out.push((d, c));
        true
    });
    assert_eq!(out, vec![(2, 9), (4, 7), (1, 5), (5, 5), (3, 1)]);
}

#[test]
fn skiplist_remove() {
    let mut sl = SkipList::new();
    for i in 0..100u64 {
        sl.insert(i % 10, i);
    }
    sl.check().unwrap();
    assert!(sl.remove(5, 5));
    assert!(!sl.remove(5, 5));
    assert!(!sl.remove(99, 99));
    assert_eq!(sl.len(), 99);
    sl.check().unwrap();
    // Remove everything.
    for i in 0..100u64 {
        if i != 5 {
            assert!(sl.remove(i % 10, i), "missing ({}, {i})", i % 10);
        }
    }
    assert!(sl.is_empty());
    sl.check().unwrap();
}

#[test]
fn skiplist_pop_insert_updates() {
    let mut sl = SkipList::new();
    sl.insert(1, 7);
    sl.insert(3, 8);
    // Bump 7's count 1 -> 4 (pop-insert).
    assert!(sl.remove(1, 7));
    sl.insert(4, 7);
    let mut out = Vec::new();
    sl.scan(|d, _| {
        out.push(d);
        true
    });
    assert_eq!(out, vec![7, 8]);
    sl.check().unwrap();
}

#[test]
fn skiplist_search_depth_sublinear() {
    let mut sl = SkipList::new();
    let mut rng = Rng64::new(2);
    for d in 0..4096u64 {
        sl.insert(rng.next_below(1000), d);
    }
    // Search depth should be far below n (O(log n) expected ~ tens).
    let depth = sl.search_depth(500, 2048);
    assert!(depth < 400, "depth {depth} not sublinear for n=4096");
    sl.check().unwrap();
}

#[test]
fn skiplist_reuses_freed_arena_slots() {
    let mut sl = SkipList::new();
    for d in 0..64u64 {
        sl.insert(d, d);
    }
    for d in 0..64u64 {
        sl.remove(d, d);
    }
    let arena_after_fill = 64;
    for d in 0..64u64 {
        sl.insert(d, d);
    }
    sl.check().unwrap();
    assert_eq!(sl.len(), arena_after_fill);
}

/// Differential: every baseline must agree with MCPrioQ (same items, same
/// probabilities) on a deterministic single-threaded workload.
#[test]
fn all_models_agree_with_mcprioq() {
    let models = all_models();
    let mut rng = Rng64::new(0xD1FF);
    let transitions: Vec<(u64, u64)> = (0..5_000)
        .map(|_| {
            let src = rng.next_below(6);
            let u = rng.next_f64();
            (src, ((u * u) * 40.0) as u64)
        })
        .collect();
    for m in &models {
        for &(s, d) in &transitions {
            m.observe(s, d);
        }
    }
    let reference = &models[0];
    for m in &models[1..] {
        assert_eq!(m.edge_count(), reference.edge_count(), "{}", m.name());
        for src in 0..6u64 {
            for t in [0.5, 0.9, 1.0] {
                let a = reference.infer_threshold(src, t);
                let b = m.infer_threshold(src, t);
                assert_eq!(a.total, b.total, "{} src {src} t {t}", m.name());
                assert_eq!(a.items.len(), b.items.len(), "{} src {src} t {t}", m.name());
                assert!(
                    (a.cumulative - b.cumulative).abs() < 1e-9,
                    "{} src {src} t {t}: {} vs {}",
                    m.name(),
                    a.cumulative,
                    b.cumulative
                );
            }
            let a = reference.infer_topk(src, 5);
            let b = m.infer_topk(src, 5);
            // Same probability multiset (tie order may differ between
            // arrival-stable MCPrioQ and dst-ordered baselines).
            let mut pa: Vec<u64> = a.items.iter().map(|&(_, p)| (p * 1e12) as u64).collect();
            let mut pb: Vec<u64> = b.items.iter().map(|&(_, p)| (p * 1e12) as u64).collect();
            pa.sort_unstable();
            pb.sort_unstable();
            assert_eq!(pa, pb, "{} src {src} topk", m.name());
        }
    }
}

/// Differential including decay cycles.
#[test]
fn all_models_agree_after_decay() {
    let models = all_models();
    let mut rng = Rng64::new(77);
    for round in 0..4 {
        for _ in 0..2_000 {
            let src = rng.next_below(4);
            let u = rng.next_f64();
            let dst = ((u * u) * 30.0) as u64;
            for m in &models {
                m.observe(src, dst);
            }
        }
        let results: Vec<(u64, usize)> = models.iter().map(|m| m.decay()).collect();
        for (m, r) in models.iter().zip(&results) {
            assert_eq!(*r, results[0], "{} decay disagrees at round {round}", m.name());
        }
    }
    for m in &models[1..] {
        assert_eq!(m.edge_count(), models[0].edge_count(), "{}", m.name());
    }
}

/// All baselines must be safe under concurrent use (the locked ones via
/// their locks): smoke stress.
#[test]
fn baselines_concurrent_smoke() {
    for model in all_models() {
        let m: Arc<dyn MarkovModel> = Arc::from(model);
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    let mut rng = Rng64::new(t);
                    for _ in 0..5_000 {
                        let src = rng.next_below(8);
                        if rng.next_bool(0.8) {
                            m.observe(src, rng.next_below(64));
                        } else {
                            let r = m.infer_threshold(src, 0.9);
                            // Lock-free readers racing writers may see a
                            // transiently inconsistent count/total ratio
                            // (approximately correct); only well-formedness
                            // is guaranteed mid-storm.
                            assert!(r.cumulative.is_finite() && r.cumulative >= 0.0);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(m.edge_count() > 0, "{}", m.name());
    }
}

#[test]
fn unknown_src_empty_everywhere() {
    for m in all_models() {
        let r = m.infer_threshold(999, 0.9);
        assert!(r.items.is_empty(), "{}", m.name());
        let r = m.infer_topk(999, 3);
        assert!(r.items.is_empty(), "{}", m.name());
    }
}

#[test]
fn helper_threshold_handles_edges() {
    let r = recommend_threshold(&[], 0, 0.9);
    assert_eq!(r.total, 0);
    let r = recommend_threshold(&[(1, 10)], 10, 0.0);
    assert!(r.items.is_empty());
    let r = recommend_topk(&[(1, 10)], 10, 0);
    assert!(r.items.is_empty());
}
