//! Heap-style baseline: O(1) counter updates into a hash map, full
//! re-sort at (dirty) inference time — §II.2's observation that heaps are
//! "optimized for fast insert and finding the top most important element",
//! not for cumulative-probability scans.

use std::collections::HashMap;
use std::sync::RwLock;

use super::{recommend_threshold, recommend_topk, MarkovModel};
use crate::chain::Recommendation;
use crate::sync::shim::{AtomicUsize, Ordering};

#[derive(Default)]
struct HeapNode {
    total: u64,
    counts: HashMap<u64, u64>,
    sorted: Vec<(u64, u64)>,
    dirty: bool,
}

impl HeapNode {
    fn rebuild(&mut self) {
        if self.dirty {
            // The "pay at query" step: O(E log E) sort of the whole edge set.
            self.sorted = self.counts.iter().map(|(&d, &c)| (d, c)).collect();
            self.sorted.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            self.dirty = false;
        }
    }
}

/// See module docs.
pub struct HeapChain {
    nodes: RwLock<HashMap<u64, RwLock<HeapNode>>>,
    edges: AtomicUsize,
}

impl Default for HeapChain {
    fn default() -> Self {
        Self::new()
    }
}

impl HeapChain {
    pub fn new() -> Self {
        HeapChain { nodes: RwLock::new(HashMap::new()), edges: AtomicUsize::new(0) }
    }

    fn with_node<R>(&self, src: u64, f: impl FnOnce(&mut HeapNode) -> R) -> Option<R> {
        let map = self.nodes.read().unwrap();
        map.get(&src).map(|n| f(&mut n.write().unwrap()))
    }
}

impl MarkovModel for HeapChain {
    fn name(&self) -> &'static str {
        "heap-lazy"
    }

    fn observe(&self, src: u64, dst: u64) {
        let done = self.with_node(src, |node| {
            let is_new = !node.counts.contains_key(&dst);
            *node.counts.entry(dst).or_insert(0) += 1;
            node.total += 1;
            node.dirty = true;
            if is_new {
                self.edges.fetch_add(1, Ordering::Relaxed);
            }
        });
        if done.is_none() {
            self.nodes.write().unwrap().entry(src).or_default();
            self.observe(src, dst);
        }
    }

    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        self.with_node(src, |node| {
            node.rebuild();
            recommend_threshold(&node.sorted, node.total, threshold)
        })
        .unwrap_or_else(|| recommend_threshold(&[], 0, threshold))
    }

    fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        self.with_node(src, |node| {
            node.rebuild();
            recommend_topk(&node.sorted, node.total, k)
        })
        .unwrap_or_else(|| recommend_topk(&[], 0, k))
    }

    fn decay(&self) -> (u64, usize) {
        let map = self.nodes.read().unwrap();
        let mut total = 0;
        let mut pruned = 0;
        for node in map.values() {
            let mut n = node.write().unwrap();
            let before = n.counts.len();
            n.counts.retain(|_, c| {
                *c /= 2;
                *c > 0
            });
            pruned += before - n.counts.len();
            n.total = n.counts.values().sum();
            n.dirty = true;
            total += n.total;
        }
        self.edges.fetch_sub(pruned, Ordering::Relaxed);
        (total, pruned)
    }

    fn edge_count(&self) -> usize {
        self.edges.load(Ordering::Relaxed)
    }
}
