//! Lock-based baselines: the textbook coarse-mutex chain and the sharded
//! reader-writer-lock chain.

use std::collections::HashMap;
use std::sync::{Mutex, RwLock};

use super::{recommend_threshold, recommend_topk, MarkovModel};
use crate::chain::Recommendation;
use crate::sync::shim::{AtomicUsize, Ordering};

/// Per-node state used by both locked baselines: counts map + a sorted view
/// rebuilt lazily (dirty flag) so inference matches MCPrioQ's head-first
/// scan order.
#[derive(Default)]
struct NodeEntry {
    total: u64,
    counts: HashMap<u64, u64>,
    /// Descending (count, dst); rebuilt when dirty.
    sorted: Vec<(u64, u64)>,
    dirty: bool,
}

impl NodeEntry {
    fn observe(&mut self, dst: u64) {
        *self.counts.entry(dst).or_insert(0) += 1;
        self.total += 1;
        self.dirty = true;
    }

    fn rebuild(&mut self) {
        if self.dirty {
            self.sorted = self.counts.iter().map(|(&d, &c)| (d, c)).collect();
            self.sorted.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            self.dirty = false;
        }
    }

    fn decay(&mut self) -> (u64, usize) {
        let before = self.counts.len();
        self.counts.retain(|_, c| {
            *c /= 2;
            *c > 0
        });
        self.total = self.counts.values().sum();
        self.dirty = true;
        (self.total, before - self.counts.len())
    }
}

/// Coarse-grained baseline: one global mutex around everything. O(1)-ish
/// single-threaded; collapses under concurrency (E1/E3's lower bound).
pub struct MutexChain {
    inner: Mutex<HashMap<u64, NodeEntry>>,
    edges: AtomicUsize,
}

impl Default for MutexChain {
    fn default() -> Self {
        Self::new()
    }
}

impl MutexChain {
    pub fn new() -> Self {
        MutexChain { inner: Mutex::new(HashMap::new()), edges: AtomicUsize::new(0) }
    }
}

impl MarkovModel for MutexChain {
    fn name(&self) -> &'static str {
        "mutex"
    }

    fn observe(&self, src: u64, dst: u64) {
        let mut g = self.inner.lock().unwrap();
        let node = g.entry(src).or_default();
        let before = node.counts.len();
        node.observe(dst);
        if node.counts.len() > before {
            self.edges.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        let mut g = self.inner.lock().unwrap();
        match g.get_mut(&src) {
            Some(node) => {
                node.rebuild();
                recommend_threshold(&node.sorted, node.total, threshold)
            }
            None => recommend_threshold(&[], 0, threshold),
        }
    }

    fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        let mut g = self.inner.lock().unwrap();
        match g.get_mut(&src) {
            Some(node) => {
                node.rebuild();
                recommend_topk(&node.sorted, node.total, k)
            }
            None => recommend_topk(&[], 0, k),
        }
    }

    fn decay(&self) -> (u64, usize) {
        let mut g = self.inner.lock().unwrap();
        let mut total = 0;
        let mut pruned = 0;
        for node in g.values_mut() {
            let (t, p) = node.decay();
            total += t;
            pruned += p;
        }
        self.edges.fetch_sub(pruned, Ordering::Relaxed);
        (total, pruned)
    }

    fn edge_count(&self) -> usize {
        self.edges.load(Ordering::Relaxed)
    }
}

/// Sharded baseline: `RwLock<HashMap>` per shard — the "industry default"
/// answer to MutexChain. Readers scale until a writer appears in their
/// shard; updates serialize per shard.
pub struct ShardedChain {
    shards: Vec<RwLock<HashMap<u64, NodeEntry>>>,
    edges: AtomicUsize,
}

impl ShardedChain {
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0);
        ShardedChain {
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
            edges: AtomicUsize::new(0),
        }
    }

    fn shard(&self, src: u64) -> &RwLock<HashMap<u64, NodeEntry>> {
        let h = src.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        &self.shards[h as usize % self.shards.len()]
    }
}

impl MarkovModel for ShardedChain {
    fn name(&self) -> &'static str {
        "sharded-rwlock"
    }

    fn observe(&self, src: u64, dst: u64) {
        let mut g = self.shard(src).write().unwrap();
        let node = g.entry(src).or_default();
        let before = node.counts.len();
        node.observe(dst);
        if node.counts.len() > before {
            self.edges.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        // Write lock: inference may rebuild the sorted view.
        let mut g = self.shard(src).write().unwrap();
        match g.get_mut(&src) {
            Some(node) => {
                node.rebuild();
                recommend_threshold(&node.sorted, node.total, threshold)
            }
            None => recommend_threshold(&[], 0, threshold),
        }
    }

    fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        let mut g = self.shard(src).write().unwrap();
        match g.get_mut(&src) {
            Some(node) => {
                node.rebuild();
                recommend_topk(&node.sorted, node.total, k)
            }
            None => recommend_topk(&[], 0, k),
        }
    }

    fn decay(&self) -> (u64, usize) {
        let mut total = 0;
        let mut pruned = 0;
        for shard in &self.shards {
            let mut g = shard.write().unwrap();
            for node in g.values_mut() {
                let (t, p) = node.decay();
                total += t;
                pruned += p;
            }
        }
        self.edges.fetch_sub(pruned, Ordering::Relaxed);
        (total, pruned)
    }

    fn edge_count(&self) -> usize {
        self.edges.load(Ordering::Relaxed)
    }
}
