//! # mcprioq — lock-free online sparse markov-chains
//!
//! Reproduction of *"MCPrioQ: A lock-free algorithm for online sparse
//! markov-chains"* (Derehag & Johansson, 2023). See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for the measured reproduction of every claim.

// Unsafe-audit gate (DESIGN.md § Concurrency verification): the body of an
// `unsafe fn` gets no blanket license — every unsafe operation must sit in
// an explicit `unsafe {}` block with its own `// SAFETY:` justification,
// which `tools/unsafe_audit.py` enforces in CI.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod audit;
pub mod baselines;
pub mod bench_harness;
pub mod chain;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod hashtable;
pub mod metrics;
pub mod persist;
pub mod prioq;
pub mod rcu;
pub mod replicate;
pub mod runtime;
pub mod sync;
pub mod testutil;
pub mod workload;
