//! Bounded MPMC queue (Mutex + Condvar) for update ingestion.
//!
//! Deliberately *not* lock-free: ingestion sits between the network and
//! the chain, where backpressure — blocking producers when consumers lag —
//! is the desired behaviour. The lock-free guarantees the paper cares
//! about apply to the *data structure* operations, which happen on the
//! consumer side of this queue (or bypass it entirely via
//! `Engine::observe_direct`).
//!
//! The engine instantiates one of these *per shard* (batch-first refactor):
//! producers route by shard hash, each consumer drains only its own shards,
//! so the queue lock is contended by `producers + 1` threads instead of
//! every ingest worker in the process. Bulk transfer happens through
//! [`BoundedQueue::push_bulk`] / [`BoundedQueue::try_pop_batch`] — one lock
//! acquisition per batch, not per item.
//!
//! Locking is *non-poisoning*: a worker that panics while holding the lock
//! must not wedge every other thread sharing the queue (see
//! [`BoundedQueue::locked`]).

use std::collections::VecDeque;
use std::sync::{Arc, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use crate::metrics::Histogram;
use crate::sync::shim::{Condvar, Mutex, MutexGuard};

struct State<T> {
    items: VecDeque<T>,
    /// Enqueue cohorts for queue-wait timing: `(items, enqueued_at)` per
    /// push, FIFO like `items`. Only maintained while a wait histogram is
    /// attached — the telemetry-off hot path never stamps a clock.
    cohorts: VecDeque<(usize, Instant)>,
    closed: bool,
}

pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    /// Queue-wait histogram (ns), attached once post-construction by the
    /// engine's telemetry registry. Each batch pop records the age of the
    /// oldest cohort it consumed — one sample per drain, not per item.
    wait_hist: OnceLock<Arc<Histogram>>,
}

impl<T> BoundedQueue<T> {
    /// Non-poisoning lock. An ingest worker that panics mid-batch poisons
    /// this mutex for every producer and its sibling consumers; the queue
    /// state itself is always valid (each critical section completes its
    /// `VecDeque` edits before any call that could panic), so recovering
    /// the guard keeps the rest of the ingest plane alive instead of
    /// cascading `PoisonError` panics through every thread that shares
    /// the queue.
    fn locked(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Non-poisoning condvar wait (same rationale as [`Self::locked`]).
    fn wait<'a>(
        &self,
        cvar: &Condvar,
        guard: MutexGuard<'a, State<T>>,
    ) -> MutexGuard<'a, State<T>> {
        cvar.wait(guard).unwrap_or_else(PoisonError::into_inner)
    }

    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                cohorts: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
            wait_hist: OnceLock::new(),
        }
    }

    /// Attach the queue-wait histogram (idempotent; first caller wins).
    /// Until this is called, pushes and pops skip cohort bookkeeping
    /// entirely.
    pub fn set_wait_histogram(&self, hist: Arc<Histogram>) {
        let _ = self.wait_hist.set(hist);
    }

    /// Stamp an enqueue cohort of `n` items (under the state lock).
    fn stamp(&self, s: &mut State<T>, n: usize) {
        if n > 0 && self.wait_hist.get().is_some() {
            s.cohorts.push_back((n, Instant::now()));
        }
    }

    /// Consume `n` popped items from the cohort FIFO and record the age of
    /// the oldest consumed cohort — the queue-wait of the batch's head,
    /// which is the latency bound the drain loop is accountable for.
    fn note_popped(&self, s: &mut State<T>, n: usize) {
        let Some(hist) = self.wait_hist.get() else { return };
        let mut remaining = n;
        let mut oldest: Option<Instant> = None;
        while remaining > 0 {
            // `break`, not unwrap: items pushed before the histogram was
            // attached have no cohort stamp.
            let Some(front) = s.cohorts.front_mut() else { break };
            if oldest.is_none() {
                oldest = Some(front.1);
            }
            if front.0 <= remaining {
                remaining -= front.0;
                s.cohorts.pop_front();
            } else {
                front.0 -= remaining;
                remaining = 0;
            }
        }
        if let Some(t) = oldest {
            hist.record(t.elapsed().as_nanos() as u64);
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.locked().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut s = self.locked();
        loop {
            if s.closed {
                return false;
            }
            if s.items.len() < self.capacity {
                s.items.push_back(item);
                self.stamp(&mut s, 1);
                self.not_empty.notify_one();
                return true;
            }
            s = self.wait(&self.not_full, s);
        }
    }

    /// Non-blocking push; `Err(item)` when full or closed (caller applies
    /// backpressure policy: drop, retry, or surface an error).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut s = self.locked();
        if s.closed || s.items.len() >= self.capacity {
            return Err(item);
        }
        s.items.push_back(item);
        self.stamp(&mut s, 1);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.locked();
        loop {
            if let Some(item) = s.items.pop_front() {
                self.note_popped(&mut s, 1);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.wait(&self.not_empty, s);
        }
    }

    /// Pop up to `max` items in one lock acquisition (batch drain).
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        let mut s = self.locked();
        loop {
            if !s.items.is_empty() {
                let take = s.items.len().min(max);
                let out: Vec<T> = s.items.drain(..take).collect();
                self.note_popped(&mut s, take);
                self.not_full.notify_all();
                return out;
            }
            if s.closed {
                return Vec::new();
            }
            s = self.wait(&self.not_empty, s);
        }
    }

    /// Blocking bulk push: enqueue every item in order, waiting for space
    /// as needed (one lock acquisition per free-capacity window instead of
    /// one per item). Returns the number of items actually enqueued — short
    /// only if the queue is closed mid-push.
    pub fn push_bulk(&self, items: Vec<T>) -> usize {
        let mut pushed = 0;
        let mut it = items.into_iter();
        let mut pending = it.next();
        let mut s = self.locked();
        loop {
            if s.closed {
                return pushed;
            }
            // One cohort stamp per capacity window, not per item.
            let before = pushed;
            while s.items.len() < self.capacity {
                match pending.take() {
                    Some(x) => {
                        s.items.push_back(x);
                        pushed += 1;
                        pending = it.next();
                    }
                    None => {
                        self.stamp(&mut s, pushed - before);
                        self.not_empty.notify_all();
                        return pushed;
                    }
                }
            }
            self.stamp(&mut s, pushed - before);
            self.not_empty.notify_all();
            s = self.wait(&self.not_full, s);
        }
    }

    /// Non-blocking bulk push: enqueue items in order until the queue is
    /// full, then *shed the remainder* instead of waiting. Returns the
    /// number accepted (0 when closed). One lock acquisition for the
    /// whole batch — the load-shedding counterpart of
    /// [`BoundedQueue::push_bulk`], used by the server when admission
    /// control decides overload must answer `ERR overload` rather than
    /// stall the accept loop.
    pub fn try_push_bulk(&self, items: Vec<T>) -> usize {
        let mut s = self.locked();
        if s.closed {
            return 0;
        }
        let room = self.capacity.saturating_sub(s.items.len());
        let take = room.min(items.len());
        let mut it = items.into_iter();
        for _ in 0..take {
            // `take <= items.len()`, so next() cannot be None here.
            if let Some(x) = it.next() {
                s.items.push_back(x);
            }
        }
        if take > 0 {
            self.stamp(&mut s, take);
            self.not_empty.notify_all();
        }
        take
    }

    /// Non-blocking batch pop: up to `max` items, possibly empty.
    pub fn try_pop_batch(&self, max: usize) -> Vec<T> {
        let mut s = self.locked();
        if s.items.is_empty() {
            return Vec::new();
        }
        let take = s.items.len().min(max);
        let out: Vec<T> = s.items.drain(..take).collect();
        self.note_popped(&mut s, take);
        self.not_full.notify_all();
        out
    }

    /// Batch pop that waits up to `timeout` for items. Returns an empty vec
    /// on timeout or once the queue is closed *and* drained — callers that
    /// own several queues use this to park without missing a close.
    pub fn pop_batch_timeout(&self, max: usize, timeout: Duration) -> Vec<T> {
        let deadline = Instant::now() + timeout;
        let mut s = self.locked();
        loop {
            if !s.items.is_empty() {
                let take = s.items.len().min(max);
                let out: Vec<T> = s.items.drain(..take).collect();
                self.note_popped(&mut s, take);
                self.not_full.notify_all();
                return out;
            }
            if s.closed {
                return Vec::new();
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(s, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            s = guard;
        }
    }

    /// Panic a helper thread while it holds the state lock — simulating a
    /// worker that dies mid-critical-section. Tests use this to prove the
    /// non-poisoning [`Self::locked`] recovery keeps every other producer
    /// and consumer alive instead of cascading `PoisonError` panics.
    #[cfg(test)]
    pub(crate) fn poison_for_test(self: &std::sync::Arc<Self>)
    where
        T: Send + 'static,
    {
        let q = std::sync::Arc::clone(self);
        let t = std::thread::spawn(move || {
            let _guard = q.state.lock().unwrap();
            panic!("simulated worker panic while holding the queue lock");
        });
        assert!(t.join().is_err(), "the helper must have panicked");
    }

    /// Close the queue: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        let mut s = self.locked();
        s.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.locked().closed
    }
}
