//! Bounded MPMC queue (Mutex + Condvar) for update ingestion.
//!
//! Deliberately *not* lock-free: ingestion sits between the network and
//! the chain, where backpressure — blocking producers when consumers lag —
//! is the desired behaviour. The lock-free guarantees the paper cares
//! about apply to the *data structure* operations, which happen on the
//! consumer side of this queue (or bypass it entirely via
//! `Engine::observe_direct`).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.closed {
                return false;
            }
            if s.items.len() < self.capacity {
                s.items.push_back(item);
                self.not_empty.notify_one();
                return true;
            }
            s = self.not_full.wait(s).unwrap();
        }
    }

    /// Non-blocking push; `Err(item)` when full or closed (caller applies
    /// backpressure policy: drop, retry, or surface an error).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.items.len() >= self.capacity {
            return Err(item);
        }
        s.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Pop up to `max` items in one lock acquisition (batch drain).
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.items.is_empty() {
                let take = s.items.len().min(max);
                let out: Vec<T> = s.items.drain(..take).collect();
                self.not_full.notify_all();
                return out;
            }
            if s.closed {
                return Vec::new();
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Close the queue: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}
