//! Engine health state machine (DESIGN.md §8).
//!
//! The ladder has three rungs:
//!
//! * `Healthy` — normal operation, all verbs accepted.
//! * `DegradedReadOnly` — some shard's WAL writer is quarantined after an
//!   I/O fault. Reads keep being served from the in-memory RCU
//!   structures; writes are rejected at the wire with
//!   `ERR degraded reason=… retry_after_ms=…` instead of being acked
//!   into a log that cannot persist them.
//! * `Recovering` — the background WAL-retry task is mid-heal: it is
//!   re-appending parked records and re-probing fsync. Writes are still
//!   rejected (the parked backlog must drain first so WAL order stays an
//!   exact prefix of apply order).
//!
//! Transitions: `degrade()` moves to `DegradedReadOnly` from anywhere;
//! `begin_recovery()` moves `DegradedReadOnly → Recovering`; `healed()`
//! moves to `Healthy` and banks the outage duration. A fault that fires
//! *during* recovery simply calls `degrade()` again — the ladder never
//! panics and never deadlocks, it just changes what the wire says.

use std::sync::{Mutex, PoisonError};
use std::time::Instant;

use crate::metrics::events::{self, Level};
use crate::metrics::Counter;
use crate::sync::shim::{AtomicU64, AtomicU8, Ordering};

/// The three rungs of the degradation ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    DegradedReadOnly,
    Recovering,
}

impl Health {
    /// Wire spelling (the `HEALTH` verb and the `health=` STATS gauge).
    pub fn as_str(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::DegradedReadOnly => "degraded",
            Health::Recovering => "recovering",
        }
    }
}

fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared, lock-light health record: the hot paths (dispatch gating,
/// STATS) read one atomic; the mutexed fields are touched only on
/// transitions and when rendering the reason string.
#[derive(Debug)]
pub(crate) struct HealthState {
    /// Encoded [`Health`]: 0 = healthy, 1 = degraded, 2 = recovering.
    state: AtomicU8,
    /// Why the engine left `Healthy` (empty when healthy).
    reason: Mutex<String>,
    /// When the current outage began (`None` when healthy).
    since: Mutex<Option<Instant>>,
    /// Outage time banked by previous heals, in nanoseconds.
    degraded_ns: AtomicU64,
    /// Hint handed to rejected writers: how long until the retry task
    /// probes the fault again. Updated by the retry task each backoff.
    retry_after_ms: AtomicU64,
    /// Heal attempts by the background WAL-retry task (the `wal_retry=`
    /// STATS gauge; grows while a fault persists).
    pub(crate) wal_retry: Counter,
}

impl HealthState {
    pub(crate) fn new() -> HealthState {
        HealthState {
            state: AtomicU8::new(0),
            reason: Mutex::new(String::new()),
            since: Mutex::new(None),
            degraded_ns: AtomicU64::new(0),
            retry_after_ms: AtomicU64::new(500),
            wal_retry: Counter::new(),
        }
    }

    pub(crate) fn health(&self) -> Health {
        match self.state.load(Ordering::Acquire) {
            0 => Health::Healthy,
            1 => Health::DegradedReadOnly,
            _ => Health::Recovering,
        }
    }

    pub(crate) fn reason(&self) -> String {
        lock_clean(&self.reason).clone()
    }

    /// Enter (or re-enter) `DegradedReadOnly`. The first reason of an
    /// outage wins — later faults during the same outage don't churn the
    /// message clients see.
    pub(crate) fn degrade(&self, why: &str) {
        {
            let mut since = lock_clean(&self.since);
            if since.is_none() {
                *since = Some(Instant::now());
                let mut reason = lock_clean(&self.reason);
                reason.clear();
                reason.push_str(why);
                // One event per outage, matching the sticky first reason.
                events::emit(Level::Error, "health", "degraded", 0, 0);
            }
        }
        self.state.store(1, Ordering::Release);
    }

    /// `DegradedReadOnly → Recovering` (no-op from any other rung, so a
    /// racing `degrade()` is never overwritten by a stale heal attempt).
    pub(crate) fn begin_recovery(&self) {
        if self.state.compare_exchange(1, 2, Ordering::AcqRel, Ordering::Acquire).is_ok() {
            events::emit(Level::Warn, "health", "recovering", 0, 0);
        }
    }

    /// Back to `Healthy`: clears the reason and banks the outage time.
    pub(crate) fn healed(&self) {
        {
            let mut since = lock_clean(&self.since);
            if let Some(t) = since.take() {
                let ns = t.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                self.degraded_ns.fetch_add(ns, Ordering::Relaxed);
                events::emit(Level::Info, "health", "healed", ns / 1_000_000, 0);
            }
            lock_clean(&self.reason).clear();
        }
        self.state.store(0, Ordering::Release);
    }

    /// Total time spent off the `Healthy` rung, including the current
    /// outage if one is in progress (whole seconds).
    pub(crate) fn degraded_seconds(&self) -> u64 {
        let banked = self.degraded_ns.load(Ordering::Relaxed);
        let live = lock_clean(&self.since)
            .map(|t| t.elapsed().as_nanos().min(u64::MAX as u128) as u64)
            .unwrap_or(0);
        (banked.saturating_add(live)) / 1_000_000_000
    }

    pub(crate) fn set_retry_after_ms(&self, ms: u64) {
        self.retry_after_ms.store(ms.max(1), Ordering::Relaxed);
    }

    pub(crate) fn retry_after_ms(&self) -> u64 {
        self.retry_after_ms.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_transitions() {
        let h = HealthState::new();
        assert_eq!(h.health(), Health::Healthy);
        assert_eq!(h.health().as_str(), "healthy");
        h.degrade("wal append on shard 0: injected ENOSPC");
        assert_eq!(h.health(), Health::DegradedReadOnly);
        assert_eq!(h.reason(), "wal append on shard 0: injected ENOSPC");
        // Later faults in the same outage keep the first reason.
        h.degrade("something else");
        assert_eq!(h.reason(), "wal append on shard 0: injected ENOSPC");
        h.begin_recovery();
        assert_eq!(h.health(), Health::Recovering);
        // A fault mid-recovery drops back to degraded…
        h.degrade("still failing");
        assert_eq!(h.health(), Health::DegradedReadOnly);
        // …and begin_recovery from healthy is a no-op.
        h.healed();
        assert_eq!(h.health(), Health::Healthy);
        assert_eq!(h.reason(), "");
        h.begin_recovery();
        assert_eq!(h.health(), Health::Healthy);
    }

    #[test]
    fn degraded_seconds_accumulates() {
        let h = HealthState::new();
        assert_eq!(h.degraded_seconds(), 0);
        h.degrade("x");
        // Sub-second outage rounds down to 0 but must not panic/underflow.
        h.healed();
        assert_eq!(h.degraded_seconds(), 0);
    }

    #[test]
    fn retry_after_hint() {
        let h = HealthState::new();
        assert_eq!(h.retry_after_ms(), 500);
        h.set_retry_after_ms(2_000);
        assert_eq!(h.retry_after_ms(), 2_000);
        h.set_retry_after_ms(0);
        assert_eq!(h.retry_after_ms(), 1);
    }
}
