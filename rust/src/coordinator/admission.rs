//! Ingress admission control: a per-connection token bucket.
//!
//! The server creates one [`TokenBucket`] per accepted connection from
//! the `[server] rate_limit_ops` / `rate_limit_burst` knobs (0 = off,
//! the default — admission is opt-in). Each write-side verb spends
//! tokens proportional to its work (`OBSERVEB` costs its pair count,
//! not 1), so a client cannot dodge the limit by batching. An exhausted
//! bucket answers `ERR ratelimited retry_after_ms=…` — the connection
//! stays open and reads are never charged, so a throttled feeder can
//! still watch `STATS`/`HEALTH` to pace itself.
//!
//! The bucket is deliberately connection-local state owned by one
//! handler thread: refill is computed lazily from elapsed time on each
//! `admit`, so there is no shared clock, no background task, and no
//! atomic traffic on the hot path.

use std::time::Instant;

/// Lazy-refill token bucket (tokens are ops; fractional refill carries).
#[derive(Debug)]
pub(crate) struct TokenBucket {
    /// Sustained refill rate, ops/sec (`rate_limit_ops`).
    rate: f64,
    /// Bucket capacity (`rate_limit_burst`).
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// `rate == 0` disables limiting (every `admit` succeeds); a zero
    /// burst with a nonzero rate falls back to one second of rate so a
    /// misconfigured bucket still passes traffic.
    pub(crate) fn new(rate: u64, burst: u64) -> TokenBucket {
        let rate = rate as f64;
        let burst = if burst == 0 { rate } else { burst as f64 };
        TokenBucket { rate, burst, tokens: burst, last: Instant::now() }
    }

    /// Spend `cost` tokens. `Ok(())` admits; `Err(retry_after_ms)` tells
    /// the client when enough tokens will have refilled. A cost larger
    /// than the whole bucket is clamped to the bucket (it admits once
    /// the bucket is full, rather than never).
    pub(crate) fn admit(&mut self, cost: u64) -> Result<(), u64> {
        if self.rate <= 0.0 {
            return Ok(());
        }
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        let cost = (cost as f64).min(self.burst);
        if self.tokens >= cost {
            self.tokens -= cost;
            return Ok(());
        }
        let deficit = cost - self.tokens;
        let ms = (deficit / self.rate * 1000.0).ceil() as u64;
        Err(ms.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_admits_everything() {
        let mut b = TokenBucket::new(0, 0);
        for _ in 0..10_000 {
            assert!(b.admit(1_000_000).is_ok());
        }
    }

    #[test]
    fn burst_then_throttle() {
        let mut b = TokenBucket::new(100, 50);
        // The initial bucket holds exactly `burst` tokens.
        assert!(b.admit(50).is_ok());
        let retry = b.admit(10).unwrap_err();
        // 10 tokens at 100/s ≈ 100ms (elapsed time between calls only
        // ever shrinks the deficit, so this is an upper bound).
        assert!(retry >= 1 && retry <= 100, "retry_after {retry}ms");
    }

    #[test]
    fn batch_cost_counts_pairs() {
        let mut b = TokenBucket::new(1_000, 100);
        assert!(b.admit(100).is_ok(), "burst covers a full batch");
        assert!(b.admit(100).is_err(), "second batch must wait for refill");
    }

    #[test]
    fn oversized_cost_clamps_to_burst() {
        let mut b = TokenBucket::new(10, 5);
        // Cost 1000 > burst 5: clamped, so a full bucket admits it
        // instead of wedging the connection forever.
        assert!(b.admit(1_000).is_ok());
        let retry = b.admit(1_000).unwrap_err();
        // Deficit is at most the whole (clamped) bucket: 5 tokens at
        // 10/s = 500ms.
        assert!(retry <= 500, "retry_after {retry}ms");
    }

    #[test]
    fn refills_over_time() {
        let mut b = TokenBucket::new(1_000_000, 10);
        assert!(b.admit(10).is_ok());
        std::thread::sleep(std::time::Duration::from_millis(5));
        // 5ms at 1M ops/s refills far more than the 10-token burst cap.
        assert!(b.admit(10).is_ok());
    }
}
