//! The line protocol of the TCP front-end.
//!
//! Requests (one per line, space-separated, `\n`-terminated):
//!
//! ```text
//! OBS <src> <dst>          record a transition (async, queued)
//! REC <src> <threshold>    items until cumulative probability >= threshold
//! TOPK <src> <k>           the k most probable next nodes
//! PROB <src> <dst>         single-edge probability
//! DECAY                    force a decay + repair pass
//! STATS                    engine statistics
//! PING                     liveness check
//! QUIT                     close the connection
//! ```
//!
//! Responses: `OK ...`, `ITEMS <n> <dst>:<prob> ... cum=<c> scanned=<s>`,
//! or `ERR <message>`.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Observe { src: u64, dst: u64 },
    Recommend { src: u64, threshold: f64 },
    TopK { src: u64, k: usize },
    Prob { src: u64, dst: u64 },
    Decay,
    Stats,
    Ping,
    Quit,
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let mut it = line.split_ascii_whitespace();
        let cmd = it.next().ok_or("empty request")?;
        let mut num = |name: &str| -> Result<u64, String> {
            it.next()
                .ok_or(format!("{cmd}: missing {name}"))?
                .parse::<u64>()
                .map_err(|_| format!("{cmd}: bad {name}"))
        };
        let req = match cmd {
            "OBS" => Request::Observe { src: num("src")?, dst: num("dst")? },
            "TOPK" => Request::TopK { src: num("src")?, k: num("k")? as usize },
            "PROB" => Request::Prob { src: num("src")?, dst: num("dst")? },
            "REC" => {
                let src = num("src")?;
                let t: f64 = it
                    .next()
                    .ok_or("REC: missing threshold")?
                    .parse()
                    .map_err(|_| "REC: bad threshold")?;
                if !(0.0..=1.0).contains(&t) {
                    return Err("REC: threshold must be in [0, 1]".into());
                }
                Request::Recommend { src, threshold: t }
            }
            "DECAY" => Request::Decay,
            "STATS" => Request::Stats,
            "PING" => Request::Ping,
            "QUIT" => Request::Quit,
            other => return Err(format!("unknown command {other:?}")),
        };
        if it.next().is_some() {
            return Err(format!("{cmd}: trailing arguments"));
        }
        Ok(req)
    }

    pub fn encode(&self) -> String {
        match self {
            Request::Observe { src, dst } => format!("OBS {src} {dst}"),
            Request::Recommend { src, threshold } => format!("REC {src} {threshold}"),
            Request::TopK { src, k } => format!("TOPK {src} {k}"),
            Request::Prob { src, dst } => format!("PROB {src} {dst}"),
            Request::Decay => "DECAY".into(),
            Request::Stats => "STATS".into(),
            Request::Ping => "PING".into(),
            Request::Quit => "QUIT".into(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok(String),
    Items { items: Vec<(u64, f64)>, cumulative: f64, scanned: usize },
    Err(String),
}

impl Response {
    pub fn parse(line: &str) -> Result<Response, String> {
        if let Some(rest) = line.strip_prefix("OK") {
            return Ok(Response::Ok(rest.trim().to_string()));
        }
        if let Some(rest) = line.strip_prefix("ERR ") {
            return Ok(Response::Err(rest.to_string()));
        }
        if let Some(rest) = line.strip_prefix("ITEMS ") {
            let mut it = rest.split_ascii_whitespace();
            let n: usize =
                it.next().ok_or("ITEMS: missing count")?.parse().map_err(|_| "bad count")?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let tok = it.next().ok_or("ITEMS: truncated")?;
                let (d, p) = tok.split_once(':').ok_or("ITEMS: bad pair")?;
                items.push((
                    d.parse().map_err(|_| "bad dst")?,
                    p.parse().map_err(|_| "bad prob")?,
                ));
            }
            let cum = it
                .next()
                .and_then(|s| s.strip_prefix("cum="))
                .ok_or("ITEMS: missing cum")?
                .parse()
                .map_err(|_| "bad cum")?;
            let scanned = it
                .next()
                .and_then(|s| s.strip_prefix("scanned="))
                .ok_or("ITEMS: missing scanned")?
                .parse()
                .map_err(|_| "bad scanned")?;
            return Ok(Response::Items { items, cumulative: cum, scanned });
        }
        Err(format!("unparseable response {line:?}"))
    }
}

impl fmt::Display for Response {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Response::Ok(msg) if msg.is_empty() => write!(f, "OK"),
            Response::Ok(msg) => write!(f, "OK {msg}"),
            Response::Err(msg) => write!(f, "ERR {msg}"),
            Response::Items { items, cumulative, scanned } => {
                write!(f, "ITEMS {}", items.len())?;
                for (d, p) in items {
                    write!(f, " {d}:{p:.6}")?;
                }
                write!(f, " cum={cumulative:.6} scanned={scanned}")
            }
        }
    }
}
