//! The line protocol of the TCP front-end.
//!
//! Requests (one per line, space-separated, `\n`-terminated):
//!
//! ```text
//! OBS <src> <dst>            record a transition (async, queued)
//! OBSERVEB <n> <s1> <d1> ... record n transitions in one request (queued,
//!                            routed shard-by-shard through the bulk path)
//! REC <src> <threshold>      items until cumulative probability >= threshold
//! TOPK <src> <k>             the k most probable next nodes
//! MTOPK <n> <k> <s1> ...     top-k for n src nodes in one request
//! PROB <src> <dst>           single-edge probability
//! DECAY                      force a decay + repair pass
//! REPAIR                     force a standalone order-repair sweep
//!                            (logged as a RepairRecord when durable)
//! SAVE                       force a durability checkpoint (WAL cut +
//!                            snapshot; ERR if persistence is disabled)
//! STATS                      engine statistics
//! METRICS                    full Prometheus text exposition of the
//!                            telemetry registry (DESIGN.md §9). The ONLY
//!                            multi-line response in the protocol: the
//!                            body is terminated by a literal `# EOF`
//!                            line, so pipelining clients know where it
//!                            ends without a length prefix.
//! TRACE on|off               arm / disarm per-thread span capture
//! TRACE dump <n>             the newest <n> captured spans, one per
//!                            response line token-packed (single line)
//! EVENTS [n]                 the newest <n> structured event records
//!                            (whole ring when omitted), token-packed on
//!                            one response line (DESIGN.md §10)
//! HEALTH                     degradation-ladder probe: the current rung
//!                            (healthy/degraded/recovering), the reason
//!                            and retry hint when off the healthy rung,
//!                            and the follower's link state when role-
//!                            aware (DESIGN.md §8)
//! PING                       liveness check
//! QUIT                       close the connection
//! REPL HELLO <epoch> <n> <s1> ... <sn>
//!                            subscribe as a follower: wal epoch plus one
//!                            last-applied seq per shard. The connection
//!                            then switches to the replication stream
//!                            (DESIGN.md §5) instead of request/response.
//! PROMOTE                    follower only: stop following, accept writes
//! ```
//!
//! `TOPK`, `MTOPK` and `OBSERVEB` accept one optional trailing
//! `id=<token>` request tag (≤ 64 chars, no whitespace). The tag is
//! echoed back on the response line and stamped into any slow-query
//! flight-recorder entry the request produces, so an operator can join a
//! client-side request id against `TRACE dump` output.
//!
//! Responses: `OK ...`, `ITEMS <n> <dst>:<prob> ... cum=<c> scanned=<s>`,
//! `MITEMS <m> ITEMS ... ITEMS ...` (one block per MTOPK src), or
//! `ERR <message>`. Tagged requests suffix their response line with
//! ` id=<token>`. Every request yields exactly one response line, so
//! clients can pipeline arbitrarily many requests behind a single flush —
//! with the sole documented exception of `METRICS`, whose multi-line body
//! runs until a `# EOF` sentinel line.

use std::fmt;
use std::fmt::Write as _;

/// Upper bound on the element count of OBSERVEB / MTOPK requests: keeps a
/// hostile or buggy client from making the server allocate unboundedly
/// from one header token. Clients chunk above this.
pub const MAX_WIRE_BATCH: usize = 65_536;

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Observe { src: u64, dst: u64 },
    ObserveBatch { pairs: Vec<(u64, u64)>, id: Option<String> },
    Recommend { src: u64, threshold: f64 },
    TopK { src: u64, k: usize, id: Option<String> },
    MultiTopK { srcs: Vec<u64>, k: usize, id: Option<String> },
    Prob { src: u64, dst: u64 },
    Decay,
    Repair,
    Save,
    Stats,
    /// Prometheus text exposition of the whole telemetry registry
    /// (multi-line response terminated by `# EOF`).
    Metrics,
    /// Span-capture control: `TRACE on`, `TRACE off`, `TRACE dump <n>`.
    Trace(TraceCmd),
    /// Drain the newest `n` structured event records (`usize::MAX` = the
    /// whole ring) from the event log (DESIGN.md §10).
    Events(usize),
    Health,
    Ping,
    Quit,
    /// Follower subscription: its WAL epoch and per-shard last seqs.
    ReplHello { epoch: u64, last_seqs: Vec<u64> },
    /// Flip a follower writable (leader failover).
    Promote,
}

/// The `TRACE` subcommands (DESIGN.md §9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceCmd {
    On,
    Off,
    /// Return the newest `n` captured spans.
    Dump(usize),
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let mut it = line.split_ascii_whitespace();
        let cmd = it.next().ok_or("empty request")?;
        // Subcommand token, consumed up front (the `num` closure below
        // holds the iterator, so it cannot be advanced directly later).
        let sub = if cmd == "REPL" || cmd == "TRACE" { it.next() } else { None };
        let mut num = |name: &str| -> Result<u64, String> {
            it.next()
                .ok_or(format!("{cmd}: missing {name}"))?
                .parse::<u64>()
                .map_err(|_| format!("{cmd}: bad {name}"))
        };
        let batch_len = |n: u64| -> Result<usize, String> {
            if n == 0 {
                return Err("count must be positive".into());
            }
            if n > MAX_WIRE_BATCH as u64 {
                return Err(format!("count {n} exceeds max {MAX_WIRE_BATCH}"));
            }
            Ok(n as usize)
        };
        let req = match cmd {
            "OBS" => Request::Observe { src: num("src")?, dst: num("dst")? },
            "OBSERVEB" => {
                let n = batch_len(num("count")?).map_err(|e| format!("OBSERVEB: {e}"))?;
                let mut pairs = Vec::with_capacity(n);
                for _ in 0..n {
                    pairs.push((num("src")?, num("dst")?));
                }
                Request::ObserveBatch { pairs, id: None }
            }
            "TOPK" => Request::TopK { src: num("src")?, k: num("k")? as usize, id: None },
            "MTOPK" => {
                let n = batch_len(num("count")?).map_err(|e| format!("MTOPK: {e}"))?;
                let k = num("k")? as usize;
                let mut srcs = Vec::with_capacity(n);
                for _ in 0..n {
                    srcs.push(num("src")?);
                }
                Request::MultiTopK { srcs, k, id: None }
            }
            "PROB" => Request::Prob { src: num("src")?, dst: num("dst")? },
            "REC" => {
                let src = num("src")?;
                let t: f64 = it
                    .next()
                    .ok_or("REC: missing threshold")?
                    .parse()
                    .map_err(|_| "REC: bad threshold")?;
                if !(0.0..=1.0).contains(&t) {
                    return Err("REC: threshold must be in [0, 1]".into());
                }
                Request::Recommend { src, threshold: t }
            }
            "DECAY" => Request::Decay,
            "REPAIR" => Request::Repair,
            "SAVE" => Request::Save,
            "STATS" => Request::Stats,
            "METRICS" => Request::Metrics,
            "TRACE" => match sub {
                Some("on") => Request::Trace(TraceCmd::On),
                Some("off") => Request::Trace(TraceCmd::Off),
                Some("dump") => Request::Trace(TraceCmd::Dump(num("n")? as usize)),
                other => return Err(format!("TRACE: unknown subcommand {other:?}")),
            },
            "EVENTS" => match it.next() {
                // Count omitted = drain the whole ring.
                None => Request::Events(usize::MAX),
                Some(t) => Request::Events(
                    t.parse::<u64>().map_err(|_| "EVENTS: bad n")? as usize,
                ),
            },
            "HEALTH" => Request::Health,
            "PING" => Request::Ping,
            "QUIT" => Request::Quit,
            "REPL" => match sub {
                Some("HELLO") => {
                    let epoch = num("epoch")?;
                    let n = batch_len(num("shards")?).map_err(|e| format!("REPL: {e}"))?;
                    let mut last_seqs = Vec::with_capacity(n);
                    for _ in 0..n {
                        last_seqs.push(num("seq")?);
                    }
                    Request::ReplHello { epoch, last_seqs }
                }
                other => return Err(format!("REPL: unknown subcommand {other:?}")),
            },
            "PROMOTE" => Request::Promote,
            other => return Err(format!("unknown command {other:?}")),
        };
        // Optional trailing `id=<token>` request tag on the taggable
        // verbs; anything else after the grammar above is still an error.
        let mut req = req;
        let mut trailing = it.next();
        if let (
            Some(tok),
            Request::TopK { id, .. }
            | Request::MultiTopK { id, .. }
            | Request::ObserveBatch { id, .. },
        ) = (trailing, &mut req)
        {
            if let Some(tag) = tok.strip_prefix("id=") {
                if tag.is_empty() || tag.len() > 64 {
                    return Err(format!("{cmd}: id tag must be 1..=64 chars"));
                }
                *id = Some(tag.to_string());
                trailing = it.next();
            }
        }
        if trailing.is_some() {
            return Err(format!("{cmd}: trailing arguments"));
        }
        Ok(req)
    }

    pub fn encode(&self) -> String {
        match self {
            Request::Observe { src, dst } => format!("OBS {src} {dst}"),
            Request::ObserveBatch { pairs, id } => {
                let mut s = format!("OBSERVEB {}", pairs.len());
                for (src, dst) in pairs {
                    let _ = write!(s, " {src} {dst}");
                }
                if let Some(tag) = id {
                    let _ = write!(s, " id={tag}");
                }
                s
            }
            Request::Recommend { src, threshold } => format!("REC {src} {threshold}"),
            Request::TopK { src, k, id } => {
                let mut s = format!("TOPK {src} {k}");
                if let Some(tag) = id {
                    let _ = write!(s, " id={tag}");
                }
                s
            }
            Request::MultiTopK { srcs, k, id } => {
                let mut s = format!("MTOPK {} {k}", srcs.len());
                for src in srcs {
                    let _ = write!(s, " {src}");
                }
                if let Some(tag) = id {
                    let _ = write!(s, " id={tag}");
                }
                s
            }
            Request::Prob { src, dst } => format!("PROB {src} {dst}"),
            Request::Decay => "DECAY".into(),
            Request::Repair => "REPAIR".into(),
            Request::Save => "SAVE".into(),
            Request::Stats => "STATS".into(),
            Request::Metrics => "METRICS".into(),
            Request::Trace(TraceCmd::On) => "TRACE on".into(),
            Request::Trace(TraceCmd::Off) => "TRACE off".into(),
            Request::Trace(TraceCmd::Dump(n)) => format!("TRACE dump {n}"),
            Request::Events(n) if *n == usize::MAX => "EVENTS".into(),
            Request::Events(n) => format!("EVENTS {n}"),
            Request::Health => "HEALTH".into(),
            Request::Ping => "PING".into(),
            Request::Quit => "QUIT".into(),
            Request::ReplHello { epoch, last_seqs } => {
                let mut s = format!("REPL HELLO {epoch} {}", last_seqs.len());
                for seq in last_seqs {
                    let _ = write!(s, " {seq}");
                }
                s
            }
            Request::Promote => "PROMOTE".into(),
        }
    }
}

/// One inference answer on the wire (the payload of an `ITEMS` block).
#[derive(Debug, Clone, PartialEq)]
pub struct ItemsBody {
    pub items: Vec<(u64, f64)>,
    pub cumulative: f64,
    pub scanned: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Ok(String),
    Items { items: Vec<(u64, f64)>, cumulative: f64, scanned: usize },
    /// One `ITEMS` block per query of an `MTOPK` request, in request order.
    MultiItems(Vec<ItemsBody>),
    Err(String),
}

/// Parse one `ITEMS` payload (count, pairs, cum=, scanned=) from a token
/// stream; shared by the single- and multi-answer parsers.
fn parse_items_body<'a>(
    it: &mut impl Iterator<Item = &'a str>,
) -> Result<ItemsBody, String> {
    let n: usize = it.next().ok_or("ITEMS: missing count")?.parse().map_err(|_| "bad count")?;
    let mut items = Vec::with_capacity(n.min(MAX_WIRE_BATCH));
    for _ in 0..n {
        let tok = it.next().ok_or("ITEMS: truncated")?;
        let (d, p) = tok.split_once(':').ok_or("ITEMS: bad pair")?;
        items.push((d.parse().map_err(|_| "bad dst")?, p.parse().map_err(|_| "bad prob")?));
    }
    let cumulative = it
        .next()
        .and_then(|s| s.strip_prefix("cum="))
        .ok_or("ITEMS: missing cum")?
        .parse()
        .map_err(|_| "bad cum")?;
    let scanned = it
        .next()
        .and_then(|s| s.strip_prefix("scanned="))
        .ok_or("ITEMS: missing scanned")?
        .parse()
        .map_err(|_| "bad scanned")?;
    Ok(ItemsBody { items, cumulative, scanned })
}

impl Response {
    pub fn parse(line: &str) -> Result<Response, String> {
        if let Some(rest) = line.strip_prefix("MITEMS ") {
            let mut it = rest.split_ascii_whitespace();
            let m: usize =
                it.next().ok_or("MITEMS: missing count")?.parse().map_err(|_| "bad count")?;
            if m > MAX_WIRE_BATCH {
                return Err(format!("MITEMS: count {m} exceeds max {MAX_WIRE_BATCH}"));
            }
            let mut bodies = Vec::with_capacity(m);
            for _ in 0..m {
                match it.next() {
                    Some("ITEMS") => bodies.push(parse_items_body(&mut it)?),
                    other => return Err(format!("MITEMS: expected ITEMS block, got {other:?}")),
                }
            }
            return Ok(Response::MultiItems(bodies));
        }
        if let Some(rest) = line.strip_prefix("OK") {
            return Ok(Response::Ok(rest.trim().to_string()));
        }
        if let Some(rest) = line.strip_prefix("ERR ") {
            return Ok(Response::Err(rest.to_string()));
        }
        if let Some(rest) = line.strip_prefix("ITEMS ") {
            let mut it = rest.split_ascii_whitespace();
            let body = parse_items_body(&mut it)?;
            return Ok(Response::Items {
                items: body.items,
                cumulative: body.cumulative,
                scanned: body.scanned,
            });
        }
        Err(format!("unparseable response {line:?}"))
    }
}

/// Write one `ITEMS` payload into any `fmt::Write` sink — shared by the
/// `Response` display arms and by the server's zero-allocation fast path,
/// which streams integers/probabilities straight into the per-connection
/// wire buffer (no intermediate `Response`, no `format!` per item). The
/// sink is a reused buffer, so the bytes are identical either way.
pub fn write_items_body<W: fmt::Write>(
    w: &mut W,
    items: &[(u64, f64)],
    cumulative: f64,
    scanned: usize,
) -> fmt::Result {
    write!(w, "ITEMS {}", items.len())?;
    for (d, p) in items {
        write!(w, " {d}:{p:.6}")?;
    }
    write!(w, " cum={cumulative:.6} scanned={scanned}")
}

impl fmt::Display for Response {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Response::Ok(msg) if msg.is_empty() => write!(f, "OK"),
            Response::Ok(msg) => write!(f, "OK {msg}"),
            Response::Err(msg) => write!(f, "ERR {msg}"),
            Response::Items { items, cumulative, scanned } => {
                write_items_body(f, items, *cumulative, *scanned)
            }
            Response::MultiItems(bodies) => {
                write!(f, "MITEMS {}", bodies.len())?;
                for b in bodies {
                    write!(f, " ")?;
                    write_items_body(f, &b.items, b.cumulative, b.scanned)?;
                }
                Ok(())
            }
        }
    }
}
