//! Serving coordinator: the L3 layer that turns the MCPrioQ data structure
//! into a deployable online-recommendation service (vLLM-router-style
//! shape: ingestion queues, shard routing, maintenance scheduling, a TCP
//! front-end, and metrics).
//!
//! Data flow:
//!
//! ```text
//!   TCP clients ── OBS ──▶ BoundedQueue ──▶ ingest workers ─▶ McPrioQ shard
//!              └── REC/TOPK ───────────────(direct, RCU read)──────▲
//!   decay scheduler ── every decay_interval ── decay()+repair() ───┘
//! ```
//!
//! * **Updates** are enqueued (bounded, with backpressure) and applied by
//!   dedicated ingest workers, decoupling network jitter from the
//!   structure's wait-free update path. `observe_direct` bypasses the queue
//!   for embedded use (benches use both).
//! * **Queries** run directly on the caller thread: inference is a
//!   wait-free RCU scan, so there is nothing to schedule around — this is
//!   the paper's "query while building" property, operationalized.
//! * **Decay** runs on the maintenance thread (§II.C), which also performs
//!   the order-repair sweep.

mod decay;
mod engine;
mod protocol;
mod queue;
mod server;

pub use decay::DecayScheduler;
pub use engine::{Engine, EngineStats};
pub use protocol::{Request, Response};
pub use queue::BoundedQueue;
pub use server::{Client, Server};

#[cfg(test)]
mod tests;
