//! Serving coordinator: the L3 layer that turns the MCPrioQ data structure
//! into a deployable online-recommendation service (vLLM-router-style
//! shape: ingestion queues, shard routing, maintenance scheduling, a TCP
//! front-end, and metrics).
//!
//! Data flow (batch-first):
//!
//! ```text
//!   TCP clients ── OBS/OBSERVEB ──▶ per-shard BoundedQueue ─▶ shard-affine
//!              │                      (routed by FIB hash)    worker batch
//!              │                                              observe_batch
//!              │                                                    │
//!              └── REC/TOPK/MTOPK ────(direct, RCU read)──▶ McPrioQ shard
//!   decay scheduler ── every decay_interval ── decay()+repair() ─────┘
//! ```
//!
//! * **Updates** are routed to their shard's own bounded queue (blocking
//!   backpressure per shard) and applied by shard-affine ingest workers:
//!   each worker owns a static shard subset and drains batches straight
//!   into `McPrioQ::observe_batch` — one RCU pin per batch, one queue-lock
//!   acquisition per batch, per-shard cache locality, and per-shard FIFO
//!   (which makes queued ingestion deterministic). `observe_direct` /
//!   `observe_batch_direct` bypass the queues for embedded use.
//! * **Queries** run directly on the caller thread: inference is a
//!   wait-free RCU scan, so there is nothing to schedule around — this is
//!   the paper's "query while building" property, operationalized.
//! * **Decay** runs on the maintenance thread (§II.C), which also performs
//!   the order-repair sweep.
//! * **Durability** (opt-in, DESIGN.md §4): each worker write-ahead-logs
//!   its drained batch into the shard's segmented WAL before applying it;
//!   a background checkpointer (or the wire `SAVE` command) pauses ingest
//!   at a batch boundary and commits `Engine::export` + WAL cut points to
//!   disk; `persist::open_engine` recovers checkpoint + WAL tail on boot.
//! * **Replication** (opt-in, DESIGN.md §5): a `REPL HELLO` connection
//!   turns into a push stream of that WAL (`replicate::serve_follower`);
//!   a follower built with `replicate::start_follower` applies it through
//!   `Engine::apply_replicated` and serves reads with bounded staleness,
//!   rejecting writes until `PROMOTE`.

mod admission;
mod decay;
mod engine;
mod health;
mod protocol;
mod queue;
mod server;

pub use decay::{DecayScheduler, RepairScheduler};
pub use engine::{Engine, EngineStats};
pub use health::Health;
pub use protocol::{write_items_body, ItemsBody, Request, Response, TraceCmd, MAX_WIRE_BATCH};
pub use queue::BoundedQueue;
pub(crate) use server::connect_backoff;
pub use server::{Client, MetricsSidecar, Server};

#[cfg(test)]
mod tests;
