//! TCP front-end (thread-per-connection; no async runtime offline) and the
//! matching client.
//!
//! The serving loop is allocation-free after warm-up: each connection owns
//! a request line buffer, a reusable [`Recommendation`] scratch, and a
//! response `String` that answers are formatted *directly into* (see
//! [`super::protocol::write_items_body`]) — no per-request `Response`
//! values, no `format!` per item, and `MTOPK` streams all n answers
//! through one RCU guard into one buffer flushed once.

use std::fmt::Write as _;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::chain::Recommendation;
use crate::metrics::trace;
use crate::replicate::ReplicaState;
use crate::sync::shim::{AtomicBool, AtomicUsize, Ordering};

use super::admission::TokenBucket;
use super::engine::Engine;
use super::health::Health;
use super::protocol::{write_items_body, Request, Response, TraceCmd, MAX_WIRE_BATCH};

pub struct Server {
    engine: Arc<Engine>,
    listener: TcpListener,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicUsize>,
    /// Present when this process is a follower: dispatch enforces
    /// read-only mode (until promotion) and STATS grows the role block.
    replica: Option<Arc<ReplicaState>>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port in tests).
    pub fn bind(engine: Arc<Engine>, addr: &str) -> Result<Server> {
        Self::bind_role(engine, addr, None)
    }

    /// Bind a follower front-end: same protocol, but writes are rejected
    /// while `replica` is unpromoted and STATS reports lag.
    pub fn bind_replica(
        engine: Arc<Engine>,
        addr: &str,
        replica: Arc<ReplicaState>,
    ) -> Result<Server> {
        Self::bind_role(engine, addr, Some(replica))
    }

    fn bind_role(
        engine: Arc<Engine>,
        addr: &str,
        replica: Option<Arc<ReplicaState>>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        Ok(Server {
            engine,
            listener,
            addr,
            stop: Arc::new(AtomicBool::new(false)),
            connections: Arc::new(AtomicUsize::new(0)),
            replica,
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Spawn the accept loop; returns a handle that stops it on drop.
    pub fn spawn(self) -> ServerHandle {
        let stop = Arc::clone(&self.stop);
        let addr = self.addr;
        let handle = std::thread::spawn(move || self.accept_loop());
        ServerHandle { stop, addr, handle: Some(handle) }
    }

    fn accept_loop(self) {
        // Nonblocking accept + sleep keeps the loop stoppable without
        // platform-specific socket shenanigans.
        self.listener.set_nonblocking(true).expect("nonblocking listener");
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let engine = Arc::clone(&self.engine);
                    let stop = Arc::clone(&self.stop);
                    let conns = Arc::clone(&self.connections);
                    let replica = self.replica.clone();
                    conns.fetch_add(1, Ordering::Relaxed);
                    std::thread::spawn(move || {
                        let _ =
                            handle_connection(engine, stream, stop, Arc::clone(&conns), replica);
                        conns.fetch_sub(1, Ordering::Relaxed);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    }
}

/// Keeps the accept loop alive; stops and joins it on drop.
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: std::net::SocketAddr,
    handle: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Minimal HTTP sidecar (`[server] metrics_addr`): the Prometheus text
/// exposition on `GET /metrics` (DESIGN.md §9), a load-balancer health
/// probe on `GET /healthz` (200 on the healthy rung, 503 otherwise), and
/// the structured event log on `GET /events` (DESIGN.md §10).
/// Deliberately not a web server: one request line, headers skipped, body
/// formatted into a per-connection buffer, `Connection: close`. Scrapers
/// (and `curl`) need nothing more, and the line protocol's `METRICS` /
/// `HEALTH` / `EVENTS` verbs remain the first-class interface.
pub struct MetricsSidecar {
    engine: Arc<Engine>,
    listener: TcpListener,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
}

impl MetricsSidecar {
    pub fn bind(engine: Arc<Engine>, addr: &str) -> Result<MetricsSidecar> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics {addr}"))?;
        let addr = listener.local_addr()?;
        Ok(MetricsSidecar { engine, listener, addr, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Spawn the scrape loop; the returned handle stops and joins it on
    /// drop, same contract as [`Server::spawn`].
    pub fn spawn(self) -> ServerHandle {
        let stop = Arc::clone(&self.stop);
        let addr = self.addr;
        let handle = std::thread::spawn(move || self.accept_loop());
        ServerHandle { stop, addr, handle: Some(handle) }
    }

    fn accept_loop(self) {
        self.listener.set_nonblocking(true).expect("nonblocking metrics listener");
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).ok();
                    let engine = Arc::clone(&self.engine);
                    // One thread per scrape: scrapes are rare (seconds
                    // apart) and a stalled client must not block the
                    // accept loop.
                    std::thread::spawn(move || {
                        let _ = serve_scrape(&engine, stream);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(_) => break,
            }
        }
    }
}

/// Answer one HTTP scrape: `GET /metrics` (or `/`) renders the registry,
/// `GET /healthz` answers 200/503 off the health rung, `GET /events`
/// renders the event ring, anything else 404s. Bodies are formatted
/// straight into a per-connection `String` and written with an explicit
/// `Content-Length`.
fn serve_scrape(engine: &Engine, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(());
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    // Drain request headers up to the blank line; nothing in them matters.
    let mut header = String::new();
    loop {
        header.clear();
        if reader.read_line(&mut header)? == 0 || header.trim_end().is_empty() {
            break;
        }
    }
    if method == "GET" && (path == "/metrics" || path == "/") {
        let mut body = String::with_capacity(4096);
        engine.render_metrics(&mut body);
        write!(
            writer,
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
        writer.write_all(body.as_bytes())?;
    } else if method == "GET" && path == "/healthz" {
        // The rung IS the wire status: load balancers route on the code
        // alone, the one-line body is for humans reading `curl -i`.
        let (status, body) = match engine.health() {
            Health::Healthy => ("200 OK", "healthy\n".to_string()),
            rung => ("503 Service Unavailable", format!("{}\n", rung.as_str())),
        };
        write!(
            writer,
            "HTTP/1.1 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
        writer.write_all(body.as_bytes())?;
    } else if method == "GET" && path == "/events" {
        let mut body = String::with_capacity(4096);
        crate::metrics::events::render_text(&mut body, usize::MAX);
        write!(
            writer,
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        )?;
        writer.write_all(body.as_bytes())?;
    } else {
        writer.write_all(
            b"HTTP/1.1 404 Not Found\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
        )?;
    }
    writer.flush()?;
    Ok(())
}

fn handle_connection(
    engine: Arc<Engine>,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicUsize>,
    replica: Option<Arc<ReplicaState>>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    // Per-connection scratch: the whole request->response cycle reuses
    // these three buffers, so steady-state serving performs no heap
    // allocation (OBSERVEB/MTOPK argument vectors excepted — those are
    // sized by the client's request).
    let mut line = String::new();
    let mut rec = Recommendation::default();
    let mut resp = String::with_capacity(256);
    // Per-client admission control (`[server] rate_limit_ops`, 0 = off):
    // each connection owns its bucket, so one greedy feeder throttles
    // itself without a shared-limiter lock on the hot path.
    let (rate, burst) = engine.admission_limits();
    let mut bucket = TokenBucket::new(rate, burst);
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || stop.load(Ordering::SeqCst) {
            return Ok(()); // peer closed / shutting down
        }
        let trimmed = line.trim_end();
        if trimmed.is_empty() {
            continue;
        }
        resp.clear();
        // Trace arming is one relaxed load; the pre-parse timestamp lets a
        // query span attribute parsing to its own stage (DESIGN.md §9).
        let trace_t0 = trace::armed().then(std::time::Instant::now);
        match Request::parse(trimmed) {
            Err(e) => {
                let _ = write!(resp, "ERR {e}");
            }
            Ok(Request::Quit) => {
                writer.write_all(b"OK bye\n")?;
                writer.flush()?;
                return Ok(());
            }
            Ok(Request::ReplHello { epoch, last_seqs }) => {
                // The connection leaves request/response mode: the leader-
                // side streamer owns it until the follower disconnects.
                if replica.is_some() {
                    writer.write_all(b"ERR cannot replicate from a follower\n")?;
                    writer.flush()?;
                    continue;
                }
                let _ = crate::replicate::serve_follower(
                    &engine,
                    &mut writer,
                    epoch,
                    last_seqs,
                    &stop,
                );
                return Ok(());
            }
            Ok(req) => dispatch(
                &engine,
                req,
                connections.load(Ordering::Relaxed),
                replica.as_deref(),
                &mut bucket,
                trace_t0,
                &mut rec,
                &mut resp,
            ),
        }
        resp.push('\n');
        writer.write_all(resp.as_bytes())?;
        writer.flush()?;
        // The buffer reuse must not turn one worst-case response (a
        // max-batch MTOPK can be many MB) into memory pinned for the
        // connection's whole lifetime: keep a generous steady-state
        // capacity, give the rest back.
        if resp.capacity() > RESP_KEEP_CAPACITY {
            resp.shrink_to(RESP_KEEP_CAPACITY);
        }
    }
}

/// Response-buffer capacity a connection may retain between requests.
const RESP_KEEP_CAPACITY: usize = 64 * 1024;

/// Answer one request by formatting the response line straight into `out`
/// (the caller's reused wire buffer). `rec` is the reused query scratch.
/// Infallible: `fmt::Write` into a `String` cannot fail, so the stray
/// `Result`s are dropped.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    engine: &Engine,
    req: Request,
    live_connections: usize,
    replica: Option<&crate::replicate::ReplicaState>,
    bucket: &mut TokenBucket,
    trace_t0: Option<std::time::Instant>,
    rec: &mut Recommendation,
    out: &mut String,
) {
    // An unpromoted follower serves every read but rejects mutations:
    // writes belong on the leader, and maintenance is leader-driven — the
    // leader's decay/repair arrive as WAL records (DESIGN.md §6), so a
    // local DECAY would apply on top of the replayed one and diverge the
    // replica. SAVE stays allowed — a local checkpoint of replicated
    // state is how a follower bounds its own recovery time. `writable`
    // (not just the promote latch) is the gate: writes open only after
    // the apply plane drained, so a local write can't steal a queued
    // replicated record's WAL seq.
    let read_only = replica.is_some_and(|r| !r.writable());
    if read_only
        && matches!(
            req,
            Request::Observe { .. }
                | Request::ObserveBatch { .. }
                | Request::Decay
                | Request::Repair
        )
    {
        let _ = write!(
            out,
            "ERR read-only replica (following {}; PROMOTE to accept writes)",
            replica.map(|r| r.leader()).unwrap_or("?")
        );
        return;
    }
    // Degradation gate (DESIGN.md §8): off the healthy rung the engine
    // keeps serving every read from the in-memory RCU structures, but
    // mutations are refused — acking a write into a quarantined WAL (or
    // on top of an un-drained parked backlog) would either lose it on
    // crash or reorder it against the parked ops. Clients get the reason
    // and a retry hint; the heal task re-admits writes by flipping the
    // rung back, no reconnect needed.
    let is_write = matches!(
        req,
        Request::Observe { .. } | Request::ObserveBatch { .. } | Request::Decay | Request::Repair
    );
    if is_write && engine.health() != Health::Healthy {
        let _ = write!(
            out,
            "ERR degraded reason={:?} retry_after_ms={}",
            engine.health_reason(),
            engine.health_retry_after_ms()
        );
        return;
    }
    // Ingress admission (token bucket, per connection): write verbs spend
    // tokens proportional to their work — OBSERVEB costs its pair count,
    // so batching cannot dodge the limit. Reads are never charged.
    if is_write {
        let cost = match &req {
            Request::ObserveBatch { pairs, .. } => pairs.len() as u64,
            _ => 1,
        };
        if let Err(retry_ms) = bucket.admit(cost) {
            engine.note_ratelimited();
            let _ = write!(out, "ERR ratelimited retry_after_ms={retry_ms}");
            return;
        }
    }
    // With admission control on, saturation sheds instead of blocking:
    // a full shard queue answers `ERR overload` (counted in `shed=`)
    // rather than stalling this connection — and with it the accept
    // loop's thread budget — on backpressure.
    let shedding = engine.admission_limits().0 > 0;
    match req {
        Request::Observe { src, dst } => {
            if shedding {
                if engine.observe_shed(src, dst) {
                    out.push_str("OK");
                } else {
                    out.push_str("ERR overload shed=1");
                }
            } else if engine.observe(src, dst) {
                out.push_str("OK");
            } else {
                out.push_str("ERR shutting down");
            }
        }
        Request::ObserveBatch { pairs, id } => {
            if shedding {
                let (accepted, shed) = engine.observe_batch_shed(&pairs);
                if shed == 0 {
                    let _ = write!(out, "OK n={accepted}");
                } else {
                    let _ = write!(out, "ERR overload shed={shed} accepted={accepted}");
                }
            } else {
                let accepted = engine.observe_batch(&pairs);
                if accepted == pairs.len() {
                    let _ = write!(out, "OK n={accepted}");
                } else {
                    let _ =
                        write!(out, "ERR shutting down (accepted {accepted}/{})", pairs.len());
                }
            }
            if let Some(tag) = id {
                let _ = write!(out, " id={tag}");
            }
        }
        Request::Recommend { src, threshold } => {
            // Spans only exist while tracing or the slow-query log is armed
            // (`trace_t0` is None otherwise): the untraced hot path pays a
            // single relaxed load per request, no clock reads.
            let mut span = trace_t0.map(|t0| {
                let mut s =
                    trace::Span::start_at("REC", src, (threshold * 1e6) as u64, t0);
                s.stage("parse");
                s
            });
            engine.infer_threshold_into(src, threshold, rec);
            if let Some(s) = span.as_mut() {
                s.stage("infer");
            }
            let _ = write_items_body(out, &rec.items, rec.cumulative, rec.scanned);
            if let Some(mut s) = span.take() {
                s.stage("format");
                s.finish();
            }
        }
        Request::TopK { src, k, id } => {
            let mut span = trace_t0.map(|t0| {
                let mut s = trace::Span::start_at("TOPK", src, k as u64, t0);
                if let Some(tag) = id.as_deref() {
                    s.set_id(tag);
                }
                s.stage("parse");
                s
            });
            engine.infer_topk_into(src, k, rec);
            if let Some(s) = span.as_mut() {
                s.stage("infer");
            }
            let _ = write_items_body(out, &rec.items, rec.cumulative, rec.scanned);
            if let Some(tag) = id {
                let _ = write!(out, " id={tag}");
            }
            if let Some(mut s) = span.take() {
                s.stage("format");
                s.finish();
            }
        }
        Request::MultiTopK { srcs, k, id } => {
            let mut span = trace_t0.map(|t0| {
                let mut s = trace::Span::start_at(
                    "MTOPK",
                    srcs.first().copied().unwrap_or(0),
                    k as u64,
                    t0,
                );
                if let Some(tag) = id.as_deref() {
                    s.set_id(tag);
                }
                s.stage("parse");
                s
            });
            // One RCU guard for all n queries, every ITEMS block formatted
            // into the same buffer, flushed once by the caller. Infer and
            // format interleave per answer, so a trace span charges the
            // whole loop to one combined stage.
            let _ = write!(out, "MITEMS {}", srcs.len());
            engine.infer_topk_batch(&srcs, k, rec, |r| {
                out.push(' ');
                let _ = write_items_body(out, &r.items, r.cumulative, r.scanned);
            });
            if let Some(tag) = id {
                let _ = write!(out, " id={tag}");
            }
            if let Some(mut s) = span.take() {
                s.stage("infer+format");
                s.finish();
            }
        }
        Request::Prob { src, dst } => match engine.shard(src).probability(src, dst) {
            Some(p) => {
                let _ = write!(out, "OK {p:.6}");
            }
            None => out.push_str("ERR no such edge"),
        },
        Request::Decay => {
            let (total, pruned) = engine.decay();
            let _ = write!(out, "OK total={total} pruned={pruned}");
        }
        Request::Repair => {
            let swaps = engine.repair();
            let _ = write!(out, "OK swaps={swaps}");
        }
        Request::Save => match engine.checkpoint() {
            Ok(s) => {
                let _ = write!(
                    out,
                    "OK gen={} kind={} nodes={} bytes={} wal_freed={} elapsed_ms={}",
                    s.generation, s.kind, s.nodes, s.bytes, s.wal_freed, s.elapsed_ms
                );
            }
            Err(e) => {
                let _ = write!(out, "ERR {e}");
            }
        },
        Request::Stats => {
            let s = engine.stats();
            let _ = write!(
                out,
                "OK shards={} nodes={} edges={} observes={} queries={} dropped={} \
                 queue_depth={} q_p50_ns={} q_p99_ns={} conns={} update_rate={:.0} \
                 snap_hits={} snap_rebuilds={} snap_fallbacks={} wal_bytes={} \
                 ckpt_age={} recovered_batches={} wal_errors={}",
                s.shards,
                s.nodes,
                s.edges,
                s.observes,
                s.queries,
                s.dropped_updates,
                s.queue_depth,
                s.query_ns_p50,
                s.query_ns_p99,
                live_connections,
                s.update_rate,
                s.snap_hits,
                s.snap_rebuilds,
                s.snap_fallbacks,
                s.wal_bytes,
                s.ckpt_age_s,
                s.recovered_batches,
                s.wal_errors
            );
            // Full query-latency snapshot (q_p50/q_p99 stay where parsers
            // expect them above; the long tail and extremes land here).
            let _ = write!(
                out,
                " q_p90_ns={} q_p999_ns={} q_min_ns={} q_max_ns={} q_mean_ns={:.0}",
                s.query_ns_p90, s.query_ns_p999, s.query_ns_min, s.query_ns_max, s.query_ns_mean
            );
            // Honest memory accounting (DESIGN.md §7): model bytes including
            // arena slack, plus resident arena block bytes.
            let _ = write!(out, " approx_bytes={} arena_bytes={}", s.approx_bytes, s.arena_bytes);
            // Maintenance observability (DESIGN.md §6): total decay passes
            // (summed — per-shard work), the per-shard split, and pruned
            // edges.
            let _ = write!(
                out,
                " decays={} pruned_edges={} decays_per_shard=",
                s.decays, s.pruned_edges
            );
            for (i, d) in s.decays_per_shard.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{d}");
            }
            // Replication coordinates (satellite of DESIGN.md §5): the WAL
            // epoch + per-shard heads every lag computation starts from.
            let _ = write!(out, " wal_epoch={} last_seqs=", s.wal_epoch);
            if s.wal_last_seqs.is_empty() {
                out.push('-');
            } else {
                for (i, seq) in s.wal_last_seqs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "{seq}");
                }
            }
            // Degradation-ladder gauges (DESIGN.md §8): the rung, shed /
            // ratelimited rejections, heal attempts, and outage seconds.
            let _ = write!(
                out,
                " health={} shed={} ratelimited={} wal_retry={} degraded_s={}",
                s.health, s.shed, s.ratelimited, s.wal_retry, s.degraded_s
            );
            if let Some(p) = engine.persist_state() {
                let chain = p.delta_chain();
                let _ = write!(
                    out,
                    " repl_followers={} ckpt_gen={} ckpt_chain={}",
                    p.pin_count(),
                    p.generation(),
                    chain.len
                );
            }
            if let Some(r) = replica {
                let _ = write!(
                    out,
                    " role=follower leader={} connected={} promoted={} \
                     snapshot_bootstrap={} lag_records={} lag_s={}",
                    r.leader(),
                    r.connected() as u8,
                    r.promoted() as u8,
                    r.snapshot_bootstrap() as u8,
                    r.lag_records(),
                    r.lag_seconds()
                );
                let bound = engine.replicate_config().max_lag_records;
                if bound > 0 {
                    let _ = write!(out, " lag_ok={}", (r.lag_records() <= bound) as u8);
                }
                if r.fault().is_some() {
                    out.push_str(" repl_fault=1");
                }
            }
        }
        Request::Health => {
            // Effective rung: the engine's ladder, widened on a follower
            // by link conditions — a latched replication fault or a lag
            // beyond `[replicate] max_lag_records` is a degraded state
            // clients should route around even though local disks are
            // fine (DESIGN.md §8).
            let mut rung = engine.health();
            let mut reason = engine.health_reason();
            if let Some(r) = replica {
                if rung == Health::Healthy {
                    let bound = engine.replicate_config().max_lag_records;
                    if let Some(f) = r.fault() {
                        rung = Health::DegradedReadOnly;
                        reason = format!("replication fault: {f}");
                    } else if bound > 0 && r.lag_records() > bound {
                        rung = Health::DegradedReadOnly;
                        reason = format!(
                            "lag_exceeded: {} records behind (bound {bound})",
                            r.lag_records()
                        );
                    }
                }
            }
            match rung {
                Health::Healthy => out.push_str("OK healthy"),
                _ => {
                    let _ = write!(
                        out,
                        "OK {} reason={reason:?} retry_after_ms={}",
                        rung.as_str(),
                        engine.health_retry_after_ms()
                    );
                }
            }
            if let Some(r) = replica {
                let _ = write!(
                    out,
                    " role=follower connected={} promoted={} lag_records={}",
                    r.connected() as u8,
                    r.promoted() as u8,
                    r.lag_records()
                );
            }
        }
        Request::Metrics => {
            // The one multi-line response in the protocol (DESIGN.md §12):
            // Prometheus text exposition terminated by a lone `# EOF` line.
            // `render_into` ends every sample with '\n'; the caller's
            // trailing newline closes the sentinel line.
            engine.render_metrics(out);
            out.push_str("# EOF");
        }
        Request::Trace(cmd) => match cmd {
            TraceCmd::On => {
                trace::set_enabled(true);
                out.push_str("OK trace=on");
            }
            TraceCmd::Off => {
                trace::set_enabled(false);
                out.push_str("OK trace=off");
            }
            TraceCmd::Dump(n) => {
                // Single line: `OK n=<count>` then ` | `-separated span
                // records, newest first, stages as name:nanoseconds.
                let spans = trace::dump(n);
                let _ = write!(out, "OK n={}", spans.len());
                for r in &spans {
                    let _ = write!(
                        out,
                        " | seq={} verb={} src={} k={} total_ns={} slow={} stages=",
                        r.seq, r.verb, r.src, r.k, r.total_ns, r.slow as u8
                    );
                    if r.nstages == 0 {
                        out.push('-');
                    }
                    for (i, (name, ns)) in r.stages.iter().take(r.nstages).enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        let _ = write!(out, "{name}:{ns}");
                    }
                    // Client request tag, only when the request carried one
                    // (existing dump parsers see an unchanged line).
                    if r.id_len > 0 {
                        let _ = write!(out, " id={}", r.id_str());
                    }
                }
            }
        },
        Request::Events(n) => {
            // Single line, mirroring `TRACE dump`: `OK n=<count>` then
            // ` | `-separated event records, newest first (DESIGN.md §10).
            let events = crate::metrics::events::dump(n);
            let _ = write!(out, "OK n={}", events.len());
            for r in &events {
                out.push_str(" | ");
                crate::metrics::events::render_record(out, r);
            }
        }
        Request::Ping => out.push_str("OK pong"),
        Request::Promote => match replica {
            Some(r) => {
                r.promote();
                // Reply only once writes are actually admitted: the link
                // observes the latch, closes the queues, and the apply
                // workers drain in-flight replicated records. Bounded so
                // a wedged apply plane still answers.
                let deadline =
                    std::time::Instant::now() + std::time::Duration::from_secs(10);
                while !r.writable() && std::time::Instant::now() < deadline {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                if r.writable() {
                    out.push_str("OK promoted");
                } else {
                    out.push_str(
                        "ERR promotion latched but the apply plane has not drained; retry",
                    );
                }
            }
            None => out.push_str("ERR not a follower"),
        },
        Request::Quit | Request::ReplHello { .. } => {
            unreachable!("handled by caller")
        }
    }
}

/// Dial `addr`, retrying on [`RetryPolicy::connect`] (10 ms doubling to a
/// 1 s cap, deterministic jitter) until `total` elapses. Shared by
/// [`Client::connect_with_backoff`] and the follower's leader link —
/// anything that must outlive a peer's restart window instead of failing
/// on the first refused connection.
pub(crate) fn connect_backoff(
    addr: &str,
    total: std::time::Duration,
) -> std::io::Result<TcpStream> {
    let policy = crate::runtime::RetryPolicy::connect(0xD1A1_BAC0);
    let deadline = std::time::Instant::now() + total;
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => {
                let now = std::time::Instant::now();
                if now >= deadline {
                    return Err(e);
                }
                std::thread::sleep(policy.delay(attempt).min(deadline - now));
                attempt = attempt.saturating_add(1);
            }
        }
    }
}

/// Blocking line-protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: BufWriter::new(stream) })
    }

    /// [`Client::connect`] that keeps dialing with backoff until `total`
    /// elapses — for peers that may still be starting (bench drivers, the
    /// CLI poking a just-spawned server) or restarting mid-conversation.
    pub fn connect_with_backoff(addr: &str, total: std::time::Duration) -> Result<Client> {
        let stream = connect_backoff(addr, total).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: BufWriter::new(stream) })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.encode())?;
        self.writer.flush()?;
        self.read_response()
    }

    pub fn observe(&mut self, src: u64, dst: u64) -> Result<()> {
        match self.request(&Request::Observe { src, dst })? {
            Response::Ok(_) => Ok(()),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Record a batch of transitions in one round trip. Batches above the
    /// wire limit are split into multiple `OBSERVEB` requests that are all
    /// pipelined behind a single flush (responses read back afterwards).
    /// Returns the number of updates the server accepted.
    pub fn observe_batch(&mut self, pairs: &[(u64, u64)]) -> Result<usize> {
        if pairs.is_empty() {
            return Ok(0);
        }
        let mut nchunks = 0;
        for chunk in pairs.chunks(MAX_WIRE_BATCH) {
            writeln!(
                self.writer,
                "{}",
                Request::ObserveBatch { pairs: chunk.to_vec(), id: None }.encode()
            )?;
            nchunks += 1;
        }
        self.writer.flush()?;
        // Read every pipelined response even after a failure: bailing early
        // would leave unread responses in the buffer and desync every later
        // request on this connection.
        let mut accepted = 0;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..nchunks {
            match self.read_response() {
                Ok(Response::Ok(msg)) => {
                    match msg.strip_prefix("n=").and_then(|s| s.parse::<usize>().ok()) {
                        Some(n) => accepted += n,
                        None => {
                            first_err.get_or_insert(anyhow::anyhow!("bad OBSERVEB ack {msg:?}"));
                        }
                    }
                }
                Ok(Response::Err(e)) => {
                    first_err.get_or_insert(anyhow::anyhow!("observe_batch rejected: {e}"));
                }
                Ok(other) => {
                    first_err.get_or_insert(anyhow::anyhow!("unexpected response {other:?}"));
                }
                // I/O error: the connection is gone anyway, stop reading.
                Err(e) => return Err(e),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(accepted),
        }
    }

    /// Top-k for many src nodes in one round trip (`MTOPK`), pipelining
    /// chunks behind a single flush. Answers come back in `srcs` order.
    pub fn topk_batch(&mut self, srcs: &[u64], k: usize) -> Result<Vec<Vec<(u64, f64)>>> {
        if srcs.is_empty() {
            return Ok(Vec::new());
        }
        let mut nchunks = 0;
        for chunk in srcs.chunks(MAX_WIRE_BATCH) {
            writeln!(
                self.writer,
                "{}",
                Request::MultiTopK { srcs: chunk.to_vec(), k, id: None }.encode()
            )?;
            nchunks += 1;
        }
        self.writer.flush()?;
        // As in `observe_batch`: drain every pipelined response before
        // surfacing an error, or the connection desyncs.
        let mut out = Vec::with_capacity(srcs.len());
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..nchunks {
            match self.read_response() {
                Ok(Response::MultiItems(bodies)) => {
                    out.extend(bodies.into_iter().map(|b| b.items));
                }
                Ok(Response::Err(e)) => {
                    first_err.get_or_insert(anyhow::anyhow!("topk_batch rejected: {e}"));
                }
                Ok(other) => {
                    first_err.get_or_insert(anyhow::anyhow!("unexpected response {other:?}"));
                }
                Err(e) => return Err(e),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if out.len() != srcs.len() {
            anyhow::bail!("topk_batch: {} answers for {} queries", out.len(), srcs.len());
        }
        Ok(out)
    }

    fn read_response(&mut self) -> Result<Response> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed connection");
        }
        Response::parse(line.trim_end()).map_err(|e| anyhow::anyhow!(e))
    }

    pub fn recommend(&mut self, src: u64, threshold: f64) -> Result<Vec<(u64, f64)>> {
        match self.request(&Request::Recommend { src, threshold })? {
            Response::Items { items, .. } => Ok(items),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn topk(&mut self, src: u64, k: usize) -> Result<Vec<(u64, f64)>> {
        match self.request(&Request::TopK { src, k, id: None })? {
            Response::Items { items, .. } => Ok(items),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        match self.request(&Request::Stats)? {
            Response::Ok(s) => Ok(s),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the Prometheus text exposition over the line protocol
    /// (`METRICS`). The response is the protocol's one multi-line body,
    /// read until the `# EOF` sentinel line.
    pub fn metrics(&mut self) -> Result<String> {
        writeln!(self.writer, "{}", Request::Metrics.encode())?;
        self.writer.flush()?;
        let mut body = String::new();
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("server closed connection mid-METRICS");
            }
            if line.trim_end() == "# EOF" {
                return Ok(body);
            }
            body.push_str(&line);
        }
    }

    /// `TRACE dump n`: the raw single-line span listing.
    pub fn trace_dump(&mut self, n: usize) -> Result<String> {
        match self.request(&Request::Trace(TraceCmd::Dump(n)))? {
            Response::Ok(s) => Ok(s),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// `EVENTS n`: the raw single-line event-record listing.
    pub fn events(&mut self, n: usize) -> Result<String> {
        match self.request(&Request::Events(n))? {
            Response::Ok(s) => Ok(s),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Force a durability checkpoint (`SAVE`); returns the server's
    /// `gen=... nodes=... bytes=...` detail line.
    pub fn save(&mut self) -> Result<String> {
        match self.request(&Request::Save)? {
            Response::Ok(s) => Ok(s),
            Response::Err(e) => anyhow::bail!("save rejected: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}
