//! The sharded serving engine: chain shards + per-shard ingestion queues +
//! shard-affine workers.
//!
//! Batch-first data flow (this module's refactor): producers route each
//! update to its shard's own [`BoundedQueue`] (FIB hash, same routing as
//! queries), and every ingest worker owns a *static subset* of shards —
//! worker `w` drains shards `w, w + W, w + 2W, …`. Consequences:
//!
//! * No cross-worker queue contention: a shard queue's lock is shared by
//!   the producers and exactly one consumer.
//! * Per-shard FIFO is preserved (one consumer per shard), so queued
//!   ingestion is *deterministic* per shard — the differential tests
//!   compare `export()` snapshots byte-for-byte against direct ingestion.
//! * Each drained batch is all same-shard, so it is applied through
//!   `McPrioQ::observe_batch` — one RCU pin per batch and cached src-node
//!   lookups, with the worker staying inside one shard's working set
//!   (cache locality).

use std::sync::{Arc, OnceLock, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::audit::{AuditConfig, Auditor, PersistView};
use crate::chain::{ChainConfig, McPrioQ, Recommendation};
use crate::config::ServerConfig;
use crate::metrics::events::{self, Level};
use crate::metrics::{Counter, Histogram, Meter, Registry};
use crate::persist::{codec, LogOutcome, PersistState};
use crate::rcu;
use crate::replicate::ReplicaState;
use crate::runtime::RetryPolicy;
use crate::sync::shim::{AtomicBool, Ordering};

use super::health::{Health, HealthState};
use super::queue::BoundedQueue;

const FIB: u64 = 0x9E37_79B9_7F4A_7C15;

/// Max updates a worker applies per queue drain (bounds batch latency and
/// the time one RCU guard stays pinned).
const DRAIN_BATCH: usize = 256;

/// How long an idle worker parks on one of its queues before sweeping the
/// others (closed queues wake it immediately via notify).
const IDLE_PARK: Duration = Duration::from_millis(2);

/// How often the background heal task re-checks the health ladder while
/// the engine is `Healthy` (cheap: one atomic load per tick).
const HEAL_POLL: Duration = Duration::from_millis(50);

/// Aggregated serving metrics (the STATS response / EXPERIMENTS.md rows).
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub shards: usize,
    pub nodes: usize,
    pub edges: usize,
    pub observes: u64,
    pub queries: u64,
    pub dropped_updates: u64,
    /// Updates applied by ingest workers (excludes `observe_direct`).
    pub applied_updates: u64,
    /// Decay passes summed over shards (an engine-level `decay()` counts
    /// once per shard — it is per-shard maintenance work). The old `max`
    /// aggregate under-reported multi-shard maintenance; the per-shard
    /// values are kept alongside so the old "passes" reading is still
    /// derivable.
    pub decays: u64,
    pub decays_per_shard: Vec<u64>,
    /// Edges pruned by decay, summed over shards.
    pub pruned_edges: u64,
    pub queue_depth: usize,
    /// Full query-latency summary (nanoseconds) from the engine's
    /// log-bucketed histogram — the same snapshot the telemetry registry
    /// exports as the `mcprioq_query_ns` summary family.
    pub query_ns_p50: u64,
    pub query_ns_p90: u64,
    pub query_ns_p99: u64,
    pub query_ns_p999: u64,
    pub query_ns_min: u64,
    pub query_ns_max: u64,
    pub query_ns_mean: f64,
    /// Applied updates/sec over the window since the previous `stats()`
    /// call (wired to the ingest meter; no longer a placeholder).
    pub update_rate: f64,
    /// Read-snapshot effectiveness, summed over shards (see
    /// DESIGN.md § Read pipeline): queries served from a fresh prefix-sum
    /// snapshot / snapshot rebuilds / list-walk fallbacks.
    pub snap_hits: u64,
    pub snap_rebuilds: u64,
    pub snap_fallbacks: u64,
    /// Durability gauges (all 0 when persistence is disabled): live WAL
    /// bytes on disk, seconds since the last committed checkpoint, batches
    /// replayed from the WAL at startup, and failed WAL appends (non-zero
    /// means batches are being served without surviving a crash).
    pub wal_bytes: u64,
    pub ckpt_age_s: u64,
    pub recovered_batches: u64,
    pub wal_errors: u64,
    /// WAL epoch the writers append into (0 when persistence is off) and
    /// each shard's highest appended sequence number — the replication
    /// coordinates: a follower's lag is the leader's `wal_last_seqs` minus
    /// its own, shard by shard.
    pub wal_epoch: u64,
    pub wal_last_seqs: Vec<u64>,
    /// Degradation ladder (DESIGN.md §8): the current rung ("healthy" /
    /// "degraded" / "recovering"), updates shed by admission control when
    /// a shard queue saturated, write verbs refused by a connection's
    /// token bucket, heal attempts by the WAL-retry task, and total
    /// seconds spent off the healthy rung.
    pub health: &'static str,
    pub shed: u64,
    pub ratelimited: u64,
    pub wal_retry: u64,
    pub degraded_s: u64,
    /// Approximate resident bytes: per-shard structures (node states,
    /// cache-line-padded edge nodes, dst tables, read snapshots and their
    /// Eytzinger mirrors) plus the edge arena's slack — open-block tails,
    /// headers, and not-yet-reclaimed holes — counted once process-wide,
    /// so memory reporting stays honest after the allocator change.
    pub approx_bytes: usize,
    /// Resident bytes held by edge-arena blocks (allocated − freed).
    pub arena_bytes: u64,
}

/// One MCPrioQ per shard; srcs are hash-routed so every shard sees a
/// disjoint key space (a single shard is the paper's plain design; more
/// shards are the E3 scaling ablation).
pub struct Engine {
    shards: Vec<McPrioQ>,
    /// One ingestion queue per shard, same index space as `shards`.
    queues: Vec<Arc<BoundedQueue<(u64, u64)>>>,
    workers: std::sync::Mutex<Vec<JoinHandle<u64>>>,
    stop: Arc<AtomicBool>,
    /// The engine's named metric registry (DESIGN.md §9). Every counter/
    /// histogram field below is an `Arc` handed out by this registry, so
    /// `METRICS`/`/metrics` exposition and the `STATS` verb read the very
    /// same atomics — `EngineStats` is a *view* over the registry, not a
    /// parallel set of private fields.
    telemetry: Arc<Registry>,
    queries: Arc<Counter>,
    dropped: Arc<Counter>,
    /// Updates *submitted* to some shard queue. Incremented BEFORE the
    /// push, so any update visible in a queue is already counted — that
    /// ordering is what makes `quiesce` race-free against producers.
    enqueued: Arc<Counter>,
    /// …updates actually applied by ingest workers…
    applied: Arc<Counter>,
    /// …and submissions the queue refused (closed/full): counted so the
    /// pre-push `enqueued` increment is balanced and quiesce terminates.
    rejected: Arc<Counter>,
    /// Updates shed by the non-blocking admission path (`observe_shed` /
    /// `observe_batch_shed`): the queue was full and the server answered
    /// `ERR overload` instead of blocking the connection.
    shed: Arc<Counter>,
    /// Write verbs refused by a connection's token bucket.
    ratelimited: Arc<Counter>,
    query_lat: Arc<Histogram>,
    /// Per-stage pipeline timing (DESIGN.md §9): WAL append + fsync,
    /// in-memory batch apply, whole checkpoints, and heal-drain passes.
    /// (Ingest queue wait lives in the queues themselves; snapshot-rebuild
    /// timing lives in each shard's `ReadMetrics`.)
    wal_append_ns: Arc<Histogram>,
    batch_apply_ns: Arc<Histogram>,
    checkpoint_ns: Arc<Histogram>,
    heal_drain_ns: Arc<Histogram>,
    update_meter: Meter,
    /// Durability state (WAL writers + checkpoint bookkeeping), armed once
    /// by `persist::open_engine` after recovery finishes. `None`/unset =
    /// in-memory only (the paper's original mode; also every bench/test
    /// that doesn't opt in).
    persist: OnceLock<Arc<PersistState>>,
    /// Pauses the apply path at a batch boundary: workers hold the read
    /// side around each (WAL append + observe_batch); `with_ingest_paused`
    /// takes the write side so checkpoints cut at an exact batch boundary.
    ingest_gate: RwLock<()>,
    /// Resolved `[replicate]` knobs (heartbeat cadence, snapshot fallback
    /// threshold, …) for the leader-side streamer and the follower link.
    replicate: crate::config::ReplicateConfig,
    /// Degradation-ladder state (DESIGN.md §8): what `HEALTH` answers and
    /// what the server's dispatch consults before admitting write verbs.
    health: HealthState,
    /// `[server] rate_limit_ops` / `rate_limit_burst` (0 = admission
    /// control off). Stored here so the server can build per-connection
    /// token buckets without re-threading the config.
    admission: (u64, u64),
    /// Resolved `[audit]` knobs for the correctness observatory
    /// (DESIGN.md §10).
    audit: AuditConfig,
    /// Latch so [`Engine::spawn_audit`] arms at most one audit thread.
    audit_armed: AtomicBool,
}

impl Engine {
    /// Build an engine with `shards` chains (0 = available parallelism)
    /// and `workers` ingest threads. Shards are distributed round-robin
    /// over the workers; with `workers == 0` nothing drains the queues
    /// (load-shedding test setups rely on this).
    pub fn new(config: &ServerConfig, workers: usize) -> Arc<Engine> {
        let nshards = if config.shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            config.shards
        };
        let chain_cfg: ChainConfig = config.to_chain_config();
        let queues: Vec<Arc<BoundedQueue<(u64, u64)>>> =
            (0..nshards).map(|_| Arc::new(BoundedQueue::new(config.queue_capacity))).collect();
        // Every hot-path metric is created through the registry so the
        // exposition reads the same atomics the engine records into.
        let reg = Arc::new(Registry::new());
        let c = |name: &str, help: &str| reg.counter(name, help, &[]);
        let h = |name: &str, help: &str| reg.histogram(name, help, &[]);
        // One queue-wait histogram shared by every shard queue: pops
        // record the age of the oldest consumed cohort (ingest queue-wait
        // stage, DESIGN.md §9).
        let queue_wait =
            h("mcprioq_queue_wait_ns", "Ingest queue wait per drained cohort (ns).");
        for q in &queues {
            q.set_wait_histogram(Arc::clone(&queue_wait));
        }
        let engine = Arc::new(Engine {
            shards: (0..nshards).map(|_| McPrioQ::new(chain_cfg.clone())).collect(),
            queues,
            workers: std::sync::Mutex::new(Vec::new()),
            stop: Arc::new(AtomicBool::new(false)),
            queries: c("mcprioq_queries_total", "Inference queries served."),
            dropped: c(
                "mcprioq_updates_dropped_total",
                "Lossy-path updates dropped on queue overflow.",
            ),
            enqueued: c("mcprioq_updates_enqueued_total", "Updates submitted to shard queues."),
            applied: c("mcprioq_updates_applied_total", "Updates applied by ingest workers."),
            rejected: c(
                "mcprioq_updates_rejected_total",
                "Submissions refused by a closed or full queue.",
            ),
            shed: c(
                "mcprioq_updates_shed_total",
                "Updates shed by admission control (queue saturated).",
            ),
            ratelimited: c(
                "mcprioq_ratelimited_total",
                "Write verbs refused by a connection token bucket.",
            ),
            query_lat: h("mcprioq_query_ns", "Inference query service time (ns)."),
            wal_append_ns: h("mcprioq_wal_append_ns", "WAL append + fsync per batch (ns)."),
            batch_apply_ns: h("mcprioq_batch_apply_ns", "In-memory batch apply (ns)."),
            checkpoint_ns: h("mcprioq_checkpoint_ns", "Whole checkpoint duration (ns)."),
            heal_drain_ns: h("mcprioq_heal_drain_ns", "Heal-drain pass duration (ns)."),
            update_meter: Meter::new(),
            telemetry: Arc::clone(&reg),
            persist: OnceLock::new(),
            ingest_gate: RwLock::new(()),
            replicate: config.replicate_config(),
            health: HealthState::new(),
            admission: (config.rate_limit_ops, config.rate_limit_burst),
            audit: config.audit_config(),
            audit_armed: AtomicBool::new(false),
        });
        engine.register_derived_metrics();
        // Spawn shard-affine ingest workers. They hold their queue Arcs
        // plus a Weak to the engine, so dropping the last user Arc tears
        // everything down: Engine::drop closes the queues, workers wake,
        // fail the upgrade, and exit; drop then joins them.
        {
            let pin = config.runtime.pin_workers;
            let core_offset = config.runtime.core_offset;
            let ncpus =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let mut ws = engine.workers.lock().unwrap();
            for w in 0..workers {
                let owned: Vec<(usize, Arc<BoundedQueue<(u64, u64)>>)> = (0..nshards)
                    .filter(|i| i % workers == w)
                    .map(|i| (i, Arc::clone(&engine.queues[i])))
                    .collect();
                let weak = Arc::downgrade(&engine);
                ws.push(std::thread::spawn(move || {
                    // Shard ownership is static, so pinning worker w to one
                    // core keeps its shards' working set (and its arena
                    // blocks) resident in one cache hierarchy. Best-effort:
                    // a restricted cpuset just leaves the worker floating.
                    if pin {
                        let cpu = (core_offset + w) % ncpus;
                        if let Err(errno) = crate::runtime::pin_current_thread(cpu) {
                            eprintln!(
                                "mcprioq: could not pin ingest worker {w} to cpu {cpu} \
                                 (errno {errno}); continuing unpinned"
                            );
                        }
                    }
                    Engine::ingest_loop(weak, owned)
                }));
            }
        }
        engine
    }

    /// Register every *derived* series: sampled closures evaluated only at
    /// exposition time. Closures that need the engine capture a `Weak`
    /// (the engine owns the registry, so a strong capture would cycle and
    /// leak); per-shard queue closures clone the queue `Arc`s directly.
    fn register_derived_metrics(self: &Arc<Engine>) {
        let reg = &self.telemetry;
        // Per-shard queue depth: over the queue Arcs, engine-independent.
        for (i, q) in self.queues.iter().enumerate() {
            let q = Arc::clone(q);
            reg.gauge_fn(
                "mcprioq_queue_depth",
                "Pending updates in a shard's ingest queue.",
                &[("shard", &i.to_string())],
                move || q.len() as f64,
            );
        }
        // Per-shard model shape, read-snapshot effectiveness, and arena
        // occupancy (edge count × 64-byte slot — the per-shard arena-stats
        // follow-on from ROADMAP; allocation attribution is address-based
        // and cross-thread, so occupancy is derived, not counted).
        for i in 0..self.shards.len() {
            let shard_label = i.to_string();
            let labels: &[(&str, &str)] = &[("shard", &shard_label)];
            let w = Arc::downgrade(self);
            reg.gauge_fn("mcprioq_nodes", "Distinct src nodes per shard.", labels, move || {
                w.upgrade().map_or(0.0, |e| e.shards[i].node_count() as f64)
            });
            let w = Arc::downgrade(self);
            reg.gauge_fn("mcprioq_edges", "Live edges per shard.", labels, move || {
                w.upgrade().map_or(0.0, |e| e.shards[i].edge_count() as f64)
            });
            let w = Arc::downgrade(self);
            reg.gauge_fn(
                "mcprioq_arena_occupancy_bytes",
                "Arena bytes occupied by a shard's edge nodes.",
                labels,
                move || {
                    w.upgrade().map_or(0.0, |e| {
                        (e.shards[i].edge_count() * crate::chain::arena::SLOT_BYTES) as f64
                    })
                },
            );
            let w = Arc::downgrade(self);
            reg.counter_fn(
                "mcprioq_observes_total",
                "Transitions observed per shard.",
                labels,
                move || w.upgrade().map_or(0, |e| e.shards[i].observe_count()),
            );
            let w = Arc::downgrade(self);
            reg.counter_fn(
                "mcprioq_snap_hits_total",
                "Queries served from a fresh read snapshot.",
                labels,
                move || w.upgrade().map_or(0, |e| e.shards[i].snap_counters().0),
            );
            let w = Arc::downgrade(self);
            reg.counter_fn(
                "mcprioq_snap_rebuilds_total",
                "Read-snapshot rebuilds.",
                labels,
                move || w.upgrade().map_or(0, |e| e.shards[i].snap_counters().1),
            );
            let w = Arc::downgrade(self);
            reg.counter_fn(
                "mcprioq_snap_fallbacks_total",
                "Queries that fell back to the list walk.",
                labels,
                move || w.upgrade().map_or(0, |e| e.shards[i].snap_counters().2),
            );
            let w = Arc::downgrade(self);
            reg.summary_fn(
                "mcprioq_snap_rebuild_ns",
                "Read-snapshot rebuild duration (ns).",
                labels,
                move || {
                    w.upgrade().map_or_else(Default::default, |e| e.shards[i].snap_rebuild_lat())
                },
            );
        }
        // Health ladder as a 0/1-per-rung labeled gauge timeline: exactly
        // one of the three series is 1 at any instant, so a scrape series
        // shows the ladder transitions (the chaos smoke asserts on this).
        for rung in ["healthy", "degraded", "recovering"] {
            let w = Arc::downgrade(self);
            reg.gauge_fn(
                "mcprioq_health_state",
                "Degradation-ladder rung (1 = current).",
                &[("state", rung)],
                move || match w.upgrade() {
                    Some(e) if e.health.health().as_str() == rung => 1.0,
                    _ => 0.0,
                },
            );
        }
        let w = Arc::downgrade(self);
        reg.counter_fn(
            "mcprioq_wal_retry_total",
            "Heal attempts by the WAL-retry task.",
            &[],
            move || w.upgrade().map_or(0, |e| e.health.wal_retry.get()),
        );
        let w = Arc::downgrade(self);
        reg.gauge_fn(
            "mcprioq_degraded_seconds",
            "Total seconds spent off the healthy rung.",
            &[],
            move || w.upgrade().map_or(0.0, |e| e.health.degraded_seconds() as f64),
        );
        let w = Arc::downgrade(self);
        reg.gauge_fn(
            "mcprioq_update_rate",
            "Applied updates/sec over the exposition window.",
            &[],
            move || w.upgrade().map_or(0.0, |e| e.update_meter.rate()),
        );
        // RCU reclamation: deferred-free backlog and grace-period age
        // (process-global, like the collector itself).
        reg.gauge_fn(
            "mcprioq_rcu_pending",
            "RCU deferred-free backlog (closures awaiting a grace period).",
            &[],
            || rcu::collector_stats().pending as f64,
        );
        reg.counter_fn("mcprioq_rcu_freed_total", "RCU deferred frees executed.", &[], || {
            rcu::collector_stats().freed as u64
        });
        reg.counter_fn("mcprioq_rcu_advances_total", "Global RCU epoch advances.", &[], || {
            rcu::collector_stats().advances
        });
        reg.gauge_fn(
            "mcprioq_rcu_grace_age_seconds",
            "Seconds since the RCU epoch last advanced.",
            &[],
            || rcu::grace_age_ns() as f64 / 1e9,
        );
        // Structured event log (DESIGN.md §10): the ring is process-global
        // like RCU; the counter makes event production rate scrapeable.
        reg.counter_fn(
            "mcprioq_events_emitted_total",
            "Structured events recorded in the event ring.",
            &[],
            events::emitted,
        );
        crate::chain::arena::register_metrics(reg);
    }

    /// The engine's telemetry registry (the `METRICS` verb and the HTTP
    /// sidecar render through this).
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// Append the full Prometheus text exposition to `out`.
    pub fn render_metrics(&self, out: &mut String) {
        self.telemetry.render_into(out);
    }

    /// Drain-and-apply loop for one worker's shard set. Returns the number
    /// of updates this worker applied.
    fn ingest_loop(
        weak: std::sync::Weak<Engine>,
        owned: Vec<(usize, Arc<BoundedQueue<(u64, u64)>>)>,
    ) -> u64 {
        let mut applied = 0u64;
        if owned.is_empty() {
            return 0; // more workers than shards; nothing to own
        }
        // Apply one same-shard batch; None = engine gone mid-shutdown.
        // Write-ahead: the WAL append happens before the in-memory apply,
        // both inside the ingest gate, so a checkpoint's cut point (last
        // appended seq at a quiesced pause) contains exactly the applied
        // batches — recovery never loses an acked batch and never applies
        // one twice.
        //
        // Fault handling never panics this worker (DESIGN.md §8): a failed
        // *append* parks the batch in the shard's quarantine (unapplied —
        // applying an unlogged batch would diverge recovery) and degrades
        // the engine; a failed *fsync* after the record was framed applies
        // the batch (un-acking a framed record would double-apply on
        // replay) and degrades until a sync lands.
        let apply = |shard: usize, batch: &[(u64, u64)]| -> Option<u64> {
            let engine = weak.upgrade()?;
            let _gate =
                engine.ingest_gate.read().unwrap_or_else(PoisonError::into_inner);
            if let Some(persist) = engine.persist.get() {
                let t0 = std::time::Instant::now();
                let outcome = persist.log_batch(shard, batch);
                engine.wal_append_ns.record(t0.elapsed().as_nanos() as u64);
                match outcome {
                    LogOutcome::Logged => {}
                    LogOutcome::SyncDegraded(why) => engine.health.degrade(&why),
                    LogOutcome::Parked(why) => {
                        events::emit(
                            Level::Warn,
                            "persist",
                            "parked",
                            shard as u64,
                            batch.len() as u64,
                        );
                        engine.health.degrade(&why);
                        // Parked, not applied: the heal task re-logs and
                        // applies it in order once the disk recovers.
                        return Some(0);
                    }
                }
            }
            let t0 = std::time::Instant::now();
            engine.shards[shard].observe_batch(batch);
            engine.batch_apply_ns.record(t0.elapsed().as_nanos() as u64);
            let n = batch.len() as u64;
            engine.update_meter.mark_n(n);
            engine.applied.add(n);
            Some(n)
        };
        let mut park = 0usize;
        loop {
            let mut drained = false;
            let mut live = false;
            for (shard, q) in &owned {
                let batch = q.try_pop_batch(DRAIN_BATCH);
                if batch.is_empty() {
                    live |= !q.is_closed();
                    continue;
                }
                live = true;
                drained = true;
                match apply(*shard, &batch) {
                    Some(n) => applied += n,
                    None => return applied, // drop the batch, like shutdown
                }
            }
            if drained {
                continue;
            }
            if !live {
                return applied; // every owned queue closed and drained
            }
            // Nothing ready anywhere: park briefly on one owned queue
            // (rotating) instead of spinning over empty queues.
            let (shard, q) = &owned[park % owned.len()];
            park += 1;
            let batch = q.pop_batch_timeout(DRAIN_BATCH, IDLE_PARK);
            if !batch.is_empty() {
                match apply(*shard, &batch) {
                    Some(n) => applied += n,
                    None => return applied,
                }
            }
        }
    }

    /// Hash-route `src` among `nshards`. Public because recovery across a
    /// shard-layout change needs the *old* layout's ownership to replay an
    /// old shard's maintenance records onto exactly the srcs it owned.
    #[inline]
    pub fn route(src: u64, nshards: usize) -> usize {
        (src.wrapping_mul(FIB) >> 33) as usize % nshards
    }

    #[inline]
    fn shard_index(&self, src: u64) -> usize {
        Self::route(src, self.shards.len())
    }

    #[inline]
    pub fn shard(&self, src: u64) -> &McPrioQ {
        &self.shards[self.shard_index(src)]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Group a batch into per-shard runs, indexed by shard. Shared by the
    /// queued and direct batch paths so their routing can never diverge.
    fn partition_by_shard(&self, pairs: &[(u64, u64)]) -> Vec<Vec<(u64, u64)>> {
        let mut per_shard: Vec<Vec<(u64, u64)>> = vec![Vec::new(); self.shards.len()];
        for &(src, dst) in pairs {
            per_shard[self.shard_index(src)].push((src, dst));
        }
        per_shard
    }

    /// Enqueue an update on its shard's queue (blocking backpressure).
    /// False if shutting down.
    pub fn observe(&self, src: u64, dst: u64) -> bool {
        self.enqueued.inc();
        let ok = self.queues[self.shard_index(src)].push((src, dst));
        if !ok {
            self.rejected.inc();
        }
        ok
    }

    /// Enqueue a batch of updates: route by shard, then bulk-push each
    /// shard's run in one queue-lock acquisition (blocking backpressure
    /// per shard). Returns the number of updates accepted — short only if
    /// the engine is shutting down.
    pub fn observe_batch(&self, pairs: &[(u64, u64)]) -> usize {
        if pairs.is_empty() {
            return 0;
        }
        let submit = |queue: &BoundedQueue<(u64, u64)>, items: Vec<(u64, u64)>| -> usize {
            let len = items.len();
            self.enqueued.add(len as u64);
            let n = queue.push_bulk(items);
            self.rejected.add((len - n) as u64);
            n
        };
        if self.queues.len() == 1 {
            return submit(&self.queues[0], pairs.to_vec());
        }
        let mut accepted = 0;
        for (i, items) in self.partition_by_shard(pairs).into_iter().enumerate() {
            if !items.is_empty() {
                accepted += submit(&self.queues[i], items);
            }
        }
        accepted
    }

    /// Admission-control enqueue: non-blocking, `false` when the shard
    /// queue is saturated (counted in `shed=`; the server answers
    /// `ERR overload` instead of stalling the connection).
    pub fn observe_shed(&self, src: u64, dst: u64) -> bool {
        self.enqueued.inc();
        if self.queues[self.shard_index(src)].try_push((src, dst)).is_err() {
            self.rejected.inc();
            self.shed.inc();
            return false;
        }
        true
    }

    /// Admission-control batch enqueue: accepts as much of each shard run
    /// as fits without blocking and sheds the rest. Returns
    /// `(accepted, shed)`; a non-zero shed count becomes `ERR overload`
    /// on the wire — under saturation the tail of a batch is dropped
    /// *and reported*, never silently.
    pub fn observe_batch_shed(&self, pairs: &[(u64, u64)]) -> (usize, usize) {
        if pairs.is_empty() {
            return (0, 0);
        }
        let submit = |queue: &BoundedQueue<(u64, u64)>, items: Vec<(u64, u64)>| -> (usize, usize) {
            let len = items.len();
            self.enqueued.add(len as u64);
            let n = queue.try_push_bulk(items);
            self.rejected.add((len - n) as u64);
            self.shed.add((len - n) as u64);
            (n, len - n)
        };
        if self.queues.len() == 1 {
            return submit(&self.queues[0], pairs.to_vec());
        }
        let (mut accepted, mut shed) = (0, 0);
        for (i, items) in self.partition_by_shard(pairs).into_iter().enumerate() {
            if !items.is_empty() {
                let (a, s) = submit(&self.queues[i], items);
                accepted += a;
                shed += s;
            }
        }
        (accepted, shed)
    }

    /// Enqueue without blocking; drops (and counts) on overflow — the
    /// load-shedding policy for best-effort telemetry feeds.
    pub fn observe_lossy(&self, src: u64, dst: u64) {
        self.enqueued.inc();
        if self.queues[self.shard_index(src)].try_push((src, dst)).is_err() {
            self.rejected.inc();
            self.dropped.inc();
        }
    }

    /// Apply an update on the caller thread, bypassing the queue (embedded
    /// / benchmark use; this is the raw wait-free path).
    pub fn observe_direct(&self, src: u64, dst: u64) {
        self.shard(src).observe(src, dst);
    }

    /// Apply one replicated WAL record to `shard` — the follower's apply
    /// path (DESIGN.md §5). Mirrors the ingest worker exactly: (local WAL
    /// append → in-memory apply) under the read side of the ingest gate,
    /// so follower checkpoints still cut at exact record boundaries and a
    /// promoted follower is itself durable. Maintenance records go through
    /// the same [`Engine::apply_op`] dispatch as recovery — the follower
    /// decays in lockstep with the leader (leader-driven maintenance; its
    /// own `DecayScheduler` stays off until promotion). When persistence
    /// is armed the local WAL must hand out exactly `seq` (the leader's
    /// sequence number); a mismatch means the streams diverged and is
    /// fatal to the link — applying anyway would double-count records
    /// after a restart.
    pub fn apply_replicated(
        &self,
        shard: usize,
        seq: u64,
        op: &codec::WalOp,
    ) -> Result<(), String> {
        if shard >= self.shards.len() {
            return Err(format!(
                "replicated record for shard {shard}, engine has {}",
                self.shards.len()
            ));
        }
        let _gate = self.ingest_gate.read().unwrap_or_else(PoisonError::into_inner);
        if let Some(persist) = self.persist.get() {
            let got = persist
                .append_op(shard, op)
                .map_err(|e| format!("wal append on shard {shard}: {e}"))?;
            if got != seq {
                return Err(format!(
                    "replicated seq {seq} landed at local wal seq {got} on shard {shard}"
                ));
            }
        }
        self.apply_op(shard, op);
        if let codec::WalOp::Batch(batch) = op {
            self.update_meter.mark_n(batch.len() as u64);
        }
        Ok(())
    }

    /// Resolved `[replicate]` configuration (leader streamer + follower
    /// link read their knobs through the engine).
    pub fn replicate_config(&self) -> &crate::config::ReplicateConfig {
        &self.replicate
    }

    /// Current rung of the degradation ladder (DESIGN.md §8).
    pub fn health(&self) -> Health {
        self.health.health()
    }

    /// Why the engine left `Healthy` (empty string when healthy).
    pub fn health_reason(&self) -> String {
        self.health.reason()
    }

    /// Milliseconds until the heal task probes the fault again — the
    /// `retry_after_ms=` hint on rejected writes.
    pub fn health_retry_after_ms(&self) -> u64 {
        self.health.retry_after_ms()
    }

    /// Force the ladder onto the degraded rung (tests exercise dispatch
    /// gating without needing a real disk fault).
    #[cfg(test)]
    pub(crate) fn degrade_for_test(&self, why: &str) {
        self.health.degrade(why);
    }

    /// Undo [`Engine::degrade_for_test`]: the in-memory engines the wire
    /// tests use have no persist state, so no heal task climbs back for
    /// them.
    #[cfg(test)]
    pub(crate) fn heal_for_test(&self) {
        self.health.healed();
    }

    /// Panic a helper thread while it holds each shard queue's lock — the
    /// sharpest version of "an ingest worker died mid-critical-section".
    /// Tests assert the ingest plane survives the poisoned mutexes
    /// (non-poisoning lock recovery, see `BoundedQueue::locked`).
    #[cfg(test)]
    pub(crate) fn poison_queues_for_test(&self) {
        for q in &self.queues {
            q.poison_for_test();
        }
    }

    /// Panic a helper thread while it holds the ingest gate's read side,
    /// poisoning the `RwLock` the way a dying ingest worker would.
    #[cfg(test)]
    pub(crate) fn poison_ingest_gate_for_test(self: &Arc<Self>) {
        let me = Arc::clone(self);
        let t = std::thread::spawn(move || {
            let _gate = me.ingest_gate.read().unwrap();
            panic!("simulated ingest-worker panic while holding the gate");
        });
        assert!(t.join().is_err(), "the helper must have panicked");
    }

    /// `[server] rate_limit_ops` / `rate_limit_burst` (0 = admission off).
    pub fn admission_limits(&self) -> (u64, u64) {
        self.admission
    }

    /// Count one write verb refused by a connection's token bucket.
    pub(crate) fn note_ratelimited(&self) {
        self.ratelimited.inc();
    }

    /// Apply a batch on the caller thread, bypassing the queues: grouped
    /// by shard, each group through the single-guard batch path.
    pub fn observe_batch_direct(&self, pairs: &[(u64, u64)]) {
        if self.shards.len() == 1 {
            self.shards[0].observe_batch(pairs);
            return;
        }
        for (i, items) in self.partition_by_shard(pairs).into_iter().enumerate() {
            if !items.is_empty() {
                self.shards[i].observe_batch(&items);
            }
        }
    }

    pub fn infer_threshold(&self, src: u64, t: f64) -> Recommendation {
        let mut out = Recommendation::default();
        self.infer_threshold_into(src, t, &mut out);
        out
    }

    /// Allocation-free query path: the answer lands in `out`, reusing its
    /// buffers (the server keeps one per connection).
    pub fn infer_threshold_into(&self, src: u64, t: f64, out: &mut Recommendation) {
        self.queries.inc();
        let timer = crate::metrics::Timer::start(&self.query_lat);
        self.shard(src).infer_threshold_into(src, t, out);
        drop(timer);
    }

    pub fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        let mut out = Recommendation::default();
        self.infer_topk_into(src, k, &mut out);
        out
    }

    /// Allocation-free query path: see [`Engine::infer_threshold_into`].
    pub fn infer_topk_into(&self, src: u64, k: usize, out: &mut Recommendation) {
        self.queries.inc();
        let timer = crate::metrics::Timer::start(&self.query_lat);
        self.shard(src).infer_topk_into(src, k, out);
        drop(timer);
    }

    /// Answer top-k for many srcs under **one RCU guard** (srcs may span
    /// shards — the grace period is process-global, so a single pin covers
    /// them all). Each answer is produced into `scratch` and handed to
    /// `each` before the next query overwrites it: the server's `MTOPK`
    /// streams n answers into one wire buffer with zero allocation and a
    /// single flush. Per-query latency/counter accounting is preserved.
    pub fn infer_topk_batch(
        &self,
        srcs: &[u64],
        k: usize,
        scratch: &mut Recommendation,
        mut each: impl FnMut(&Recommendation),
    ) {
        let guard = rcu::pin();
        for &src in srcs {
            self.queries.inc();
            let timer = crate::metrics::Timer::start(&self.query_lat);
            self.shard(src).infer_topk_with(&guard, src, k, scratch);
            drop(timer);
            each(scratch);
        }
    }

    /// Run one decay + repair pass over every shard (§II.C maintenance).
    ///
    /// With persistence armed, maintenance is *data* (DESIGN.md §6): a
    /// `DecayRecord` is appended to each shard's WAL under the write side
    /// of the ingest gate — the same gate batch applies hold — so the
    /// record's sequence position equals its apply position. The gate is
    /// taken **per shard** (append + decay that shard, release, next):
    /// the invariant is per-shard (seqs and cuts are per-shard; shards
    /// hold disjoint srcs), so the ingest stall is bounded by one shard's
    /// sweep instead of the whole model. Recovery and followers then
    /// replay decay exactly where it happened instead of restoring
    /// conservatively-larger pre-decay counts. In-memory engines keep the
    /// paper's lock-free concurrent decay (no gate).
    pub fn decay(&self) -> (u64, usize) {
        let cfg = self.shards[0].config();
        let (num, den) = (cfg.decay_num, cfg.decay_den);
        let mut total = 0;
        let mut pruned = 0;
        for (shard, s) in self.shards.iter().enumerate() {
            let (t, p) = match self.persist.get() {
                Some(persist) => {
                    let _gate =
                        self.ingest_gate.write().unwrap_or_else(PoisonError::into_inner);
                    // Log-then-apply, like the batch path. An unloggable
                    // decay is *dropped*, not applied: maintenance is
                    // periodic, so skipping a pass on a quarantined shard
                    // keeps memory and WAL consistent, while applying it
                    // unlogged would diverge recovery (DESIGN.md §8).
                    match persist.log_maintenance(shard, &codec::WalOp::Decay { num, den }) {
                        LogOutcome::Logged => s.decay_with(num, den),
                        LogOutcome::SyncDegraded(why) => {
                            self.health.degrade(&why);
                            s.decay_with(num, den)
                        }
                        LogOutcome::Parked(why) => {
                            self.health.degrade(&why);
                            (0, 0)
                        }
                    }
                }
                None => s.decay_with(num, den),
            };
            total += t;
            pruned += p;
        }
        (total, pruned)
    }

    /// Run one standalone order-repair sweep over every shard, logged as a
    /// `RepairRecord` when persistence is armed (same per-shard gate
    /// discipline as [`Engine::decay`]). Returns the swap count.
    pub fn repair(&self) -> u64 {
        let mut swaps = 0;
        for (shard, s) in self.shards.iter().enumerate() {
            swaps += match self.persist.get() {
                Some(persist) => {
                    let _gate =
                        self.ingest_gate.write().unwrap_or_else(PoisonError::into_inner);
                    // Same drop-on-failure policy as [`Engine::decay`].
                    match persist.log_maintenance(shard, &codec::WalOp::Repair) {
                        LogOutcome::Logged => s.repair(),
                        LogOutcome::SyncDegraded(why) => {
                            self.health.degrade(&why);
                            s.repair()
                        }
                        LogOutcome::Parked(why) => {
                            self.health.degrade(&why);
                            0
                        }
                    }
                }
                None => s.repair(),
            };
        }
        swaps
    }

    /// Apply one decoded WAL record to `shard`, in memory only — the one
    /// dispatch recovery and the follower apply path share, so replayed
    /// maintenance can never diverge from streamed maintenance.
    pub fn apply_op(&self, shard: usize, op: &codec::WalOp) {
        match op {
            codec::WalOp::Batch(pairs) => {
                self.shards[shard].observe_batch(pairs);
            }
            codec::WalOp::Decay { num, den } => {
                self.shards[shard].decay_with(*num, *den);
            }
            codec::WalOp::Repair => {
                self.shards[shard].repair();
            }
        }
    }

    /// Wait until every update enqueued *before this call* is applied (or
    /// was rejected by a closing queue, or parked in a degraded shard's
    /// quarantine). Tracked by submit/apply counters rather than queue
    /// emptiness, so batches popped-but-in-flight are waited on too;
    /// `enqueued` is incremented before items become visible in a queue,
    /// so the target can never undercount. Parked updates count as
    /// settled so a degraded engine still quiesces (it would otherwise
    /// spin forever against a quarantined WAL); the checkpointer refuses
    /// to cut while the engine is off the healthy rung, so the relaxation
    /// never reaches a manifest.
    pub fn quiesce(&self) {
        let target = self.enqueued.get();
        let parked = || self.persist.get().map(|p| p.parked_updates()).unwrap_or(0);
        while self.applied.get() + self.rejected.get() + parked() < target {
            std::thread::yield_now();
        }
        // One grace period so applied updates are fully visible.
        rcu::synchronize();
    }

    /// Merged snapshot across shards, sorted by src id (shards hold
    /// disjoint srcs, so this equals a single-chain export of the same
    /// stream — the differential tests rely on that).
    ///
    /// This does **not** quiesce the shard queues: batches still queued or
    /// mid-apply are silently missing from the result. Callers that need
    /// the every-acked-batch guarantee (the checkpointer, model save)
    /// must use [`Engine::export_quiesced`].
    pub fn export(&self) -> Vec<(u64, u64, Vec<(u64, u64)>)> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.export());
        }
        out.sort_unstable_by_key(|&(id, _, _)| id);
        out
    }

    /// [`Engine::export`] with the consistency guarantee a checkpoint
    /// needs: drains every update enqueued before the call (`quiesce`),
    /// then pauses the apply path at a batch boundary for the duration of
    /// the export. The result therefore contains *every* batch whose WAL
    /// append (when persistence is on) happened before the pause — an
    /// exact prefix of each shard's sequence — and no torn batches.
    pub fn export_quiesced(&self) -> Vec<(u64, u64, Vec<(u64, u64)>)> {
        self.with_ingest_paused(|| self.export())
    }

    /// Quiesce, then run `f` with the apply path paused at a batch
    /// boundary (workers blocked on the ingest gate; producers keep
    /// enqueueing against the queues' backpressure). The checkpointer uses
    /// this window to read WAL cut points and export atomically.
    pub(crate) fn with_ingest_paused<R>(&self, f: impl FnOnce() -> R) -> R {
        self.quiesce();
        let _gate = self.ingest_gate.write().unwrap_or_else(PoisonError::into_inner);
        f()
    }

    /// [`Engine::export`] restricted to nodes dirtied at or after mark
    /// `since` — the payload of a differential checkpoint. Call inside the
    /// checkpointer's ingest pause for an exact dirty set.
    pub fn export_dirty(&self, since: u64) -> Vec<(u64, u64, Vec<(u64, u64)>)> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.export_dirty(since));
        }
        out.sort_unstable_by_key(|&(id, _, _)| id);
        out
    }

    /// Total src nodes across shards (O(1) per shard — the checkpointer's
    /// dirty-ratio denominator).
    pub fn node_count(&self) -> usize {
        self.shards.iter().map(|s| s.node_count()).sum()
    }

    /// The shards' shared checkpoint mark (advanced in lockstep, so any
    /// shard's value is the engine's).
    pub fn ckpt_mark(&self) -> u64 {
        self.shards[0].ckpt_mark()
    }

    /// Advance every shard's checkpoint mark; returns the new value. Only
    /// meaningful inside an ingest pause (the checkpointer's window).
    pub fn advance_ckpt_mark(&self) -> u64 {
        let mut mark = 0;
        for s in &self.shards {
            mark = s.advance_ckpt_mark();
        }
        mark
    }

    /// Set every shard's checkpoint mark. Recovery uses this to restore
    /// the persisted mark from the `CKPT_MARK` sidecar so the first
    /// post-restart checkpoint can stay differential; only meaningful
    /// before ingestion starts or inside an ingest pause.
    pub fn set_ckpt_mark(&self, mark: u64) {
        for s in &self.shards {
            s.set_ckpt_mark(mark);
        }
    }

    /// Rebuild state from an exported snapshot: each node's edge list is
    /// replayed as one same-src weighted batch into its shard, mirroring
    /// `McPrioQ::import` (recovery and the persist tests rely on the
    /// result being export-identical). Bypasses the queues and the WAL.
    pub fn import_snapshot(&self, snapshot: &[(u64, u64, Vec<(u64, u64)>)]) {
        let mut batch = Vec::new();
        for (src, _total, edges) in snapshot {
            batch.clear();
            batch.extend(edges.iter().map(|&(dst, count)| (*src, dst, count)));
            self.shard(*src).observe_batch_weighted(&batch);
        }
    }

    /// Arm durability: called exactly once by `persist::open_engine` after
    /// recovery has replayed the WAL (so replayed batches are not
    /// re-logged). Ingest workers start logging on their next batch. Also
    /// spawns the WAL-retry heal task — durable engines are the only ones
    /// that can degrade, so in-memory engines never pay for the thread.
    pub(crate) fn attach_persist(self: &Arc<Self>, state: Arc<PersistState>) {
        state.register_metrics(&self.telemetry);
        if self.persist.set(state).is_err() {
            panic!("persist state attached twice");
        }
        let weak = Arc::downgrade(self);
        std::thread::spawn(move || Engine::heal_loop(weak));
    }

    /// Background WAL-retry task (DESIGN.md §8): while the engine is off
    /// the healthy rung, periodically re-arm quarantined shards — drain
    /// parked ops back through the WAL and re-probe fsync — under capped
    /// exponential backoff. Holds only a `Weak`, so engine teardown is
    /// never blocked on it; it exits on the poll after the engine drops.
    fn heal_loop(weak: std::sync::Weak<Engine>) {
        let retry = RetryPolicy::wal_retry(0x4EA1_5EED);
        let mut failures = 0u32;
        loop {
            let pause = {
                let Some(engine) = weak.upgrade() else { return };
                if engine.stop.load(Ordering::SeqCst) {
                    return;
                }
                if engine.health.health() == Health::Healthy {
                    failures = 0;
                    HEAL_POLL
                } else {
                    engine.health.begin_recovery();
                    engine.health.wal_retry.inc();
                    match engine.try_heal() {
                        Ok(()) => {
                            engine.health.healed();
                            failures = 0;
                            HEAL_POLL
                        }
                        Err(why) => {
                            // Back onto the degraded rung; the first
                            // reason of the outage is kept for clients.
                            engine.health.degrade(&why);
                            let pause = retry.delay(failures);
                            failures = failures.saturating_add(1);
                            engine
                                .health
                                .set_retry_after_ms(pause.as_millis().max(1) as u64);
                            pause
                        }
                    }
                }
            };
            // The Arc is out of scope before sleeping: a parked healer
            // must not keep a dropped engine alive for up to `cap`.
            std::thread::sleep(pause);
        }
    }

    /// One heal attempt across all shards: re-log + apply every parked op
    /// in arrival order (crash-safe — the abandoned segment left their
    /// seqs unconsumed, so re-appending stays contiguous), then force an
    /// fsync to clear any sync-degraded shard. Errors leave the remaining
    /// ops parked for the next attempt.
    fn try_heal(&self) -> Result<(), String> {
        let Some(persist) = self.persist.get() else { return Ok(()) };
        let t0 = std::time::Instant::now();
        // Same lock order as the ingest workers (gate.read → quarantine →
        // wal), so the drain serializes cleanly against batch applies and
        // checkpoint pauses.
        let _gate = self.ingest_gate.read().unwrap_or_else(PoisonError::into_inner);
        for shard in 0..self.shards.len() {
            persist
                .drain_quarantine(shard, |op| {
                    self.apply_op(shard, op);
                    if let codec::WalOp::Batch(batch) = op {
                        let n = batch.len() as u64;
                        self.applied.add(n);
                        self.update_meter.mark_n(n);
                    }
                })
                .map_err(|e| format!("shard {shard} wal retry failed: {e}"))?;
            persist
                .sync_shard(shard)
                .map_err(|e| format!("shard {shard} fsync probe failed: {e}"))?;
        }
        self.heal_drain_ns.record(t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    pub(crate) fn persist_state(&self) -> Option<&Arc<PersistState>> {
        self.persist.get()
    }

    /// Arm the correctness observatory (DESIGN.md §10): one background
    /// thread alternating approximation-error sampling with invariant
    /// watchdog rounds. Idempotent; no-op when `[audit] enabled = false`.
    /// On a follower, `replica` feeds the lag-bound check.
    pub fn spawn_audit(self: &Arc<Self>, replica: Option<Arc<ReplicaState>>) {
        if !self.audit.enabled || self.audit_armed.swap(true, Ordering::SeqCst) {
            return;
        }
        let weak = Arc::downgrade(self);
        std::thread::spawn(move || Engine::audit_loop(weak, replica));
    }

    /// The audit thread: same lifetime rules as [`Engine::heal_loop`] —
    /// holds only a `Weak`, and the upgraded Arc is out of scope before
    /// every sleep so a parked auditor never keeps a dropped engine alive.
    fn audit_loop(weak: std::sync::Weak<Engine>, replica: Option<Arc<ReplicaState>>) {
        let mut auditor: Option<Auditor> = None;
        loop {
            let pause = {
                let Some(engine) = weak.upgrade() else { return };
                if engine.stop.load(Ordering::SeqCst) {
                    return;
                }
                let auditor = auditor.get_or_insert_with(|| {
                    Auditor::new(&engine.telemetry, engine.audit.clone())
                });
                engine.audit_round(auditor, replica.as_deref());
                Duration::from_millis(engine.audit.interval_ms.max(1))
            };
            std::thread::sleep(pause);
        }
    }

    /// One observatory round (also driven directly by the bench overhead
    /// probe): error sampling over the hot set, then the watchdog's
    /// rotating invariant checks. An escalation-worthy violation degrades
    /// the health ladder — a structure that failed a structural check
    /// must not ack more writes until the heal task (or an operator)
    /// intervenes — and is stamped into both the event ring and the
    /// slow-query flight recorder. Returns that violation count.
    pub fn audit_round(&self, auditor: &mut Auditor, replica: Option<&ReplicaState>) -> u64 {
        let chains: Vec<&McPrioQ> = self.shards.iter().collect();
        auditor.error_round(&chains);
        let persist_view = self.persist.get().map(|p| {
            // Generation is re-read around the chain snapshot: a checkpoint
            // committing mid-capture would otherwise pair an old generation
            // with a new chain and read as a phantom violation. Generation 0
            // makes the ckpt-chain check skip this round.
            let before = p.generation();
            let chain = p.delta_chain();
            let generation = if p.generation() == before { before } else { 0 };
            PersistView {
                epoch: p.epoch(),
                last_seqs: p.last_seqs(),
                generation,
                chain_base: chain.base,
                chain_len: chain.len as u64,
            }
        });
        let repl_lag = replica.map(|r| (r.lag_records(), self.replicate.max_lag_records));
        let violations = auditor.watchdog_round(&chains, persist_view.as_ref(), repl_lag);
        if violations > 0 {
            self.health
                .degrade(&format!("invariant violations: {violations} this round"));
            crate::metrics::trace::record_mark("AUDIT", violations, 0);
        }
        violations
    }

    /// Approximation-error samples across all shards (up to `max` per
    /// shard, top-`k` deep) — the bench's staleness-vs-error curve reads
    /// these without arming the background thread.
    pub fn audit_error_samples(&self, max: usize, k: usize) -> Vec<crate::chain::AuditSample> {
        let mut out = Vec::new();
        for s in &self.shards {
            s.audit_samples(0, max, k, &mut out);
        }
        out
    }

    /// Write a checkpoint now (quiesce + pause, snapshot to `tmp` +
    /// `rename`, manifest commit, WAL truncation). Errors if persistence
    /// is not enabled. Backs the wire `SAVE` command and the scheduler.
    pub fn checkpoint(&self) -> Result<crate::persist::CheckpointSummary, String> {
        let t0 = std::time::Instant::now();
        let summary = crate::persist::run_checkpoint(self)?;
        // Only committed checkpoints land in the histogram — a refused or
        // failed cut would skew the duration summary with early exits.
        self.checkpoint_ns.record(t0.elapsed().as_nanos() as u64);
        events::emit(Level::Info, "checkpoint", summary.kind, summary.generation, summary.bytes);
        Ok(summary)
    }

    pub fn stats(&self) -> EngineStats {
        let mut nodes = 0;
        let mut edges = 0;
        let mut observes = 0;
        let mut decays = 0;
        let mut decays_per_shard = Vec::with_capacity(self.shards.len());
        let mut pruned_edges = 0;
        let mut snap_hits = 0;
        let mut snap_rebuilds = 0;
        let mut snap_fallbacks = 0;
        let mut approx_bytes = 0usize;
        for s in &self.shards {
            let st = s.stats();
            nodes += st.nodes;
            edges += st.edges;
            observes += st.observes;
            approx_bytes += st.approx_bytes;
            // Sum, not max: every aggregate in this block is total work
            // across shards. (`max` here silently under-reported decay by
            // a factor of the shard count.)
            decays += st.decays;
            decays_per_shard.push(st.decays);
            pruned_edges += st.pruned_edges;
            snap_hits += st.snap_hits;
            snap_rebuilds += st.snap_rebuilds;
            snap_fallbacks += st.snap_fallbacks;
        }
        let snap = self.query_lat.snapshot();
        let arena = crate::chain::arena::stats();
        let (wal_bytes, ckpt_age_s, recovered_batches, wal_errors, wal_epoch, wal_last_seqs) =
            match self.persist.get() {
                Some(p) => (
                    p.wal_bytes(),
                    p.checkpoint_age().as_secs(),
                    p.recovered_batches(),
                    p.wal_errors(),
                    p.epoch(),
                    p.last_seqs(),
                ),
                None => (0, 0, 0, 0, 0, Vec::new()),
            };
        EngineStats {
            shards: self.shards.len(),
            nodes,
            edges,
            observes,
            queries: self.queries.get(),
            dropped_updates: self.dropped.get(),
            applied_updates: self.applied.get(),
            decays,
            decays_per_shard,
            pruned_edges,
            queue_depth: self.queues.iter().map(|q| q.len()).sum(),
            query_ns_p50: snap.p50,
            query_ns_p90: snap.p90,
            query_ns_p99: snap.p99,
            query_ns_p999: snap.p999,
            query_ns_min: snap.min,
            query_ns_max: snap.max,
            query_ns_mean: snap.mean,
            update_rate: self.update_meter.rate(),
            snap_hits,
            snap_rebuilds,
            snap_fallbacks,
            wal_bytes,
            ckpt_age_s,
            recovered_batches,
            wal_errors,
            wal_epoch,
            wal_last_seqs,
            health: self.health.health().as_str(),
            shed: self.shed.get(),
            ratelimited: self.ratelimited.get(),
            wal_retry: self.health.wal_retry.get(),
            degraded_s: self.health.degraded_seconds(),
            // The arena is process-global; its slack is added once at the
            // engine level, not per shard (shards would double-count it).
            approx_bytes: approx_bytes + arena.slack_bytes() as usize,
            arena_bytes: arena.resident_bytes(),
        }
    }

    /// Stop ingest workers after draining the queues. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for q in &self.queues {
            q.close();
        }
    }

    /// Direct access to a shard's chain for tests/benches.
    pub fn chains(&self) -> &[McPrioQ] {
        &self.shards
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}
