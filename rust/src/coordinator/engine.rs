//! The sharded serving engine: chain shards + ingestion queue + workers.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::chain::{ChainConfig, McPrioQ, Recommendation};
use crate::config::ServerConfig;
use crate::metrics::{Counter, Histogram, Meter};
use crate::rcu;

use super::queue::BoundedQueue;

const FIB: u64 = 0x9E37_79B9_7F4A_7C15;

/// Aggregated serving metrics (the STATS response / EXPERIMENTS.md rows).
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub shards: usize,
    pub nodes: usize,
    pub edges: usize,
    pub observes: u64,
    pub queries: u64,
    pub dropped_updates: u64,
    pub decays: u64,
    pub queue_depth: usize,
    pub query_ns_p50: u64,
    pub query_ns_p99: u64,
    pub update_rate: f64,
}

/// One MCPrioQ per shard; srcs are hash-routed so every shard sees a
/// disjoint key space (a single shard is the paper's plain design; more
/// shards are the E3 scaling ablation).
pub struct Engine {
    shards: Vec<McPrioQ>,
    queue: Arc<BoundedQueue<(u64, u64)>>,
    workers: std::sync::Mutex<Vec<JoinHandle<u64>>>,
    stop: Arc<AtomicBool>,
    queries: Counter,
    dropped: Counter,
    query_lat: Histogram,
    update_meter: Meter,
}

impl Engine {
    /// Build an engine with `shards` chains (0 = available parallelism)
    /// and `workers` ingest threads draining the update queue.
    pub fn new(config: &ServerConfig, workers: usize) -> Arc<Engine> {
        let nshards = if config.shards == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            config.shards
        };
        let chain_cfg: ChainConfig = config.to_chain_config();
        let queue = Arc::new(BoundedQueue::new(config.queue_capacity));
        let engine = Arc::new(Engine {
            shards: (0..nshards).map(|_| McPrioQ::new(chain_cfg.clone())).collect(),
            queue,
            workers: std::sync::Mutex::new(Vec::new()),
            stop: Arc::new(AtomicBool::new(false)),
            queries: Counter::new(),
            dropped: Counter::new(),
            query_lat: Histogram::new(),
            update_meter: Meter::new(),
        });
        // Spawn ingest workers. They hold the queue Arc plus a Weak to the
        // engine, so dropping the last user Arc tears everything down:
        // Engine::drop closes the queue, workers wake, fail the upgrade,
        // and exit; drop then joins them.
        {
            let mut ws = engine.workers.lock().unwrap();
            for _ in 0..workers {
                let weak = Arc::downgrade(&engine);
                let queue = Arc::clone(&engine.queue);
                ws.push(std::thread::spawn(move || Engine::ingest_loop(weak, queue)));
            }
        }
        engine
    }

    fn ingest_loop(weak: std::sync::Weak<Engine>, queue: Arc<BoundedQueue<(u64, u64)>>) -> u64 {
        let mut applied = 0u64;
        loop {
            let batch = queue.pop_batch(256);
            if batch.is_empty() {
                return applied; // queue closed and drained
            }
            let Some(engine) = weak.upgrade() else {
                return applied; // engine gone mid-shutdown; drop the batch
            };
            for (src, dst) in batch {
                engine.shard(src).observe(src, dst);
                applied += 1;
            }
            engine.update_meter.mark_n(1); // per batch; rate() scales anyway
        }
    }

    #[inline]
    pub fn shard(&self, src: u64) -> &McPrioQ {
        &self.shards[(src.wrapping_mul(FIB) >> 33) as usize % self.shards.len()]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Enqueue an update (blocking backpressure). False if shutting down.
    pub fn observe(&self, src: u64, dst: u64) -> bool {
        self.queue.push((src, dst))
    }

    /// Enqueue without blocking; drops (and counts) on overflow — the
    /// load-shedding policy for best-effort telemetry feeds.
    pub fn observe_lossy(&self, src: u64, dst: u64) {
        if self.queue.try_push((src, dst)).is_err() {
            self.dropped.inc();
        }
    }

    /// Apply an update on the caller thread, bypassing the queue (embedded
    /// / benchmark use; this is the raw wait-free path).
    pub fn observe_direct(&self, src: u64, dst: u64) {
        self.shard(src).observe(src, dst);
    }

    pub fn infer_threshold(&self, src: u64, t: f64) -> Recommendation {
        self.queries.inc();
        let timer = crate::metrics::Timer::start(&self.query_lat);
        let r = self.shard(src).infer_threshold(src, t);
        drop(timer);
        r
    }

    pub fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        self.queries.inc();
        let timer = crate::metrics::Timer::start(&self.query_lat);
        let r = self.shard(src).infer_topk(src, k);
        drop(timer);
        r
    }

    /// Run one decay + repair pass over every shard (§II.C maintenance).
    pub fn decay(&self) -> (u64, usize) {
        let mut total = 0;
        let mut pruned = 0;
        for s in &self.shards {
            let (t, p) = s.decay();
            total += t;
            pruned += p;
        }
        (total, pruned)
    }

    /// Wait until every update enqueued *before this call* is applied.
    pub fn quiesce(&self) {
        while !self.queue.is_empty() {
            std::thread::yield_now();
        }
        // One grace period so applied updates are fully visible.
        rcu::synchronize();
    }

    pub fn stats(&self) -> EngineStats {
        let mut nodes = 0;
        let mut edges = 0;
        let mut observes = 0;
        let mut decays = 0;
        for s in &self.shards {
            let st = s.stats();
            nodes += st.nodes;
            edges += st.edges;
            observes += st.observes;
            decays = decays.max(st.decays);
        }
        let snap = self.query_lat.snapshot();
        EngineStats {
            shards: self.shards.len(),
            nodes,
            edges,
            observes,
            queries: self.queries.get(),
            dropped_updates: self.dropped.get(),
            decays,
            queue_depth: self.queue.len(),
            query_ns_p50: snap.p50,
            query_ns_p99: snap.p99,
            update_rate: 0.0, // filled by callers that track intervals
        }
    }

    /// Stop ingest workers after draining the queue. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    /// Direct access to a shard's chain for tests/benches.
    pub fn chains(&self) -> &[McPrioQ] {
        &self.shards
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}
