//! The maintenance scheduler: periodic model decay (§II.C) plus the order
//! repair sweep, on a dedicated thread.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::engine::Engine;

pub struct DecayScheduler {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
    runs: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
}

impl DecayScheduler {
    /// Decay every `interval`; stops when the handle drops.
    pub fn start(engine: Arc<Engine>, interval: Duration) -> DecayScheduler {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let runs = Arc::new(AtomicU64::new(0));
        let running = Arc::new(AtomicBool::new(true));
        let handle = {
            let stop = Arc::clone(&stop);
            let runs = Arc::clone(&runs);
            let running = Arc::clone(&running);
            std::thread::spawn(move || {
                let (lock, cvar) = &*stop;
                loop {
                    // Interruptible sleep.
                    let mut stopped = lock.lock().unwrap();
                    let (guard, timeout) = cvar.wait_timeout(stopped, interval).unwrap();
                    stopped = guard;
                    if *stopped {
                        break;
                    }
                    drop(stopped);
                    if timeout.timed_out() {
                        engine.decay();
                        runs.fetch_add(1, Ordering::Relaxed);
                    }
                }
                running.store(false, Ordering::SeqCst);
            })
        };
        DecayScheduler { stop, handle: Some(handle), runs, running }
    }

    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    pub fn stop(&self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
    }
}

impl Drop for DecayScheduler {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
