//! The maintenance scheduler: periodic model decay (§II.C) plus the order
//! repair sweep, on a dedicated thread.

use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};

use super::engine::Engine;

pub struct DecayScheduler {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
    runs: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
}

impl DecayScheduler {
    /// Decay every `interval`; stops when the handle drops.
    pub fn start(engine: Arc<Engine>, interval: Duration) -> DecayScheduler {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let runs = Arc::new(AtomicU64::new(0));
        let running = Arc::new(AtomicBool::new(true));
        let handle = {
            let stop = Arc::clone(&stop);
            let runs = Arc::clone(&runs);
            let running = Arc::clone(&running);
            std::thread::spawn(move || {
                let (lock, cvar) = &*stop;
                // The cadence is an *absolute* deadline carried across
                // `wait_timeout` iterations: a spurious condvar wakeup
                // re-waits only the remainder, instead of rearming the
                // full interval and drifting the decay schedule.
                let mut deadline = Instant::now() + interval;
                'run: loop {
                    {
                        let mut stopped =
                            lock.lock().unwrap_or_else(PoisonError::into_inner);
                        loop {
                            if *stopped {
                                break 'run;
                            }
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let (guard, _) = cvar
                                .wait_timeout(stopped, deadline - now)
                                .unwrap_or_else(PoisonError::into_inner);
                            stopped = guard;
                        }
                    }
                    engine.decay();
                    runs.fetch_add(1, Ordering::Relaxed);
                    // Next tick from the previous deadline, not from "now":
                    // a slow decay pass doesn't shift the whole schedule —
                    // unless it overran a full interval, then skip ahead
                    // rather than firing a catch-up burst.
                    deadline += interval;
                    let now = Instant::now();
                    if deadline < now {
                        deadline = now + interval;
                    }
                }
                running.store(false, Ordering::SeqCst);
            })
        };
        DecayScheduler { stop, handle: Some(handle), runs, running }
    }

    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    pub fn stop(&self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cvar.notify_all();
    }
}

impl Drop for DecayScheduler {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Standalone order-repair scheduler (`[chain] repair_interval_s`): runs
/// [`Engine::repair`] on its own deadline instead of only piggybacking on
/// decay. Decay cadence is a *model* knob (how fast history fades);
/// repair cadence is a *structural* one (how long opportunistically
/// skipped swaps may persist) — high-churn deployments want frequent
/// repair without accelerating decay. Same absolute-deadline condvar
/// protocol as [`DecayScheduler`].
pub struct RepairScheduler {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<std::thread::JoinHandle<()>>,
    runs: Arc<AtomicU64>,
    running: Arc<AtomicBool>,
}

impl RepairScheduler {
    /// Repair every `interval`; stops when the handle drops.
    pub fn start(engine: Arc<Engine>, interval: Duration) -> RepairScheduler {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let runs = Arc::new(AtomicU64::new(0));
        let running = Arc::new(AtomicBool::new(true));
        let handle = {
            let stop = Arc::clone(&stop);
            let runs = Arc::clone(&runs);
            let running = Arc::clone(&running);
            std::thread::spawn(move || {
                let (lock, cvar) = &*stop;
                let mut deadline = Instant::now() + interval;
                'run: loop {
                    {
                        let mut stopped =
                            lock.lock().unwrap_or_else(PoisonError::into_inner);
                        loop {
                            if *stopped {
                                break 'run;
                            }
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let (guard, _) = cvar
                                .wait_timeout(stopped, deadline - now)
                                .unwrap_or_else(PoisonError::into_inner);
                            stopped = guard;
                        }
                    }
                    engine.repair();
                    runs.fetch_add(1, Ordering::Relaxed);
                    deadline += interval;
                    let now = Instant::now();
                    if deadline < now {
                        deadline = now + interval;
                    }
                }
                running.store(false, Ordering::SeqCst);
            })
        };
        RepairScheduler { stop, handle: Some(handle), runs, running }
    }

    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    pub fn stop(&self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cvar.notify_all();
    }
}

impl Drop for RepairScheduler {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
