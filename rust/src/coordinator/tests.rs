//! Coordinator tests: queue semantics, engine routing/ingestion, protocol
//! round-trips, and end-to-end TCP serving.

use super::*;
use crate::config::ServerConfig;
use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn test_config() -> ServerConfig {
    ServerConfig { shards: 2, queue_capacity: 1024, ..Default::default() }
}

// ---- queue ----

#[test]
fn queue_fifo_and_len() {
    let q = BoundedQueue::new(4);
    assert!(q.try_push(1).is_ok());
    assert!(q.try_push(2).is_ok());
    assert_eq!(q.len(), 2);
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(2));
    assert!(q.is_empty());
}

#[test]
fn queue_try_push_full() {
    let q = BoundedQueue::new(2);
    assert!(q.try_push(1).is_ok());
    assert!(q.try_push(2).is_ok());
    assert_eq!(q.try_push(3), Err(3));
    q.pop();
    assert!(q.try_push(3).is_ok());
}

#[test]
fn queue_close_drains_then_none() {
    let q = BoundedQueue::new(4);
    q.push(7);
    q.close();
    assert!(!q.push(8));
    assert_eq!(q.try_push(9), Err(9));
    assert_eq!(q.pop(), Some(7));
    assert_eq!(q.pop(), None);
    assert!(q.is_closed());
}

#[test]
fn queue_blocking_push_waits_for_space() {
    let q = Arc::new(BoundedQueue::new(1));
    q.push(1);
    let q2 = Arc::clone(&q);
    let t = std::thread::spawn(move || q2.push(2));
    std::thread::sleep(Duration::from_millis(20));
    assert_eq!(q.pop(), Some(1)); // unblocks the pusher
    assert!(t.join().unwrap());
    assert_eq!(q.pop(), Some(2));
}

#[test]
fn queue_pop_batch() {
    let q = BoundedQueue::new(16);
    for i in 0..10 {
        q.push(i);
    }
    let b = q.pop_batch(4);
    assert_eq!(b, vec![0, 1, 2, 3]);
    let b = q.pop_batch(100);
    assert_eq!(b.len(), 6);
    q.close();
    assert!(q.pop_batch(4).is_empty());
}

#[test]
fn queue_mpmc_stress() {
    let q = Arc::new(BoundedQueue::new(64));
    let sum = Arc::new(AtomicU64::new(0));
    const PER: u64 = 10_000;
    let consumers: Vec<_> = (0..3)
        .map(|_| {
            let q = Arc::clone(&q);
            let sum = Arc::clone(&sum);
            std::thread::spawn(move || {
                while let Some(v) = q.pop() {
                    sum.fetch_add(v, Ordering::Relaxed);
                }
            })
        })
        .collect();
    let producers: Vec<_> = (0..3)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 1..=PER {
                    assert!(q.push(i));
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }
    q.close();
    for c in consumers {
        c.join().unwrap();
    }
    assert_eq!(sum.load(Ordering::Relaxed), 3 * PER * (PER + 1) / 2);
}

#[test]
fn queue_try_pop_batch_nonblocking() {
    let q = BoundedQueue::new(8);
    assert!(q.try_pop_batch(4).is_empty()); // empty: returns immediately
    for i in 0..6 {
        q.push(i);
    }
    assert_eq!(q.try_pop_batch(4), vec![0, 1, 2, 3]);
    assert_eq!(q.try_pop_batch(100), vec![4, 5]);
    assert!(q.try_pop_batch(4).is_empty());
}

#[test]
fn queue_push_bulk_blocks_for_space() {
    let q = Arc::new(BoundedQueue::new(4));
    let q2 = Arc::clone(&q);
    let t = std::thread::spawn(move || q2.push_bulk((0..10).collect()));
    // Drain until the producer can finish.
    let mut got = Vec::new();
    while got.len() < 10 {
        got.extend(q.pop_batch_timeout(16, Duration::from_millis(50)));
    }
    assert_eq!(t.join().unwrap(), 10);
    assert_eq!(got, (0..10).collect::<Vec<_>>());
}

#[test]
fn queue_push_bulk_short_on_close() {
    let q = BoundedQueue::new(4);
    q.close();
    assert_eq!(q.push_bulk(vec![1, 2, 3]), 0);
    let q = BoundedQueue::new(8);
    assert_eq!(q.push_bulk(vec![1, 2, 3]), 3);
    assert_eq!(q.pop_batch(8), vec![1, 2, 3]);
}

#[test]
fn queue_pop_batch_timeout_semantics() {
    let q: BoundedQueue<u32> = BoundedQueue::new(4);
    let t0 = std::time::Instant::now();
    assert!(q.pop_batch_timeout(4, Duration::from_millis(10)).is_empty());
    assert!(t0.elapsed() >= Duration::from_millis(8));
    q.push(7);
    assert_eq!(q.pop_batch_timeout(4, Duration::from_millis(10)), vec![7]);
    q.close();
    // Closed + drained: returns immediately, no timeout wait.
    let t0 = std::time::Instant::now();
    assert!(q.pop_batch_timeout(4, Duration::from_secs(5)).is_empty());
    assert!(t0.elapsed() < Duration::from_secs(1));
}

// ---- engine ----

#[test]
fn engine_routes_and_applies_queued_updates() {
    let engine = Engine::new(&test_config(), 2);
    for i in 0..100u64 {
        assert!(engine.observe(i % 10, i % 7));
    }
    engine.quiesce();
    let s = engine.stats();
    assert_eq!(s.observes, 100);
    assert_eq!(s.shards, 2);
    assert!(s.nodes > 0);
    // Shard routing is consistent.
    let shard_a = engine.shard(3) as *const _;
    let shard_b = engine.shard(3) as *const _;
    assert_eq!(shard_a, shard_b);
    engine.shutdown();
}

#[test]
fn engine_direct_and_query_paths() {
    let engine = Engine::new(&test_config(), 1);
    for _ in 0..8 {
        engine.observe_direct(5, 50);
    }
    engine.observe_direct(5, 60);
    let r = engine.infer_topk(5, 2);
    assert_eq!(r.items[0].0, 50);
    let r = engine.infer_threshold(5, 0.8);
    assert!(!r.items.is_empty());
    assert!(engine.stats().queries >= 2);
    engine.shutdown();
}

#[test]
fn engine_decay_runs_over_all_shards() {
    let engine = Engine::new(&test_config(), 1);
    for src in 0..20u64 {
        engine.observe_direct(src, 1);
        engine.observe_direct(src, 1);
    }
    let (total, pruned) = engine.decay();
    assert_eq!(total, 20); // each edge 2 -> 1
    assert_eq!(pruned, 0);
    let (total, pruned) = engine.decay();
    assert_eq!(total, 0);
    assert_eq!(pruned, 20);
    // Aggregation fix: `decays` is per-shard maintenance work summed (two
    // engine passes × shard count), with the per-shard split exposed.
    let stats = engine.stats();
    assert_eq!(stats.decays_per_shard.len(), stats.shards);
    assert!(stats.decays_per_shard.iter().all(|&d| d == 2), "{stats:?}");
    assert_eq!(stats.decays, 2 * stats.shards as u64);
    assert_eq!(stats.pruned_edges, 20);
    engine.shutdown();
}

#[test]
fn engine_lossy_observe_counts_drops() {
    let cfg = ServerConfig { shards: 1, queue_capacity: 4, ..Default::default() };
    // No workers: the queue can only fill up.
    let engine = Engine::new(&cfg, 0);
    for i in 0..100 {
        engine.observe_lossy(i, i);
    }
    assert_eq!(engine.stats().dropped_updates, 96);
    engine.shutdown();
}

#[test]
fn engine_observe_batch_routes_and_applies() {
    let engine = Engine::new(&test_config(), 2);
    let pairs: Vec<(u64, u64)> = (0..500u64).map(|i| (i % 13, i % 7)).collect();
    assert_eq!(engine.observe_batch(&pairs), 500);
    engine.quiesce();
    let s = engine.stats();
    assert_eq!(s.observes, 500);
    assert_eq!(s.dropped_updates, 0);
    assert!(s.update_rate > 0.0, "update_rate {}", s.update_rate);
    engine.shutdown();
    // After shutdown the queues are closed: both paths refuse.
    assert!(!engine.observe(1, 2));
    assert_eq!(engine.observe_batch(&pairs), 0);
}

/// The queued shard-affine path (single or batched) must build exactly the
/// model the direct path builds — per-shard FIFO with one consumer per
/// shard makes queued ingestion deterministic.
#[test]
fn queued_batched_and_direct_ingest_identical() {
    let mut rng = crate::testutil::Rng64::new(0x5EED);
    let pairs: Vec<(u64, u64)> = (0..20_000)
        .map(|_| (rng.next_below(64), rng.next_below(32)))
        .collect();

    let direct = Engine::new(&test_config(), 0);
    for &(s, d) in &pairs {
        direct.observe_direct(s, d);
    }

    let queued_single = Engine::new(&test_config(), 2);
    for &(s, d) in &pairs {
        assert!(queued_single.observe(s, d));
    }
    queued_single.quiesce();

    let queued_batched = Engine::new(&test_config(), 2);
    for chunk in pairs.chunks(173) {
        assert_eq!(queued_batched.observe_batch(chunk), chunk.len());
    }
    queued_batched.quiesce();

    let direct_batched = Engine::new(&test_config(), 0);
    for chunk in pairs.chunks(173) {
        direct_batched.observe_batch_direct(chunk);
    }

    let reference = direct.export();
    assert_eq!(reference, queued_single.export());
    assert_eq!(reference, queued_batched.export());
    assert_eq!(reference, direct_batched.export());
    for chain in queued_batched.chains() {
        chain.check_invariants().unwrap();
    }
    for e in [direct, queued_single, queued_batched, direct_batched] {
        e.shutdown();
    }
}

/// More workers than shards: surplus workers own nothing and must exit
/// cleanly; ingestion still drains.
#[test]
fn engine_more_workers_than_shards() {
    let cfg = ServerConfig { shards: 1, queue_capacity: 1024, ..Default::default() };
    let engine = Engine::new(&cfg, 4);
    for i in 0..200u64 {
        assert!(engine.observe(i % 9, i % 5));
    }
    engine.quiesce();
    assert_eq!(engine.stats().observes, 200);
    engine.shutdown();
}

#[test]
fn engine_meters_per_update_not_per_batch() {
    let engine = Engine::new(&test_config(), 1);
    let pairs: Vec<(u64, u64)> = (0..1_000u64).map(|i| (i % 11, i % 3)).collect();
    assert_eq!(engine.observe_batch(&pairs), 1_000);
    engine.quiesce();
    let s = engine.stats();
    // Every applied update counted (previously one mark per drained batch,
    // undercounting the rate by up to the batch size).
    assert_eq!(s.applied_updates, 1_000);
    assert!(s.update_rate > 0.0);
    engine.shutdown();
}

// ---- protocol ----

#[test]
fn protocol_request_roundtrip() {
    for req in [
        Request::Observe { src: 1, dst: 2 },
        Request::ObserveBatch { pairs: vec![(1, 2), (3, 4), (5, 6)], id: None },
        Request::Recommend { src: 3, threshold: 0.9 },
        Request::TopK { src: 4, k: 7, id: None },
        Request::TopK { src: 4, k: 7, id: Some("req-77".into()) },
        Request::MultiTopK { srcs: vec![4, 9, 11], k: 3, id: None },
        Request::MultiTopK { srcs: vec![4, 9, 11], k: 3, id: Some("batch.1".into()) },
        Request::ObserveBatch { pairs: vec![(1, 2)], id: Some("w1".into()) },
        Request::Events(usize::MAX),
        Request::Events(16),
        Request::Prob { src: 1, dst: 9 },
        Request::Decay,
        Request::Repair,
        Request::Save,
        Request::Stats,
        Request::Ping,
        Request::Quit,
        Request::ReplHello { epoch: 3, last_seqs: vec![17, 0, 42] },
        Request::Promote,
        Request::Health,
    ] {
        assert_eq!(Request::parse(&req.encode()).unwrap(), req, "{req:?}");
    }
}

#[test]
fn protocol_rejects_malformed() {
    for bad in [
        "",
        "NOPE",
        "OBS 1",
        "OBS x y",
        "OBS 1 2 3",
        "REC 1",
        "REC 1 1.5",
        "REC 1 -0.1",
        "TOPK 1",
        "OBSERVEB",
        "OBSERVEB 0",
        "OBSERVEB 2 1 2",       // truncated
        "OBSERVEB 1 1 2 3 4",   // trailing
        "OBSERVEB 99999999 1 2", // over the wire cap
        "MTOPK",
        "MTOPK 0 3",
        "MTOPK 2 3 7",          // truncated
        "MTOPK 1 3 7 8",        // trailing
        "TOPK 1 3 id=",         // empty id tag
        "TOPK 1 3 id=a b",      // trailing after tag
        "REC 1 0.5 id=x",       // tag on an untaggable verb
        "EVENTS x",
        "EVENTS 4 5",
        "REPL",
        "REPL GOODBYE",
        "REPL HELLO 1",         // missing shard count
        "REPL HELLO 1 2 5",     // truncated seq list
        "REPL HELLO 1 1 5 6",   // trailing
        "PROMOTE now",          // trailing
    ] {
        assert!(Request::parse(bad).is_err(), "{bad:?} should fail");
    }
}

#[test]
fn protocol_multi_items_roundtrip() {
    let r = Response::MultiItems(vec![
        ItemsBody { items: vec![(5, 0.5), (9, 0.25)], cumulative: 0.75, scanned: 2 },
        ItemsBody { items: vec![], cumulative: 0.0, scanned: 0 },
        ItemsBody { items: vec![(1, 1.0)], cumulative: 1.0, scanned: 1 },
    ]);
    match Response::parse(&r.to_string()).unwrap() {
        Response::MultiItems(bodies) => {
            assert_eq!(bodies.len(), 3);
            assert_eq!(bodies[0].items[0].0, 5);
            assert!((bodies[0].cumulative - 0.75).abs() < 1e-6);
            assert!(bodies[1].items.is_empty());
            assert_eq!(bodies[2].scanned, 1);
        }
        other => panic!("{other:?}"),
    }
    assert!(Response::parse("MITEMS 2 ITEMS 0 cum=0.0 scanned=0").is_err()); // short
    assert!(Response::parse("MITEMS 1 NOPE").is_err());
}

#[test]
fn protocol_response_roundtrip() {
    let r = Response::Items {
        items: vec![(5, 0.5), (9, 0.25)],
        cumulative: 0.75,
        scanned: 2,
    };
    let parsed = Response::parse(&r.to_string()).unwrap();
    match parsed {
        Response::Items { items, cumulative, scanned } => {
            assert_eq!(items.len(), 2);
            assert_eq!(items[0].0, 5);
            assert!((items[0].1 - 0.5).abs() < 1e-6);
            assert!((cumulative - 0.75).abs() < 1e-6);
            assert_eq!(scanned, 2);
        }
        other => panic!("{other:?}"),
    }
    assert_eq!(Response::parse("OK pong").unwrap(), Response::Ok("pong".into()));
    assert_eq!(Response::parse("ERR nope").unwrap(), Response::Err("nope".into()));
    assert!(Response::parse("GARBAGE").is_err());
}

// ---- decay scheduler ----

#[test]
fn decay_scheduler_fires_and_stops() {
    let engine = Engine::new(&test_config(), 1);
    for _ in 0..16 {
        engine.observe_direct(1, 2);
    }
    let sched = DecayScheduler::start(Arc::clone(&engine), Duration::from_millis(10));
    std::thread::sleep(Duration::from_millis(80));
    sched.stop();
    let runs = sched.runs();
    assert!(runs >= 2, "scheduler ran {runs} times");
    drop(sched);
    // Counter halved at least twice: 16 -> <= 4.
    let r = engine.infer_topk(1, 1);
    assert!(r.total <= 4, "total {}", r.total);
    engine.shutdown();
}

// ---- end-to-end TCP ----

#[test]
fn tcp_server_end_to_end() {
    let engine = Engine::new(&test_config(), 2);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let handle = server.spawn();

    let mut client = Client::connect(addr).unwrap();
    // Liveness.
    assert_eq!(client.request(&Request::Ping).unwrap(), Response::Ok("pong".into()));
    // Feed transitions: 1 -> 2 (x3), 1 -> 3 (x1).
    for _ in 0..3 {
        client.observe(1, 2).unwrap();
    }
    client.observe(1, 3).unwrap();
    engine.quiesce();

    let items = client.topk(1, 2).unwrap();
    assert_eq!(items[0].0, 2);
    assert!((items[0].1 - 0.75).abs() < 1e-6);
    let rec = client.recommend(1, 0.7).unwrap();
    assert_eq!(rec.len(), 1);

    // PROB + STATS + DECAY.
    match client.request(&Request::Prob { src: 1, dst: 2 }).unwrap() {
        Response::Ok(p) => assert!((p.parse::<f64>().unwrap() - 0.75).abs() < 1e-6),
        other => panic!("{other:?}"),
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("observes=4"), "{stats}");
    match client.request(&Request::Decay).unwrap() {
        Response::Ok(msg) => assert!(msg.contains("pruned=1"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // Unknown command surfaces as ERR, connection stays usable.
    assert!(matches!(
        client.request(&Request::Recommend { src: 999, threshold: 0.5 }).unwrap(),
        Response::Items { items, .. } if items.is_empty()
    ));
    // Clean shutdown.
    assert_eq!(client.request(&Request::Quit).unwrap(), Response::Ok("bye".into()));
    drop(handle);
    engine.shutdown();
}

#[test]
fn tcp_batched_observe_and_multi_topk() {
    let engine = Engine::new(&test_config(), 2);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();

    let mut client = Client::connect(addr).unwrap();
    // 1 -> 2 (x3), 1 -> 3 (x1), 9 -> 4 (x2) in one bulk request.
    let pairs = vec![(1, 2), (1, 2), (1, 2), (1, 3), (9, 4), (9, 4)];
    assert_eq!(client.observe_batch(&pairs).unwrap(), 6);
    engine.quiesce();

    let answers = client.topk_batch(&[1, 9, 777], 2).unwrap();
    assert_eq!(answers.len(), 3);
    assert_eq!(answers[0][0].0, 2);
    assert!((answers[0][0].1 - 0.75).abs() < 1e-6);
    assert_eq!(answers[1], vec![(4, 1.0)]);
    assert!(answers[2].is_empty()); // unknown src

    // STATS now surfaces connection count, the applied-update rate, and
    // the read-snapshot effectiveness counters.
    let stats = client.stats().unwrap();
    assert!(stats.contains("conns=1"), "{stats}");
    assert!(stats.contains("update_rate="), "{stats}");
    assert!(stats.contains("observes=6"), "{stats}");
    assert!(stats.contains("snap_hits="), "{stats}");
    assert!(stats.contains("snap_rebuilds="), "{stats}");
    assert!(stats.contains("snap_fallbacks="), "{stats}");
    // Durability gauges are always present (zero while persistence is off).
    assert!(stats.contains("wal_bytes=0"), "{stats}");
    assert!(stats.contains("ckpt_age=0"), "{stats}");
    assert!(stats.contains("recovered_batches=0"), "{stats}");
    assert!(stats.contains("wal_errors=0"), "{stats}");
    engine.shutdown();
}

/// `export_quiesced` must contain every update enqueued before the call —
/// plain `export` makes no such promise (documented; the checkpointer
/// relies on the quiesced variant).
#[test]
fn export_quiesced_contains_all_enqueued_updates() {
    let engine = Engine::new(&test_config(), 2);
    let pairs: Vec<(u64, u64)> = (0..30_000u64).map(|i| (i % 17, i % 5)).collect();
    for chunk in pairs.chunks(977) {
        assert_eq!(engine.observe_batch(chunk), chunk.len());
    }
    // No explicit quiesce: the export itself must drain the queues first.
    let snap = engine.export_quiesced();
    let total: u64 = snap.iter().map(|(_, total, _)| *total).sum();
    assert_eq!(total, pairs.len() as u64);
    // And it matches a direct-ingest reference exactly.
    let reference = Engine::new(&test_config(), 0);
    for chunk in pairs.chunks(977) {
        reference.observe_batch_direct(chunk);
    }
    assert_eq!(snap, reference.export());
    engine.shutdown();
    reference.shutdown();
}

/// The engine's one-guard batched read path answers exactly like the
/// per-query path, in request order, reusing one scratch buffer.
#[test]
fn engine_topk_batch_matches_single_queries() {
    let engine = Engine::new(&test_config(), 0);
    for i in 0..2_000u64 {
        engine.observe_direct(i % 7, i % 23);
    }
    let srcs = [3u64, 0, 999, 5];
    let queries_before = engine.stats().queries;
    let mut scratch = crate::chain::Recommendation::default();
    let mut batched = Vec::new();
    engine.infer_topk_batch(&srcs, 4, &mut scratch, |r| batched.push(r.clone()));
    assert_eq!(batched.len(), srcs.len());
    for (src, got) in srcs.iter().zip(&batched) {
        assert_eq!(*got, engine.infer_topk(*src, 4), "src {src}");
    }
    // Per-query accounting is preserved (batch counted 4, singles 4 more).
    assert_eq!(engine.stats().queries, queries_before + 8);
    engine.shutdown();
}

// ---- robustness: shedding, admission, degradation (DESIGN.md §8) ----

#[test]
fn queue_try_push_bulk_sheds_overflow() {
    let q = BoundedQueue::new(4);
    assert_eq!(q.try_push_bulk(vec![0, 1, 2]), 3);
    // Room for one more: the prefix is accepted, the rest shed.
    assert_eq!(q.try_push_bulk(vec![3, 4, 5]), 1);
    assert_eq!(q.pop_batch(16), vec![0, 1, 2, 3]);
    assert_eq!(q.try_push_bulk(Vec::new()), 0);
    q.close();
    assert_eq!(q.try_push_bulk(vec![9]), 0);
}

/// A worker that panics while holding the queue mutex poisons it; the
/// non-poisoning `locked()` recovery must keep every other producer and
/// consumer alive.
#[test]
fn queue_survives_poisoned_lock() {
    let q = Arc::new(BoundedQueue::new(8));
    q.push(1);
    q.poison_for_test();
    assert!(q.try_push(2).is_ok());
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop_batch(8), vec![2]);
    q.close();
    assert_eq!(q.pop(), None);
}

/// The sharpest worker-death scenario: a thread dies holding each shard
/// queue's mutex *and* the ingest gate's read side. Ingest must keep
/// moving, `quiesce` must still terminate, and the gate's write side
/// (the checkpoint pause) must still be takeable.
#[test]
fn engine_ingest_survives_poisoned_worker_locks() {
    let engine = Engine::new(&test_config(), 2);
    for i in 0..100u64 {
        assert!(engine.observe(i % 7, i % 5));
    }
    engine.quiesce();
    engine.poison_queues_for_test();
    engine.poison_ingest_gate_for_test();
    for i in 0..100u64 {
        assert!(engine.observe(i % 7, i % 5));
    }
    engine.quiesce();
    assert_eq!(engine.stats().observes, 200);
    assert!(!engine.export_quiesced().is_empty());
    engine.shutdown();
}

/// The degradation gate over TCP: a degraded engine refuses every write
/// verb with the first-fault reason and a retry hint, keeps serving
/// reads from the RCU structures, reports the rung via `HEALTH` and
/// `STATS`, and re-admits writes on the same connection once healed.
#[test]
fn tcp_degraded_rejects_writes_serves_reads_then_heals() {
    let engine = Engine::new(&test_config(), 2);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    for _ in 0..3 {
        client.observe(1, 2).unwrap();
    }
    client.observe(1, 3).unwrap();
    engine.quiesce();

    engine.degrade_for_test("wal append on shard 0: injected ENOSPC");

    // Every mutation is refused with reason + retry hint…
    for req in [
        Request::Observe { src: 1, dst: 2 },
        Request::ObserveBatch { pairs: vec![(1, 2), (3, 4)], id: None },
        Request::Decay,
        Request::Repair,
    ] {
        match client.request(&req).unwrap() {
            Response::Err(e) => {
                assert!(e.starts_with("degraded reason="), "{req:?}: {e}");
                assert!(e.contains("injected ENOSPC"), "{req:?}: {e}");
                assert!(e.contains("retry_after_ms="), "{req:?}: {e}");
            }
            other => panic!("{req:?} must be refused while degraded, got {other:?}"),
        }
    }
    // …while reads are still served…
    let items = client.topk(1, 2).unwrap();
    assert_eq!(items[0].0, 2);
    assert!((items[0].1 - 0.75).abs() < 1e-6);
    // …and both HEALTH and the STATS gauge say why.
    match client.request(&Request::Health).unwrap() {
        Response::Ok(msg) => {
            assert!(msg.starts_with("degraded reason="), "{msg}");
            assert!(msg.contains("retry_after_ms="), "{msg}");
        }
        other => panic!("{other:?}"),
    }
    let stats = client.stats().unwrap();
    assert!(stats.contains("health=degraded"), "{stats}");
    assert!(stats.contains("wal_retry="), "{stats}");
    assert!(stats.contains("degraded_s="), "{stats}");

    // Heal: the same connection starts writing again, no reconnect.
    engine.heal_for_test();
    client.observe(1, 2).unwrap();
    assert_eq!(
        client.request(&Request::Health).unwrap(),
        Response::Ok("healthy".into())
    );
    engine.quiesce();
    assert_eq!(engine.stats().observes, 5);
    engine.shutdown();
}

/// Per-connection token buckets: a burst is admitted, the next write is
/// refused with `ERR ratelimited retry_after_ms=…`, batches pay their
/// pair count, and reads are never charged.
#[test]
fn tcp_admission_ratelimits_writes_not_reads() {
    let cfg = ServerConfig {
        shards: 1,
        queue_capacity: 1024,
        rate_limit_ops: 1,
        rate_limit_burst: 3,
        ..Default::default()
    };
    let engine = Engine::new(&cfg, 1);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    // The initial bucket holds exactly `burst` tokens.
    for _ in 0..3 {
        client.observe(5, 6).unwrap();
    }
    match client.request(&Request::Observe { src: 5, dst: 6 }).unwrap() {
        Response::Err(e) => {
            assert!(e.starts_with("ratelimited retry_after_ms="), "{e}");
        }
        other => panic!("4th write must be throttled, got {other:?}"),
    }
    // OBSERVEB costs its pair count — batching cannot dodge the limit.
    match client.request(&Request::ObserveBatch { pairs: vec![(1, 2); 100], id: None }).unwrap() {
        Response::Err(e) => assert!(e.starts_with("ratelimited"), "{e}"),
        other => panic!("batch must be throttled, got {other:?}"),
    }
    // Reads ride free: a throttled feeder can still watch the engine.
    for _ in 0..20 {
        client.topk(5, 2).unwrap();
    }
    client.stats().unwrap();
    engine.quiesce();
    let s = engine.stats();
    assert_eq!(s.observes, 3, "only the admitted burst reached the shards");
    assert!(s.ratelimited >= 2, "ratelimited={}", s.ratelimited);
    let stats = client.stats().unwrap();
    assert!(stats.contains("ratelimited="), "{stats}");
    engine.shutdown();
}

/// With admission control on, saturation sheds instead of blocking: a
/// full shard queue answers `ERR overload` (with the honest
/// accepted/shed split for batches) rather than stalling the
/// connection on backpressure.
#[test]
fn tcp_overload_sheds_instead_of_blocking() {
    let cfg = ServerConfig {
        shards: 1,
        queue_capacity: 4,
        // Admission on (the shedding gate) but effectively unlimited, so
        // every rejection below is overload, not ratelimiting.
        rate_limit_ops: 1_000_000,
        rate_limit_burst: 1_000_000,
        ..Default::default()
    };
    // No workers: the queue can only fill up.
    let engine = Engine::new(&cfg, 0);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    for _ in 0..4 {
        client.observe(1, 2).unwrap();
    }
    match client.request(&Request::Observe { src: 1, dst: 2 }).unwrap() {
        Response::Err(e) => assert_eq!(e, "overload shed=1"),
        other => panic!("a saturated queue must shed, got {other:?}"),
    }
    match client.request(&Request::ObserveBatch { pairs: vec![(1, 2); 8], id: None }).unwrap() {
        Response::Err(e) => {
            assert!(e.starts_with("overload shed=8"), "{e}");
            assert!(e.contains("accepted=0"), "{e}");
        }
        other => panic!("a saturated queue must shed the batch, got {other:?}"),
    }
    let s = engine.stats();
    assert_eq!(s.shed, 9, "shed={}", s.shed);
    let stats = client.stats().unwrap();
    assert!(stats.contains("shed=9"), "{stats}");
    engine.shutdown();
}

// ---- telemetry: registry exposition, METRICS/TRACE wire, sidecar ----

/// Value of the first sample line of `name` in a Prometheus text body
/// (skips HELP/TYPE comments; tolerates a label block).
fn metric_value(body: &str, name: &str) -> Option<f64> {
    let plain = format!("{name} ");
    let labeled = format!("{name}{{");
    body.lines()
        .find(|l| !l.starts_with('#') && (l.starts_with(&plain) || l.starts_with(&labeled)))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

#[test]
fn protocol_metrics_and_trace_roundtrip() {
    for req in [
        Request::Metrics,
        Request::Trace(TraceCmd::On),
        Request::Trace(TraceCmd::Off),
        Request::Trace(TraceCmd::Dump(25)),
    ] {
        assert_eq!(Request::parse(&req.encode()).unwrap(), req, "{req:?}");
    }
    for bad in ["TRACE", "TRACE nope", "TRACE dump", "TRACE dump x", "TRACE on 1", "METRICS 1"] {
        assert!(Request::parse(bad).is_err(), "{bad:?} should fail");
    }
}

#[test]
fn queue_wait_histogram_cohorts() {
    let q = BoundedQueue::new(8);
    // Items pushed before attachment have no cohort stamp and must not
    // wedge or panic the pop-side accounting.
    q.push(1);
    q.push(2);
    let hist = Arc::new(crate::metrics::Histogram::new());
    q.set_wait_histogram(Arc::clone(&hist));
    q.push(3);
    std::thread::sleep(Duration::from_millis(5));
    assert_eq!(q.pop_batch(100).len(), 3);
    let s = hist.snapshot();
    assert_eq!(s.count, 1, "one sample per batch pop");
    assert!(s.min >= 2_000_000, "queue wait {}ns should cover the sleep", s.min);
    // A second cycle records a second sample.
    q.push(4);
    assert_eq!(q.pop(), Some(4));
    assert_eq!(hist.snapshot().count, 2);
}

/// The tentpole end-to-end: a durable engine serves `METRICS` over the
/// wire covering query/ingest/WAL/checkpoint/health/arena/RCU families
/// with per-shard labels, the body is structurally valid Prometheus text
/// exposition, the HTTP sidecar serves the same thing on GET /metrics,
/// and SAVE/STATS grew their new fields.
#[test]
fn tcp_metrics_exposition_and_sidecar() {
    let dir = crate::testutil::TempDir::new("coord-metrics");
    let cfg = ServerConfig {
        shards: 2,
        queue_capacity: 1024,
        persist: crate::config::PersistSection {
            data_dir: dir.path().to_string_lossy().into_owned(),
            fsync: "never".into(),
            checkpoint_interval_ms: 0,
            ..Default::default()
        },
        ..Default::default()
    };
    let (engine, _) = crate::persist::open_engine(&cfg, 2).unwrap();
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    let pairs: Vec<(u64, u64)> = (0..600u64).map(|i| (i % 13, i % 7 + 1)).collect();
    assert_eq!(client.observe_batch(&pairs).unwrap(), 600);
    engine.quiesce();
    for _ in 0..5 {
        client.topk(1, 3).unwrap();
    }
    let save = client.save().unwrap();
    assert!(save.contains("elapsed_ms="), "{save}");

    let body = client.metrics().unwrap();
    // Family coverage: query, ingest, WAL, checkpoint, health, arena, RCU,
    // and the per-shard labeled gauges.
    for family in [
        "mcprioq_queries_total",
        "mcprioq_updates_applied_total",
        "mcprioq_query_ns",
        "mcprioq_queue_wait_ns",
        "mcprioq_batch_apply_ns",
        "mcprioq_wal_append_ns",
        "mcprioq_queue_depth{shard=\"0\"}",
        "mcprioq_nodes{shard=\"1\"}",
        "mcprioq_arena_occupancy_bytes{shard=\"0\"}",
        "mcprioq_snap_hits_total{shard=\"0\"}",
        "mcprioq_health_state{state=\"healthy\"} 1",
        "mcprioq_health_state{state=\"degraded\"} 0",
        "mcprioq_update_rate",
        "mcprioq_rcu_pending",
        "mcprioq_rcu_grace_age_seconds",
        "mcprioq_arena_nodes_live",
        "mcprioq_wal_bytes",
        "mcprioq_wal_appends_total",
        "mcprioq_wal_fsyncs_total",
        "mcprioq_checkpoint_generation",
        "mcprioq_checkpoint_age_seconds",
        "mcprioq_query_ns{quantile=\"0.99\"}",
    ] {
        assert!(body.contains(family), "missing {family} in:\n{body}");
    }
    assert_eq!(metric_value(&body, "mcprioq_queries_total"), Some(5.0), "{body}");
    assert!(metric_value(&body, "mcprioq_updates_applied_total").unwrap() >= 600.0);
    assert!(metric_value(&body, "mcprioq_wal_appends_total").unwrap() > 0.0);
    assert!(metric_value(&body, "mcprioq_queue_wait_ns_count").unwrap() > 0.0);
    assert!(metric_value(&body, "mcprioq_checkpoint_generation").unwrap() >= 1.0);
    // Text-format conformance: every line is a HELP/TYPE comment or
    // `name[{labels}] value` with a numeric value.
    for line in body.lines() {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        assert!(!name.is_empty(), "bad line {line:?}");
        assert!(value.parse::<f64>().is_ok(), "non-numeric value in {line:?}");
        if let Some(open) = line.find('{') {
            assert!(line[open..].contains('}'), "unclosed labels in {line:?}");
        }
    }

    // STATS grew the full query-latency snapshot.
    let stats = client.stats().unwrap();
    for key in ["q_p90_ns=", "q_p999_ns=", "q_min_ns=", "q_max_ns=", "q_mean_ns="] {
        assert!(stats.contains(key), "{stats}");
    }

    // The HTTP sidecar serves the same exposition.
    let sidecar = MetricsSidecar::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let maddr = sidecar.local_addr();
    let _mh = sidecar.spawn();
    use std::io::{Read as _, Write as _};
    let mut s = std::net::TcpStream::connect(maddr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    let mut http = String::new();
    s.read_to_string(&mut http).unwrap();
    assert!(http.starts_with("HTTP/1.1 200 OK"), "{http}");
    assert!(http.contains("text/plain; version=0.0.4"), "{http}");
    assert!(http.contains("mcprioq_queries_total"), "{http}");
    let mut s = std::net::TcpStream::connect(maddr).unwrap();
    s.write_all(b"GET /nope HTTP/1.1\r\n\r\n").unwrap();
    let mut http = String::new();
    s.read_to_string(&mut http).unwrap();
    assert!(http.starts_with("HTTP/1.1 404"), "{http}");

    engine.shutdown();
}

/// Registry reads (renders) race live registration and recording: render
/// repeatedly while ingest and query traffic runs, then check the final
/// counters agree with the engine's own accounting.
#[test]
fn engine_registry_concurrent_with_traffic() {
    let engine = Engine::new(&test_config(), 2);
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let engine = Arc::clone(&engine);
        std::thread::spawn(move || {
            let pairs: Vec<(u64, u64)> = (0..100u64).map(|i| (i % 11, i % 5)).collect();
            for _ in 0..200 {
                engine.observe_batch(&pairs);
            }
        })
    };
    let reader = {
        let engine = Arc::clone(&engine);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut n = 0u64;
            while !stop.load(Ordering::Relaxed) {
                engine.infer_topk(3, 4);
                n += 1;
            }
            n
        })
    };
    let mut out = String::new();
    for _ in 0..100 {
        out.clear();
        engine.render_metrics(&mut out);
        assert!(out.contains("mcprioq_queries_total"), "{out}");
        assert!(out.ends_with('\n'), "render must end each sample line");
    }
    writer.join().unwrap();
    stop.store(true, Ordering::Relaxed);
    let queries = reader.join().unwrap();
    engine.quiesce();
    out.clear();
    engine.render_metrics(&mut out);
    assert_eq!(
        metric_value(&out, "mcprioq_updates_applied_total"),
        Some(20_000.0),
        "{out}"
    );
    assert!(metric_value(&out, "mcprioq_queries_total").unwrap() >= queries as f64);
    engine.shutdown();
}

/// Slow-query capture over TCP: with the threshold armed, a wire TOPK
/// lands in the flight recorder with its parse/infer/format stage split,
/// and `TRACE dump` returns it.
#[test]
fn tcp_trace_slow_query_capture() {
    use crate::metrics::trace;
    let _guard = trace::test_lock();
    trace::reset();

    let engine = Engine::new(&test_config(), 1);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    for _ in 0..3 {
        client.observe(1, 2).unwrap();
    }
    engine.quiesce();

    // Disarmed: queries leave no spans.
    client.topk(1, 2).unwrap();
    assert!(trace::dump(10).is_empty());

    // The armed threshold is process-global, so wire queries from tests
    // running in parallel also land in the shared slow log and can crowd
    // a single dump. Re-issue the query until our span shows in the
    // newest records — its window (query → dump on one connection) is
    // tiny, so one pass is the norm.
    let find_record = |dump: &str, verb: &str| -> Option<String> {
        dump.split(" | ").find(|seg| seg.contains(&format!("verb={verb}"))).map(str::to_string)
    };

    // 1 µs threshold: every wire query is "slow" — worst case for the
    // capture path, deterministic for the test.
    trace::set_slow_query_us(1);
    let mut topk_rec = None;
    for _ in 0..50 {
        client.topk(1, 2).unwrap();
        let dump = client.trace_dump(16).unwrap();
        assert!(dump.starts_with("n="), "{dump}");
        topk_rec = find_record(&dump, "TOPK");
        if topk_rec.is_some() {
            break;
        }
    }
    let rec = topk_rec.expect("slow TOPK span never surfaced in TRACE dump");
    assert!(rec.contains("slow=1"), "{rec}");
    assert!(rec.contains("src=1"), "{rec}");
    for stage in ["parse:", "infer:", "format:"] {
        assert!(rec.contains(stage), "missing {stage} in {rec}");
    }

    // TRACE on/off round-trips over the wire; MTOPK spans carry the
    // combined stage.
    assert_eq!(
        client.request(&Request::Trace(TraceCmd::On)).unwrap(),
        Response::Ok("trace=on".into())
    );
    let mut mtopk_rec = None;
    for _ in 0..50 {
        client.topk_batch(&[1, 9], 2).unwrap();
        mtopk_rec = find_record(&client.trace_dump(16).unwrap(), "MTOPK");
        if mtopk_rec.is_some() {
            break;
        }
    }
    let rec = mtopk_rec.expect("traced MTOPK span never surfaced in TRACE dump");
    assert!(rec.contains("infer+format:"), "{rec}");
    assert_eq!(
        client.request(&Request::Trace(TraceCmd::Off)).unwrap(),
        Response::Ok("trace=off".into())
    );

    trace::reset();
    engine.shutdown();
}

/// The `id=` request tag (DESIGN.md §10): echoed on TOPK/MTOPK/OBSERVEB
/// response lines and stamped into the slow-query flight recorder.
#[test]
fn tcp_request_id_echo_and_flight_recorder_stamp() {
    use crate::metrics::trace;
    let _guard = trace::test_lock();
    trace::reset();

    let engine = Engine::new(&test_config(), 1);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    // OBSERVEB echoes the tag on its ack.
    match client
        .request(&Request::ObserveBatch { pairs: vec![(1, 2), (1, 3)], id: Some("w7".into()) })
        .unwrap()
    {
        Response::Ok(msg) => assert_eq!(msg, "n=2 id=w7"),
        other => panic!("{other:?}"),
    }
    engine.quiesce();

    // Untagged requests stay byte-identical to the old wire format.
    match client.request(&Request::TopK { src: 1, k: 2, id: None }).unwrap() {
        Response::Items { .. } => {}
        other => panic!("{other:?}"),
    }

    // Tagged TOPK answers normally (the trailing id= token is ignored by
    // the ITEMS parser) and, once slow capture is armed, the tag shows up
    // in TRACE dump. MTOPK takes the same path.
    trace::set_slow_query_us(1);
    let mut tagged = None;
    for _ in 0..50 {
        client.request(&Request::TopK { src: 1, k: 2, id: Some("req-42".into()) }).unwrap();
        let dump = client.trace_dump(16).unwrap();
        tagged = dump.split(" | ").find(|seg| seg.contains("id=req-42")).map(str::to_string);
        if tagged.is_some() {
            break;
        }
    }
    let rec = tagged.expect("tagged TOPK span never surfaced in TRACE dump");
    assert!(rec.contains("verb=TOPK"), "{rec}");
    assert!(rec.contains("src=1"), "{rec}");
    match client
        .request(&Request::MultiTopK { srcs: vec![1, 9], k: 2, id: Some("m1".into()) })
        .unwrap()
    {
        Response::MultiItems(bodies) => assert_eq!(bodies.len(), 2),
        other => panic!("{other:?}"),
    }

    trace::reset();
    engine.shutdown();
}

/// The EVENTS wire verb and the sidecar's /healthz + /events routes
/// (DESIGN.md §10).
#[test]
fn tcp_events_verb_and_sidecar_health_routes() {
    use crate::metrics::events;
    let _eguard = events::test_lock();
    events::reset();

    let engine = Engine::new(&test_config(), 1);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();
    let mut client = Client::connect(addr).unwrap();

    // The ring is process-global, so parallel tests may land events here
    // too — assert on the verb's shape and on our own records, never on
    // exact counts.
    assert!(client.events(8).unwrap().starts_with("n="));

    // A health transition is an event; EVENTS drains it newest-first with
    // the full record grammar.
    engine.degrade_for_test("injected for events test");
    let listing = client.events(8).unwrap();
    assert!(listing.starts_with("n="), "{listing}");
    let rec = listing
        .split(" | ")
        .find(|seg| seg.contains("kind=health"))
        .unwrap_or_else(|| panic!("no health event in {listing}"));
    for field in ["ts_ms=", "seq=", "level=error", "what=degraded"] {
        assert!(rec.contains(field), "missing {field} in {rec}");
    }

    // Sidecar: /healthz follows the rung, /events renders the ring, and
    // unknown paths still 404.
    let sidecar = MetricsSidecar::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let maddr = sidecar.local_addr();
    let _mh = sidecar.spawn();
    use std::io::{Read as _, Write as _};
    let http_get = |path: &str| -> String {
        let mut s = std::net::TcpStream::connect(maddr).unwrap();
        s.write_all(format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes()).unwrap();
        let mut http = String::new();
        s.read_to_string(&mut http).unwrap();
        http
    };
    let http = http_get("/healthz");
    assert!(http.starts_with("HTTP/1.1 503"), "{http}");
    assert!(http.contains("degraded"), "{http}");
    engine.heal_for_test();
    let http = http_get("/healthz");
    assert!(http.starts_with("HTTP/1.1 200 OK"), "{http}");
    assert!(http.contains("healthy"), "{http}");
    let http = http_get("/events");
    assert!(http.starts_with("HTTP/1.1 200 OK"), "{http}");
    assert!(http.contains("kind=health"), "{http}");
    assert!(http.contains("what=healed"), "{http}");
    let http = http_get("/eventz");
    assert!(http.starts_with("HTTP/1.1 404"), "{http}");

    events::reset();
    engine.shutdown();
}

#[test]
fn tcp_concurrent_clients() {
    let engine = Engine::new(&test_config(), 2);
    let server = Server::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let _handle = server.spawn();

    let threads: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for i in 0..200u64 {
                    c.observe(t, i % 5).unwrap();
                }
                c.topk(t, 3).unwrap()
            })
        })
        .collect();
    for t in threads {
        let items = t.join().unwrap();
        assert!(items.len() <= 3);
    }
    engine.quiesce();
    assert_eq!(engine.stats().observes, 800);
    engine.shutdown();
}
