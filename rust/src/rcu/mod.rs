//! Epoch-based read-copy-update (RCU) — the reclamation substrate of MCPrioQ.
//!
//! The paper (§II.1) requires that the src/dst hash-tables and the
//! priority-queue doubly-linked list share *one* grace period, exactly like
//! userspace RCU (McKenney & Slingwine [2]). No third-party EBR crate is
//! available offline, so this module implements the classic three-epoch
//! scheme from scratch:
//!
//! * A global epoch counter cycles through `0, 1, 2, …` (only `e mod 3`
//!   matters for garbage bags).
//! * Every thread that enters a read-side critical section *pins* itself:
//!   it publishes the global epoch it observed plus an ACTIVE bit, with a
//!   full fence so writers cannot miss it.
//! * Garbage retired at epoch `e` may be freed once the global epoch has
//!   advanced to `e + 2`: at that point every pinned reader has observed at
//!   least epoch `e + 1`, so none can still hold a reference obtained at
//!   epoch `e`.
//! * [`synchronize`] spins until two epoch advances complete — the drop-in
//!   equivalent of `synchronize_rcu()`.
//!
//! Progress properties: `pin`/`unpin` are wait-free; `defer` is wait-free in
//! the common case (local bag push) and epoch advancement is lock-free
//! (a stalled reader merely delays reclamation, never blocks readers or
//! writers).
//!
//! The collector is process-global (like kernel/liburcu RCU): every
//! `McPrioQ` instance, hash table and list shares it, which is precisely the
//! shared-grace-period property §II.1 asks for.

mod collector;
mod guard;

pub use collector::{collector_stats, grace_age_ns, try_advance, CollectorStats};
pub use guard::{pin, Guard};

use crate::sync::shim::Ordering;

/// Retire a raw pointer allocated with `Box::into_raw`. The pointed-to value
/// is dropped and freed after a full grace period has elapsed.
///
/// # Safety
/// `ptr` must have been produced by `Box::into_raw`, must not be retired
/// twice, and no new references to it may be created after this call
/// (readers that already hold it inside a read-side critical section are
/// exactly what the grace period protects).
pub unsafe fn defer_free<T: Send + 'static>(guard: &Guard, ptr: *mut T) {
    let ptr = ptr as usize;
    guard.defer(move || {
        // SAFETY: per this function's contract, `ptr` came from
        // `Box::into_raw` and is retired exactly once; the grace period
        // guarantees no reader still holds it when the closure runs.
        drop(unsafe { Box::from_raw(ptr as *mut T) });
    });
}

/// Retire an arbitrary closure to run after a grace period.
pub fn defer<F: FnOnce() + Send + 'static>(guard: &Guard, f: F) {
    guard.defer(f);
}

/// Block until a full grace period has elapsed: every read-side critical
/// section that was active when `synchronize` was called has ended.
/// Equivalent to `synchronize_rcu()`.
///
/// Must NOT be called while the calling thread holds a [`Guard`] (it would
/// deadlock on itself); debug builds assert this.
pub fn synchronize() {
    debug_assert!(!guard::current_thread_pinned(), "synchronize() inside read-side critical section");
    // Two successful epoch advances guarantee that every reader pinned
    // before the call has unpinned at least once.
    let start = collector::global_epoch(Ordering::SeqCst);
    while collector::global_epoch(Ordering::SeqCst) < start + 2 {
        collector::try_advance();
        crate::sync::shim::hint::spin_loop();
    }
    // Give reclamation a nudge so callers that synchronize-then-inspect see
    // freed garbage actually freed.
    guard::flush_current_thread();
}

/// Drive epoch advancement and reclamation until all currently-retired
/// garbage has been freed (test/shutdown helper; not part of the hot path).
pub fn drain() {
    for _ in 0..64 {
        synchronize();
        guard::flush_current_thread();
        if collector_stats().pending == 0 {
            return;
        }
    }
}

#[cfg(test)]
mod tests;
