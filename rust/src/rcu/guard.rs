//! Thread-local handles and read-side critical-section guards.

use std::cell::{Cell, RefCell};

use crate::sync::shim::Ordering;

use super::collector::{self, Participant};

/// How many pins between housekeeping attempts (epoch advance + collect).
const PIN_HOUSEKEEP_MASK: u64 = 0x7f;
/// How many defers before forcing housekeeping regardless of pin count.
const DEFER_HOUSEKEEP: u64 = 32;

struct LocalHandle {
    participant: Cell<Option<&'static Participant>>,
    depth: Cell<u32>,
    pins: Cell<u64>,
    defers: Cell<u64>,
}

impl LocalHandle {
    fn participant(&self) -> &'static Participant {
        match self.participant.get() {
            Some(p) => p,
            None => {
                let p = collector::register();
                self.participant.set(Some(p));
                p
            }
        }
    }
}

impl Drop for LocalHandle {
    fn drop(&mut self) {
        if let Some(p) = self.participant.get() {
            collector::unregister(p);
        }
    }
}

thread_local! {
    static HANDLE: LocalHandle = LocalHandle {
        participant: Cell::new(None),
        depth: Cell::new(0),
        pins: Cell::new(0),
        defers: Cell::new(0),
    };
    /// Deferred closures captured while this thread had no participant yet
    /// (never in practice; kept for drop-order robustness during TLS
    /// destruction).
    static FALLBACK: RefCell<Vec<Box<dyn FnOnce() + Send>>> = const { RefCell::new(Vec::new()) };
}

/// A read-side critical section. While any `Guard` is alive on a thread, no
/// grace period that started after the outermost `pin()` can complete, so
/// every pointer loaded from an RCU-protected structure stays valid.
///
/// Guards nest; only the outermost pin/unpin touches shared state.
/// `!Send` by construction (raw pointer field).
pub struct Guard {
    participant: &'static Participant,
    _not_send: std::marker::PhantomData<*mut ()>,
}

/// Enter a read-side critical section. Wait-free.
pub fn pin() -> Guard {
    HANDLE.with(|h| {
        let p = h.participant();
        let depth = h.depth.get();
        h.depth.set(depth + 1);
        if depth == 0 {
            let global = collector::global_epoch(Ordering::SeqCst);
            p.pin(global);
            let pins = h.pins.get().wrapping_add(1);
            h.pins.set(pins);
            if pins & PIN_HOUSEKEEP_MASK == 0 {
                collector::try_advance();
                collector::collect(p);
            }
        } else {
            // Nested pin: already published. Refresh the observed epoch so
            // long-running outer sections don't stall advancement forever.
            // (Safe: refreshing can only move our observed epoch forward.)
            let global = collector::global_epoch(Ordering::SeqCst);
            if p.observed_epoch() != global {
                p.repin(global);
            }
        }
        Guard { participant: p, _not_send: std::marker::PhantomData }
    })
}

impl Guard {
    pub(super) fn defer<F: FnOnce() + Send + 'static>(&self, f: F) {
        let epoch = collector::global_epoch(Ordering::SeqCst);
        collector::retire(self.participant, epoch, Box::new(f));
        HANDLE.with(|h| {
            let d = h.defers.get() + 1;
            h.defers.set(d);
            if d % DEFER_HOUSEKEEP == 0 {
                collector::try_advance();
            }
        });
    }

    /// Momentarily exit and re-enter the critical section so grace periods
    /// can complete across long scans. Any pointer loaded before `repin` is
    /// invalid afterwards. No-op when the guard is nested.
    pub fn repin(&mut self) {
        HANDLE.with(|h| {
            if h.depth.get() == 1 {
                self.participant.unpin();
                let global = collector::global_epoch(Ordering::SeqCst);
                self.participant.pin(global);
            }
        });
    }
}

impl Drop for Guard {
    fn drop(&mut self) {
        HANDLE.with(|h| {
            let depth = h.depth.get();
            h.depth.set(depth - 1);
            if depth == 1 {
                self.participant.unpin();
            }
        });
    }
}

/// True if the current thread currently holds at least one `Guard`.
pub(super) fn current_thread_pinned() -> bool {
    HANDLE.with(|h| h.depth.get() > 0)
}

/// Collect ready garbage from every participant (called by synchronize).
pub(super) fn flush_current_thread() {
    collector::collect_all();
}
