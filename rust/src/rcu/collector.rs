//! Global epoch state and participant registry.
//!
//! Participants (one per OS thread that has ever pinned) live in a global
//! intrusive singly-linked list. Registration CASes onto the head
//! (lock-free); participants are never physically removed — a dead thread's
//! record is marked DEAD and recycled by the next new thread. This keeps
//! `try_advance`'s registry scan simple and safe without reclamation cycles
//! in the reclaimer itself.

use crate::sync::shim::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Mutex, Ordering};
use crate::sync::CachePadded;

/// Local-epoch encoding: `epoch << 1 | ACTIVE`.
const ACTIVE: u64 = 1;

/// One record per OS thread that has ever entered a read-side critical
/// section.
pub(super) struct Participant {
    /// `observed_epoch << 1 | active`.
    pub(super) local: CachePadded<AtomicU64>,
    /// Set while an OS thread owns this record.
    pub(super) owned: AtomicBool,
    /// Intrusive registry link (immutable after registration).
    next: AtomicPtr<Participant>,
    /// Garbage bags, indexed by `epoch % 3`. Only the owning thread pushes;
    /// the global orphan path takes the whole record under `owned=false`.
    pub(super) bags: [Mutex<Vec<(u64, Box<dyn FnOnce() + Send>)>>; 3],
}

// SAFETY: all fields are Sync; bag contents are Send closures.
unsafe impl Send for Participant {}
// SAFETY: see the `Send` justification above.
unsafe impl Sync for Participant {}

impl Participant {
    fn new() -> Self {
        Participant {
            local: CachePadded::new(AtomicU64::new(0)),
            owned: AtomicBool::new(true),
            next: AtomicPtr::new(std::ptr::null_mut()),
            bags: [Mutex::new(Vec::new()), Mutex::new(Vec::new()), Mutex::new(Vec::new())],
        }
    }

    pub(super) fn is_pinned(&self) -> bool {
        self.local.load(Ordering::SeqCst) & ACTIVE != 0
    }

    pub(super) fn pin(&self, global: u64) {
        // Publish "I am reading at epoch `global`". The SeqCst store + the
        // SeqCst load of the global epoch in the caller forms the fence that
        // try_advance relies on.
        self.local.store(global << 1 | ACTIVE, Ordering::SeqCst);
    }

    pub(super) fn repin(&self, global: u64) {
        self.local.store(global << 1 | ACTIVE, Ordering::SeqCst);
    }

    pub(super) fn unpin(&self) {
        let e = self.local.load(Ordering::Relaxed) >> 1;
        self.local.store(e << 1, Ordering::Release);
    }

    pub(super) fn observed_epoch(&self) -> u64 {
        self.local.load(Ordering::SeqCst) >> 1
    }
}

static GLOBAL_EPOCH: AtomicU64 = AtomicU64::new(2);
static REGISTRY: AtomicPtr<Participant> = AtomicPtr::new(std::ptr::null_mut());
static PENDING: AtomicUsize = AtomicUsize::new(0);
static FREED: AtomicUsize = AtomicUsize::new(0);
static ADVANCES: AtomicU64 = AtomicU64::new(0);
/// Nanoseconds (since the process epoch below) of the last successful
/// epoch advance. 0 = never advanced.
static LAST_ADVANCE_NS: AtomicU64 = AtomicU64::new(0);

/// Monotonic process epoch for the grace-age clock (Instant is not
/// representable as an atomic, so ages are stored as offsets from here).
fn process_epoch() -> std::time::Instant {
    static EPOCH: std::sync::OnceLock<std::time::Instant> = std::sync::OnceLock::new();
    *EPOCH.get_or_init(std::time::Instant::now)
}

fn now_ns() -> u64 {
    process_epoch().elapsed().as_nanos() as u64
}

/// Age of the current grace period: nanoseconds since the global epoch
/// last advanced (or since this was first asked, if it never has). A
/// stalled reader shows up as this climbing while `pending` stays flat —
/// the telemetry plane exports it as `mcprioq_rcu_grace_age_seconds`.
pub fn grace_age_ns() -> u64 {
    let last = LAST_ADVANCE_NS.load(Ordering::Relaxed);
    now_ns().saturating_sub(last)
}

pub(super) fn global_epoch(order: Ordering) -> u64 {
    GLOBAL_EPOCH.load(order)
}

/// Acquire a participant record for the current thread: recycle a dead one
/// or allocate + CAS-push a fresh record. Lock-free.
pub(super) fn register() -> &'static Participant {
    // Try to adopt an abandoned record first.
    let mut cur = REGISTRY.load(Ordering::Acquire);
    while !cur.is_null() {
        // SAFETY: registry records are never freed (dead ones are recycled,
        // not removed), so any non-null pointer read from the list stays
        // valid for the process lifetime; Acquire on the list loads orders
        // them after the record's initialization.
        let p = unsafe { &*cur };
        if !p.owned.load(Ordering::Acquire)
            && p.owned
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
        {
            return p;
        }
        cur = p.next.load(Ordering::Acquire);
    }
    // Allocate a new record and push it onto the registry head.
    let rec = Box::into_raw(Box::new(Participant::new()));
    let mut head = REGISTRY.load(Ordering::Acquire);
    loop {
        // SAFETY: `rec` came from Box::into_raw above and is not yet
        // published, so this thread has exclusive access to it.
        unsafe { (*rec).next.store(head, Ordering::Relaxed) };
        match REGISTRY.compare_exchange_weak(head, rec, Ordering::AcqRel, Ordering::Acquire) {
            // SAFETY: `rec` is a live heap allocation that is never freed
            // (see module docs), so promoting it to &'static is sound.
            Ok(_) => return unsafe { &*rec },
            Err(h) => head = h,
        }
    }
}

/// Release the current thread's record so a future thread can adopt it.
/// Outstanding garbage stays in its bags and is reclaimed by whoever adopts
/// the record (or by `flush` calls from other threads via epoch advance —
/// bags are only drained by their owner, so adoption is the mechanism).
pub(super) fn unregister(p: &'static Participant) {
    p.unpin();
    p.owned.store(false, Ordering::Release);
}

/// Record garbage retired at `epoch` in the participant's bag.
pub(super) fn retire(p: &Participant, epoch: u64, f: Box<dyn FnOnce() + Send>) {
    p.bags[(epoch % 3) as usize].lock().unwrap().push((epoch, f));
    PENDING.fetch_add(1, Ordering::Relaxed);
}

/// Free every closure in `p`'s bags that was retired two or more epochs ago.
pub(super) fn collect(p: &Participant) {
    let global = GLOBAL_EPOCH.load(Ordering::SeqCst);
    for bag in &p.bags {
        let ready: Vec<_> = {
            let mut g = bag.lock().unwrap();
            if g.is_empty() || g[0].0 + 2 > global {
                continue;
            }
            std::mem::take(&mut *g)
        };
        let mut keep = Vec::new();
        for (e, f) in ready {
            if e + 2 <= global {
                f();
                FREED.fetch_add(1, Ordering::Relaxed);
                PENDING.fetch_sub(1, Ordering::Relaxed);
            } else {
                keep.push((e, f));
            }
        }
        if !keep.is_empty() {
            bag.lock().unwrap().extend(keep);
        }
    }
}

/// Try to advance the global epoch: succeeds iff every *pinned* participant
/// has observed the current epoch. Lock-free: a failure means someone else
/// advanced or a reader is still on the previous epoch.
pub fn try_advance() -> bool {
    let global = GLOBAL_EPOCH.load(Ordering::SeqCst);
    let mut cur = REGISTRY.load(Ordering::Acquire);
    while !cur.is_null() {
        // SAFETY: registry records are never freed; see `register`.
        let p = unsafe { &*cur };
        let local = p.local.load(Ordering::SeqCst);
        if local & ACTIVE != 0 && (local >> 1) != global {
            return false; // a reader still runs in the previous epoch
        }
        cur = p.next.load(Ordering::Acquire);
    }
    let ok = GLOBAL_EPOCH
        .compare_exchange(global, global + 1, Ordering::SeqCst, Ordering::SeqCst)
        .is_ok();
    if ok {
        ADVANCES.fetch_add(1, Ordering::Relaxed);
        LAST_ADVANCE_NS.store(now_ns(), Ordering::Relaxed);
    }
    ok
}

/// Snapshot of collector counters (tests, metrics endpoint).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectorStats {
    pub epoch: u64,
    pub pending: usize,
    pub freed: usize,
    pub advances: u64,
    pub participants: usize,
}

pub fn collector_stats() -> CollectorStats {
    let mut participants = 0;
    let mut cur = REGISTRY.load(Ordering::Acquire);
    while !cur.is_null() {
        participants += 1;
        // SAFETY: registry records are never freed; see `register`.
        cur = unsafe { &*cur }.next.load(Ordering::Acquire);
    }
    CollectorStats {
        epoch: GLOBAL_EPOCH.load(Ordering::SeqCst),
        pending: PENDING.load(Ordering::Relaxed),
        freed: FREED.load(Ordering::Relaxed),
        advances: ADVANCES.load(Ordering::Relaxed),
        participants,
    }
}

/// Walk every registry record and collect ready garbage (used by
/// `synchronize`/`drain` so orphaned bags of dead threads still get freed).
pub(super) fn collect_all() {
    let mut cur = REGISTRY.load(Ordering::Acquire);
    while !cur.is_null() {
        // SAFETY: registry records are never freed; see `register`.
        let p = unsafe { &*cur };
        collect(p);
        cur = p.next.load(Ordering::Acquire);
    }
}
