//! Unit + stress tests for the epoch-based RCU collector.
//!
//! NOTE: the collector is process-global and the test harness runs tests in
//! parallel, so assertions are written against *relative* deltas (local
//! AtomicBool/AtomicUsize flags), never global totals.

use super::*;
use crate::sync::shim::{AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn pin_unpin_nested() {
    let g1 = pin();
    {
        let g2 = pin();
        drop(g2);
    }
    drop(g1);
    // Re-pin works after full unpin.
    let _g = pin();
}

#[test]
fn deferred_runs_after_synchronize() {
    let ran = Arc::new(AtomicBool::new(false));
    {
        let guard = pin();
        let ran = Arc::clone(&ran);
        defer(&guard, move || ran.store(true, Ordering::SeqCst));
    }
    // Not freed while we could still hold references... after synchronize +
    // drain it must have run.
    drain();
    assert!(ran.load(Ordering::SeqCst));
}

#[test]
fn deferred_does_not_run_while_pinned_reader_exists() {
    // A reader pinned in another thread blocks the grace period.
    let ran = Arc::new(AtomicBool::new(false));
    let release = Arc::new(AtomicBool::new(false));
    let entered = Arc::new(AtomicBool::new(false));

    let reader = {
        let release = Arc::clone(&release);
        let entered = Arc::clone(&entered);
        std::thread::spawn(move || {
            let _g = pin();
            entered.store(true, Ordering::SeqCst);
            while !release.load(Ordering::SeqCst) {
                std::hint::spin_loop();
            }
        })
    };
    while !entered.load(Ordering::SeqCst) {
        std::hint::spin_loop();
    }

    {
        let guard = pin();
        let ran = Arc::clone(&ran);
        defer(&guard, move || ran.store(true, Ordering::SeqCst));
    }
    // Try hard to advance; the pinned reader must hold the closure back.
    for _ in 0..100 {
        try_advance();
    }
    // Even after some advancement attempts the reader pins the old epoch, so
    // at most one advance can have happened since its pin — after which the
    // closure (needing +2) cannot run.
    assert!(!ran.load(Ordering::SeqCst), "grace period completed under a pinned reader");

    release.store(true, Ordering::SeqCst);
    reader.join().unwrap();
    drain();
    assert!(ran.load(Ordering::SeqCst));
}

#[test]
fn defer_free_reclaims_box() {
    struct DropFlag(Arc<AtomicUsize>);
    impl Drop for DropFlag {
        fn drop(&mut self) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }
    let drops = Arc::new(AtomicUsize::new(0));
    let ptr = Box::into_raw(Box::new(DropFlag(Arc::clone(&drops))));
    {
        let guard = pin();
        // SAFETY: `ptr` is from Box::into_raw, retired once, never reused.
        unsafe { defer_free(&guard, ptr) };
    }
    drain();
    assert_eq!(drops.load(Ordering::SeqCst), 1);
}

#[test]
fn synchronize_advances_epoch_by_two() {
    let before = collector_stats().epoch;
    synchronize();
    let after = collector_stats().epoch;
    assert!(after >= before + 2, "epoch before={before} after={after}");
}

#[test]
fn stats_report_participants() {
    let _g = pin();
    let s = collector_stats();
    assert!(s.participants >= 1);
}

/// End-to-end reader/writer stress: writers publish boxed values through an
/// AtomicPtr and retire the old ones; readers continuously dereference under
/// a guard. ASAN-less proxy: values are checksummed so a use-after-free that
/// scribbles memory is likely caught by the checksum assert.
#[test]
fn stress_publish_retire() {
    // Miri's interpreter is ~1000x slower than native; shrink the stress
    // volume so the pointer-heavy suites stay in CI budget while still
    // exercising every publish/retire path.
    const WRITER_OPS: usize = if cfg!(miri) { 50 } else { 2_000 };
    const READERS: usize = if cfg!(miri) { 2 } else { 3 };

    #[derive(Debug)]
    struct Val {
        a: u64,
        b: u64, // must equal !a
    }

    let slot: Arc<AtomicPtr<Val>> = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(Val {
        a: 0,
        b: !0,
    }))));
    let stop = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..READERS)
        .map(|_| {
            let slot = Arc::clone(&slot);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut checks = 0u64;
                // `checks == 0` forces at least one validation even if the
                // writer finishes before this thread is scheduled.
                while checks == 0 || !stop.load(Ordering::Relaxed) {
                    let g = pin();
                    let p = slot.load(Ordering::Acquire);
                    // SAFETY: loaded under the pin `g`, so the grace period
                    // keeps the pointee alive until `g` drops.
                    let v = unsafe { &*p };
                    assert_eq!(v.b, !v.a, "torn/freed value observed");
                    checks += 1;
                    drop(g);
                }
                checks
            })
        })
        .collect();

    for i in 1..=WRITER_OPS as u64 {
        let newp = Box::into_raw(Box::new(Val { a: i, b: !i }));
        let old = slot.swap(newp, Ordering::AcqRel);
        let g = pin();
        // SAFETY: `old` was unlinked by the swap above, so no new reader
        // can reach it; it is retired exactly once.
        unsafe { defer_free(&g, old) };
    }
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        assert!(r.join().unwrap() > 0);
    }
    // Cleanup: retire the final value too.
    let last = slot.swap(std::ptr::null_mut(), Ordering::AcqRel);
    let g = pin();
    // SAFETY: same as above — unlinked by the swap, retired once.
    unsafe { defer_free(&g, last) };
    drop(g);
    drain();
}

#[test]
fn guard_repin_allows_advance() {
    let mut g = pin();
    let e0 = collector_stats().epoch;
    // Other tests running in parallel may hold pins; retry with yields.
    for i in 0..if cfg!(miri) { 2_000 } else { 100_000 } {
        g.repin();
        try_advance();
        if collector_stats().epoch > e0 {
            break;
        }
        if i % 64 == 0 {
            std::thread::yield_now();
        }
    }
    let e1 = collector_stats().epoch;
    assert!(e1 > e0, "repin never allowed the epoch to advance ({e0} -> {e1})");
}

#[test]
fn dead_thread_record_is_adopted() {
    // Spawn a thread that registers and dies; its participant record must be
    // reusable (participants count should not grow monotonically per thread).
    let before = collector_stats().participants;
    for _ in 0..16 {
        std::thread::spawn(|| {
            let _g = pin();
        })
        .join()
        .unwrap();
    }
    let after = collector_stats().participants;
    assert!(
        after <= before + 16,
        "registry grew unboundedly: {before} -> {after}"
    );
    // Stronger: spawning 16 sequential threads should reuse at most a couple
    // of records (each dies before the next starts, modulo harness threads).
    assert!(after <= before + 4, "records not adopted: {before} -> {after}");
}
