//! `mcprioq` — the serving binary: run the recommendation server, poke it
//! as a client, or print build/runtime info.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::cli::{App, Command, Matches, Opt};
use mcprioq::config::ServerConfig;
use mcprioq::coordinator::{
    Client, DecayScheduler, Engine, MetricsSidecar, RepairScheduler, Request, Server,
};

fn app() -> App {
    App {
        name: "mcprioq",
        about: "lock-free online sparse markov-chain server (Derehag & Johansson, 2023)",
        commands: vec![
            Command {
                name: "serve",
                help: "run the recommendation server",
                opts: vec![
                    Opt { name: "config", help: "TOML config path", default: Some("") },
                    Opt { name: "listen", help: "bind address (overrides config)", default: Some("") },
                    Opt { name: "workers", help: "ingest worker threads", default: Some("2") },
                    Opt { name: "no-decay", help: "disable the decay scheduler", default: None },
                    Opt {
                        name: "data-dir",
                        help: "durability directory: WAL + checkpoints + crash recovery \
                               (overrides config; empty = in-memory only)",
                        default: Some(""),
                    },
                    Opt {
                        name: "fsync",
                        help: "WAL fsync policy: never|batch|always (overrides config)",
                        default: Some(""),
                    },
                    Opt {
                        name: "follow",
                        help: "run as a read replica of this leader address: stream \
                               its WAL, serve reads, reject writes until PROMOTE",
                        default: Some(""),
                    },
                    Opt {
                        name: "fault-plan",
                        help: "inject storage faults (chaos testing only), e.g. \
                               'seed=1;fail_fsync_every=3;enospc_after=65536'",
                        default: Some(""),
                    },
                    Opt {
                        name: "metrics-addr",
                        help: "Prometheus exposition sidecar bind address \
                               (overrides config; empty = off)",
                        default: Some(""),
                    },
                    Opt {
                        name: "slow-query-us",
                        help: "slow-query capture threshold in microseconds \
                               (overrides config; 0 = off)",
                        default: Some(""),
                    },
                ],
                positionals: vec![],
            },
            Command {
                name: "client",
                help: "send one request to a running server",
                opts: vec![Opt {
                    name: "addr",
                    help: "server address",
                    default: Some("127.0.0.1:7171"),
                }],
                positionals: vec![("request", "e.g. 'TOPK 5 3' or 'STATS'")],
            },
            Command {
                name: "bench",
                help: "in-process update + read benchmarks; emits BENCH_*.json artifacts",
                opts: vec![
                    Opt { name: "threads", help: "writer threads", default: Some("4") },
                    Opt {
                        name: "batches",
                        help: "comma-separated batch sizes to sweep",
                        default: Some("1,16,256"),
                    },
                    Opt { name: "shards", help: "shards (0 = CPU count)", default: Some("0") },
                    Opt { name: "millis", help: "measure window per point", default: Some("400") },
                    Opt {
                        name: "queued",
                        help: "drive the queued engine path (per-shard queues + workers) \
                               instead of the chain directly",
                        default: None,
                    },
                    Opt {
                        name: "read-threads",
                        help: "comma-separated reader thread counts for the read sweep",
                        default: Some("1,2,4,8"),
                    },
                    Opt {
                        name: "read-fanout",
                        help: "edges on the hot node the read sweep queries",
                        default: Some("256"),
                    },
                    Opt {
                        name: "json-dir",
                        help: "directory for BENCH_read.json / BENCH_update.json",
                        default: Some("."),
                    },
                    Opt {
                        name: "durability",
                        help: "also run the durability sweep (WAL off/never/batch/always \
                               + recovery replay) and emit BENCH_durability.json",
                        default: None,
                    },
                    Opt {
                        name: "replication",
                        help: "also run the replication bench (leader + streaming \
                               follower, wire ingest) and emit BENCH_replication.json",
                        default: None,
                    },
                ],
                positionals: vec![],
            },
            Command {
                name: "info",
                help: "print artifact/runtime information",
                opts: vec![],
                positionals: vec![],
            },
        ],
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let matches = match app().parse(&args) {
        Ok(m) => m,
        Err(usage) => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    };
    let result = match matches.command.as_str() {
        "serve" => serve(&matches),
        "client" => client(&matches),
        "bench" => bench(&matches),
        "info" => info(),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn serve(m: &Matches) -> anyhow::Result<()> {
    let mut config = match m.get("config") {
        Some("") | None => ServerConfig::default(),
        Some(path) => ServerConfig::load(path).map_err(|e| anyhow::anyhow!(e))?,
    };
    if let Some(listen) = m.get("listen") {
        if !listen.is_empty() {
            config.listen = listen.to_string();
        }
    }
    if let Some(dir) = m.get("data-dir") {
        if !dir.is_empty() {
            config.persist.data_dir = dir.to_string();
        }
    }
    if let Some(fsync) = m.get("fsync") {
        if !fsync.is_empty() {
            mcprioq::persist::FsyncPolicy::parse(fsync).map_err(|e| anyhow::anyhow!(e))?;
            config.persist.fsync = fsync.to_string();
        }
    }
    if let Some(plan) = m.get("fault-plan") {
        if !plan.is_empty() {
            // Validated (and turned into a FaultyIo) by persist_config().
            config.persist.fault_plan = plan.to_string();
            eprintln!("[persist] FAULT INJECTION ACTIVE: {plan}");
        }
    }
    if let Some(addr) = m.get("metrics-addr") {
        if !addr.is_empty() {
            config.metrics_addr = addr.to_string();
        }
    }
    if let Some(us) = m.get("slow-query-us") {
        if !us.is_empty() {
            config.slow_query_us =
                us.parse().map_err(|e| anyhow::anyhow!("bad --slow-query-us: {e}"))?;
        }
    }
    let workers = m.get_u64("workers").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(2) as usize;

    // Slow-query flight recorder ([server] slow_query_us, 0 = off): a
    // process-global knob, armed before either serving mode starts.
    mcprioq::metrics::trace::set_slow_query_us(config.slow_query_us);

    // Follower mode: bootstrap from the leader, serve reads, track lag.
    if let Some(leader) = m.get("follow").filter(|s| !s.is_empty()) {
        return serve_follower(config, workers, leader, m.flag("no-decay"));
    }

    // Durable path: recover (checkpoint + WAL replay) before serving.
    let persist_cfg = config.persist_config().map_err(|e| anyhow::anyhow!(e))?;
    let engine = match &persist_cfg {
        Some(pcfg) => {
            let (engine, r) =
                mcprioq::persist::open_engine(&config, workers).map_err(|e| anyhow::anyhow!(e))?;
            println!(
                "recovered from {}: gen={} (+{} deltas) epoch={} nodes={} \
                 replayed_batches={} ({} updates) replayed_maintenance={}{}{}",
                pcfg.data_dir.display(),
                r.generation,
                r.snapshot_deltas,
                r.epoch,
                r.snapshot_nodes,
                r.replayed_batches,
                r.replayed_updates,
                r.replayed_maintenance,
                if r.torn_tails > 0 { " [torn tail tolerated]" } else { "" },
                if r.layout_changed { " [shard layout changed; epoch bumped]" } else { "" },
            );
            engine
        }
        None => Engine::new(&config, workers),
    };
    let _decay = match config.decay_interval {
        Some(interval) if !m.flag("no-decay") => {
            Some(DecayScheduler::start(Arc::clone(&engine), interval))
        }
        _ => None,
    };
    // Standalone repair cadence ([chain] repair_interval_s): structural
    // maintenance decoupled from the decay (model) schedule. `--no-decay`
    // disables both — it means "no background maintenance".
    let _repair = (config.chain.repair_interval_s > 0 && !m.flag("no-decay")).then(|| {
        RepairScheduler::start(
            Arc::clone(&engine),
            Duration::from_secs(config.chain.repair_interval_s),
        )
    });
    let _checkpointer = match &persist_cfg {
        Some(pcfg) => pcfg.checkpoint_interval.map(|interval| {
            mcprioq::persist::CheckpointScheduler::start(Arc::clone(&engine), interval)
        }),
        None => None,
    };
    // Correctness observatory ([audit], DESIGN.md §10): error sampling +
    // invariant watchdog in one background thread.
    engine.spawn_audit(None);
    let server = Server::bind(Arc::clone(&engine), &config.listen)?;
    println!(
        "mcprioq serving on {} ({} shards, {} ingest workers, decay {:?}, durability {})",
        server.local_addr(),
        engine.shard_count(),
        workers,
        config.decay_interval,
        match &persist_cfg {
            Some(p) => format!("{} fsync={}", p.data_dir.display(), p.fsync.as_str()),
            None => "off".to_string(),
        }
    );
    let handle = server.spawn();
    // Prometheus sidecar ([server] metrics_addr, empty = off): scrape
    // GET /metrics without a line-protocol client.
    let _metrics = if config.metrics_addr.is_empty() {
        None
    } else {
        let sidecar = MetricsSidecar::bind(Arc::clone(&engine), &config.metrics_addr)?;
        println!("metrics exposition on http://{}/metrics", sidecar.local_addr());
        Some(sidecar.spawn())
    };

    // Periodic stats until ^C.
    loop {
        std::thread::sleep(Duration::from_secs(10));
        let s = engine.stats();
        println!(
            "[stats] nodes={} edges={} observes={} queries={} queue={} p50={}ns p99={}ns \
             rate={:.0}/s wal_bytes={} ckpt_age={}s health={} shed={} ratelimited={}",
            s.nodes,
            s.edges,
            s.observes,
            s.queries,
            s.queue_depth,
            s.query_ns_p50,
            s.query_ns_p99,
            s.update_rate,
            s.wal_bytes,
            s.ckpt_age_s,
            s.health,
            s.shed,
            s.ratelimited
        );
        let _ = &handle;
    }
}

/// `mcprioq serve --follow <leader>`: run the follower plane (DESIGN.md
/// §5) behind the normal TCP front-end in read-only mode. The decay
/// scheduler stays off while following — maintenance is leader-driven:
/// the leader's decay/repair arrive as WAL records and are replayed in
/// sequence position (DESIGN.md §6), so an independent local decay would
/// double-apply it. It starts exactly once on promotion, and only after
/// the apply plane has drained (`writable`), so a replayed leader decay
/// record and the new local timer can never cover the same interval
/// twice. The checkpoint scheduler runs as usual so a durable follower
/// bounds its own recovery time.
fn serve_follower(
    config: ServerConfig,
    workers: usize,
    leader: &str,
    no_decay: bool,
) -> anyhow::Result<()> {
    let persist_cfg = config.persist_config().map_err(|e| anyhow::anyhow!(e))?;
    let handle = mcprioq::replicate::start_follower(config.clone(), workers, leader)
        .map_err(|e| anyhow::anyhow!(e))?;
    let engine = Arc::clone(&handle.engine);
    let _checkpointer = match &persist_cfg {
        Some(pcfg) => pcfg.checkpoint_interval.map(|interval| {
            mcprioq::persist::CheckpointScheduler::start(Arc::clone(&engine), interval)
        }),
        None => None,
    };
    // Observatory on the follower too, with the replica's lag feeding the
    // repl_lag watchdog check (DESIGN.md §10).
    engine.spawn_audit(Some(Arc::clone(&handle.state)));
    let server =
        Server::bind_replica(Arc::clone(&engine), &config.listen, Arc::clone(&handle.state))?;
    println!(
        "mcprioq following {leader} on {} ({} shards, bootstrap={}, durability {})",
        server.local_addr(),
        engine.shard_count(),
        if handle.state.snapshot_bootstrap() { "snapshot" } else { "log" },
        match &persist_cfg {
            Some(p) => p.data_dir.display().to_string(),
            None => "off".to_string(),
        }
    );
    let _handle = server.spawn();
    // Same sidecar as the leader: follower scrapes additionally expose the
    // mcprioq_repl_* lag/link family registered by start_follower.
    let _metrics = if config.metrics_addr.is_empty() {
        None
    } else {
        let sidecar = MetricsSidecar::bind(Arc::clone(&engine), &config.metrics_addr)?;
        println!("metrics exposition on http://{}/metrics", sidecar.local_addr());
        Some(sidecar.spawn())
    };

    let mut decay: Option<DecayScheduler> = None;
    let mut repair: Option<RepairScheduler> = None;
    let mut promoted_seen = false;
    let mut fault_reported = false;
    let mut ticks = 0u64;
    loop {
        std::thread::sleep(Duration::from_secs(1));
        // Promotion watch: once *writable* (promotion latched AND the
        // apply plane drained of queued replicated records — a still-
        // queued leader DecayRecord must land before the local timer can
        // own maintenance), this node is a leader: start the maintenance
        // plane it was holding back, exactly once (`promoted_seen`).
        if handle.state.writable() && !promoted_seen {
            promoted_seen = true;
            println!("[replicate] promoted: accepting writes");
            if let Some(interval) = config.decay_interval.filter(|_| !no_decay) {
                decay = Some(DecayScheduler::start(Arc::clone(&engine), interval));
            }
            // Same promotion gate as decay: the leader's repair records
            // were replayed in sequence position until now, so the local
            // repair timer must not start before writability.
            if config.chain.repair_interval_s > 0 && !no_decay {
                repair = Some(RepairScheduler::start(
                    Arc::clone(&engine),
                    Duration::from_secs(config.chain.repair_interval_s),
                ));
            }
        }
        let _ = (&decay, &repair);
        if !fault_reported {
            if let Some(fault) = handle.state.fault() {
                eprintln!("[replicate] replication faulted: {fault} (reads still served)");
                fault_reported = true;
            }
        }
        ticks += 1;
        if ticks % 10 == 0 {
            let s = engine.stats();
            println!(
                "[stats] nodes={} edges={} queries={} lag_records={} lag_s={} connected={}",
                s.nodes,
                s.edges,
                s.queries,
                handle.state.lag_records(),
                handle.state.lag_seconds(),
                handle.state.connected(),
            );
        }
    }
}

fn client(m: &Matches) -> anyhow::Result<()> {
    let addr = m.get_or("addr", "127.0.0.1:7171");
    let line = m.positional(0).ok_or_else(|| anyhow::anyhow!("missing request argument"))?;
    let req = Request::parse(line).map_err(|e| anyhow::anyhow!(e))?;
    let mut client = Client::connect(addr)?;
    if matches!(req, Request::Metrics) {
        // The protocol's one multi-line response: print the exposition
        // body with its terminating sentinel intact.
        print!("{}", client.metrics()?);
        println!("# EOF");
    } else {
        println!("{}", client.request(&req)?);
    }
    Ok(())
}

/// In-process benchmark suite behind `mcprioq bench`:
///
/// 1. **Update sweep** — batch sizes over the ingest hot path: either the
///    chain's `observe_batch` directly, or the whole queued pipeline
///    (per-shard queues + shard-affine workers) with `--queued`.
/// 2. **Read sweep** — hot-node `infer_topk` throughput across reader
///    thread counts, prefix-sum snapshots off vs on (the read-path
///    acceptance sweep: snapshots must win ≥ 2× at 8 threads).
/// 3. **Threshold layout sweep** — `infer_threshold` with the sorted
///    prefix array vs the Eytzinger+SIMD layout (the mechanical-sympathy
///    acceptance sweep: ≥ 1.5× at 64+ edges).
///
/// Every row carries hardware perf columns (IPC, LLC/branch misses per
/// kiloinstruction) when `perf_event_open` is permitted, `-` otherwise.
/// All sweeps emit machine-readable artifacts (`BENCH_update.json`,
/// `BENCH_read.json`) under `--json-dir` for the CI perf trajectory.
fn bench(m: &Matches) -> anyhow::Result<()> {
    use mcprioq::bench_harness::{
        fmt_rate, hot_node_chain, parse_batch_list, read_topk_sweep, threshold_layout_sweep,
        Bench, JsonArtifact, JsonVal, Table,
    };
    use mcprioq::chain::{ChainConfig, McPrioQ};
    use mcprioq::coordinator::Engine;
    use mcprioq::workload::{TransitionStream, ZipfChainStream};

    let threads = m.get_u64("threads").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(4) as usize;
    let shards = m.get_u64("shards").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(0) as usize;
    let millis = m.get_u64("millis").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(400);
    let batches = parse_batch_list(m.get_or("batches", "1,16,256"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let read_threads = parse_batch_list(m.get_or("read-threads", "1,2,4,8"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let read_fanout =
        m.get_u64("read-fanout").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(256).max(2);
    let json_dir = std::path::PathBuf::from(m.get_or("json-dir", "."));
    let queued = m.flag("queued");
    let duration = Duration::from_millis(millis.max(50));
    let bench = Bench::quick();

    let path = if queued { "engine-queued" } else { "chain-direct" };
    println!("mcprioq bench: {path}, {threads} threads, {}ms/point", duration.as_millis());
    let mut update_json = JsonArtifact::new("update_batch_sweep");
    let mut table = Table::new(
        "cli_batch_sweep",
        &["path", "threads", "batch", "updates_per_s", "vs_first", "apply_p50_ns", "apply_p99_ns"],
    );
    let mut base = 0.0;
    for (point, &batch) in batches.iter().enumerate() {
        let chain = Arc::new(McPrioQ::new(ChainConfig::default()));
        // The engine only matters on the queued path; on the chain-direct
        // path build the smallest possible one (1 shard, 0 workers) so no
        // idle queues/threads sit behind the measurement.
        let config = mcprioq::config::ServerConfig {
            shards: if queued { shards } else { 1 },
            queue_capacity: 65_536,
            ..Default::default()
        };
        let engine = Engine::new(&config, if queued { threads.max(1) } else { 0 });
        let applied_before = engine.stats().applied_updates;
        // Queued writes are asynchronous: the thunks count nothing and the
        // rate comes from the applied-update counter over the window, so
        // backlog that shutdown would discard is never credited.
        let thunk_rate = bench.run_threads(threads.max(1), duration, |t| {
            let chain = Arc::clone(&chain);
            let engine = Arc::clone(&engine);
            let mut stream = ZipfChainStream::new(10_000, 24, 1.1, t as u64 + 1);
            let mut buf = Vec::with_capacity(batch);
            move || {
                buf.clear();
                for _ in 0..batch {
                    buf.push(stream.next_transition());
                }
                if queued {
                    if batch == 1 {
                        engine.observe(buf[0].0, buf[0].1);
                    } else {
                        engine.observe_batch(&buf);
                    }
                    0
                } else {
                    if batch == 1 {
                        chain.observe(buf[0].0, buf[0].1);
                    } else {
                        chain.observe_batch(&buf);
                    }
                    batch as u64
                }
            }
        });
        let applied_after = engine.stats().applied_updates;
        let rate = if queued {
            (applied_after - applied_before) as f64 / duration.as_secs_f64()
        } else {
            thunk_rate
        };
        // First sweep point is the baseline even if it measured 0 (a 0.0
        // sentinel would silently rebase later ratios and print NaN).
        if point == 0 {
            base = rate;
        }
        let vs_first =
            if base > 0.0 { format!("{:.2}", rate / base) } else { "-".to_string() };
        // Batch-apply latency straight from the engine's registry (the
        // same histogram METRICS exposes); the direct path never touches
        // the engine pipeline, so its columns stay empty.
        let apply = queued.then(|| {
            engine
                .telemetry()
                .histogram("mcprioq_batch_apply_ns", "Batch apply duration (ns).", &[])
                .snapshot()
        });
        table.row(&[
            path.to_string(),
            threads.to_string(),
            batch.to_string(),
            format!("{rate:.0}"),
            vs_first,
            apply.map_or_else(|| "-".to_string(), |s| s.p50.to_string()),
            apply.map_or_else(|| "-".to_string(), |s| s.p99.to_string()),
        ]);
        update_json.row(&[
            ("path", JsonVal::Str(path.to_string())),
            ("threads", JsonVal::Int(threads as u64)),
            ("batch", JsonVal::Int(batch as u64)),
            ("updates_per_s", JsonVal::Num(rate)),
            ("apply_p50_ns", JsonVal::Num(apply.map_or(f64::NAN, |s| s.p50 as f64))),
            ("apply_p99_ns", JsonVal::Num(apply.map_or(f64::NAN, |s| s.p99 as f64))),
        ]);
        println!("  batch {batch:>5}: {}", fmt_rate(rate));
        engine.shutdown();
    }
    table.finish();
    let p = update_json.finish(&json_dir.join("BENCH_update.json"))?;
    println!("wrote {}", p.display());

    // ---- read sweep: hot-node topk, snapshots off vs on ----
    println!(
        "mcprioq bench: read sweep, fanout {read_fanout}, {}ms/point",
        duration.as_millis()
    );
    // Perf-counter columns (`metrics::PerfCounters`): `-` / JSON null when
    // perf_event_open is unavailable (non-Linux, paranoid, seccomp).
    let fmt_opt = |v: Option<f64>| v.map_or_else(|| "-".to_string(), |x| format!("{x:.2}"));
    let json_opt = |v: Option<f64>| JsonVal::Num(v.unwrap_or(f64::NAN));
    let mut read_json = JsonArtifact::new("read_topk_sweep");
    let mut read_table = Table::new(
        "cli_read_sweep",
        &[
            "mode",
            "threads",
            "topk_per_s",
            "vs_list_walk",
            "p50_ns",
            "p99_ns",
            "ipc",
            "llc_pki",
            "br_pki",
        ],
    );
    // Shared fixture (bench_harness::hot_node_chain, same as bench e9): a
    // single hot src node with `read_fanout` Zipf-weighted edges.
    let train = 200_000;
    let list_chain = hot_node_chain(
        ChainConfig { snap_enabled: false, ..Default::default() },
        read_fanout as usize,
        train,
        42,
    );
    let snap_chain = hot_node_chain(ChainConfig::default(), read_fanout as usize, train, 42);
    for row in read_topk_sweep(&bench, duration, &read_threads, 10, &list_chain, &snap_chain) {
        read_table.row(&[
            row.mode.to_string(),
            row.threads.to_string(),
            format!("{:.0}", row.topk_per_s),
            format!("{:.2}", row.vs_list_walk),
            row.lat.p50.to_string(),
            row.lat.p99.to_string(),
            fmt_opt(row.perf.ipc()),
            fmt_opt(row.perf.llc_per_kinst()),
            fmt_opt(row.perf.branch_miss_per_kinst()),
        ]);
        read_json.row(&[
            ("mode", JsonVal::Str(row.mode.to_string())),
            ("threads", JsonVal::Int(row.threads as u64)),
            ("fanout", JsonVal::Int(read_fanout)),
            ("topk_per_s", JsonVal::Num(row.topk_per_s)),
            ("vs_list_walk", JsonVal::Num(row.vs_list_walk)),
            ("p50_ns", JsonVal::Int(row.lat.p50)),
            ("p99_ns", JsonVal::Int(row.lat.p99)),
            ("ipc", json_opt(row.perf.ipc())),
            ("llc_miss_per_kinst", json_opt(row.perf.llc_per_kinst())),
            ("branch_miss_per_kinst", json_opt(row.perf.branch_miss_per_kinst())),
        ]);
        println!(
            "  {:>9} x{}: {} ({:.2}x, ipc {})",
            row.mode,
            row.threads,
            fmt_rate(row.topk_per_s),
            row.vs_list_walk,
            fmt_opt(row.perf.ipc()),
        );
    }
    read_table.finish();

    // ---- snapshot-layout sweep: sorted binary search vs Eytzinger+SIMD ----
    // The mechanical-sympathy acceptance sweep: infer_threshold over the
    // Eytzinger layout must beat the sorted prefix array ≥ 1.5x at 64+
    // edges, and the perf columns should attribute the win (fewer branch
    // misses from the branchless descent).
    println!("mcprioq bench: threshold layout sweep, sorted vs eytzinger");
    let mut layout_table = Table::new(
        "cli_threshold_layout_sweep",
        &["layout", "fanout", "thresholds_per_s", "vs_sorted", "ipc", "llc_pki", "br_pki"],
    );
    let layout_fanouts: Vec<usize> =
        [16usize, 64, read_fanout as usize].into_iter().filter(|&f| f >= 2).collect();
    let layout_threads = read_threads.iter().copied().max().unwrap_or(1);
    for row in threshold_layout_sweep(&bench, duration, layout_threads, &layout_fanouts, train) {
        layout_table.row(&[
            row.layout.to_string(),
            row.fanout.to_string(),
            format!("{:.0}", row.thresholds_per_s),
            format!("{:.2}", row.vs_sorted),
            fmt_opt(row.perf.ipc()),
            fmt_opt(row.perf.llc_per_kinst()),
            fmt_opt(row.perf.branch_miss_per_kinst()),
        ]);
        read_json.row(&[
            ("mode", JsonVal::Str(format!("threshold-{}", row.layout))),
            ("threads", JsonVal::Int(layout_threads as u64)),
            ("fanout", JsonVal::Int(row.fanout as u64)),
            ("thresholds_per_s", JsonVal::Num(row.thresholds_per_s)),
            ("vs_sorted", JsonVal::Num(row.vs_sorted)),
            ("ipc", json_opt(row.perf.ipc())),
            ("llc_miss_per_kinst", json_opt(row.perf.llc_per_kinst())),
            ("branch_miss_per_kinst", json_opt(row.perf.branch_miss_per_kinst())),
        ]);
        println!(
            "  {:>9} fanout {:>4}: {} ({:.2}x, br_pki {})",
            row.layout,
            row.fanout,
            fmt_rate(row.thresholds_per_s),
            row.vs_sorted,
            fmt_opt(row.perf.branch_miss_per_kinst()),
        );
    }
    layout_table.finish();

    // ---- correctness observatory: staleness-vs-error curve ----
    // One row per target staleness, so the artifact records what the
    // `chain.snap_staleness` serving bound costs in rank/mass error
    // (DESIGN.md §10).
    let audit_overhead = {
        use mcprioq::bench_harness::{audit_overhead_probe, staleness_error_curve};
        println!("mcprioq bench: audit staleness-vs-error curve, fanout {read_fanout}");
        let mut stale0_mass_error = 0.0f64;
        for pt in staleness_error_curve(&[0, 16, 64, 256, 1024], read_fanout as usize) {
            if pt.target_staleness == 0 {
                stale0_mass_error = pt.mass_error;
            }
            read_json.row(&[
                ("mode", JsonVal::Str("audit_staleness_curve".to_string())),
                ("fanout", JsonVal::Int(read_fanout)),
                ("target_staleness", JsonVal::Int(pt.target_staleness)),
                ("staleness", JsonVal::Int(pt.staleness)),
                ("mass_error", JsonVal::Num(pt.mass_error)),
                ("rank_inversions", JsonVal::Int(pt.rank_inversions)),
                ("displacement", JsonVal::Int(pt.displacement)),
                ("samples", JsonVal::Int(pt.samples as u64)),
            ]);
            println!(
                "  staleness {:>5} (target {:>4}): mass err {:.3e}, inversions {}, displacement {}",
                pt.staleness,
                pt.target_staleness,
                pt.mass_error,
                pt.rank_inversions,
                pt.displacement
            );
        }

        // ---- audit-overhead gate: armed auditor must cost < 2% reads ----
        let probe_threads = read_threads.iter().copied().max().unwrap_or(2).min(4);
        println!(
            "mcprioq bench: audit overhead, {probe_threads} wire clients, {}ms/window",
            duration.as_millis()
        );
        let probe = audit_overhead_probe(&bench, duration, probe_threads, read_fanout as usize)
            .map_err(|e| anyhow::anyhow!(e))?;
        read_json.row(&[
            ("mode", JsonVal::Str("audit_overhead".to_string())),
            ("threads", JsonVal::Int(probe_threads as u64)),
            ("reads_per_s_off", JsonVal::Num(probe.reads_per_s_off)),
            ("reads_per_s_on", JsonVal::Num(probe.reads_per_s_on)),
            ("overhead_frac", JsonVal::Num(probe.overhead_frac)),
            ("audit_rounds", JsonVal::Int(probe.audit_rounds)),
        ]);
        println!(
            "  disarmed {} | armed {} | overhead {:.2}% ({} audit rounds)",
            fmt_rate(probe.reads_per_s_off),
            fmt_rate(probe.reads_per_s_on),
            100.0 * probe.overhead_frac,
            probe.audit_rounds
        );
        (probe.overhead_frac, stale0_mass_error)
    };
    let p = read_json.finish(&json_dir.join("BENCH_read.json"))?;
    println!("wrote {}", p.display());
    // Gates bail after the artifact is written, so a failed run still
    // leaves the evidence on disk.
    if audit_overhead.1 != 0.0 {
        anyhow::bail!(
            "audit exactness gate: mass error {:.3e} at staleness 0 (must be exactly 0)",
            audit_overhead.1
        );
    }
    if audit_overhead.0 > 0.02 {
        anyhow::bail!(
            "audit overhead gate: armed auditor costs {:.2}% read throughput (> 2%)",
            100.0 * audit_overhead.0
        );
    }

    // ---- telemetry-overhead gate: armed tracing must cost < 3% reads ----
    {
        use mcprioq::bench_harness::telemetry_overhead_probe;
        let probe_threads = read_threads.iter().copied().max().unwrap_or(2).min(4);
        println!(
            "mcprioq bench: telemetry overhead, {probe_threads} wire clients, {}ms/window",
            duration.as_millis()
        );
        let probe =
            telemetry_overhead_probe(&bench, duration, probe_threads, read_fanout as usize)
                .map_err(|e| anyhow::anyhow!(e))?;
        let mut tel_json = JsonArtifact::new("telemetry_overhead");
        tel_json.row(&[
            ("threads", JsonVal::Int(probe_threads as u64)),
            ("reads_per_s_off", JsonVal::Num(probe.reads_per_s_off)),
            ("reads_per_s_on", JsonVal::Num(probe.reads_per_s_on)),
            ("overhead_frac", JsonVal::Num(probe.overhead_frac)),
        ]);
        println!(
            "  disarmed {} | armed {} | overhead {:.2}%",
            fmt_rate(probe.reads_per_s_off),
            fmt_rate(probe.reads_per_s_on),
            100.0 * probe.overhead_frac
        );
        let p = tel_json.finish(&json_dir.join("BENCH_telemetry.json"))?;
        println!("wrote {}", p.display());
        if probe.overhead_frac > 0.03 {
            anyhow::bail!(
                "telemetry overhead gate: armed tracing costs {:.2}% read throughput (> 3%)",
                100.0 * probe.overhead_frac
            );
        }
    }

    // ---- durability sweep: WAL off vs fsync policies + recovery ----
    if m.flag("durability") {
        use mcprioq::bench_harness::durability_sweep;
        use mcprioq::testutil::TempDir;
        println!(
            "mcprioq bench: durability sweep, {threads} threads, {}ms/point",
            duration.as_millis()
        );
        let scratch = TempDir::new("bench-durability");
        let (rows, probe) = durability_sweep(&bench, duration, threads, shards, 256, scratch.path())
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut dur_json = JsonArtifact::new("durability_sweep");
        let mut dur_table =
            Table::new("cli_durability_sweep", &["mode", "updates_per_s", "vs_memory"]);
        for row in &rows {
            dur_table.row(&[
                row.mode.to_string(),
                format!("{:.0}", row.updates_per_s),
                format!("{:.2}", row.vs_memory),
            ]);
            dur_json.row(&[
                ("mode", JsonVal::Str(row.mode.to_string())),
                ("threads", JsonVal::Int(threads as u64)),
                ("updates_per_s", JsonVal::Num(row.updates_per_s)),
                ("vs_memory", JsonVal::Num(row.vs_memory)),
            ]);
            println!(
                "  fsync {:>7}: {} ({:.2}x)",
                row.mode,
                fmt_rate(row.updates_per_s),
                row.vs_memory
            );
        }
        dur_table.finish();
        dur_json.row(&[
            ("mode", JsonVal::Str("recover".to_string())),
            ("replayed_batches", JsonVal::Int(probe.batches)),
            ("replayed_updates", JsonVal::Int(probe.updates)),
            ("updates_per_s", JsonVal::Num(probe.updates_per_s)),
        ]);
        println!(
            "  recovery: {} updates in {:.3}s ({})",
            probe.updates,
            probe.secs,
            fmt_rate(probe.updates_per_s)
        );

        // Checkpoint-cost metric (DESIGN.md §6): differential bytes at a
        // fixed 10% dirty ratio vs the full snapshot, plus the
        // decay-record replay equality gate.
        use mcprioq::bench_harness::checkpoint_cost_probe;
        let ckpt = checkpoint_cost_probe(shards, 20_000, 0.1, scratch.path())
            .map_err(|e| anyhow::anyhow!(e))?;
        dur_json.row(&[
            ("mode", JsonVal::Str("ckpt_full".to_string())),
            ("model_nodes", JsonVal::Int(ckpt.model_nodes as u64)),
            ("bytes", JsonVal::Int(ckpt.full_bytes)),
        ]);
        dur_json.row(&[
            ("mode", JsonVal::Str("ckpt_delta".to_string())),
            ("dirty_nodes", JsonVal::Int(ckpt.dirty_nodes as u64)),
            (
                "dirty_ratio",
                JsonVal::Num(ckpt.dirty_nodes as f64 / ckpt.model_nodes as f64),
            ),
            ("bytes", JsonVal::Int(ckpt.delta_bytes)),
            ("vs_full", JsonVal::Num(ckpt.delta_vs_full)),
            ("decay_replay_ok", JsonVal::Bool(ckpt.decay_replay_ok)),
        ]);
        println!(
            "  checkpoint: full {} bytes, delta {} bytes at {:.0}% dirty \
             ({:.3}x full), decay_replay_ok={}",
            ckpt.full_bytes,
            ckpt.delta_bytes,
            100.0 * ckpt.dirty_nodes as f64 / ckpt.model_nodes as f64,
            ckpt.delta_vs_full,
            ckpt.decay_replay_ok
        );
        // Fault-recovery gate (DESIGN.md §8): injected ENOSPC must degrade
        // the engine, the heal loop must bring it back, and the healed +
        // recovered state must equal a never-faulted reference.
        use mcprioq::bench_harness::fault_recovery_probe;
        let fault = fault_recovery_probe(shards, scratch.path()).map_err(|e| anyhow::anyhow!(e))?;
        dur_json.row(&[
            ("mode", JsonVal::Str("fault_recovery".to_string())),
            ("degraded", JsonVal::Bool(fault.degraded)),
            ("healed", JsonVal::Bool(fault.healed)),
            ("wal_retries", JsonVal::Int(fault.wal_retries)),
            ("recovery_equal", JsonVal::Bool(fault.recovery_equal)),
            ("fault_recovery_ok", JsonVal::Bool(fault.ok())),
        ]);
        println!(
            "  fault recovery: degraded={} healed={} wal_retries={} equal={} -> ok={}",
            fault.degraded,
            fault.healed,
            fault.wal_retries,
            fault.recovery_equal,
            fault.ok()
        );
        let p = dur_json.finish(&json_dir.join("BENCH_durability.json"))?;
        println!("wrote {}", p.display());
        // The smoke gate: a differential must cost a fraction of the full
        // snapshot at 10% dirty, and decay-record replay must reproduce
        // the never-crashed state exactly.
        if !ckpt.decay_replay_ok {
            anyhow::bail!("decay-record replay changed recovery equality");
        }
        if ckpt.delta_vs_full > 0.5 {
            anyhow::bail!(
                "differential checkpoint bytes do not scale with the dirty set: \
                 {:.3}x full at 10% dirty",
                ckpt.delta_vs_full
            );
        }
        if !fault.ok() {
            anyhow::bail!(
                "fault-recovery gate failed: degraded={} healed={} recovery_equal={}",
                fault.degraded,
                fault.healed,
                fault.recovery_equal
            );
        }
    }

    // ---- replication bench: leader + streaming follower over the wire ----
    if m.flag("replication") {
        use mcprioq::bench_harness::replication_sweep;
        use mcprioq::testutil::TempDir;
        println!(
            "mcprioq bench: replication, {threads} wire clients, {}ms window",
            duration.as_millis()
        );
        let scratch = TempDir::new("bench-replication");
        let probe = replication_sweep(&bench, duration, threads, shards, 256, scratch.path())
            .map_err(|e| anyhow::anyhow!(e))?;
        let mut repl_json = JsonArtifact::new("replication");
        repl_json.row(&[
            ("threads", JsonVal::Int(threads as u64)),
            ("leader_updates_per_s", JsonVal::Num(probe.leader_updates_per_s)),
            ("follower_updates_per_s", JsonVal::Num(probe.follower_updates_per_s)),
            ("steady_lag_records", JsonVal::Int(probe.steady_lag_records)),
            ("catchup_secs", JsonVal::Num(probe.catchup_secs)),
            ("converged", JsonVal::Bool(probe.converged)),
        ]);
        println!(
            "  leader ingest {} | follower apply {} | steady lag {} records | \
             catch-up {:.3}s | converged={}",
            fmt_rate(probe.leader_updates_per_s),
            fmt_rate(probe.follower_updates_per_s),
            probe.steady_lag_records,
            probe.catchup_secs,
            probe.converged
        );
        let p = repl_json.finish(&json_dir.join("BENCH_replication.json"))?;
        println!("wrote {}", p.display());
    }
    Ok(())
}

fn info() -> anyhow::Result<()> {
    println!("mcprioq {} — three-layer build", env!("CARGO_PKG_VERSION"));
    let dir = mcprioq::runtime::default_artifacts_dir();
    match mcprioq::runtime::XlaRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts dir: {dir:?}");
            for e in &rt.manifest().entries {
                println!("  {:?} n={} b={} k={} ({})", e.kind, e.n, e.b, e.k, e.file);
            }
        }
        Err(e) => println!("dense engine unavailable: {e:#} (run `make artifacts`)"),
    }
    Ok(())
}
