//! `mcprioq` — the serving binary: run the recommendation server, poke it
//! as a client, or print build/runtime info.

use std::sync::Arc;
use std::time::Duration;

use mcprioq::cli::{App, Command, Matches, Opt};
use mcprioq::config::ServerConfig;
use mcprioq::coordinator::{Client, DecayScheduler, Engine, Request, Server};

fn app() -> App {
    App {
        name: "mcprioq",
        about: "lock-free online sparse markov-chain server (Derehag & Johansson, 2023)",
        commands: vec![
            Command {
                name: "serve",
                help: "run the recommendation server",
                opts: vec![
                    Opt { name: "config", help: "TOML config path", default: Some("") },
                    Opt { name: "listen", help: "bind address (overrides config)", default: Some("") },
                    Opt { name: "workers", help: "ingest worker threads", default: Some("2") },
                    Opt { name: "no-decay", help: "disable the decay scheduler", default: None },
                ],
                positionals: vec![],
            },
            Command {
                name: "client",
                help: "send one request to a running server",
                opts: vec![Opt {
                    name: "addr",
                    help: "server address",
                    default: Some("127.0.0.1:7171"),
                }],
                positionals: vec![("request", "e.g. 'TOPK 5 3' or 'STATS'")],
            },
            Command {
                name: "info",
                help: "print artifact/runtime information",
                opts: vec![],
                positionals: vec![],
            },
        ],
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let matches = match app().parse(&args) {
        Ok(m) => m,
        Err(usage) => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    };
    let result = match matches.command.as_str() {
        "serve" => serve(&matches),
        "client" => client(&matches),
        "info" => info(),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn serve(m: &Matches) -> anyhow::Result<()> {
    let mut config = match m.get("config") {
        Some("") | None => ServerConfig::default(),
        Some(path) => ServerConfig::load(path).map_err(|e| anyhow::anyhow!(e))?,
    };
    if let Some(listen) = m.get("listen") {
        if !listen.is_empty() {
            config.listen = listen.to_string();
        }
    }
    let workers = m.get_u64("workers").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(2) as usize;

    let engine = Engine::new(&config, workers);
    let _decay = match config.decay_interval {
        Some(interval) if !m.flag("no-decay") => {
            Some(DecayScheduler::start(Arc::clone(&engine), interval))
        }
        _ => None,
    };
    let server = Server::bind(Arc::clone(&engine), &config.listen)?;
    println!(
        "mcprioq serving on {} ({} shards, {} ingest workers, decay {:?})",
        server.local_addr(),
        engine.shard_count(),
        workers,
        config.decay_interval
    );
    let handle = server.spawn();

    // Periodic stats until ^C.
    loop {
        std::thread::sleep(Duration::from_secs(10));
        let s = engine.stats();
        println!(
            "[stats] nodes={} edges={} observes={} queries={} queue={} p50={}ns p99={}ns",
            s.nodes, s.edges, s.observes, s.queries, s.queue_depth, s.query_ns_p50, s.query_ns_p99
        );
        let _ = &handle;
    }
}

fn client(m: &Matches) -> anyhow::Result<()> {
    let addr = m.get_or("addr", "127.0.0.1:7171");
    let line = m.positional(0).ok_or_else(|| anyhow::anyhow!("missing request argument"))?;
    let req = Request::parse(line).map_err(|e| anyhow::anyhow!(e))?;
    let mut client = Client::connect(addr)?;
    println!("{}", client.request(&req)?);
    Ok(())
}

fn info() -> anyhow::Result<()> {
    println!("mcprioq {} — three-layer build", env!("CARGO_PKG_VERSION"));
    let dir = mcprioq::runtime::default_artifacts_dir();
    match mcprioq::runtime::XlaRuntime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts dir: {dir:?}");
            for e in &rt.manifest().entries {
                println!("  {:?} n={} b={} k={} ({})", e.kind, e.n, e.b, e.k, e.file);
            }
        }
        Err(e) => println!("dense engine unavailable: {e:#} (run `make artifacts`)"),
    }
    Ok(())
}
