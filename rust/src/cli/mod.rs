//! Command-line argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, and generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    /// None = boolean flag; Some(default) = valued option.
    pub default: Option<&'static str>,
}

#[derive(Debug, Clone)]
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<Opt>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Matches {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|_| format!("--{key}: expected integer, got {v:?}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.get(key)
            .map(|v| v.parse::<f64>().map_err(|_| format!("--{key}: expected number, got {v:?}")))
            .transpose()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

/// Top-level application parser.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn parse(&self, args: &[String]) -> Result<Matches, String> {
        if args.is_empty() || args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
            return Err(self.usage());
        }
        let cmd_name = &args[0];
        let Some(cmd) = self.commands.iter().find(|c| c.name == cmd_name) else {
            return Err(format!("unknown command {cmd_name:?}\n\n{}", self.usage()));
        };
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positionals = Vec::new();
        // Seed defaults.
        for o in &cmd.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.command_usage(cmd));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let Some(opt) = cmd.opts.iter().find(|o| o.name == key) else {
                    return Err(format!("unknown option --{key}\n\n{}", self.command_usage(cmd)));
                };
                match (opt.default.is_some(), inline_val) {
                    (false, None) => flags.push(key.to_string()),
                    (false, Some(_)) => {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    (true, Some(v)) => {
                        values.insert(key.to_string(), v);
                    }
                    (true, None) => {
                        i += 1;
                        let Some(v) = args.get(i) else {
                            return Err(format!("--{key} requires a value"));
                        };
                        values.insert(key.to_string(), v.clone());
                    }
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        if positionals.len() > cmd.positionals.len() {
            return Err(format!(
                "too many positional arguments for {}: expected at most {}",
                cmd.name,
                cmd.positionals.len()
            ));
        }
        Ok(Matches { command: cmd.name.to_string(), values, flags, positionals })
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<12} {}\n", c.name, c.help));
        }
        s.push_str("\nRun '<command> --help' for command options.\n");
        s
    }

    fn command_usage(&self, cmd: &Command) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.help);
        for o in &cmd.opts {
            let head = match o.default {
                Some(d) => format!("--{} <v> [default: {}]", o.name, d),
                None => format!("--{}", o.name),
            };
            s.push_str(&format!("  {head:<40} {}\n", o.help));
        }
        for (p, h) in &cmd.positionals {
            s.push_str(&format!("  <{p}>  {h}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "mcprioq",
            about: "test",
            commands: vec![Command {
                name: "serve",
                help: "run server",
                opts: vec![
                    Opt { name: "config", help: "config path", default: Some("") },
                    Opt { name: "threads", help: "worker count", default: Some("4") },
                    Opt { name: "verbose", help: "log more", default: None },
                ],
                positionals: vec![("address", "bind address")],
            }],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let m = app()
            .parse(&argv(&["serve", "--config", "/tmp/c.toml", "--verbose", "0.0.0.0:1"]))
            .unwrap();
        assert_eq!(m.get("config"), Some("/tmp/c.toml"));
        assert_eq!(m.get("threads"), Some("4")); // default
        assert!(m.flag("verbose"));
        assert_eq!(m.positional(0), Some("0.0.0.0:1"));
    }

    #[test]
    fn equals_syntax() {
        let m = app().parse(&argv(&["serve", "--threads=8"])).unwrap();
        assert_eq!(m.get_u64("threads").unwrap(), Some(8));
    }

    #[test]
    fn errors() {
        assert!(app().parse(&argv(&["bogus"])).is_err());
        assert!(app().parse(&argv(&["serve", "--nope"])).is_err());
        assert!(app().parse(&argv(&["serve", "--config"])).is_err());
        assert!(app().parse(&argv(&["serve", "--verbose=1"])).is_err());
        assert!(app().parse(&argv(&["serve", "a", "b"])).is_err());
        assert!(app().parse(&argv(&[])).is_err()); // usage
    }

    #[test]
    fn help_lists_commands_and_options() {
        let u = app().usage();
        assert!(u.contains("serve"));
        let err = app().parse(&argv(&["serve", "--help"])).unwrap_err();
        assert!(err.contains("--threads"));
        assert!(err.contains("default: 4"));
    }

    #[test]
    fn typed_getters() {
        let m = app().parse(&argv(&["serve", "--threads", "abc"])).unwrap();
        assert!(m.get_u64("threads").is_err());
        let m = app().parse(&argv(&["serve", "--threads", "2.5"])).unwrap();
        assert_eq!(m.get_f64("threads").unwrap(), Some(2.5));
        assert_eq!(m.get_u64("missing").unwrap(), None);
    }
}
