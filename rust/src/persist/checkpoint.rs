//! Atomic incremental checkpoints: pause ingest at a batch boundary,
//! encode either the full quiesced export (`ckpt-<gen>.snap`) or only the
//! nodes dirtied since the previous generation (`ckpt-<gen>.delta`) to a
//! `tmp` + `rename`, commit a manifest recording the base→delta chain and
//! the per-shard WAL cut points, then truncate sealed WAL segments.
//!
//! Full vs differential (DESIGN.md §6): the first generation after
//! startup is always full (in-memory dirty epochs reset on restart); a
//! generation is also full when the chain already holds
//! `delta_chain_max` deltas or when at least `delta_dirty_ratio` of the
//! nodes are dirty — otherwise it is a delta and checkpoint cost scales
//! with the nodes touched since the base, not the model size.
//!
//! Commit protocol (crash-safe at every step):
//!
//! 1. `quiesce` + ingest gate → read `(cuts, payload)` atomically. The cut
//!    for shard `i` is its WAL's last appended sequence number; because
//!    appends happen before applies inside the gate, the payload contains
//!    exactly the records with `seq <= cuts[i]`. The engine's checkpoint
//!    mark advances inside the same pause, so dirty stamps never straddle
//!    the cut.
//! 2. Write `ckpt-<gen>.{snap|delta}.tmp`, `sync_data`, rename, fsync the
//!    directory. A crash before the rename leaves only a `.tmp` recovery
//!    ignores (and sweeps).
//! 3. Write `MANIFEST.tmp`, rename over `MANIFEST`, fsync the directory.
//!    *This rename is the commit point*: before it, recovery uses the
//!    previous chain + a longer WAL suffix; after it, the new one.
//! 4. Truncate WAL segments fully covered by the *previous* generation's
//!    cuts (lag-one, bounded below by follower retention pins up to the
//!    `[replicate] max_pin_lag_bytes` escape hatch); delete checkpoint
//!    files behind the previous chain's base (a torn newest file still
//!    has the rest of its chain as fallback).

use std::fs;
use std::io;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TomlDoc;
use crate::coordinator::{Engine, Health};
use crate::runtime::RetryPolicy;
use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};

use super::io::IoHandle;
use super::{codec, DeltaChain};

/// Result of one committed checkpoint (`SAVE` reply, logs).
#[derive(Debug, Clone, Copy)]
pub struct CheckpointSummary {
    pub generation: u64,
    /// "full" or "delta".
    pub kind: &'static str,
    /// Src nodes written in this generation's file (for a delta: only the
    /// dirty nodes).
    pub nodes: usize,
    /// Encoded file size of this generation.
    pub bytes: u64,
    /// WAL bytes freed by truncation.
    pub wal_freed: u64,
    /// Wall-clock duration of the whole checkpoint (pause + encode +
    /// commit + truncate), in milliseconds.
    pub elapsed_ms: u64,
}

/// The committed-checkpoint pointer (`checkpoint/MANIFEST`), in the same
/// TOML subset `ServerConfig` uses, so it is both human-greppable and
/// parsed by the existing `TomlDoc`. `snapshot` names the chain's base
/// (full) file; `deltas` lists the differential generations on top of it,
/// oldest first. `wal_cuts` are the cuts of the *newest* generation. A
/// PR 3-era manifest has no `deltas` key and parses as an empty chain.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Manifest {
    pub generation: u64,
    pub epoch: u64,
    pub shards: usize,
    pub snapshot: String,
    pub deltas: Vec<String>,
    pub wal_cuts: Vec<u64>,
}

impl Manifest {
    pub(crate) fn render(&self) -> String {
        let cuts =
            self.wal_cuts.iter().map(u64::to_string).collect::<Vec<_>>().join(", ");
        let deltas = self
            .deltas
            .iter()
            .map(|d| format!("\"{d}\""))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "# mcprioq durability manifest — do not edit while the server runs\n\
             [checkpoint]\n\
             generation = {}\n\
             epoch = {}\n\
             shards = {}\n\
             snapshot = \"{}\"\n\
             deltas = [{}]\n\
             wal_cuts = [{}]\n",
            self.generation, self.epoch, self.shards, self.snapshot, deltas, cuts
        )
    }

    pub(crate) fn parse(text: &str) -> Result<Manifest, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
        let get = |key: &str| {
            doc.get(key).ok_or_else(|| format!("manifest: missing {key}"))
        };
        let wal_cuts = get("checkpoint.wal_cuts")?
            .as_array()?
            .iter()
            .map(|v| v.as_u64())
            .collect::<Result<Vec<_>, _>>()?;
        let deltas = match doc.get("checkpoint.deltas") {
            Some(v) => v
                .as_array()?
                .iter()
                .map(|d| Ok(d.as_str()?.to_string()))
                .collect::<Result<Vec<_>, String>>()?,
            None => Vec::new(),
        };
        let m = Manifest {
            generation: get("checkpoint.generation")?.as_u64()?,
            epoch: get("checkpoint.epoch")?.as_u64()?,
            shards: get("checkpoint.shards")?.as_usize()?,
            snapshot: get("checkpoint.snapshot")?.as_str()?.to_string(),
            deltas,
            wal_cuts,
        };
        if m.wal_cuts.len() != m.shards {
            return Err(format!(
                "manifest: {} cuts for {} shards",
                m.wal_cuts.len(),
                m.shards
            ));
        }
        // The chain must be contiguous generations ending at `generation`:
        // base, base+1, …, generation.
        if let Some(base) = snapshot_generation(&m.snapshot) {
            for (i, d) in m.deltas.iter().enumerate() {
                match delta_generation(d) {
                    Some(gen) if gen == base + 1 + i as u64 => {}
                    _ => return Err(format!("manifest: delta {d:?} breaks the chain")),
                }
            }
            if base + m.deltas.len() as u64 != m.generation {
                return Err(format!(
                    "manifest: chain {} + {} deltas does not reach generation {}",
                    base,
                    m.deltas.len(),
                    m.generation
                ));
            }
        } else {
            return Err(format!("manifest: bad snapshot name {:?}", m.snapshot));
        }
        Ok(m)
    }
}

/// Write `bytes` to `path` atomically: `<path>.tmp` + fsync + rename +
/// directory fsync. All through the storage-I/O handle, so fault plans
/// can fail any step (a failed tmp write or fsync aborts *before* the
/// rename — the commit point is never reached with unsynced data).
fn write_atomic(io: &IoHandle, path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = io.create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_data()?;
    }
    io.rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        io.sync_dir(dir);
    }
    Ok(())
}

pub(crate) fn snapshot_name(generation: u64) -> String {
    format!("ckpt-{generation:06}.snap")
}

pub(crate) fn delta_name(generation: u64) -> String {
    format!("ckpt-{generation:06}.delta")
}

/// Parse a `ckpt-<gen>.snap` filename back to its generation.
pub(crate) fn snapshot_generation(name: &str) -> Option<u64> {
    name.strip_prefix("ckpt-")?.strip_suffix(".snap")?.parse().ok()
}

/// Parse a `ckpt-<gen>.delta` filename back to its generation.
pub(crate) fn delta_generation(name: &str) -> Option<u64> {
    name.strip_prefix("ckpt-")?.strip_suffix(".delta")?.parse().ok()
}

/// Generation of any checkpoint file (full or delta).
pub(crate) fn file_generation(name: &str) -> Option<u64> {
    snapshot_generation(name).or_else(|| delta_generation(name))
}

/// Take one checkpoint of `engine` now. Errors if persistence was never
/// armed. Concurrent callers (scheduler vs wire `SAVE`) serialize.
pub fn run_checkpoint(engine: &Engine) -> Result<CheckpointSummary, String> {
    let persist = Arc::clone(
        engine.persist_state().ok_or("persistence is not enabled (no data dir)")?,
    );
    let t0 = std::time::Instant::now();
    let _serial = persist.serialize_checkpoints();

    // A degraded engine has acked batches parked outside the WAL (and its
    // quiesce target includes them): pausing ingest now would either hang
    // or cut a checkpoint that silently excludes parked history. Refuse;
    // the scheduler retries after the heal.
    if engine.health() != Health::Healthy {
        return Err(format!(
            "engine is {} ({}); checkpoint deferred until it heals",
            engine.health().as_str(),
            engine.health_reason()
        ));
    }

    let nshards = persist.shard_count();
    let chain = persist.delta_chain();
    let pcfg = persist.config().clone();
    let generation = persist.generation() + 1;

    // Everything under the pause: the cuts, the full-vs-delta decision,
    // the payload collection, and the mark advance form one atomic cut.
    // One model sweep in the common case: the dirty export doubles as the
    // dirty count (the node total is O(1)), and only a compaction trigger
    // pays for the second, full sweep.
    let (cuts, full, payload, new_floor) = engine.with_ingest_paused(|| {
        let cuts: Vec<u64> = (0..nshards).map(|i| persist.wal(i).last_seq()).collect();
        let mut full = chain.base == 0
            || chain.floor == 0
            || pcfg.delta_chain_max == 0
            || chain.len >= pcfg.delta_chain_max;
        let mut payload = if full { Vec::new() } else { engine.export_dirty(chain.floor) };
        if !full {
            let total = engine.node_count();
            full = total > 0
                && payload.len() as f64 / total as f64 >= pcfg.delta_dirty_ratio;
        }
        if full {
            payload = engine.export();
        }
        let new_floor = engine.advance_ckpt_mark();
        (cuts, full, payload, new_floor)
    });

    let epoch = persist.epoch();
    let (name, bytes) = if full {
        (snapshot_name(generation), codec::encode_snapshot(epoch, &cuts, &payload))
    } else {
        (
            delta_name(generation),
            codec::encode_delta(generation - 1, epoch, &cuts, &payload),
        )
    };
    let dir = pcfg.checkpoint_dir();
    write_atomic(&pcfg.io, &dir.join(&name), &bytes)
        .map_err(|e| format!("writing {name}: {e}"))?;
    let new_chain = if full {
        DeltaChain { base: generation, len: 0, floor: new_floor }
    } else {
        DeltaChain { base: chain.base, len: chain.len + 1, floor: new_floor }
    };
    let manifest = Manifest {
        generation,
        epoch,
        shards: nshards,
        snapshot: snapshot_name(new_chain.base),
        deltas: (new_chain.base + 1..=generation).map(delta_name).collect(),
        wal_cuts: cuts.clone(),
    };
    // The commit point: MANIFEST now names the new generation's chain.
    write_atomic(&pcfg.io, &pcfg.manifest_path(), manifest.render().as_bytes())
        .map_err(|e| format!("committing manifest: {e}"))?;

    // Persist the mark floor beside the manifest (after the commit point,
    // best-effort): recovery reads it to keep post-restart checkpoints
    // differential. A crash between the two writes leaves a *stale lower*
    // floor, whose dirty export is a superset — correct, just larger.
    if let Err(e) =
        write_atomic(&pcfg.io, &pcfg.ckpt_mark_path(), format!("{new_floor}\n").as_bytes())
    {
        eprintln!("[persist] writing ckpt mark sidecar: {e} (next restart checkpoints full)");
    }

    // Truncation lags one generation: delete only segments covered by the
    // *previous* committed generation's cuts, so recovery can still fall
    // back to it (its chain files are retained, see below) without hitting
    // a WAL hole. Connected followers pin the floor further: a segment a
    // live replication stream hasn't fully sent yet is never deleted, so a
    // slow follower lags instead of being forced into a snapshot resync —
    // bounded by `[replicate] max_pin_lag_bytes`: past that, the pin is
    // overridden (the dead or hopeless follower renegotiates a snapshot
    // bootstrap when it returns) rather than pinning the log forever.
    let max_pin_lag = engine.replicate_config().max_pin_lag_bytes;
    let trunc_cuts = persist.rotate_cuts(cuts.clone());
    let mut wal_freed = 0u64;
    for (shard, &cut) in trunc_cuts.iter().enumerate().take(nshards) {
        let mut wal = persist.wal(shard);
        let effective = match persist.pin_floor(shard) {
            Some(floor) if floor < cut => {
                let pinned = wal
                    .pinned_bytes(floor, cut)
                    .map_err(|e| format!("sizing wal shard {shard}: {e}"))?;
                if max_pin_lag > 0 && pinned > max_pin_lag {
                    eprintln!(
                        "[persist] shard {shard}: follower pin at seq {floor} holds \
                         {pinned} bytes (> max_pin_lag_bytes {max_pin_lag}); truncating \
                         past it"
                    );
                    cut
                } else {
                    floor
                }
            }
            Some(_) | None => cut,
        };
        wal_freed += wal
            .truncate_upto(effective)
            .map_err(|e| format!("truncating wal shard {shard}: {e}"))?;
    }
    // Retention: the committed chain plus the previous committed chain's
    // files. Everything behind the *previous* chain's base predates the
    // fallback horizon (a torn newest file falls back within its own
    // chain) and is deleted. `chain.base` is the previous chain's base for
    // a delta commit (same chain) and for a full commit (the chain it
    // supersedes) alike.
    if chain.base > 0 {
        if let Ok(rd) = fs::read_dir(&dir) {
            for entry in rd.flatten() {
                if let Some(gen) = entry.file_name().to_str().and_then(file_generation)
                {
                    if gen < chain.base {
                        let _ = pcfg.io.remove_file(&entry.path());
                    }
                }
            }
        }
    }
    persist.set_delta_chain(new_chain);
    persist.set_generation(generation);
    Ok(CheckpointSummary {
        generation,
        kind: if full { "full" } else { "delta" },
        nodes: payload.len(),
        bytes: bytes.len() as u64,
        wal_freed,
        elapsed_ms: t0.elapsed().as_millis() as u64,
    })
}

/// Install a leader-sent snapshot (checkpoint codec bytes) as this data
/// dir's committed checkpoint: any local WAL and checkpoints are wiped —
/// a follower bootstrapping from a snapshot supersedes whatever divergent
/// or stale history it held — then the snapshot and a matching MANIFEST
/// are committed atomically. [`super::open_engine`] afterwards recovers
/// from it and arms the WAL writers at the embedded cut points, which is
/// exactly where the leader resumes streaming. Returns `(epoch, cuts)`.
pub fn install_snapshot(
    pcfg: &super::PersistConfig,
    generation: u64,
    bytes: &[u8],
) -> Result<(u64, Vec<u64>), String> {
    let (epoch, cuts, _snap) =
        codec::decode_snapshot(bytes).map_err(|e| format!("leader snapshot: {e}"))?;
    let _ = fs::remove_dir_all(pcfg.wal_root());
    let _ = fs::remove_dir_all(pcfg.checkpoint_dir());
    let dir = pcfg.checkpoint_dir();
    fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let name = snapshot_name(generation);
    write_atomic(&pcfg.io, &dir.join(&name), bytes)
        .map_err(|e| format!("writing {name}: {e}"))?;
    let manifest = Manifest {
        generation,
        epoch,
        shards: cuts.len(),
        snapshot: name,
        deltas: Vec::new(),
        wal_cuts: cuts.clone(),
    };
    write_atomic(&pcfg.io, &pcfg.manifest_path(), manifest.render().as_bytes())
        .map_err(|e| format!("committing manifest: {e}"))?;
    Ok((epoch, cuts))
}

/// Background checkpointer: fires every `checkpoint_interval` on an
/// absolute deadline (wakeups don't drift the cadence) and early whenever
/// the live WAL exceeds `checkpoint_wal_bytes`. Stops when dropped.
pub struct CheckpointScheduler {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
    runs: Arc<AtomicU64>,
    failed: Arc<AtomicBool>,
}

impl CheckpointScheduler {
    /// How often the threshold condition is polled between interval ticks.
    const POLL: Duration = Duration::from_secs(1);

    pub fn start(engine: Arc<Engine>, interval: Duration) -> CheckpointScheduler {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let runs = Arc::new(AtomicU64::new(0));
        let failed = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = Arc::clone(&stop);
            let runs = Arc::clone(&runs);
            let failed = Arc::clone(&failed);
            std::thread::spawn(move || {
                let (lock, cvar) = &*stop;
                let threshold = engine
                    .persist_state()
                    .map(|p| p.config().checkpoint_wal_bytes)
                    .unwrap_or(u64::MAX);
                let retry = RetryPolicy::wal_retry(0xC4EC_0000);
                let mut failures = 0u32;
                let mut deadline = Instant::now() + interval;
                loop {
                    {
                        let mut stopped =
                            lock.lock().unwrap_or_else(PoisonError::into_inner);
                        while !*stopped {
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let wait = (deadline - now).min(Self::POLL);
                            let (guard, _) = cvar
                                .wait_timeout(stopped, wait)
                                .unwrap_or_else(PoisonError::into_inner);
                            stopped = guard;
                            // Early checkpoint once the WAL outgrows the
                            // bound, without waiting out the interval.
                            if !*stopped
                                && engine
                                    .persist_state()
                                    .is_some_and(|p| p.wal_bytes() >= threshold)
                            {
                                break;
                            }
                        }
                        if *stopped {
                            return;
                        }
                    }
                    match engine.checkpoint() {
                        Ok(_) => {
                            failures = 0;
                            runs.fetch_add(1, Ordering::Relaxed);
                            // Absolute cadence: late checkpoints don't
                            // compound.
                            deadline += interval;
                            let now = Instant::now();
                            if deadline < now {
                                deadline = now + interval;
                            }
                        }
                        Err(e) => {
                            // An I/O error (or a degraded engine) must not
                            // wedge the scheduler: keep looping, reprobing
                            // on capped backoff instead of the full
                            // interval so the next generation lands soon
                            // after the disk (or the engine) heals.
                            failed.store(true, Ordering::Relaxed);
                            eprintln!("[persist] periodic checkpoint failed: {e}");
                            let pause = retry.delay(failures).min(interval);
                            failures = failures.saturating_add(1);
                            deadline = Instant::now() + pause;
                        }
                    }
                }
            })
        };
        CheckpointScheduler { stop, handle: Some(handle), runs, failed }
    }

    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    pub fn has_failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn stop(&self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cvar.notify_all();
    }
}

impl Drop for CheckpointScheduler {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
