//! Atomic checkpoints: pause ingest at a batch boundary, encode the
//! quiesced export to `ckpt-<gen>.snap.tmp`, fsync + `rename`, commit a
//! manifest recording the per-shard WAL cut points, then truncate sealed
//! WAL segments the snapshot covers.
//!
//! Commit protocol (crash-safe at every step):
//!
//! 1. `quiesce` + ingest gate → read `(cuts, export)` atomically. The cut
//!    for shard `i` is its WAL's last appended sequence number; because
//!    appends happen before applies inside the gate, the export contains
//!    exactly the batches with `seq <= cuts[i]`.
//! 2. Write `ckpt-<gen>.snap.tmp`, `sync_data`, rename to
//!    `ckpt-<gen>.snap`, fsync the directory. A crash before the rename
//!    leaves only a `.tmp` recovery ignores (and sweeps).
//! 3. Write `MANIFEST.tmp`, rename over `MANIFEST`, fsync the directory.
//!    *This rename is the commit point*: before it, recovery uses the
//!    previous checkpoint + a longer WAL suffix; after it, the new one.
//! 4. Truncate WAL segments fully covered by the cuts; delete snapshot
//!    generations older than the previous one (retention: current + 1,
//!    so a torn current snapshot still has a fallback).

use std::fs::{self, File};
use std::io::{self, Write as _};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::TomlDoc;
use crate::coordinator::Engine;

use super::{codec, wal};

/// Result of one committed checkpoint (`SAVE` reply, logs).
#[derive(Debug, Clone, Copy)]
pub struct CheckpointSummary {
    pub generation: u64,
    /// Src nodes in the snapshot.
    pub nodes: usize,
    /// Encoded snapshot size.
    pub bytes: u64,
    /// WAL bytes freed by truncation.
    pub wal_freed: u64,
}

/// The committed-checkpoint pointer (`checkpoint/MANIFEST`), in the same
/// TOML subset `ServerConfig` uses, so it is both human-greppable and
/// parsed by the existing `TomlDoc`.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Manifest {
    pub generation: u64,
    pub epoch: u64,
    pub shards: usize,
    pub snapshot: String,
    pub wal_cuts: Vec<u64>,
}

impl Manifest {
    pub(crate) fn render(&self) -> String {
        let cuts =
            self.wal_cuts.iter().map(u64::to_string).collect::<Vec<_>>().join(", ");
        format!(
            "# mcprioq durability manifest — do not edit while the server runs\n\
             [checkpoint]\n\
             generation = {}\n\
             epoch = {}\n\
             shards = {}\n\
             snapshot = \"{}\"\n\
             wal_cuts = [{}]\n",
            self.generation, self.epoch, self.shards, self.snapshot, cuts
        )
    }

    pub(crate) fn parse(text: &str) -> Result<Manifest, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
        let get = |key: &str| {
            doc.get(key).ok_or_else(|| format!("manifest: missing {key}"))
        };
        let wal_cuts = get("checkpoint.wal_cuts")?
            .as_array()?
            .iter()
            .map(|v| v.as_u64())
            .collect::<Result<Vec<_>, _>>()?;
        let m = Manifest {
            generation: get("checkpoint.generation")?.as_u64()?,
            epoch: get("checkpoint.epoch")?.as_u64()?,
            shards: get("checkpoint.shards")?.as_usize()?,
            snapshot: get("checkpoint.snapshot")?.as_str()?.to_string(),
            wal_cuts,
        };
        if m.wal_cuts.len() != m.shards {
            return Err(format!(
                "manifest: {} cuts for {} shards",
                m.wal_cuts.len(),
                m.shards
            ));
        }
        Ok(m)
    }
}

/// Write `bytes` to `path` atomically: `<path>.tmp` + fsync + rename +
/// directory fsync.
fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_data()?;
    }
    fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        wal::sync_dir(dir);
    }
    Ok(())
}

pub(crate) fn snapshot_name(generation: u64) -> String {
    format!("ckpt-{generation:06}.snap")
}

/// Parse a `ckpt-<gen>.snap` filename back to its generation.
pub(crate) fn snapshot_generation(name: &str) -> Option<u64> {
    name.strip_prefix("ckpt-")?.strip_suffix(".snap")?.parse().ok()
}

/// Take one checkpoint of `engine` now. Errors if persistence was never
/// armed. Concurrent callers (scheduler vs wire `SAVE`) serialize.
pub fn run_checkpoint(engine: &Engine) -> Result<CheckpointSummary, String> {
    let persist = Arc::clone(
        engine.persist_state().ok_or("persistence is not enabled (no data dir)")?,
    );
    let _serial = persist.serialize_checkpoints();

    let nshards = persist.shard_count();
    let (cuts, export) = engine.with_ingest_paused(|| {
        let cuts: Vec<u64> = (0..nshards).map(|i| persist.wal(i).last_seq()).collect();
        (cuts, engine.export())
    });

    let generation = persist.generation() + 1;
    let bytes = codec::encode_snapshot(persist.epoch(), &cuts, &export);
    let dir = persist.config().checkpoint_dir();
    let name = snapshot_name(generation);
    write_atomic(&dir.join(&name), &bytes)
        .map_err(|e| format!("writing {name}: {e}"))?;
    let manifest = Manifest {
        generation,
        epoch: persist.epoch(),
        shards: nshards,
        snapshot: name,
        wal_cuts: cuts.clone(),
    };
    // The commit point: MANIFEST now names the new generation.
    write_atomic(&persist.config().manifest_path(), manifest.render().as_bytes())
        .map_err(|e| format!("committing manifest: {e}"))?;

    // Truncation lags one generation: delete only segments covered by the
    // *previous* retained snapshot's cuts, so recovery can still fall back
    // to it (retention keeps two generations) without hitting a WAL hole.
    // Connected followers pin the floor further: a segment a live
    // replication stream hasn't fully sent yet is never deleted, so a slow
    // follower lags instead of being forced into a snapshot resync.
    let trunc_cuts = persist.rotate_cuts(cuts.clone());
    let mut wal_freed = 0u64;
    for (shard, &cut) in trunc_cuts.iter().enumerate().take(nshards) {
        let cut = match persist.pin_floor(shard) {
            Some(floor) => cut.min(floor),
            None => cut,
        };
        wal_freed += persist
            .wal(shard)
            .truncate_upto(cut)
            .map_err(|e| format!("truncating wal shard {shard}: {e}"))?;
    }
    // Retention: keep this generation and the previous one.
    if let Ok(rd) = fs::read_dir(&dir) {
        for entry in rd.flatten() {
            if let Some(gen) =
                entry.file_name().to_str().and_then(snapshot_generation)
            {
                if gen + 1 < generation {
                    let _ = fs::remove_file(entry.path());
                }
            }
        }
    }
    persist.set_generation(generation);
    Ok(CheckpointSummary {
        generation,
        nodes: export.len(),
        bytes: bytes.len() as u64,
        wal_freed,
    })
}

/// Install a leader-sent snapshot (checkpoint codec bytes) as this data
/// dir's committed checkpoint: any local WAL and checkpoints are wiped —
/// a follower bootstrapping from a snapshot supersedes whatever divergent
/// or stale history it held — then the snapshot and a matching MANIFEST
/// are committed atomically. [`super::open_engine`] afterwards recovers
/// from it and arms the WAL writers at the embedded cut points, which is
/// exactly where the leader resumes streaming. Returns `(epoch, cuts)`.
pub fn install_snapshot(
    pcfg: &super::PersistConfig,
    generation: u64,
    bytes: &[u8],
) -> Result<(u64, Vec<u64>), String> {
    let (epoch, cuts, _snap) =
        codec::decode_snapshot(bytes).map_err(|e| format!("leader snapshot: {e}"))?;
    let _ = fs::remove_dir_all(pcfg.wal_root());
    let _ = fs::remove_dir_all(pcfg.checkpoint_dir());
    let dir = pcfg.checkpoint_dir();
    fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let name = snapshot_name(generation);
    write_atomic(&dir.join(&name), bytes).map_err(|e| format!("writing {name}: {e}"))?;
    let manifest = Manifest {
        generation,
        epoch,
        shards: cuts.len(),
        snapshot: name,
        wal_cuts: cuts.clone(),
    };
    write_atomic(&pcfg.manifest_path(), manifest.render().as_bytes())
        .map_err(|e| format!("committing manifest: {e}"))?;
    Ok((epoch, cuts))
}

/// Background checkpointer: fires every `checkpoint_interval` on an
/// absolute deadline (wakeups don't drift the cadence) and early whenever
/// the live WAL exceeds `checkpoint_wal_bytes`. Stops when dropped.
pub struct CheckpointScheduler {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
    runs: Arc<AtomicU64>,
    failed: Arc<AtomicBool>,
}

impl CheckpointScheduler {
    /// How often the threshold condition is polled between interval ticks.
    const POLL: Duration = Duration::from_secs(1);

    pub fn start(engine: Arc<Engine>, interval: Duration) -> CheckpointScheduler {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let runs = Arc::new(AtomicU64::new(0));
        let failed = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = Arc::clone(&stop);
            let runs = Arc::clone(&runs);
            let failed = Arc::clone(&failed);
            std::thread::spawn(move || {
                let (lock, cvar) = &*stop;
                let threshold = engine
                    .persist_state()
                    .map(|p| p.config().checkpoint_wal_bytes)
                    .unwrap_or(u64::MAX);
                let mut deadline = Instant::now() + interval;
                loop {
                    {
                        let mut stopped =
                            lock.lock().unwrap_or_else(PoisonError::into_inner);
                        while !*stopped {
                            let now = Instant::now();
                            if now >= deadline {
                                break;
                            }
                            let wait = (deadline - now).min(Self::POLL);
                            let (guard, _) = cvar
                                .wait_timeout(stopped, wait)
                                .unwrap_or_else(PoisonError::into_inner);
                            stopped = guard;
                            // Early checkpoint once the WAL outgrows the
                            // bound, without waiting out the interval.
                            if !*stopped
                                && engine
                                    .persist_state()
                                    .is_some_and(|p| p.wal_bytes() >= threshold)
                            {
                                break;
                            }
                        }
                        if *stopped {
                            return;
                        }
                    }
                    match engine.checkpoint() {
                        Ok(_) => {
                            runs.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            failed.store(true, Ordering::Relaxed);
                            eprintln!("[persist] periodic checkpoint failed: {e}");
                        }
                    }
                    // Absolute cadence: late checkpoints don't compound.
                    deadline += interval;
                    let now = Instant::now();
                    if deadline < now {
                        deadline = now + interval;
                    }
                }
            })
        };
        CheckpointScheduler { stop, handle: Some(handle), runs, failed }
    }

    pub fn runs(&self) -> u64 {
        self.runs.load(Ordering::Relaxed)
    }

    pub fn has_failed(&self) -> bool {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn stop(&self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap_or_else(PoisonError::into_inner) = true;
        cvar.notify_all();
    }
}

impl Drop for CheckpointScheduler {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
