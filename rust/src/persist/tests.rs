//! Persist unit tests: codec round trips, CRC vectors, WAL segment
//! mechanics (rotation, truncation, torn tails), manifest parsing, and
//! checkpoint commit behaviour. The cross-layer recovery differentials
//! live in `rust/tests/persist_recovery.rs`.

use super::checkpoint::Manifest;
use super::codec::{self, CodecError, WalOp};
use super::wal::{self, ShardWal};
use super::{FsyncPolicy, IoHandle};
use crate::testutil::{Rng64, TempDir};

use std::time::Duration;

/// Unwrap a batch op (most WAL tests only write batches).
fn as_batch(op: WalOp) -> Vec<(u64, u64)> {
    match op {
        WalOp::Batch(batch) => batch,
        other => panic!("expected a batch record, got {other:?}"),
    }
}

fn wal_cfg(dir: std::path::PathBuf, segment_bytes: u64) -> ShardWal {
    ShardWal::open(
        dir,
        IoHandle::std(),
        0,
        FsyncPolicy::Never,
        Duration::from_millis(50),
        segment_bytes,
    )
    .unwrap()
}

// ---- codec ----

#[test]
fn varint_roundtrip_edges() {
    let values = [
        0u64,
        1,
        127,
        128,
        129,
        16_383,
        16_384,
        u32::MAX as u64,
        1 << 53,
        u64::MAX - 1,
        u64::MAX,
    ];
    let mut buf = Vec::new();
    for &v in &values {
        codec::put_varint(&mut buf, v);
    }
    let mut pos = 0;
    for &v in &values {
        assert_eq!(codec::get_varint(&buf, &mut pos).unwrap(), v);
    }
    assert_eq!(pos, buf.len());
    // Truncated and overflowing varints are rejected.
    assert_eq!(codec::get_varint(&[0x80], &mut 0), Err(CodecError::Truncated));
    assert_eq!(
        codec::get_varint(&[0xFF; 10], &mut 0),
        Err(CodecError::Overflow)
    );
}

#[test]
fn crc32_known_vector() {
    // The canonical IEEE CRC32 check value.
    assert_eq!(codec::crc32(b"123456789"), 0xCBF4_3926);
    assert_eq!(codec::crc32(b""), 0);
}

#[test]
fn snapshot_codec_roundtrip_and_rejects_corruption() {
    let snap: codec::Export = vec![
        (1, 7, vec![(2, 4), (3, 3)]),
        (9, 2, vec![(4, 2)]),
        (u64::MAX, u64::MAX, vec![(u64::MAX - 1, u64::MAX)]),
    ];
    let cuts = vec![12, 0, u64::MAX];
    let bytes = codec::encode_snapshot(3, &cuts, &snap);
    let (epoch, got_cuts, got) = codec::decode_snapshot(&bytes).unwrap();
    assert_eq!(epoch, 3);
    assert_eq!(got_cuts, cuts);
    assert_eq!(got, snap);
    // Re-encoding the decoded value is byte-identical.
    assert_eq!(codec::encode_snapshot(epoch, &got_cuts, &got), bytes);

    // Bad magic.
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    assert_eq!(codec::decode_snapshot(&bad), Err(CodecError::BadMagic));
    // Flipped body bit → CRC mismatch.
    let mut bad = bytes.clone();
    bad[10] ^= 0x01;
    assert!(matches!(codec::decode_snapshot(&bad), Err(CodecError::BadCrc { .. })));
    // Truncation anywhere → some error, never a partial Ok.
    for cut in 0..bytes.len() {
        assert!(codec::decode_snapshot(&bytes[..cut]).is_err(), "cut {cut}");
    }
}

#[test]
fn record_codec_roundtrip() {
    let batch: Vec<(u64, u64)> = (0..100).map(|i| (i * 3, i * 7 + 1)).collect();
    let mut buf = Vec::new();
    codec::encode_record(&mut buf, 42, &batch);
    let (seq, got) = codec::decode_record(&buf).unwrap();
    assert_eq!(seq, 42);
    assert_eq!(got, WalOp::Batch(batch.clone()));
    // The dedicated batch encoder and the generic op encoder are
    // byte-identical (the hot path takes the former).
    let mut via_op = Vec::new();
    codec::encode_op_record(&mut via_op, 42, &WalOp::Batch(batch));
    assert_eq!(via_op, buf);
    buf.push(0);
    assert_eq!(codec::decode_record(&buf), Err(CodecError::TrailingBytes(1)));
}

#[test]
fn maintenance_record_codec_roundtrip() {
    for op in [WalOp::Decay { num: 1, den: 2 }, WalOp::Decay { num: 9, den: 10 }, WalOp::Repair] {
        let mut buf = Vec::new();
        codec::encode_op_record(&mut buf, 7, &op);
        assert_eq!(codec::decode_record(&buf).unwrap(), (7, op.clone()));
        // Truncation anywhere is an error, never a partial Ok.
        for cut in 0..buf.len() {
            assert!(codec::decode_record(&buf[..cut]).is_err(), "{op:?} cut {cut}");
        }
    }
    // A zero decay denominator and an unknown kind tag are rejected — a
    // CRC-valid frame this build cannot apply must fail recovery loudly.
    let mut bad = Vec::new();
    codec::put_varint(&mut bad, 7);
    codec::put_varint(&mut bad, 1); // decay
    codec::put_varint(&mut bad, 1);
    codec::put_varint(&mut bad, 0); // den = 0
    assert!(codec::decode_record(&bad).is_err());
    let mut unknown = Vec::new();
    codec::put_varint(&mut unknown, 7);
    codec::put_varint(&mut unknown, 99);
    assert!(codec::decode_record(&unknown).is_err());
}

#[test]
fn delta_codec_roundtrip_and_fold() {
    let base: codec::Export = vec![
        (1, 7, vec![(2, 4), (3, 3)]),
        (5, 1, vec![(6, 1)]),
        (9, 2, vec![(4, 2)]),
    ];
    // Delta: replaces node 5 (decayed empty), updates node 9, adds node 12.
    let dirty: codec::Export =
        vec![(5, 0, vec![]), (9, 4, vec![(4, 3), (8, 1)]), (12, 1, vec![(1, 1)])];
    let bytes = codec::encode_delta(3, 2, &[10, 11], &dirty);
    let (parent, epoch, cuts, got) = codec::decode_delta(&bytes).unwrap();
    assert_eq!((parent, epoch, &cuts), (3, 2, &vec![10, 11]));
    assert_eq!(got, dirty);
    // Re-encoding is byte-identical; a full-snapshot decode rejects it.
    assert_eq!(codec::encode_delta(parent, epoch, &cuts, &got), bytes);
    assert_eq!(codec::decode_snapshot(&bytes), Err(CodecError::BadMagic));
    for cut in 0..bytes.len() {
        assert!(codec::decode_delta(&bytes[..cut]).is_err(), "cut {cut}");
    }

    let mut folded = base.clone();
    codec::fold_delta(&mut folded, dirty.clone());
    assert_eq!(
        folded,
        vec![
            (1, 7, vec![(2, 4), (3, 3)]),
            (5, 0, vec![]),
            (9, 4, vec![(4, 3), (8, 1)]),
            (12, 1, vec![(1, 1)]),
        ]
    );
    // Folding into an empty base is the delta itself.
    let mut empty: codec::Export = Vec::new();
    codec::fold_delta(&mut empty, dirty.clone());
    assert_eq!(empty, dirty);
}

// ---- wal ----

#[test]
fn wal_append_replay_roundtrip() {
    let tmp = TempDir::new("wal-roundtrip");
    let mut wal = wal_cfg(tmp.join("shard-0000"), 1 << 20);
    let mut rng = Rng64::new(7);
    let mut batches = Vec::new();
    for _ in 0..50 {
        let batch: Vec<(u64, u64)> =
            (0..rng.next_below(20) + 1).map(|_| (rng.next_below(64), rng.next_below(64))).collect();
        wal.append(&batch).unwrap();
        batches.push(batch);
    }
    assert_eq!(wal.last_seq(), 50);
    drop(wal);

    let mut replayed = Vec::new();
    let stats = wal::replay_dir(&tmp.join("shard-0000"), 0, |seq, op| {
        replayed.push((seq, as_batch(op)));
    })
    .unwrap();
    assert_eq!(stats.batches, 50);
    assert_eq!(stats.last_seq, 50);
    assert!(!stats.torn);
    for (i, (seq, batch)) in replayed.iter().enumerate() {
        assert_eq!(*seq, i as u64 + 1);
        assert_eq!(batch, &batches[i]);
    }
    // A cut skips the prefix but still validates it.
    let stats = wal::replay_dir(&tmp.join("shard-0000"), 30, |seq, _| {
        assert!(seq > 30);
    })
    .unwrap();
    assert_eq!(stats.batches, 20);
}

#[test]
fn wal_rotates_and_truncates_sealed_segments() {
    let tmp = TempDir::new("wal-rotate");
    let dir = tmp.join("shard-0000");
    // Tiny segments: every append rotates.
    let mut wal = wal_cfg(dir.clone(), 16);
    for i in 0..10u64 {
        wal.append(&[(i, i + 1)]).unwrap();
    }
    let segs = wal::scan_segments(&dir).unwrap();
    assert!(segs.len() >= 10, "expected one segment per append, got {}", segs.len());
    let bytes_before = wal.live_bytes();

    // Checkpoint cut at 6: segments holding 1..=6 go, the rest stay.
    let freed = wal.truncate_upto(6).unwrap();
    assert!(freed > 0);
    assert_eq!(wal.live_bytes(), bytes_before - freed);
    let mut seen = Vec::new();
    wal::replay_dir(&dir, 6, |seq, _| seen.push(seq)).unwrap();
    assert_eq!(seen, vec![7, 8, 9, 10]);
    // Replaying a truncated log from an older cut is a WAL hole — the
    // batches in (old cut, oldest surviving seq) are gone — and must fail
    // loudly instead of silently recovering a partial model.
    let err = wal::replay_dir(&dir, 0, |_, _| {}).unwrap_err();
    assert!(err.contains("wal hole"), "{err}");

    // Appends continue seamlessly after truncation.
    wal.append(&[(99, 100)]).unwrap();
    assert_eq!(wal.last_seq(), 11);
    drop(wal);
    let stats = wal::replay_dir(&dir, 6, |_, _| {}).unwrap();
    assert_eq!(stats.batches, 5); // 7..=11
}

#[test]
fn covered_bytes_sizes_without_deleting() {
    let tmp = TempDir::new("wal-covered");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 16); // rotate every append
    for i in 0..10u64 {
        wal.append(&[(i, i + 1)]).unwrap();
    }
    // Sizing at a cut matches what truncation then frees, and frees less
    // at a pinned (lower) cut — the max_pin_lag_bytes arithmetic.
    let at_cut = wal.covered_bytes(8).unwrap();
    let at_pin = wal.covered_bytes(3).unwrap();
    assert!(at_cut > at_pin, "{at_cut} vs {at_pin}");
    assert!(at_pin > 0);
    // The one-scan pinned-span sizing agrees with the two-point difference.
    assert_eq!(wal.pinned_bytes(3, 8).unwrap(), at_cut - at_pin);
    assert_eq!(wal.pinned_bytes(0, 8).unwrap(), at_cut);
    assert_eq!(wal.pinned_bytes(8, 8).unwrap(), 0);
    let segs_before = wal::scan_segments(&dir).unwrap().len();
    assert_eq!(wal.covered_bytes(8).unwrap(), at_cut, "sizing is read-only");
    assert_eq!(wal::scan_segments(&dir).unwrap().len(), segs_before);
    assert_eq!(wal.truncate_upto(8).unwrap(), at_cut);
}

#[test]
fn wal_tolerates_torn_tail_and_detects_gaps() {
    let tmp = TempDir::new("wal-torn");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    for i in 0..5u64 {
        wal.append(&[(i, i)]).unwrap();
    }
    drop(wal);
    let seg = wal::scan_segments(&dir).unwrap().remove(0);

    // Garbage appended after valid frames: replay stops at the tear.
    let clean = std::fs::read(&seg.path).unwrap();
    let mut torn = clean.clone();
    torn.extend_from_slice(&[0xAB; 7]);
    std::fs::write(&seg.path, &torn).unwrap();
    let stats = wal::replay_dir(&dir, 0, |_, _| {}).unwrap();
    assert!(stats.torn);
    assert_eq!(stats.batches, 5);

    // A mid-file flip kills that record and everything after it.
    let mut corrupt = clean.clone();
    let mid = clean.len() / 2;
    corrupt[mid] ^= 0xFF;
    std::fs::write(&seg.path, &corrupt).unwrap();
    let stats = wal::replay_dir(&dir, 0, |_, _| {}).unwrap();
    assert!(stats.torn);
    assert!(stats.batches < 5);

    // A gap between segments (lost file in the middle) is corruption.
    std::fs::write(&seg.path, &clean).unwrap();
    let mut wal = ShardWal::open(
        dir.clone(),
        IoHandle::std(),
        5,
        FsyncPolicy::Never,
        Duration::from_millis(50),
        1 << 20,
    )
    .unwrap();
    wal.append(&[(9, 9)]).unwrap(); // seq 6 in a fresh segment
    drop(wal);
    // Simulate a hole: bump the new segment's name past the expected seq.
    let segs = wal::scan_segments(&dir).unwrap();
    let newest = segs.last().unwrap().path.clone();
    std::fs::rename(&newest, dir.join("seg-00000000000000000099.wal")).unwrap();
    assert!(wal::replay_dir(&dir, 0, |_, _| {}).is_err());
}

#[test]
fn crc_valid_unknown_record_fails_replay_loudly() {
    let tmp = TempDir::new("wal-poison");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    wal.append(&[(1, 2)]).unwrap();
    wal.append(&[(3, 4)]).unwrap();
    drop(wal);
    // Hand-craft a CRC-valid frame carrying a record kind this build does
    // not know (a newer binary wrote it, then rolled back). Unlike a torn
    // tail, skipping it would silently drop durable history — replay must
    // fail loudly instead of "recovering" a stale prefix.
    let mut payload = Vec::new();
    codec::put_varint(&mut payload, 3); // the expected next seq
    codec::put_varint(&mut payload, 99); // unknown kind
    let mut frame = Vec::new();
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&codec::crc32(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    let seg = wal::scan_segments(&dir).unwrap().remove(0);
    let mut bytes = std::fs::read(&seg.path).unwrap();
    bytes.extend_from_slice(&frame);
    std::fs::write(&seg.path, &bytes).unwrap();
    let err = wal::replay_dir(&dir, 0, |_, _| {}).unwrap_err();
    assert!(err.contains("undecodable"), "{err}");
}

#[test]
fn wal_restart_resumes_contiguously() {
    let tmp = TempDir::new("wal-resume");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    for i in 0..3u64 {
        wal.append(&[(i, 1)]).unwrap();
    }
    drop(wal);
    // "Restart": recovery reports last_seq = 3, a new writer continues at 4
    // in a new segment; replay sees one contiguous sequence.
    let mut wal = ShardWal::open(
        dir.clone(),
        IoHandle::std(),
        3,
        FsyncPolicy::Batch,
        Duration::from_millis(50),
        1 << 20,
    )
    .unwrap();
    for i in 0..3u64 {
        wal.append(&[(10 + i, 1)]).unwrap();
    }
    assert_eq!(wal.last_seq(), 6);
    drop(wal);
    let mut seqs = Vec::new();
    let stats = wal::replay_dir(&dir, 0, |seq, _| seqs.push(seq)).unwrap();
    assert_eq!(seqs, vec![1, 2, 3, 4, 5, 6]);
    assert!(!stats.torn);
}

// ---- wal cursor (live tailing) ----

#[test]
fn cursor_tails_live_appends_across_rotation() {
    let tmp = TempDir::new("wal-cursor");
    let dir = tmp.join("shard-0000");
    // Tiny segments: appends rotate constantly, so the cursor must follow
    // seal → fresh-segment transitions while the writer stays live.
    let mut wal = wal_cfg(dir.clone(), 64);
    let mut cursor = wal::WalCursor::new(dir.clone(), 0);
    assert_eq!(cursor.poll().unwrap(), None, "empty dir: caught up");

    wal.append(&[(1, 2), (1, 3)]).unwrap();
    wal.append_op(&WalOp::Decay { num: 1, den: 2 }).unwrap();
    wal.append(&[(4, 5)]).unwrap();
    assert_eq!(cursor.poll().unwrap(), Some((1, WalOp::Batch(vec![(1, 2), (1, 3)]))));
    // Maintenance records stream through the same cursor, in seq order.
    assert_eq!(cursor.poll().unwrap(), Some((2, WalOp::Decay { num: 1, den: 2 })));
    assert_eq!(cursor.poll().unwrap(), Some((3, WalOp::Batch(vec![(4, 5)]))));
    assert_eq!(cursor.poll().unwrap(), None, "caught up with the writer");

    // The writer keeps going; the same cursor picks the new records up.
    for i in 0..20u64 {
        wal.append(&[(i, i + 1)]).unwrap();
    }
    let mut seen = Vec::new();
    while let Some((seq, _)) = cursor.poll().unwrap() {
        seen.push(seq);
    }
    assert_eq!(seen, (4..=23).collect::<Vec<u64>>());
    assert!(!cursor.torn());
    assert!(wal::scan_segments(&dir).unwrap().len() > 1, "rotation must have happened");
}

#[test]
fn cursor_skips_to_cut_and_matches_replay() {
    let tmp = TempDir::new("wal-cursor-cut");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 256);
    let mut rng = Rng64::new(99);
    let mut batches = Vec::new();
    for _ in 0..30 {
        let batch: Vec<(u64, u64)> =
            (0..rng.next_below(8) + 1).map(|_| (rng.next_below(32), rng.next_below(32))).collect();
        wal.append(&batch).unwrap();
        batches.push(batch);
    }
    drop(wal);
    for cut in [0u64, 1, 13, 29, 30] {
        let mut cursor = wal::WalCursor::new(dir.clone(), cut);
        let mut streamed = Vec::new();
        while let Some(rec) = cursor.poll().unwrap() {
            streamed.push(rec);
        }
        let mut replayed = Vec::new();
        wal::replay_dir(&dir, cut, |seq, op| replayed.push((seq, op))).unwrap();
        assert_eq!(streamed, replayed, "cut {cut}");
        assert_eq!(streamed.len(), 30 - cut as usize, "cut {cut}");
        for (i, (seq, op)) in streamed.iter().enumerate() {
            assert_eq!(*seq, cut + i as u64 + 1);
            assert_eq!(op, &WalOp::Batch(batches[(cut as usize) + i].clone()));
        }
        assert_eq!(cursor.last_seq(), 30);
    }
}

#[test]
fn cursor_retries_partial_tail_until_complete() {
    let tmp = TempDir::new("wal-cursor-partial");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    wal.append(&[(1, 1)]).unwrap();
    wal.append(&[(2, 2), (2, 3)]).unwrap();
    drop(wal);
    let seg = wal::scan_segments(&dir).unwrap().remove(0);
    let full = std::fs::read(&seg.path).unwrap();

    // Simulate a reader racing the writer: only a prefix of record 2's
    // frame is visible. The cursor must neither yield garbage nor give up
    // permanently — once the rest lands, the record comes through.
    std::fs::write(&seg.path, &full[..full.len() - 5]).unwrap();
    let mut cursor = wal::WalCursor::new(dir.clone(), 0);
    assert_eq!(cursor.poll().unwrap(), Some((1, WalOp::Batch(vec![(1, 1)]))));
    assert_eq!(cursor.poll().unwrap(), None, "partial frame is not yielded");
    std::fs::write(&seg.path, &full).unwrap();
    assert_eq!(cursor.poll().unwrap(), Some((2, WalOp::Batch(vec![(2, 2), (2, 3)]))));
    assert_eq!(cursor.poll().unwrap(), None);
}

#[test]
fn cursor_reports_wal_hole_past_truncation() {
    let tmp = TempDir::new("wal-cursor-hole");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 16); // rotate every append
    for i in 0..8u64 {
        wal.append(&[(i, i)]).unwrap();
    }
    wal.truncate_upto(5).unwrap();
    drop(wal);
    // A cursor below the truncation point must fail loudly (the follower
    // behind this point needs a snapshot, not a silently skipped prefix)…
    let err = wal::WalCursor::new(dir.clone(), 2).poll().unwrap_err();
    assert!(err.contains("wal hole"), "{err}");
    // …while a cursor at or past it streams normally.
    let mut cursor = wal::WalCursor::new(dir.clone(), 5);
    let mut seqs = Vec::new();
    while let Some((seq, _)) = cursor.poll().unwrap() {
        seqs.push(seq);
    }
    assert_eq!(seqs, vec![6, 7, 8]);
}

// ---- manifest ----

#[test]
fn manifest_roundtrip_and_validation() {
    let m = Manifest {
        generation: 7,
        epoch: 2,
        shards: 3,
        snapshot: "ckpt-000007.snap".into(),
        deltas: Vec::new(),
        wal_cuts: vec![10, 0, 4],
    };
    let parsed = Manifest::parse(&m.render()).unwrap();
    assert_eq!(parsed, m);
    // Wrong cut arity is rejected.
    let bad = m.render().replace("[10, 0, 4]", "[10, 0]");
    assert!(Manifest::parse(&bad).is_err());
    assert!(Manifest::parse("not toml at all =").is_err());
    assert!(Manifest::parse("[checkpoint]\ngeneration = 1\n").is_err());

    // A chained manifest round-trips, and the chain must be contiguous
    // generations reaching `generation`.
    let chained = Manifest {
        generation: 9,
        epoch: 2,
        shards: 3,
        snapshot: "ckpt-000007.snap".into(),
        deltas: vec!["ckpt-000008.delta".into(), "ckpt-000009.delta".into()],
        wal_cuts: vec![20, 5, 9],
    };
    assert_eq!(Manifest::parse(&chained.render()).unwrap(), chained);
    let gap = chained.render().replace("ckpt-000008.delta", "ckpt-000006.delta");
    assert!(Manifest::parse(&gap).is_err(), "non-consecutive delta chain");
    let short = chained.render().replace(", \"ckpt-000009.delta\"", "");
    assert!(Manifest::parse(&short).is_err(), "chain not reaching generation");

    // A PR 3-era manifest (no `deltas` key) parses as an empty chain.
    let legacy = m.render().replace("deltas = []\n", "");
    let parsed = Manifest::parse(&legacy).unwrap();
    assert!(parsed.deltas.is_empty());
}

#[test]
fn fsync_policy_parses() {
    assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
    assert_eq!(FsyncPolicy::parse("batch").unwrap(), FsyncPolicy::Batch);
    assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
    assert!(FsyncPolicy::parse("sometimes").is_err());
    for p in [FsyncPolicy::Never, FsyncPolicy::Batch, FsyncPolicy::Always] {
        assert_eq!(FsyncPolicy::parse(p.as_str()).unwrap(), p);
    }
}

// ---- follower retention pins vs truncation ----

#[test]
fn max_pin_lag_bytes_overrides_stalled_pin() {
    use crate::config::{PersistSection, ReplicateSection, ServerConfig};
    let tmp = TempDir::new("pin-lag");
    let mk = |max_pin: u64| ServerConfig {
        shards: 1,
        queue_capacity: 4_096,
        persist: PersistSection {
            data_dir: tmp.join(&format!("d{max_pin}")).to_string_lossy().into_owned(),
            fsync: "never".into(),
            checkpoint_interval_ms: 0,
            // Tiny segments: truncation has sealed segments to take.
            segment_bytes: 512,
            ..PersistSection::default()
        },
        replicate: ReplicateSection {
            max_pin_lag_bytes: max_pin,
            ..ReplicateSection::default()
        },
        ..Default::default()
    };
    for (max_pin, expect_override) in [(2_048u64, true), (0u64, false)] {
        let (engine, _) = crate::persist::open_engine(&mk(max_pin), 1).unwrap();
        let persist = std::sync::Arc::clone(engine.persist_state().unwrap());
        // A follower stream stalled at seq 0 (dead peer whose pin never
        // advanced) — without the escape hatch it pins the whole log.
        let pin = persist.pin_create(vec![0]);
        let pairs: Vec<(u64, u64)> = (0..4_000u64).map(|i| (i % 37, i % 53)).collect();
        for chunk in pairs.chunks(100) {
            assert_eq!(engine.observe_batch(chunk), chunk.len());
        }
        engine.quiesce();
        engine.checkpoint().unwrap();
        let freed = engine.checkpoint().unwrap().wal_freed;
        let dir = persist.config().shard_dir(1, 0);
        let first = wal::scan_segments(&dir).unwrap().first().unwrap().first_seq;
        if expect_override {
            assert!(freed > 0, "escape hatch must let truncation proceed");
            assert!(first > 1, "oldest segment must move past the stalled pin");
        } else {
            assert_eq!(freed, 0, "max_pin_lag_bytes = 0 honours the pin forever");
            assert_eq!(first, 1, "whole log retained for the pinned follower");
        }
        persist.pin_drop(pin);
        engine.shutdown();
    }
}
