//! Persist unit tests: codec round trips, CRC vectors, WAL segment
//! mechanics (rotation, truncation, torn tails), manifest parsing, and
//! checkpoint commit behaviour. The cross-layer recovery differentials
//! live in `rust/tests/persist_recovery.rs`.

use super::checkpoint::Manifest;
use super::codec::{self, CodecError};
use super::wal::{self, ShardWal};
use super::FsyncPolicy;
use crate::testutil::{Rng64, TempDir};

use std::time::Duration;

fn wal_cfg(dir: std::path::PathBuf, segment_bytes: u64) -> ShardWal {
    ShardWal::open(dir, 0, FsyncPolicy::Never, Duration::from_millis(50), segment_bytes)
        .unwrap()
}

// ---- codec ----

#[test]
fn varint_roundtrip_edges() {
    let values = [
        0u64,
        1,
        127,
        128,
        129,
        16_383,
        16_384,
        u32::MAX as u64,
        1 << 53,
        u64::MAX - 1,
        u64::MAX,
    ];
    let mut buf = Vec::new();
    for &v in &values {
        codec::put_varint(&mut buf, v);
    }
    let mut pos = 0;
    for &v in &values {
        assert_eq!(codec::get_varint(&buf, &mut pos).unwrap(), v);
    }
    assert_eq!(pos, buf.len());
    // Truncated and overflowing varints are rejected.
    assert_eq!(codec::get_varint(&[0x80], &mut 0), Err(CodecError::Truncated));
    assert_eq!(
        codec::get_varint(&[0xFF; 10], &mut 0),
        Err(CodecError::Overflow)
    );
}

#[test]
fn crc32_known_vector() {
    // The canonical IEEE CRC32 check value.
    assert_eq!(codec::crc32(b"123456789"), 0xCBF4_3926);
    assert_eq!(codec::crc32(b""), 0);
}

#[test]
fn snapshot_codec_roundtrip_and_rejects_corruption() {
    let snap: codec::Export = vec![
        (1, 7, vec![(2, 4), (3, 3)]),
        (9, 2, vec![(4, 2)]),
        (u64::MAX, u64::MAX, vec![(u64::MAX - 1, u64::MAX)]),
    ];
    let cuts = vec![12, 0, u64::MAX];
    let bytes = codec::encode_snapshot(3, &cuts, &snap);
    let (epoch, got_cuts, got) = codec::decode_snapshot(&bytes).unwrap();
    assert_eq!(epoch, 3);
    assert_eq!(got_cuts, cuts);
    assert_eq!(got, snap);
    // Re-encoding the decoded value is byte-identical.
    assert_eq!(codec::encode_snapshot(epoch, &got_cuts, &got), bytes);

    // Bad magic.
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    assert_eq!(codec::decode_snapshot(&bad), Err(CodecError::BadMagic));
    // Flipped body bit → CRC mismatch.
    let mut bad = bytes.clone();
    bad[10] ^= 0x01;
    assert!(matches!(codec::decode_snapshot(&bad), Err(CodecError::BadCrc { .. })));
    // Truncation anywhere → some error, never a partial Ok.
    for cut in 0..bytes.len() {
        assert!(codec::decode_snapshot(&bytes[..cut]).is_err(), "cut {cut}");
    }
}

#[test]
fn record_codec_roundtrip() {
    let batch: Vec<(u64, u64)> = (0..100).map(|i| (i * 3, i * 7 + 1)).collect();
    let mut buf = Vec::new();
    codec::encode_record(&mut buf, 42, &batch);
    let (seq, got) = codec::decode_record(&buf).unwrap();
    assert_eq!(seq, 42);
    assert_eq!(got, batch);
    buf.push(0);
    assert_eq!(codec::decode_record(&buf), Err(CodecError::TrailingBytes(1)));
}

// ---- wal ----

#[test]
fn wal_append_replay_roundtrip() {
    let tmp = TempDir::new("wal-roundtrip");
    let mut wal = wal_cfg(tmp.join("shard-0000"), 1 << 20);
    let mut rng = Rng64::new(7);
    let mut batches = Vec::new();
    for _ in 0..50 {
        let batch: Vec<(u64, u64)> =
            (0..rng.next_below(20) + 1).map(|_| (rng.next_below(64), rng.next_below(64))).collect();
        wal.append(&batch).unwrap();
        batches.push(batch);
    }
    assert_eq!(wal.last_seq(), 50);
    drop(wal);

    let mut replayed = Vec::new();
    let stats = wal::replay_dir(&tmp.join("shard-0000"), 0, |seq, batch| {
        replayed.push((seq, batch));
    })
    .unwrap();
    assert_eq!(stats.batches, 50);
    assert_eq!(stats.last_seq, 50);
    assert!(!stats.torn);
    for (i, (seq, batch)) in replayed.iter().enumerate() {
        assert_eq!(*seq, i as u64 + 1);
        assert_eq!(batch, &batches[i]);
    }
    // A cut skips the prefix but still validates it.
    let stats = wal::replay_dir(&tmp.join("shard-0000"), 30, |seq, _| {
        assert!(seq > 30);
    })
    .unwrap();
    assert_eq!(stats.batches, 20);
}

#[test]
fn wal_rotates_and_truncates_sealed_segments() {
    let tmp = TempDir::new("wal-rotate");
    let dir = tmp.join("shard-0000");
    // Tiny segments: every append rotates.
    let mut wal = wal_cfg(dir.clone(), 16);
    for i in 0..10u64 {
        wal.append(&[(i, i + 1)]).unwrap();
    }
    let segs = wal::scan_segments(&dir).unwrap();
    assert!(segs.len() >= 10, "expected one segment per append, got {}", segs.len());
    let bytes_before = wal.live_bytes();

    // Checkpoint cut at 6: segments holding 1..=6 go, the rest stay.
    let freed = wal.truncate_upto(6).unwrap();
    assert!(freed > 0);
    assert_eq!(wal.live_bytes(), bytes_before - freed);
    let mut seen = Vec::new();
    wal::replay_dir(&dir, 6, |seq, _| seen.push(seq)).unwrap();
    assert_eq!(seen, vec![7, 8, 9, 10]);
    // Replaying a truncated log from an older cut is a WAL hole — the
    // batches in (old cut, oldest surviving seq) are gone — and must fail
    // loudly instead of silently recovering a partial model.
    let err = wal::replay_dir(&dir, 0, |_, _| {}).unwrap_err();
    assert!(err.contains("wal hole"), "{err}");

    // Appends continue seamlessly after truncation.
    wal.append(&[(99, 100)]).unwrap();
    assert_eq!(wal.last_seq(), 11);
    drop(wal);
    let stats = wal::replay_dir(&dir, 6, |_, _| {}).unwrap();
    assert_eq!(stats.batches, 5); // 7..=11
}

#[test]
fn wal_tolerates_torn_tail_and_detects_gaps() {
    let tmp = TempDir::new("wal-torn");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    for i in 0..5u64 {
        wal.append(&[(i, i)]).unwrap();
    }
    drop(wal);
    let seg = wal::scan_segments(&dir).unwrap().remove(0);

    // Garbage appended after valid frames: replay stops at the tear.
    let clean = std::fs::read(&seg.path).unwrap();
    let mut torn = clean.clone();
    torn.extend_from_slice(&[0xAB; 7]);
    std::fs::write(&seg.path, &torn).unwrap();
    let stats = wal::replay_dir(&dir, 0, |_, _| {}).unwrap();
    assert!(stats.torn);
    assert_eq!(stats.batches, 5);

    // A mid-file flip kills that record and everything after it.
    let mut corrupt = clean.clone();
    let mid = clean.len() / 2;
    corrupt[mid] ^= 0xFF;
    std::fs::write(&seg.path, &corrupt).unwrap();
    let stats = wal::replay_dir(&dir, 0, |_, _| {}).unwrap();
    assert!(stats.torn);
    assert!(stats.batches < 5);

    // A gap between segments (lost file in the middle) is corruption.
    std::fs::write(&seg.path, &clean).unwrap();
    let mut wal = ShardWal::open(
        dir.clone(),
        5,
        FsyncPolicy::Never,
        Duration::from_millis(50),
        1 << 20,
    )
    .unwrap();
    wal.append(&[(9, 9)]).unwrap(); // seq 6 in a fresh segment
    drop(wal);
    // Simulate a hole: bump the new segment's name past the expected seq.
    let segs = wal::scan_segments(&dir).unwrap();
    let newest = segs.last().unwrap().path.clone();
    std::fs::rename(&newest, dir.join("seg-00000000000000000099.wal")).unwrap();
    assert!(wal::replay_dir(&dir, 0, |_, _| {}).is_err());
}

#[test]
fn wal_restart_resumes_contiguously() {
    let tmp = TempDir::new("wal-resume");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    for i in 0..3u64 {
        wal.append(&[(i, 1)]).unwrap();
    }
    drop(wal);
    // "Restart": recovery reports last_seq = 3, a new writer continues at 4
    // in a new segment; replay sees one contiguous sequence.
    let mut wal = ShardWal::open(
        dir.clone(),
        3,
        FsyncPolicy::Batch,
        Duration::from_millis(50),
        1 << 20,
    )
    .unwrap();
    for i in 0..3u64 {
        wal.append(&[(10 + i, 1)]).unwrap();
    }
    assert_eq!(wal.last_seq(), 6);
    drop(wal);
    let mut seqs = Vec::new();
    let stats = wal::replay_dir(&dir, 0, |seq, _| seqs.push(seq)).unwrap();
    assert_eq!(seqs, vec![1, 2, 3, 4, 5, 6]);
    assert!(!stats.torn);
}

// ---- wal cursor (live tailing) ----

#[test]
fn cursor_tails_live_appends_across_rotation() {
    let tmp = TempDir::new("wal-cursor");
    let dir = tmp.join("shard-0000");
    // Tiny segments: appends rotate constantly, so the cursor must follow
    // seal → fresh-segment transitions while the writer stays live.
    let mut wal = wal_cfg(dir.clone(), 64);
    let mut cursor = wal::WalCursor::new(dir.clone(), 0);
    assert_eq!(cursor.poll().unwrap(), None, "empty dir: caught up");

    wal.append(&[(1, 2), (1, 3)]).unwrap();
    wal.append(&[(4, 5)]).unwrap();
    assert_eq!(cursor.poll().unwrap(), Some((1, vec![(1, 2), (1, 3)])));
    assert_eq!(cursor.poll().unwrap(), Some((2, vec![(4, 5)])));
    assert_eq!(cursor.poll().unwrap(), None, "caught up with the writer");

    // The writer keeps going; the same cursor picks the new records up.
    for i in 0..20u64 {
        wal.append(&[(i, i + 1)]).unwrap();
    }
    let mut seen = Vec::new();
    while let Some((seq, _)) = cursor.poll().unwrap() {
        seen.push(seq);
    }
    assert_eq!(seen, (3..=22).collect::<Vec<u64>>());
    assert!(!cursor.torn());
    assert!(wal::scan_segments(&dir).unwrap().len() > 1, "rotation must have happened");
}

#[test]
fn cursor_skips_to_cut_and_matches_replay() {
    let tmp = TempDir::new("wal-cursor-cut");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 256);
    let mut rng = Rng64::new(99);
    let mut batches = Vec::new();
    for _ in 0..30 {
        let batch: Vec<(u64, u64)> =
            (0..rng.next_below(8) + 1).map(|_| (rng.next_below(32), rng.next_below(32))).collect();
        wal.append(&batch).unwrap();
        batches.push(batch);
    }
    drop(wal);
    for cut in [0u64, 1, 13, 29, 30] {
        let mut cursor = wal::WalCursor::new(dir.clone(), cut);
        let mut streamed = Vec::new();
        while let Some(rec) = cursor.poll().unwrap() {
            streamed.push(rec);
        }
        let mut replayed = Vec::new();
        wal::replay_dir(&dir, cut, |seq, batch| replayed.push((seq, batch))).unwrap();
        assert_eq!(streamed, replayed, "cut {cut}");
        assert_eq!(streamed.len(), 30 - cut as usize, "cut {cut}");
        for (i, (seq, batch)) in streamed.iter().enumerate() {
            assert_eq!(*seq, cut + i as u64 + 1);
            assert_eq!(batch, &batches[(cut as usize) + i]);
        }
        assert_eq!(cursor.last_seq(), 30);
    }
}

#[test]
fn cursor_retries_partial_tail_until_complete() {
    let tmp = TempDir::new("wal-cursor-partial");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 1 << 20);
    wal.append(&[(1, 1)]).unwrap();
    wal.append(&[(2, 2), (2, 3)]).unwrap();
    drop(wal);
    let seg = wal::scan_segments(&dir).unwrap().remove(0);
    let full = std::fs::read(&seg.path).unwrap();

    // Simulate a reader racing the writer: only a prefix of record 2's
    // frame is visible. The cursor must neither yield garbage nor give up
    // permanently — once the rest lands, the record comes through.
    std::fs::write(&seg.path, &full[..full.len() - 5]).unwrap();
    let mut cursor = wal::WalCursor::new(dir.clone(), 0);
    assert_eq!(cursor.poll().unwrap(), Some((1, vec![(1, 1)])));
    assert_eq!(cursor.poll().unwrap(), None, "partial frame is not yielded");
    std::fs::write(&seg.path, &full).unwrap();
    assert_eq!(cursor.poll().unwrap(), Some((2, vec![(2, 2), (2, 3)])));
    assert_eq!(cursor.poll().unwrap(), None);
}

#[test]
fn cursor_reports_wal_hole_past_truncation() {
    let tmp = TempDir::new("wal-cursor-hole");
    let dir = tmp.join("shard-0000");
    let mut wal = wal_cfg(dir.clone(), 16); // rotate every append
    for i in 0..8u64 {
        wal.append(&[(i, i)]).unwrap();
    }
    wal.truncate_upto(5).unwrap();
    drop(wal);
    // A cursor below the truncation point must fail loudly (the follower
    // behind this point needs a snapshot, not a silently skipped prefix)…
    let err = wal::WalCursor::new(dir.clone(), 2).poll().unwrap_err();
    assert!(err.contains("wal hole"), "{err}");
    // …while a cursor at or past it streams normally.
    let mut cursor = wal::WalCursor::new(dir.clone(), 5);
    let mut seqs = Vec::new();
    while let Some((seq, _)) = cursor.poll().unwrap() {
        seqs.push(seq);
    }
    assert_eq!(seqs, vec![6, 7, 8]);
}

// ---- manifest ----

#[test]
fn manifest_roundtrip_and_validation() {
    let m = Manifest {
        generation: 7,
        epoch: 2,
        shards: 3,
        snapshot: "ckpt-000007.snap".into(),
        wal_cuts: vec![10, 0, 4],
    };
    let parsed = Manifest::parse(&m.render()).unwrap();
    assert_eq!(parsed, m);
    // Wrong cut arity is rejected.
    let bad = m.render().replace("[10, 0, 4]", "[10, 0]");
    assert!(Manifest::parse(&bad).is_err());
    assert!(Manifest::parse("not toml at all =").is_err());
    assert!(Manifest::parse("[checkpoint]\ngeneration = 1\n").is_err());
}

#[test]
fn fsync_policy_parses() {
    assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
    assert_eq!(FsyncPolicy::parse("batch").unwrap(), FsyncPolicy::Batch);
    assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
    assert!(FsyncPolicy::parse("sometimes").is_err());
    for p in [FsyncPolicy::Never, FsyncPolicy::Batch, FsyncPolicy::Always] {
        assert_eq!(FsyncPolicy::parse(p.as_str()).unwrap(), p);
    }
}
