//! Crash recovery: build an [`Engine`] from the newest valid checkpoint
//! plus the WAL tails behind it.
//!
//! State machine (every arrow is crash-safe to re-enter):
//!
//! ```text
//! read MANIFEST ── ok ──▶ decode base snapshot + fold its delta chain
//!      │ missing/corrupt        │ corrupt base   │ corrupt delta k
//!      ▼                        ▼                ▼ (fold the prefix ..k-1,
//! scan checkpoint/ for the newest ckpt-*.snap      cuts of gen k-1, the
//! that decodes, then fold the consecutive          rest replays from WAL)
//! ckpt-*.delta generations after it
//!      │ none
//!      ▼
//! empty model, epoch = newest wal/e<N> dir (or 1), cuts = zeros
//!      │
//!      ▼
//! import the folded model, then stream wal/e<epoch>/shard-*/ records
//! with seq > cut through the shared `Engine::apply_op` dispatch
//! (per-shard seq order, record-by-record via `wal::WalCursor`, torn tail
//! tolerated) — observation batches AND the logged decay/repair records,
//! so recovered maintenance lands in exactly its sequence position
//!      │
//!      ▼
//! shard layout unchanged?  ── yes ─▶ arm WAL writers at seq = last+1
//!      │ no (shards reconfigured: batches re-route; an old shard's decay
//!      ▼  record replays onto exactly the srcs that shard owned)
//! bump epoch, arm writers at seq 0, checkpoint immediately (commits the
//! new epoch), delete the old epoch's directory
//! ```
//!
//! The epoch bump makes shard-count changes crash-safe: cut points always
//! index the layout that wrote them, and a crash between "new snapshot
//! committed" and "old epoch deleted" just leaves a dead directory the
//! next recovery ignores (manifest names the new epoch) and sweeps.

use std::fs;
use std::sync::Arc;

use crate::config::ServerConfig;
use crate::coordinator::Engine;

use super::checkpoint::{delta_name, snapshot_generation, Manifest};
use super::{codec, remove_stale_tmp, wal, DeltaChain, PersistConfig, PersistState};

/// What recovery found and did (printed by `mcprioq serve`, asserted by
/// the recovery tests).
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Checkpoint generation recovered from (0 = none found).
    pub generation: u64,
    /// Differential generations folded on top of the base snapshot.
    pub snapshot_deltas: usize,
    /// WAL epoch recovered from.
    pub epoch: u64,
    /// Src nodes imported from the folded snapshot chain.
    pub snapshot_nodes: usize,
    /// WAL batches replayed on top of the snapshot.
    pub replayed_batches: u64,
    /// Updates (pairs) inside those batches.
    pub replayed_updates: u64,
    /// Maintenance records (decay/repair) replayed in sequence position.
    pub replayed_maintenance: u64,
    /// Shard directories whose tail record was torn (tolerated).
    pub torn_tails: usize,
    /// True when the shard count changed since the checkpoint: recovery
    /// re-routed the old data, bumped the WAL epoch, and re-checkpointed.
    pub layout_changed: bool,
}

/// Open a durable engine: recover, then arm the WAL writers. This is the
/// front door `mcprioq serve --data-dir` uses; `Engine::new` alone never
/// persists anything.
pub fn open_engine(
    config: &ServerConfig,
    workers: usize,
) -> Result<(Arc<Engine>, RecoveryReport), String> {
    let pcfg = config
        .persist_config()?
        .ok_or("persist.data_dir is not configured")?;
    fs::create_dir_all(pcfg.checkpoint_dir())
        .map_err(|e| format!("{}: {e}", pcfg.checkpoint_dir().display()))?;
    fs::create_dir_all(pcfg.wal_root())
        .map_err(|e| format!("{}: {e}", pcfg.wal_root().display()))?;
    remove_stale_tmp(&pcfg.checkpoint_dir());

    let mut report = RecoveryReport::default();

    // --- 1. newest valid checkpoint chain ---
    let loaded = load_checkpoint(&pcfg);
    let (generation, chain_base, deltas_folded, epoch, cuts, snapshot) = match loaded {
        Some(t) => t,
        None => (0, 0, 0, detect_epoch(&pcfg)?, Vec::new(), Vec::new()),
    };
    report.generation = generation;
    report.snapshot_deltas = deltas_folded;
    report.epoch = epoch;
    report.snapshot_nodes = snapshot.len();

    // --- 2. build the engine, then stream the WAL tails through it ---
    let epoch_dir = pcfg.epoch_dir(epoch);
    let shard_dirs = scan_shard_dirs(&epoch_dir)?;
    let old_shards = if cuts.is_empty() { shard_dirs.len() } else { cuts.len() };
    // Seed from the cuts so a shard whose WAL directory is missing (e.g.
    // wiped by hand) still resumes *above* its checkpointed seq instead of
    // re-issuing sequence numbers replay would then skip.
    let mut last_seqs = vec![0u64; old_shards.max(shard_dirs.len())];
    for (seq, &cut) in last_seqs.iter_mut().zip(&cuts) {
        *seq = cut;
    }
    let engine = Engine::new(config, workers);
    // Restore the persisted checkpoint mark (the CKPT_MARK sidecar) so the
    // recovered state carries real dirty epochs instead of resetting to 0
    // — the first post-restart checkpoint can then stay differential.
    // Nodes rebuilt from the snapshot chain are stamped F-1 ("clean as of
    // the recovered generation"), then the mark moves to F so everything
    // the WAL replay below touches is dirty relative to the committed
    // floor. A stale (lower) sidecar only widens the dirty set.
    let sidecar_floor = read_ckpt_mark(&pcfg).filter(|&f| f >= 2);
    if let Some(f) = sidecar_floor {
        engine.set_ckpt_mark(f - 1);
    }
    engine.import_snapshot(&snapshot);
    if let Some(f) = sidecar_floor {
        engine.set_ckpt_mark(f);
    }
    let nshards = engine.shard_count();
    let layout_changed = old_shards != 0 && old_shards != nshards;
    report.layout_changed = layout_changed;
    for (shard, dir) in &shard_dirs {
        let cut = cuts.get(*shard).copied().unwrap_or(0);
        // Record-by-record streaming replay: each WAL record goes straight
        // through the apply path instead of being collected into a
        // per-shard tail first, so recovery memory is bounded by one
        // record, not by the time since the last checkpoint. Old shards
        // hold disjoint src sets, so cross-shard order is irrelevant;
        // within a shard the cursor yields apply order. Unchanged layouts
        // go through the same `apply_op` dispatch the follower uses; a
        // changed layout re-routes batches by the current layout and
        // replays an old shard's decay records onto exactly the srcs that
        // old shard owned (`Engine::route` under the old count).
        let old_shard = *shard;
        let stats = wal::replay_dir(dir, cut, |_seq, op| {
            if !layout_changed {
                engine.apply_op(old_shard, &op);
                return;
            }
            match op {
                codec::WalOp::Batch(batch) => engine.observe_batch_direct(&batch),
                codec::WalOp::Decay { num, den } => {
                    for chain in engine.chains() {
                        chain.decay_where(num, den, |src| {
                            Engine::route(src, old_shards) == old_shard
                        });
                    }
                }
                // Repair restores exact order and re-bases totals from the
                // edge sums; at replay quiescence it is idempotent, so the
                // unfiltered sweep is safe under any routing.
                codec::WalOp::Repair => {
                    for chain in engine.chains() {
                        chain.repair();
                    }
                }
            }
        })?;
        report.replayed_batches += stats.batches;
        report.replayed_updates += stats.updates;
        report.replayed_maintenance += stats.maintenance;
        report.torn_tails += stats.torn as usize;
        if *shard < last_seqs.len() {
            last_seqs[*shard] = stats.last_seq.max(cut);
        }
    }

    // --- 3. arm the WAL writers ---
    // The delta-chain floor re-arms from the sidecar (0 when absent, so
    // the first post-restart checkpoint forces a full base exactly as
    // before the sidecar existed). A layout change keeps floor 0: its
    // immediate re-checkpoint must be a *full* snapshot — a delta would
    // chain across the epoch bump (its parent's cuts index the deleted
    // old epoch) and be rejected by the next recovery's fold.
    let chain = DeltaChain {
        base: chain_base,
        len: generation.saturating_sub(chain_base) as usize,
        floor: if layout_changed { 0 } else { sidecar_floor.unwrap_or(0) },
    };
    if report.layout_changed {
        let new_epoch = epoch + 1;
        let state = PersistState::create(
            pcfg.clone(),
            new_epoch,
            generation,
            chain,
            &vec![0u64; nshards],
            vec![0u64; nshards],
            report.replayed_batches,
        )
        .map_err(|e| format!("opening wal epoch {new_epoch}: {e}"))?;
        engine.attach_persist(Arc::new(state));
        // Commits a snapshot of everything just replayed under the new
        // epoch/layout; only then is the old epoch's WAL dead weight.
        engine.checkpoint()?;
        let _ = fs::remove_dir_all(&epoch_dir);
        report.epoch = new_epoch;
    } else {
        let mut starts = vec![0u64; nshards];
        for (start, &last) in starts.iter_mut().zip(&last_seqs) {
            *start = last;
        }
        // Lag-one truncation must keep the WAL reachable for the
        // generation just recovered from: its cuts seed `prev_cuts`.
        let mut prev_cuts = vec![0u64; nshards];
        for (prev, &cut) in prev_cuts.iter_mut().zip(&cuts) {
            *prev = cut;
        }
        let state = PersistState::create(
            pcfg.clone(),
            epoch.max(1),
            generation,
            chain,
            &starts,
            prev_cuts,
            report.replayed_batches,
        )
        .map_err(|e| format!("opening wal epoch {epoch}: {e}"))?;
        report.epoch = epoch.max(1);
        engine.attach_persist(Arc::new(state));
    }
    // Dead epochs from interrupted layout changes (manifest already names
    // a newer one) are swept lazily.
    sweep_dead_epochs(&pcfg, report.epoch);
    Ok((engine, report))
}

/// Try the manifest first, then fall back to scanning for the newest
/// snapshot that decodes (the manifest is a pointer, not the only truth).
/// Returns `(generation, chain_base, deltas_folded, epoch, cuts, export)`
/// with the delta chain already folded into the export.
fn load_checkpoint(
    pcfg: &PersistConfig,
) -> Option<(u64, u64, usize, u64, Vec<u64>, codec::Export)> {
    if let Ok(text) = fs::read_to_string(pcfg.manifest_path()) {
        match Manifest::parse(&text) {
            Ok(m) => match load_manifest_chain(pcfg, &m) {
                Some(loaded) => return Some(loaded),
                None => eprintln!(
                    "[persist] snapshot {} unreadable, falling back to scan",
                    m.snapshot
                ),
            },
            Err(e) => eprintln!("[persist] bad manifest ({e}), falling back to scan"),
        }
    }
    // Fallback: the newest full snapshot that decodes, plus whatever
    // consecutive delta generations after it still decode.
    let mut gens: Vec<(u64, std::path::PathBuf)> = fs::read_dir(pcfg.checkpoint_dir())
        .ok()?
        .flatten()
        .filter_map(|e| {
            let gen = e.file_name().to_str().and_then(snapshot_generation)?;
            Some((gen, e.path()))
        })
        .collect();
    gens.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (base, path) in gens {
        if let Some((epoch, cuts, snap)) =
            fs::read(&path).ok().and_then(|b| codec::decode_snapshot(&b).ok())
        {
            let (generation, folded, epoch, cuts, snap) =
                fold_deltas(pcfg, base, epoch, cuts, snap, usize::MAX);
            return Some((generation, base, folded, epoch, cuts, snap));
        }
        eprintln!("[persist] skipping unreadable snapshot {}", path.display());
    }
    None
}

/// Load the chain a parsed manifest names. `None` when the base snapshot
/// itself is unreadable; a broken *delta* degrades to the decodable chain
/// prefix (its cuts are older, so the WAL replays the difference).
fn load_manifest_chain(
    pcfg: &PersistConfig,
    m: &Manifest,
) -> Option<(u64, u64, usize, u64, Vec<u64>, codec::Export)> {
    let base = snapshot_generation(&m.snapshot)?;
    let (epoch, cuts, snap) = fs::read(pcfg.checkpoint_dir().join(&m.snapshot))
        .ok()
        .and_then(|b| codec::decode_snapshot(&b).ok())?;
    let (generation, folded, epoch, cuts, snap) =
        fold_deltas(pcfg, base, epoch, cuts, snap, m.deltas.len());
    if generation == m.generation && (epoch != m.epoch || cuts != m.wal_cuts) {
        // Both were written in one commit; a full-chain decode that
        // disagrees with the manifest means cross-generation confusion.
        eprintln!("[persist] manifest/snapshot disagree, falling back to scan");
        return None;
    }
    Some((generation, base, folded, epoch, cuts, snap))
}

/// Fold up to `max_deltas` consecutive delta generations (`base+1`, …)
/// into `snap`. Returns `(newest_generation, folded, epoch, cuts, snap)`
/// where epoch/cuts come from the newest generation that decoded.
fn fold_deltas(
    pcfg: &PersistConfig,
    base: u64,
    mut epoch: u64,
    mut cuts: Vec<u64>,
    mut snap: codec::Export,
    max_deltas: usize,
) -> (u64, usize, u64, Vec<u64>, codec::Export) {
    let mut generation = base;
    let mut folded = 0usize;
    while folded < max_deltas {
        let name = delta_name(generation + 1);
        let Some((parent, depoch, dcuts, dirty)) = fs::read(pcfg.checkpoint_dir().join(&name))
            .ok()
            .and_then(|b| codec::decode_delta(&b).ok())
        else {
            break;
        };
        if parent != generation || depoch != epoch || dcuts.len() != cuts.len() {
            eprintln!(
                "[persist] delta {name} does not chain onto generation {generation}; \
                 recovering the chain prefix"
            );
            break;
        }
        codec::fold_delta(&mut snap, dirty);
        generation += 1;
        folded += 1;
        epoch = depoch;
        cuts = dcuts;
    }
    (generation, folded, epoch, cuts, snap)
}

/// The checkpoint mark committed with the newest generation (the
/// `CKPT_MARK` sidecar), or `None` when absent/unreadable.
fn read_ckpt_mark(pcfg: &PersistConfig) -> Option<u64> {
    fs::read_to_string(pcfg.ckpt_mark_path()).ok()?.trim().parse().ok()
}

/// Without a checkpoint the epoch comes from the newest `e<N>` directory
/// (a crash before the first checkpoint), else 1.
fn detect_epoch(pcfg: &PersistConfig) -> Result<u64, String> {
    let rd = match fs::read_dir(pcfg.wal_root()) {
        Ok(rd) => rd,
        Err(_) => return Ok(1),
    };
    let mut newest = 1u64;
    for entry in rd.flatten() {
        if let Some(n) = entry
            .file_name()
            .to_str()
            .and_then(|s| s.strip_prefix('e'))
            .and_then(|s| s.parse::<u64>().ok())
        {
            newest = newest.max(n);
        }
    }
    Ok(newest)
}

/// `(index, path)` for every `shard-<i>` directory, sorted by index.
fn scan_shard_dirs(
    epoch_dir: &std::path::Path,
) -> Result<Vec<(usize, std::path::PathBuf)>, String> {
    let mut out = Vec::new();
    let rd = match fs::read_dir(epoch_dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // fresh start: no epoch dir yet
    };
    for entry in rd.flatten() {
        if let Some(i) = entry
            .file_name()
            .to_str()
            .and_then(|s| s.strip_prefix("shard-"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            out.push((i, entry.path()));
        }
    }
    out.sort_unstable_by_key(|&(i, _)| i);
    Ok(out)
}

fn sweep_dead_epochs(pcfg: &PersistConfig, live_epoch: u64) {
    let Ok(rd) = fs::read_dir(pcfg.wal_root()) else { return };
    for entry in rd.flatten() {
        if let Some(n) = entry
            .file_name()
            .to_str()
            .and_then(|s| s.strip_prefix('e'))
            .and_then(|s| s.parse::<u64>().ok())
        {
            if n < live_epoch {
                let _ = fs::remove_dir_all(entry.path());
            }
        }
    }
}
