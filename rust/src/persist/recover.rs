//! Crash recovery: build an [`Engine`] from the newest valid checkpoint
//! plus the WAL tails behind it.
//!
//! State machine (every arrow is crash-safe to re-enter):
//!
//! ```text
//! read MANIFEST ── ok ──▶ decode named snapshot ── ok ─▶ (gen, epoch, cuts, model)
//!      │ missing/corrupt        │ corrupt
//!      ▼                        ▼
//! scan checkpoint/ for the newest ckpt-*.snap that decodes
//!      │ none                   (cuts/epoch are embedded in the snapshot)
//!      ▼
//! empty model, epoch = newest wal/e<N> dir (or 1), cuts = zeros
//!      │
//!      ▼
//! import snapshot, then stream wal/e<epoch>/shard-*/ records with
//! seq > cut through the apply path (per-shard seq order, record-by-record
//! via `wal::WalCursor`, torn tail tolerated)
//!      │
//!      ▼
//! shard layout unchanged?  ── yes ─▶ arm WAL writers at seq = last+1
//!      │ no (shards reconfigured)
//!      ▼
//! bump epoch, arm writers at seq 0, checkpoint immediately (commits the
//! new epoch), delete the old epoch's directory
//! ```
//!
//! The epoch bump makes shard-count changes crash-safe: cut points always
//! index the layout that wrote them, and a crash between "new snapshot
//! committed" and "old epoch deleted" just leaves a dead directory the
//! next recovery ignores (manifest names the new epoch) and sweeps.

use std::fs;
use std::sync::Arc;

use crate::config::ServerConfig;
use crate::coordinator::Engine;

use super::checkpoint::{snapshot_generation, Manifest};
use super::{codec, remove_stale_tmp, wal, PersistConfig, PersistState};

/// What recovery found and did (printed by `mcprioq serve`, asserted by
/// the recovery tests).
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Checkpoint generation recovered from (0 = none found).
    pub generation: u64,
    /// WAL epoch recovered from.
    pub epoch: u64,
    /// Src nodes imported from the snapshot.
    pub snapshot_nodes: usize,
    /// WAL batches replayed on top of the snapshot.
    pub replayed_batches: u64,
    /// Updates (pairs) inside those batches.
    pub replayed_updates: u64,
    /// Shard directories whose tail record was torn (tolerated).
    pub torn_tails: usize,
    /// True when the shard count changed since the checkpoint: recovery
    /// re-routed the old data, bumped the WAL epoch, and re-checkpointed.
    pub layout_changed: bool,
}

/// Open a durable engine: recover, then arm the WAL writers. This is the
/// front door `mcprioq serve --data-dir` uses; `Engine::new` alone never
/// persists anything.
pub fn open_engine(
    config: &ServerConfig,
    workers: usize,
) -> Result<(Arc<Engine>, RecoveryReport), String> {
    let pcfg = config
        .persist_config()?
        .ok_or("persist.data_dir is not configured")?;
    fs::create_dir_all(pcfg.checkpoint_dir())
        .map_err(|e| format!("{}: {e}", pcfg.checkpoint_dir().display()))?;
    fs::create_dir_all(pcfg.wal_root())
        .map_err(|e| format!("{}: {e}", pcfg.wal_root().display()))?;
    remove_stale_tmp(&pcfg.checkpoint_dir());

    let mut report = RecoveryReport::default();

    // --- 1. newest valid checkpoint ---
    let loaded = load_checkpoint(&pcfg);
    let (generation, epoch, cuts, snapshot) = match loaded {
        Some(t) => t,
        None => (0, detect_epoch(&pcfg)?, Vec::new(), Vec::new()),
    };
    report.generation = generation;
    report.epoch = epoch;
    report.snapshot_nodes = snapshot.len();

    // --- 2. build the engine, then stream the WAL tails through it ---
    let epoch_dir = pcfg.epoch_dir(epoch);
    let shard_dirs = scan_shard_dirs(&epoch_dir)?;
    let old_shards = if cuts.is_empty() { shard_dirs.len() } else { cuts.len() };
    // Seed from the cuts so a shard whose WAL directory is missing (e.g.
    // wiped by hand) still resumes *above* its checkpointed seq instead of
    // re-issuing sequence numbers replay would then skip.
    let mut last_seqs = vec![0u64; old_shards.max(shard_dirs.len())];
    for (seq, &cut) in last_seqs.iter_mut().zip(&cuts) {
        *seq = cut;
    }
    let engine = Engine::new(config, workers);
    engine.import_snapshot(&snapshot);
    for (shard, dir) in &shard_dirs {
        let cut = cuts.get(*shard).copied().unwrap_or(0);
        // Record-by-record streaming replay: each WAL record goes straight
        // through the apply path instead of being collected into a
        // per-shard tail first, so recovery memory is bounded by one
        // record, not by the time since the last checkpoint. Old shards
        // hold disjoint src sets, so cross-shard order is irrelevant;
        // within a shard the cursor yields apply order.
        // `observe_batch_direct` re-routes by the *current* layout, which
        // is what makes shard-count changes transparent here.
        let stats = wal::replay_dir(dir, cut, |_seq, batch| {
            engine.observe_batch_direct(&batch);
        })?;
        report.replayed_batches += stats.batches;
        report.replayed_updates += stats.updates;
        report.torn_tails += stats.torn as usize;
        if *shard < last_seqs.len() {
            last_seqs[*shard] = stats.last_seq.max(cut);
        }
    }

    // --- 3. arm the WAL writers ---
    let nshards = engine.shard_count();
    report.layout_changed = old_shards != 0 && old_shards != nshards;
    if report.layout_changed {
        let new_epoch = epoch + 1;
        let state = PersistState::create(
            pcfg.clone(),
            new_epoch,
            generation,
            &vec![0u64; nshards],
            vec![0u64; nshards],
            report.replayed_batches,
        )
        .map_err(|e| format!("opening wal epoch {new_epoch}: {e}"))?;
        engine.attach_persist(Arc::new(state));
        // Commits a snapshot of everything just replayed under the new
        // epoch/layout; only then is the old epoch's WAL dead weight.
        engine.checkpoint()?;
        let _ = fs::remove_dir_all(&epoch_dir);
        report.epoch = new_epoch;
    } else {
        let mut starts = vec![0u64; nshards];
        for (start, &last) in starts.iter_mut().zip(&last_seqs) {
            *start = last;
        }
        // Lag-one truncation must keep the WAL reachable for the
        // generation just recovered from: its cuts seed `prev_cuts`.
        let mut prev_cuts = vec![0u64; nshards];
        for (prev, &cut) in prev_cuts.iter_mut().zip(&cuts) {
            *prev = cut;
        }
        let state = PersistState::create(
            pcfg.clone(),
            epoch.max(1),
            generation,
            &starts,
            prev_cuts,
            report.replayed_batches,
        )
        .map_err(|e| format!("opening wal epoch {epoch}: {e}"))?;
        report.epoch = epoch.max(1);
        engine.attach_persist(Arc::new(state));
    }
    // Dead epochs from interrupted layout changes (manifest already names
    // a newer one) are swept lazily.
    sweep_dead_epochs(&pcfg, report.epoch);
    Ok((engine, report))
}

/// Try the manifest first, then fall back to scanning for the newest
/// snapshot that decodes (the manifest is a pointer, not the only truth).
fn load_checkpoint(
    pcfg: &PersistConfig,
) -> Option<(u64, u64, Vec<u64>, codec::Export)> {
    if let Ok(text) = fs::read_to_string(pcfg.manifest_path()) {
        match Manifest::parse(&text) {
            Ok(m) => {
                match fs::read(pcfg.checkpoint_dir().join(&m.snapshot))
                    .ok()
                    .and_then(|b| codec::decode_snapshot(&b).ok())
                {
                    Some((epoch, cuts, snap)) => {
                        // Trust the manifest for generation; the snapshot
                        // carries its own epoch/cuts (they must agree —
                        // both were written in one checkpoint).
                        if epoch == m.epoch && cuts == m.wal_cuts {
                            return Some((m.generation, epoch, cuts, snap));
                        }
                        eprintln!(
                            "[persist] manifest/snapshot disagree, falling back to scan"
                        );
                    }
                    None => eprintln!(
                        "[persist] snapshot {} unreadable, falling back to scan",
                        m.snapshot
                    ),
                }
            }
            Err(e) => eprintln!("[persist] bad manifest ({e}), falling back to scan"),
        }
    }
    // Fallback: newest generation first.
    let mut gens: Vec<(u64, std::path::PathBuf)> = fs::read_dir(pcfg.checkpoint_dir())
        .ok()?
        .flatten()
        .filter_map(|e| {
            let gen = e.file_name().to_str().and_then(snapshot_generation)?;
            Some((gen, e.path()))
        })
        .collect();
    gens.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (gen, path) in gens {
        if let Some((epoch, cuts, snap)) =
            fs::read(&path).ok().and_then(|b| codec::decode_snapshot(&b).ok())
        {
            return Some((gen, epoch, cuts, snap));
        }
        eprintln!("[persist] skipping unreadable snapshot {}", path.display());
    }
    None
}

/// Without a checkpoint the epoch comes from the newest `e<N>` directory
/// (a crash before the first checkpoint), else 1.
fn detect_epoch(pcfg: &PersistConfig) -> Result<u64, String> {
    let rd = match fs::read_dir(pcfg.wal_root()) {
        Ok(rd) => rd,
        Err(_) => return Ok(1),
    };
    let mut newest = 1u64;
    for entry in rd.flatten() {
        if let Some(n) = entry
            .file_name()
            .to_str()
            .and_then(|s| s.strip_prefix('e'))
            .and_then(|s| s.parse::<u64>().ok())
        {
            newest = newest.max(n);
        }
    }
    Ok(newest)
}

/// `(index, path)` for every `shard-<i>` directory, sorted by index.
fn scan_shard_dirs(
    epoch_dir: &std::path::Path,
) -> Result<Vec<(usize, std::path::PathBuf)>, String> {
    let mut out = Vec::new();
    let rd = match fs::read_dir(epoch_dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // fresh start: no epoch dir yet
    };
    for entry in rd.flatten() {
        if let Some(i) = entry
            .file_name()
            .to_str()
            .and_then(|s| s.strip_prefix("shard-"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            out.push((i, entry.path()));
        }
    }
    out.sort_unstable_by_key(|&(i, _)| i);
    Ok(out)
}

fn sweep_dead_epochs(pcfg: &PersistConfig, live_epoch: u64) {
    let Ok(rd) = fs::read_dir(pcfg.wal_root()) else { return };
    for entry in rd.flatten() {
        if let Some(n) = entry
            .file_name()
            .to_str()
            .and_then(|s| s.strip_prefix('e'))
            .and_then(|s| s.parse::<u64>().ok())
        {
            if n < live_epoch {
                let _ = fs::remove_dir_all(entry.path());
            }
        }
    }
}
