//! Compact binary encoding shared by checkpoints and the WAL: LEB128
//! varints, an IEEE CRC32, the snapshot formats (full + differential), and
//! the WAL record payload. One codec for both artifacts keeps the two
//! durability paths byte-compatible by construction (the round-trip
//! property tests compare them directly).
//!
//! Full snapshot layout (`ckpt-<gen>.snap`):
//!
//! ```text
//! magic   "MCPQCKP1"                      8 bytes
//! body    epoch                           varint (WAL epoch this cut is in)
//!         shard_count                     varint
//!         wal_cut[shard_count]            varint each (last seq in snapshot)
//!         node_count                      varint
//!         node*: src, total, edge_count   varints
//!                edge*: dst, count        varints, list order (head first)
//! crc32   over `body`                     u32 LE
//! ```
//!
//! Differential snapshot layout (`ckpt-<gen>.delta`, DESIGN.md §6): the
//! same body prefixed with the *parent generation* it applies on top of —
//! only the nodes dirtied since that generation are present, and recovery
//! folds the chain base → delta → delta with [`fold_delta`]:
//!
//! ```text
//! magic   "MCPQDLT1"                      8 bytes
//! body    parent_generation              varint (must be this gen - 1)
//!         epoch, cuts, nodes              as in the full snapshot
//! crc32   over `body`                     u32 LE
//! ```
//!
//! WAL record payload (inside a `wal.rs` frame): `seq`, a record *kind*
//! tag, then the kind-specific body. Kind 0 is the observation batch; the
//! maintenance kinds (decay / repair, §II.C) make maintenance replayable
//! data instead of a recovery-skewing side channel:
//!
//! ```text
//! seq varint, kind varint
//!   kind 0 (batch):  count, (src, dst)*   the §II.A update batch
//!   kind 1 (decay):  numerator, denominator
//!   kind 2 (repair): (empty)
//! ```
//!
//! The WAL cut points are embedded *in the snapshot itself* (as well as in
//! the manifest) so a snapshot alone is enough to recover from — the
//! manifest is a pointer, not the only source of truth.

use std::fmt;

/// The in-memory snapshot shape: `McPrioQ::export` / `Engine::export`.
pub type Export = Vec<(u64, u64, Vec<(u64, u64)>)>;

/// Magic prefix of a full checkpoint snapshot file.
pub const SNAP_MAGIC: &[u8; 8] = b"MCPQCKP1";

/// Magic prefix of a differential checkpoint file.
pub const DELTA_MAGIC: &[u8; 8] = b"MCPQDLT1";

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Input ended inside a value.
    Truncated,
    /// A varint encoded more than 64 bits.
    Overflow,
    /// Wrong magic prefix (not a snapshot / wrong version).
    BadMagic,
    /// Checksum mismatch: the artifact is corrupt or torn.
    BadCrc { stored: u32, computed: u32 },
    /// Well-formed prefix followed by unconsumed bytes.
    TrailingBytes(usize),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "truncated input"),
            CodecError::Overflow => write!(f, "varint overflows u64"),
            CodecError::BadMagic => write!(f, "bad magic (not a MCPQCKP1 snapshot)"),
            CodecError::BadCrc { stored, computed } => {
                write!(f, "crc mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            CodecError::TrailingBytes(n) => write!(f, "{n} trailing bytes after value"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---- varint ----

/// Append `v` as a LEB128 varint (1–10 bytes).
#[inline]
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

/// Read one varint at `*pos`, advancing it past the value.
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64, CodecError> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or(CodecError::Truncated)?;
        *pos += 1;
        if shift == 63 && (b & 0x7f) > 1 {
            return Err(CodecError::Overflow);
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(CodecError::Overflow);
        }
    }
}

// ---- crc32 (IEEE 802.3, the zlib/gzip polynomial) ----

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---- snapshot ----

/// Append the shared snapshot body (epoch, cuts, nodes) to `buf`.
fn put_snapshot_body(buf: &mut Vec<u8>, epoch: u64, cuts: &[u64], snap: &Export) {
    put_varint(buf, epoch);
    put_varint(buf, cuts.len() as u64);
    for &c in cuts {
        put_varint(buf, c);
    }
    put_varint(buf, snap.len() as u64);
    for (src, total, edges) in snap {
        put_varint(buf, *src);
        put_varint(buf, *total);
        put_varint(buf, edges.len() as u64);
        for &(dst, count) in edges {
            put_varint(buf, dst);
            put_varint(buf, count);
        }
    }
}

/// Read the shared snapshot body starting at `*pos`.
fn get_snapshot_body(
    body: &[u8],
    pos: &mut usize,
) -> Result<(u64, Vec<u64>, Export), CodecError> {
    let epoch = get_varint(body, pos)?;
    let nshards = get_varint(body, pos)? as usize;
    let mut cuts = Vec::with_capacity(nshards.min(1 << 16));
    for _ in 0..nshards {
        cuts.push(get_varint(body, pos)?);
    }
    let nodes = get_varint(body, pos)? as usize;
    let mut snap = Vec::with_capacity(nodes.min(1 << 20));
    for _ in 0..nodes {
        let src = get_varint(body, pos)?;
        let total = get_varint(body, pos)?;
        let nedges = get_varint(body, pos)? as usize;
        let mut edges = Vec::with_capacity(nedges.min(1 << 20));
        for _ in 0..nedges {
            let dst = get_varint(body, pos)?;
            let count = get_varint(body, pos)?;
            edges.push((dst, count));
        }
        snap.push((src, total, edges));
    }
    Ok((epoch, cuts, snap))
}

/// Validate `bytes` against `magic` + trailing CRC; returns the body
/// slice between them.
fn checked_body<'a>(bytes: &'a [u8], magic: &[u8; 8]) -> Result<&'a [u8], CodecError> {
    if bytes.len() < magic.len() + 4 || &bytes[..magic.len()] != magic {
        return Err(CodecError::BadMagic);
    }
    let crc_at = bytes.len() - 4;
    let stored = u32::from_le_bytes(bytes[crc_at..].try_into().unwrap());
    let computed = crc32(&bytes[magic.len()..crc_at]);
    if stored != computed {
        return Err(CodecError::BadCrc { stored, computed });
    }
    Ok(&bytes[..crc_at])
}

/// Encode a quiesced export plus its WAL cut points into the snapshot
/// format. `cuts[i]` is the last WAL sequence number (per shard, in WAL
/// `epoch`) whose effects are contained in `snap`; recovery replays
/// strictly after it.
pub fn encode_snapshot(epoch: u64, cuts: &[u64], snap: &Export) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + 16 * snap.len());
    buf.extend_from_slice(SNAP_MAGIC);
    let body = SNAP_MAGIC.len();
    put_snapshot_body(&mut buf, epoch, cuts, snap);
    let crc = crc32(&buf[body..]);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Decode and validate a snapshot: returns `(epoch, cuts, export)`.
/// Rejects bad magic, any CRC mismatch, and trailing garbage, so recovery
/// can treat "decodes" as "valid".
pub fn decode_snapshot(bytes: &[u8]) -> Result<(u64, Vec<u64>, Export), CodecError> {
    let body = checked_body(bytes, SNAP_MAGIC)?;
    let mut pos = SNAP_MAGIC.len();
    let (epoch, cuts, snap) = get_snapshot_body(body, &mut pos)?;
    if pos != body.len() {
        return Err(CodecError::TrailingBytes(body.len() - pos));
    }
    Ok((epoch, cuts, snap))
}

/// Encode a differential checkpoint: the nodes dirtied since generation
/// `parent`, with the cut points of *this* generation. Applies on top of
/// the folded state of generations `..= parent` only.
pub fn encode_delta(parent: u64, epoch: u64, cuts: &[u64], dirty: &Export) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + 16 * dirty.len());
    buf.extend_from_slice(DELTA_MAGIC);
    let body = DELTA_MAGIC.len();
    put_varint(&mut buf, parent);
    put_snapshot_body(&mut buf, epoch, cuts, dirty);
    let crc = crc32(&buf[body..]);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Decode and validate a differential checkpoint: returns
/// `(parent_generation, epoch, cuts, dirty_nodes)`.
pub fn decode_delta(bytes: &[u8]) -> Result<(u64, u64, Vec<u64>, Export), CodecError> {
    let body = checked_body(bytes, DELTA_MAGIC)?;
    let mut pos = DELTA_MAGIC.len();
    let parent = get_varint(body, &mut pos)?;
    let (epoch, cuts, snap) = get_snapshot_body(body, &mut pos)?;
    if pos != body.len() {
        return Err(CodecError::TrailingBytes(body.len() - pos));
    }
    Ok((parent, epoch, cuts, snap))
}

/// Fold one delta generation into the accumulated base: every node in
/// `delta` *replaces* its base entry (or is inserted). Both sides are
/// sorted by src (export order) and stay sorted. Nodes never disappear —
/// decay prunes edges, not nodes — so a node pruned empty arrives as a
/// zero-edge entry, not an absence.
pub fn fold_delta(base: &mut Export, delta: Export) {
    if base.is_empty() {
        *base = delta;
        return;
    }
    for node in delta {
        match base.binary_search_by_key(&node.0, |&(src, _, _)| src) {
            Ok(i) => base[i] = node,
            Err(i) => base.insert(i, node),
        }
    }
}

// ---- WAL record payload ----

/// Record-kind tags (see the module docs for the payload grammar).
const REC_BATCH: u64 = 0;
const REC_DECAY: u64 = 1;
const REC_REPAIR: u64 = 2;

/// One decoded WAL record: the observation batch, or a §II.C maintenance
/// operation logged as data so recovery and followers replay maintenance
/// exactly instead of skipping it (DESIGN.md §6).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalOp {
    /// An applied update batch (the shard-affine ingest unit).
    Batch(Vec<(u64, u64)>),
    /// One decay pass over the shard with this multiplier. The recorded
    /// numerator/denominator (not the replaying process's config) drive
    /// the replay, so a config change across a restart cannot skew it.
    Decay { num: u64, den: u64 },
    /// One order-repair sweep over the shard.
    Repair,
}

/// Append one WAL batch-record payload (`seq`, kind 0, the batch) to
/// `buf`. The frame (length + CRC) around it is the WAL writer's job.
/// Split out from [`encode_op_record`] so the ingest hot path borrows the
/// batch instead of materialising a `WalOp`.
pub fn encode_record(buf: &mut Vec<u8>, seq: u64, batch: &[(u64, u64)]) {
    put_varint(buf, seq);
    put_varint(buf, REC_BATCH);
    put_varint(buf, batch.len() as u64);
    for &(src, dst) in batch {
        put_varint(buf, src);
        put_varint(buf, dst);
    }
}

/// Append one WAL record payload of any kind to `buf`.
pub fn encode_op_record(buf: &mut Vec<u8>, seq: u64, op: &WalOp) {
    match op {
        WalOp::Batch(batch) => encode_record(buf, seq, batch),
        WalOp::Decay { num, den } => {
            put_varint(buf, seq);
            put_varint(buf, REC_DECAY);
            put_varint(buf, *num);
            put_varint(buf, *den);
        }
        WalOp::Repair => {
            put_varint(buf, seq);
            put_varint(buf, REC_REPAIR);
        }
    }
}

/// Decode one WAL record payload into `(seq, op)`. An unknown kind tag is
/// rejected (`BadMagic`): a frame that CRC-validated but carries a kind
/// this build does not know cannot be safely skipped — its effects would
/// be missing from the replayed state.
pub fn decode_record(payload: &[u8]) -> Result<(u64, WalOp), CodecError> {
    let mut pos = 0usize;
    let seq = get_varint(payload, &mut pos)?;
    let kind = get_varint(payload, &mut pos)?;
    let op = match kind {
        REC_BATCH => {
            let n = get_varint(payload, &mut pos)? as usize;
            let mut batch = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let src = get_varint(payload, &mut pos)?;
                let dst = get_varint(payload, &mut pos)?;
                batch.push((src, dst));
            }
            WalOp::Batch(batch)
        }
        REC_DECAY => {
            let num = get_varint(payload, &mut pos)?;
            let den = get_varint(payload, &mut pos)?;
            if den == 0 {
                return Err(CodecError::BadMagic);
            }
            WalOp::Decay { num, den }
        }
        REC_REPAIR => WalOp::Repair,
        _ => return Err(CodecError::BadMagic),
    };
    if pos != payload.len() {
        return Err(CodecError::TrailingBytes(payload.len() - pos));
    }
    Ok((seq, op))
}
