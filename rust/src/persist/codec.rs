//! Compact binary encoding shared by checkpoints and the WAL: LEB128
//! varints, an IEEE CRC32, the snapshot format (`export()` on disk), and
//! the WAL record payload. One codec for both artifacts keeps the two
//! durability paths byte-compatible by construction (the round-trip
//! property tests compare them directly).
//!
//! Snapshot layout (`ckpt-<gen>.snap`):
//!
//! ```text
//! magic   "MCPQCKP1"                      8 bytes
//! body    epoch                           varint (WAL epoch this cut is in)
//!         shard_count                     varint
//!         wal_cut[shard_count]            varint each (last seq in snapshot)
//!         node_count                      varint
//!         node*: src, total, edge_count   varints
//!                edge*: dst, count        varints, list order (head first)
//! crc32   over `body`                     u32 LE
//! ```
//!
//! The WAL cut points are embedded *in the snapshot itself* (as well as in
//! the manifest) so a snapshot alone is enough to recover from — the
//! manifest is a pointer, not the only source of truth.

use std::fmt;

/// The in-memory snapshot shape: `McPrioQ::export` / `Engine::export`.
pub type Export = Vec<(u64, u64, Vec<(u64, u64)>)>;

/// Magic prefix of a checkpoint snapshot file.
pub const SNAP_MAGIC: &[u8; 8] = b"MCPQCKP1";

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// Input ended inside a value.
    Truncated,
    /// A varint encoded more than 64 bits.
    Overflow,
    /// Wrong magic prefix (not a snapshot / wrong version).
    BadMagic,
    /// Checksum mismatch: the artifact is corrupt or torn.
    BadCrc { stored: u32, computed: u32 },
    /// Well-formed prefix followed by unconsumed bytes.
    TrailingBytes(usize),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "truncated input"),
            CodecError::Overflow => write!(f, "varint overflows u64"),
            CodecError::BadMagic => write!(f, "bad magic (not a MCPQCKP1 snapshot)"),
            CodecError::BadCrc { stored, computed } => {
                write!(f, "crc mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            CodecError::TrailingBytes(n) => write!(f, "{n} trailing bytes after value"),
        }
    }
}

impl std::error::Error for CodecError {}

// ---- varint ----

/// Append `v` as a LEB128 varint (1–10 bytes).
#[inline]
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

/// Read one varint at `*pos`, advancing it past the value.
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64, CodecError> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or(CodecError::Truncated)?;
        *pos += 1;
        if shift == 63 && (b & 0x7f) > 1 {
            return Err(CodecError::Overflow);
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(CodecError::Overflow);
        }
    }
}

// ---- crc32 (IEEE 802.3, the zlib/gzip polynomial) ----

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---- snapshot ----

/// Encode a quiesced export plus its WAL cut points into the snapshot
/// format. `cuts[i]` is the last WAL sequence number (per shard, in WAL
/// `epoch`) whose effects are contained in `snap`; recovery replays
/// strictly after it.
pub fn encode_snapshot(epoch: u64, cuts: &[u64], snap: &Export) -> Vec<u8> {
    let mut buf = Vec::with_capacity(64 + 16 * snap.len());
    buf.extend_from_slice(SNAP_MAGIC);
    let body = SNAP_MAGIC.len();
    put_varint(&mut buf, epoch);
    put_varint(&mut buf, cuts.len() as u64);
    for &c in cuts {
        put_varint(&mut buf, c);
    }
    put_varint(&mut buf, snap.len() as u64);
    for (src, total, edges) in snap {
        put_varint(&mut buf, *src);
        put_varint(&mut buf, *total);
        put_varint(&mut buf, edges.len() as u64);
        for &(dst, count) in edges {
            put_varint(&mut buf, dst);
            put_varint(&mut buf, count);
        }
    }
    let crc = crc32(&buf[body..]);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Decode and validate a snapshot: returns `(epoch, cuts, export)`.
/// Rejects bad magic, any CRC mismatch, and trailing garbage, so recovery
/// can treat "decodes" as "valid".
pub fn decode_snapshot(bytes: &[u8]) -> Result<(u64, Vec<u64>, Export), CodecError> {
    if bytes.len() < SNAP_MAGIC.len() + 4 || &bytes[..SNAP_MAGIC.len()] != SNAP_MAGIC {
        return Err(CodecError::BadMagic);
    }
    let crc_at = bytes.len() - 4;
    let stored = u32::from_le_bytes(bytes[crc_at..].try_into().unwrap());
    let computed = crc32(&bytes[SNAP_MAGIC.len()..crc_at]);
    if stored != computed {
        return Err(CodecError::BadCrc { stored, computed });
    }
    let body = &bytes[..crc_at];
    let mut pos = SNAP_MAGIC.len();
    let epoch = get_varint(body, &mut pos)?;
    let nshards = get_varint(body, &mut pos)? as usize;
    let mut cuts = Vec::with_capacity(nshards.min(1 << 16));
    for _ in 0..nshards {
        cuts.push(get_varint(body, &mut pos)?);
    }
    let nodes = get_varint(body, &mut pos)? as usize;
    let mut snap = Vec::with_capacity(nodes.min(1 << 20));
    for _ in 0..nodes {
        let src = get_varint(body, &mut pos)?;
        let total = get_varint(body, &mut pos)?;
        let nedges = get_varint(body, &mut pos)? as usize;
        let mut edges = Vec::with_capacity(nedges.min(1 << 20));
        for _ in 0..nedges {
            let dst = get_varint(body, &mut pos)?;
            let count = get_varint(body, &mut pos)?;
            edges.push((dst, count));
        }
        snap.push((src, total, edges));
    }
    if pos != body.len() {
        return Err(CodecError::TrailingBytes(body.len() - pos));
    }
    Ok((epoch, cuts, snap))
}

// ---- WAL record payload ----

/// Append one WAL record payload (`seq`, then the batch) to `buf`.
/// The frame (length + CRC) around it is the WAL writer's job.
pub fn encode_record(buf: &mut Vec<u8>, seq: u64, batch: &[(u64, u64)]) {
    put_varint(buf, seq);
    put_varint(buf, batch.len() as u64);
    for &(src, dst) in batch {
        put_varint(buf, src);
        put_varint(buf, dst);
    }
}

/// Decode one WAL record payload into `(seq, batch)`.
pub fn decode_record(payload: &[u8]) -> Result<(u64, Vec<(u64, u64)>), CodecError> {
    let mut pos = 0usize;
    let seq = get_varint(payload, &mut pos)?;
    let n = get_varint(payload, &mut pos)? as usize;
    let mut batch = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let src = get_varint(payload, &mut pos)?;
        let dst = get_varint(payload, &mut pos)?;
        batch.push((src, dst));
    }
    if pos != payload.len() {
        return Err(CodecError::TrailingBytes(payload.len() - pos));
    }
    Ok((seq, batch))
}
