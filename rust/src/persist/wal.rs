//! Per-shard segmented write-ahead log.
//!
//! Layout: `<data_dir>/wal/e<epoch>/shard-<i>/seg-<first_seq>.wal`, where
//! `first_seq` is the sequence number of the segment's first record. Each
//! segment starts with an 8-byte magic, followed by framed records:
//!
//! ```text
//! frame  len: u32 LE   payload byte length
//!        crc: u32 LE   crc32(payload)
//!        payload       codec::encode_record(seq, batch)
//! ```
//!
//! Invariants the reader checks and the writer maintains:
//!
//! * Sequence numbers are per-shard, start at 1, and are contiguous within
//!   and across segments (a segment's filename is its first seq).
//! * Only the *tail* of the newest write position can be torn: a bad frame
//!   ends that segment's replay. A later segment then continues at exactly
//!   the next seq (the restart that created it replayed up to the torn
//!   point) — any other gap is real corruption and fails recovery.
//! * Sealed segments are fsynced on rotation regardless of policy, so
//!   truncation (after a checkpoint) never races unsynced data.
//!
//! One writer exists per shard — the shard's single ingest worker — so the
//! surrounding `Mutex` (in `PersistState`) is uncontended except during
//! checkpoints.

use std::fs::{self, File, OpenOptions};
use std::io::{self, Write as _};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::codec;
use super::FsyncPolicy;

/// Magic prefix of every WAL segment file.
pub const SEGMENT_MAGIC: &[u8; 8] = b"MCPQWAL1";

/// Frame header bytes (len + crc).
const FRAME_HEADER: usize = 8;

struct OpenSegment {
    file: File,
    path: PathBuf,
    /// Bytes written so far, including the magic.
    len: u64,
}

/// Append side of one shard's segmented log.
pub struct ShardWal {
    dir: PathBuf,
    policy: FsyncPolicy,
    fsync_interval: Duration,
    segment_bytes: u64,
    seg: Option<OpenSegment>,
    /// Sequence number the next append will carry.
    next_seq: u64,
    last_sync: Instant,
    dirty: bool,
    /// Reusable frame buffer: [len u32][crc u32][payload].
    frame: Vec<u8>,
    /// Bytes appended minus bytes truncated (the engine's `wal_bytes=`).
    live_bytes: u64,
}

impl ShardWal {
    /// Open the log for appending. `last_seq` is the highest sequence
    /// number already on disk (or covered by a checkpoint); the first
    /// append gets `last_seq + 1`. The directory is created eagerly so the
    /// shard layout is visible to recovery even before the first record.
    pub fn open(
        dir: PathBuf,
        last_seq: u64,
        policy: FsyncPolicy,
        fsync_interval: Duration,
        segment_bytes: u64,
    ) -> io::Result<ShardWal> {
        fs::create_dir_all(&dir)?;
        let live_bytes = scan_segments(&dir)?.iter().map(|s| s.bytes).sum();
        Ok(ShardWal {
            dir,
            policy,
            fsync_interval,
            segment_bytes: segment_bytes.max(1),
            seg: None,
            next_seq: last_seq + 1,
            last_sync: Instant::now(),
            dirty: false,
            frame: Vec::with_capacity(4096),
            live_bytes,
        })
    }

    /// Highest sequence number handed out so far (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Bytes currently on disk for this shard (appends minus truncations).
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Length of the currently open segment (0 if none is open yet) —
    /// exposed so the kill-point tests can enumerate record boundaries.
    pub fn segment_len(&self) -> u64 {
        self.seg.as_ref().map_or(0, |s| s.len)
    }

    /// Append one batch as a single framed record; returns its sequence
    /// number. One `write` syscall per record; fsync per policy.
    pub fn append(&mut self, batch: &[(u64, u64)]) -> io::Result<u64> {
        let seq = self.next_seq;
        self.frame.clear();
        self.frame.extend_from_slice(&[0u8; FRAME_HEADER]);
        codec::encode_record(&mut self.frame, seq, batch);
        let payload_len = (self.frame.len() - FRAME_HEADER) as u32;
        let crc = codec::crc32(&self.frame[FRAME_HEADER..]);
        self.frame[..4].copy_from_slice(&payload_len.to_le_bytes());
        self.frame[4..FRAME_HEADER].copy_from_slice(&crc.to_le_bytes());

        if self.seg.is_none() {
            self.open_segment()?;
        }
        let frame_len = self.frame.len() as u64;
        let write_res =
            self.seg.as_mut().expect("segment open").file.write_all(&self.frame);
        if let Err(e) = write_res {
            // A partial frame may now sit at the segment's tail. Abandon the
            // segment: replay treats the partial frame as a torn tail, and
            // the next append opens a fresh segment at this same
            // (unconsumed) seq, so the sequence stays contiguous. Appending
            // after the partial write instead would hide every later record
            // behind the tear.
            self.seg = None;
            return Err(e);
        }
        self.seg.as_mut().expect("segment open").len += frame_len;
        self.live_bytes += frame_len;
        self.next_seq += 1;
        self.dirty = true;
        match self.policy {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::Batch => {
                // Group commit: at most one fsync per interval. The power-
                // loss window is bounded by the interval (SIGKILL loses
                // nothing either way — the page cache survives the process).
                if self.last_sync.elapsed() >= self.fsync_interval {
                    self.sync()?;
                }
            }
            FsyncPolicy::Never => {}
        }
        if self.seg.as_ref().is_some_and(|s| s.len >= self.segment_bytes) {
            self.rotate()?;
        }
        Ok(seq)
    }

    /// Force an fsync of the open segment.
    pub fn sync(&mut self) -> io::Result<()> {
        if self.dirty {
            if let Some(seg) = &self.seg {
                seg.file.sync_data()?;
            }
            self.dirty = false;
            self.last_sync = Instant::now();
        }
        Ok(())
    }

    fn open_segment(&mut self) -> io::Result<()> {
        let path = self.dir.join(format!("seg-{:020}.wal", self.next_seq));
        // truncate(true): a file with this exact name can only be a torn
        // leftover (its first record would carry a seq recovery already
        // accounted for when it computed our starting seq), so its bytes
        // are dead. Appending after them would hide our records behind a
        // torn frame; starting clean cannot lose anything.
        // The stale leftover's bytes were counted into `live_bytes` at
        // open() time; the truncation reclaims them.
        let stale = fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let mut file = OpenOptions::new().write(true).create(true).truncate(true).open(&path)?;
        file.write_all(SEGMENT_MAGIC)?;
        self.seg = Some(OpenSegment { file, path, len: SEGMENT_MAGIC.len() as u64 });
        self.live_bytes = self.live_bytes.saturating_sub(stale) + SEGMENT_MAGIC.len() as u64;
        self.dirty = true;
        Ok(())
    }

    /// Seal the current segment (fsync regardless of policy) and start the
    /// next one lazily on the following append.
    fn rotate(&mut self) -> io::Result<()> {
        if let Some(seg) = self.seg.take() {
            seg.file.sync_data()?;
            sync_dir(&self.dir);
        }
        self.dirty = false;
        self.last_sync = Instant::now();
        Ok(())
    }

    /// Delete sealed segments whose every record is `<= cut` (covered by a
    /// checkpoint). A segment qualifies when its *successor's* first seq is
    /// `<= cut + 1`; the newest segment (no successor bound) and the open
    /// segment are always kept. Returns the bytes freed.
    pub fn truncate_upto(&mut self, cut: u64) -> io::Result<u64> {
        let segs = scan_segments(&self.dir)?;
        let current = self.seg.as_ref().map(|s| s.path.clone());
        let mut freed = 0u64;
        for (i, seg) in segs.iter().enumerate() {
            let covered = match segs.get(i + 1) {
                Some(next) => next.first_seq <= cut.saturating_add(1),
                None => false,
            };
            if covered && Some(&seg.path) != current.as_ref() {
                fs::remove_file(&seg.path)?;
                freed += seg.bytes;
            }
        }
        self.live_bytes = self.live_bytes.saturating_sub(freed);
        Ok(freed)
    }
}

impl Drop for ShardWal {
    fn drop(&mut self) {
        // Best effort: make a clean shutdown's tail durable.
        let _ = self.sync();
    }
}

/// Best-effort directory fsync (makes renames/creates durable on ext4).
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// One on-disk segment, from `scan_segments`.
#[derive(Debug, Clone)]
pub struct SegmentInfo {
    pub first_seq: u64,
    pub path: PathBuf,
    pub bytes: u64,
}

/// List a shard directory's segments sorted by first sequence number.
pub fn scan_segments(dir: &Path) -> io::Result<Vec<SegmentInfo>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(first_seq) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".wal"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        out.push(SegmentInfo { first_seq, path: entry.path(), bytes: entry.metadata()?.len() });
    }
    out.sort_unstable_by_key(|s| s.first_seq);
    Ok(out)
}

/// Outcome of replaying one shard directory.
#[derive(Debug, Default, Clone, Copy)]
pub struct ReplayStats {
    /// Batches handed to the sink (seq strictly after the cut).
    pub batches: u64,
    /// Updates (pairs) handed to the sink.
    pub updates: u64,
    /// Highest valid sequence number seen (0 = none).
    pub last_seq: u64,
    /// True if replay stopped at a torn/corrupt tail record.
    pub torn: bool,
}

/// Replay every record with `seq > cut` from a shard directory, in
/// sequence order, into `sink`. Tolerates a torn record at the *end* of
/// the newest write position (see the module docs for why a torn tail in a
/// non-final segment is still consistent); any sequence gap between
/// segments is corruption and fails.
pub fn replay_dir(
    dir: &Path,
    cut: u64,
    mut sink: impl FnMut(u64, Vec<(u64, u64)>),
) -> Result<ReplayStats, String> {
    let segs = scan_segments(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut stats = ReplayStats::default();
    // The oldest surviving segment must reach back to the cut, or batches
    // in (cut, first_seq) are unrecoverable — seen when a checkpoint's
    // truncation outran the snapshot being recovered from. Fail loudly
    // rather than silently losing acked batches.
    if let Some(first) = segs.first() {
        if first.first_seq > cut.saturating_add(1) {
            return Err(format!(
                "wal hole in {}: recovering from cut {cut} but the oldest segment starts at {}",
                dir.display(),
                first.first_seq
            ));
        }
    }
    let mut expected: Option<u64> = None;
    for seg in &segs {
        if let Some(e) = expected {
            if seg.first_seq > e {
                return Err(format!(
                    "wal gap in {}: expected seq {e}, next segment starts at {}",
                    dir.display(),
                    seg.first_seq
                ));
            }
            if seg.first_seq < e {
                return Err(format!(
                    "overlapping wal segments in {}: seq {} after {}",
                    dir.display(),
                    seg.first_seq,
                    e - 1
                ));
            }
        }
        let bytes =
            fs::read(&seg.path).map_err(|e| format!("{}: {e}", seg.path.display()))?;
        if bytes.len() < SEGMENT_MAGIC.len() || &bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
            // Torn before the first record: no valid seqs in this file. A
            // later segment (if any) must start at exactly this one's first
            // seq — the gap check above enforces it next iteration.
            stats.torn = true;
            expected = Some(seg.first_seq);
            continue;
        }
        let mut pos = SEGMENT_MAGIC.len();
        let mut seg_expected = seg.first_seq;
        let mut torn = false;
        while pos < bytes.len() {
            if bytes.len() - pos < FRAME_HEADER {
                torn = true;
                break;
            }
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            let start = pos + FRAME_HEADER;
            if len > bytes.len() - start {
                torn = true;
                break;
            }
            let payload = &bytes[start..start + len];
            if codec::crc32(payload) != crc {
                torn = true;
                break;
            }
            let (seq, batch) = match codec::decode_record(payload) {
                Ok(r) => r,
                Err(_) => {
                    torn = true;
                    break;
                }
            };
            if seq != seg_expected {
                torn = true;
                break;
            }
            pos = start + len;
            seg_expected = seq + 1;
            stats.last_seq = seq;
            if seq > cut {
                stats.batches += 1;
                stats.updates += batch.len() as u64;
                sink(seq, batch);
            }
        }
        // A torn tail is tolerated anywhere: either this was the newest
        // write position (replay simply ends), or a restart continued in a
        // later segment starting at exactly `seg_expected` — any other
        // successor trips the gap check and fails recovery.
        stats.torn |= torn;
        expected = Some(seg_expected);
    }
    Ok(stats)
}
