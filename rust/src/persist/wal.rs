//! Per-shard segmented write-ahead log.
//!
//! Layout: `<data_dir>/wal/e<epoch>/shard-<i>/seg-<first_seq>.wal`, where
//! `first_seq` is the sequence number of the segment's first record. Each
//! segment starts with an 8-byte magic, followed by framed records:
//!
//! ```text
//! frame  len: u32 LE   payload byte length
//!        crc: u32 LE   crc32(payload)
//!        payload       codec::encode_op_record(seq, op)
//! ```
//!
//! Format v2 (`MCPQWAL2`): record payloads carry a kind tag so §II.C
//! maintenance (decay / repair) is logged as replayable data alongside
//! observation batches (DESIGN.md §6). A v1 segment fails the magic check
//! and recovery reports it as corruption rather than misreading it.
//!
//! Invariants the reader checks and the writer maintains:
//!
//! * Sequence numbers are per-shard, start at 1, and are contiguous within
//!   and across segments (a segment's filename is its first seq).
//! * Only the *tail* of the newest write position can be torn: a bad frame
//!   ends that segment's replay. A later segment then continues at exactly
//!   the next seq (the restart that created it replayed up to the torn
//!   point) — any other gap is real corruption and fails recovery.
//! * Sealed segments are fsynced on rotation regardless of policy, so
//!   truncation (after a checkpoint) never races unsynced data.
//!
//! One writer exists per shard — the shard's single ingest worker — so the
//! surrounding `Mutex` (in `PersistState`) is uncontended except during
//! checkpoints.

use std::fs::{self, File};
use std::io::{self, Read as _, Seek as _, SeekFrom};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::codec;
use super::io::{IoFile, IoHandle};
use super::FsyncPolicy;

/// Magic prefix of every WAL segment file.
pub const SEGMENT_MAGIC: &[u8; 8] = b"MCPQWAL2";

/// Frame header bytes (len + crc).
const FRAME_HEADER: usize = 8;

struct OpenSegment {
    file: Box<dyn IoFile>,
    path: PathBuf,
    /// Bytes written so far, including the magic.
    len: u64,
}

/// Append side of one shard's segmented log.
pub struct ShardWal {
    dir: PathBuf,
    io: IoHandle,
    policy: FsyncPolicy,
    fsync_interval: Duration,
    segment_bytes: u64,
    seg: Option<OpenSegment>,
    /// Sequence number the next append will carry.
    next_seq: u64,
    last_sync: Instant,
    dirty: bool,
    /// Reusable frame buffer: [len u32][crc u32][payload].
    frame: Vec<u8>,
    /// Bytes appended minus bytes truncated (the engine's `wal_bytes=`).
    live_bytes: u64,
    /// Successful fsyncs (policy-driven, seal, and heal probes) — the
    /// `mcprioq_wal_fsyncs_total` telemetry series.
    fsyncs: u64,
    /// A policy-driven fsync failed *after* its record was framed into
    /// the segment. The append itself is not failed (the record would
    /// replay; un-acking it and retrying would write it twice), but the
    /// durability guarantee is weakened until a sync succeeds — the
    /// caller drains this and degrades (DESIGN.md §8).
    sync_error: Option<io::Error>,
}

impl ShardWal {
    /// Open the log for appending. `last_seq` is the highest sequence
    /// number already on disk (or covered by a checkpoint); the first
    /// append gets `last_seq + 1`. The directory is created eagerly so the
    /// shard layout is visible to recovery even before the first record.
    pub fn open(
        dir: PathBuf,
        io: IoHandle,
        last_seq: u64,
        policy: FsyncPolicy,
        fsync_interval: Duration,
        segment_bytes: u64,
    ) -> io::Result<ShardWal> {
        fs::create_dir_all(&dir)?;
        let live_bytes = scan_segments(&dir)?.iter().map(|s| s.bytes).sum();
        Ok(ShardWal {
            dir,
            io,
            policy,
            fsync_interval,
            segment_bytes: segment_bytes.max(1),
            seg: None,
            next_seq: last_seq + 1,
            last_sync: Instant::now(),
            dirty: false,
            frame: Vec::with_capacity(4096),
            live_bytes,
            fsyncs: 0,
            sync_error: None,
        })
    }

    /// Highest sequence number handed out so far (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Bytes currently on disk for this shard (appends minus truncations).
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Length of the currently open segment (0 if none is open yet) —
    /// exposed so the kill-point tests can enumerate record boundaries.
    pub fn segment_len(&self) -> u64 {
        self.seg.as_ref().map_or(0, |s| s.len)
    }

    /// Append one batch as a single framed record; returns its sequence
    /// number. One `write` syscall per record; fsync per policy.
    pub fn append(&mut self, batch: &[(u64, u64)]) -> io::Result<u64> {
        self.append_encoded(|frame, seq| codec::encode_record(frame, seq, batch))
    }

    /// Append one record of any kind (maintenance records share the batch
    /// frame path, so decay/repair get contiguous seqs for free).
    pub fn append_op(&mut self, op: &codec::WalOp) -> io::Result<u64> {
        self.append_encoded(|frame, seq| codec::encode_op_record(frame, seq, op))
    }

    fn append_encoded(
        &mut self,
        encode: impl FnOnce(&mut Vec<u8>, u64),
    ) -> io::Result<u64> {
        let seq = self.next_seq;
        self.frame.clear();
        self.frame.extend_from_slice(&[0u8; FRAME_HEADER]);
        encode(&mut self.frame, seq);
        let payload_len = (self.frame.len() - FRAME_HEADER) as u32;
        let crc = codec::crc32(&self.frame[FRAME_HEADER..]);
        self.frame[..4].copy_from_slice(&payload_len.to_le_bytes());
        self.frame[4..FRAME_HEADER].copy_from_slice(&crc.to_le_bytes());

        if self.seg.is_none() {
            self.open_segment()?;
        }
        let frame_len = self.frame.len() as u64;
        let write_res =
            self.seg.as_mut().expect("segment open").file.write_all(&self.frame);
        if let Err(e) = write_res {
            // A partial frame may now sit at the segment's tail. Abandon the
            // segment: replay treats the partial frame as a torn tail, and
            // the next append opens a fresh segment at this same
            // (unconsumed) seq, so the sequence stays contiguous. Appending
            // after the partial write instead would hide every later record
            // behind the tear.
            self.seg = None;
            return Err(e);
        }
        self.seg.as_mut().expect("segment open").len += frame_len;
        self.live_bytes += frame_len;
        self.next_seq += 1;
        self.dirty = true;
        // Policy-driven fsync. A failure here must NOT fail the append —
        // the record is already framed in the segment and will replay, so
        // the sequence number stays consumed; the error is parked in
        // `sync_error` for the caller to observe and degrade on.
        let sync_res = match self.policy {
            FsyncPolicy::Always => self.sync(),
            FsyncPolicy::Batch => {
                // Group commit: at most one fsync per interval. The power-
                // loss window is bounded by the interval (SIGKILL loses
                // nothing either way — the page cache survives the process).
                if self.last_sync.elapsed() >= self.fsync_interval {
                    self.sync()
                } else {
                    Ok(())
                }
            }
            FsyncPolicy::Never => Ok(()),
        };
        if let Err(e) = sync_res {
            self.sync_error.get_or_insert(e);
        }
        if self.seg.as_ref().is_some_and(|s| s.len >= self.segment_bytes) {
            if let Err(e) = self.rotate() {
                // Same shape: the record is durable-pending; a failed seal
                // sync leaves the segment open to retry the seal later.
                self.sync_error.get_or_insert(e);
            }
        }
        Ok(seq)
    }

    /// Force an fsync of the open segment.
    pub fn sync(&mut self) -> io::Result<()> {
        if self.dirty {
            if let Some(seg) = &mut self.seg {
                seg.file.sync_data()?;
            }
            self.fsyncs += 1;
            self.dirty = false;
            self.last_sync = Instant::now();
        }
        Ok(())
    }

    /// Successful fsyncs on this shard's log so far.
    pub fn fsync_count(&self) -> u64 {
        self.fsyncs
    }

    /// Take the deferred fsync error from the newest policy-driven sync
    /// attempt, if one failed (see the `sync_error` field).
    pub fn take_sync_error(&mut self) -> Option<io::Error> {
        self.sync_error.take()
    }

    fn open_segment(&mut self) -> io::Result<()> {
        let path = self.dir.join(format!("seg-{:020}.wal", self.next_seq));
        // truncate(true): a file with this exact name can only be a torn
        // leftover (its first record would carry a seq recovery already
        // accounted for when it computed our starting seq), so its bytes
        // are dead. Appending after them would hide our records behind a
        // torn frame; starting clean cannot lose anything.
        // The stale leftover's bytes were counted into `live_bytes` at
        // open() time; the truncation reclaims them.
        let stale = fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let mut file = self.io.create(&path)?;
        file.write_all(SEGMENT_MAGIC)?;
        self.seg = Some(OpenSegment { file, path, len: SEGMENT_MAGIC.len() as u64 });
        self.live_bytes = self.live_bytes.saturating_sub(stale) + SEGMENT_MAGIC.len() as u64;
        self.dirty = true;
        Ok(())
    }

    /// Seal the current segment (fsync regardless of policy) and start the
    /// next one lazily on the following append.
    fn rotate(&mut self) -> io::Result<()> {
        if let Some(seg) = &mut self.seg {
            // Seal-sync *before* dropping the writer: on failure the
            // segment stays open so the seal can be retried, instead of
            // losing track of an unsynced sealed file.
            seg.file.sync_data()?;
            self.fsyncs += 1;
        }
        if self.seg.take().is_some() {
            self.io.sync_dir(&self.dir);
        }
        self.dirty = false;
        self.last_sync = Instant::now();
        Ok(())
    }

    /// Delete sealed segments whose every record is `<= cut` (covered by a
    /// checkpoint). A segment qualifies when its *successor's* first seq is
    /// `<= cut + 1`; the newest segment (no successor bound) and the open
    /// segment are always kept. Returns the bytes freed.
    pub fn truncate_upto(&mut self, cut: u64) -> io::Result<u64> {
        let mut freed = 0u64;
        let io = self.io.clone();
        self.for_covered(cut, |seg, _| {
            io.remove_file(&seg.path)?;
            freed += seg.bytes;
            Ok(())
        })?;
        self.live_bytes = self.live_bytes.saturating_sub(freed);
        Ok(freed)
    }

    /// Bytes [`ShardWal::truncate_upto`] would free at `cut` without
    /// deleting anything.
    pub fn covered_bytes(&self, cut: u64) -> io::Result<u64> {
        let mut bytes = 0u64;
        self.for_covered(cut, |seg, _| {
            bytes += seg.bytes;
            Ok(())
        })?;
        Ok(bytes)
    }

    /// Bytes truncation would free at `cut` but not at `floor` — the log a
    /// retention pin at `floor` is holding back, measured in one directory
    /// scan (the checkpointer compares it against the
    /// `[replicate] max_pin_lag_bytes` escape hatch every generation).
    pub fn pinned_bytes(&self, floor: u64, cut: u64) -> io::Result<u64> {
        let mut pinned = 0u64;
        self.for_covered(cut, |seg, succ_first| {
            // Same deletability rule, tighter bound: covered at `cut` but
            // not at `floor` = retained only because of the pin.
            if !Self::seq_covered(succ_first, floor) {
                pinned += seg.bytes;
            }
            Ok(())
        })?;
        Ok(pinned)
    }

    /// One deletability rule for truncation and both sizing paths: a
    /// segment is fully covered by `cut` when its successor's first seq
    /// (`succ_first`) is `<= cut + 1`.
    fn seq_covered(succ_first: u64, cut: u64) -> bool {
        succ_first <= cut.saturating_add(1)
    }

    /// Visit every sealed, non-current segment fully covered by `cut`,
    /// passing its successor's first seq for tighter-bound checks.
    fn for_covered(
        &self,
        cut: u64,
        mut f: impl FnMut(&SegmentInfo, u64) -> io::Result<()>,
    ) -> io::Result<()> {
        let segs = scan_segments(&self.dir)?;
        let current = self.seg.as_ref().map(|s| s.path.clone());
        for (i, seg) in segs.iter().enumerate() {
            let Some(next) = segs.get(i + 1) else { continue };
            if Self::seq_covered(next.first_seq, cut) && Some(&seg.path) != current.as_ref()
            {
                f(seg, next.first_seq)?;
            }
        }
        Ok(())
    }
}

impl Drop for ShardWal {
    fn drop(&mut self) {
        // Best effort: make a clean shutdown's tail durable.
        let _ = self.sync();
    }
}

/// Best-effort directory fsync (makes renames/creates durable on ext4).
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// One on-disk segment, from `scan_segments`.
#[derive(Debug, Clone)]
pub struct SegmentInfo {
    pub first_seq: u64,
    pub path: PathBuf,
    pub bytes: u64,
}

/// List a shard directory's segments sorted by first sequence number.
pub fn scan_segments(dir: &Path) -> io::Result<Vec<SegmentInfo>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(first_seq) = name
            .strip_prefix("seg-")
            .and_then(|s| s.strip_suffix(".wal"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        out.push(SegmentInfo { first_seq, path: entry.path(), bytes: entry.metadata()?.len() });
    }
    out.sort_unstable_by_key(|s| s.first_seq);
    Ok(out)
}

/// Outcome of replaying one shard directory.
#[derive(Debug, Default, Clone, Copy)]
pub struct ReplayStats {
    /// Batch records handed to the sink (seq strictly after the cut).
    pub batches: u64,
    /// Updates (pairs) inside those batches.
    pub updates: u64,
    /// Maintenance records (decay/repair) handed to the sink.
    pub maintenance: u64,
    /// Highest valid sequence number seen (0 = none).
    pub last_seq: u64,
    /// True if replay stopped at a torn/corrupt tail record.
    pub torn: bool,
}

/// Replay every record with `seq > cut` from a shard directory, in
/// sequence order, into `sink`. Tolerates a torn record at the *end* of
/// the newest write position (see the module docs for why a torn tail in a
/// non-final segment is still consistent); any sequence gap between
/// segments is corruption and fails.
///
/// Thin wrapper over [`WalCursor`]: one pass until the cursor reports the
/// end of the durable log. Unlike the cursor (whose `End` is retryable for
/// live tailing), a single pass treats that end as final — exactly the
/// recovery semantics.
pub fn replay_dir(
    dir: &Path,
    cut: u64,
    mut sink: impl FnMut(u64, codec::WalOp),
) -> Result<ReplayStats, String> {
    let mut cursor = WalCursor::new(dir.to_path_buf(), cut);
    let mut stats = ReplayStats::default();
    while let Some((seq, op)) = cursor.poll()? {
        match &op {
            codec::WalOp::Batch(batch) => {
                stats.batches += 1;
                stats.updates += batch.len() as u64;
            }
            codec::WalOp::Decay { .. } | codec::WalOp::Repair => stats.maintenance += 1,
        }
        sink(seq, op);
    }
    stats.last_seq = cursor.last_seq();
    stats.torn = cursor.torn();
    Ok(stats)
}

/// Bytes read from a segment file per refill (bounds cursor memory while
/// keeping recovery replay close to sequential-read speed).
const CURSOR_READ_CHUNK: usize = 128 * 1024;

/// Buffered reader over one segment file, restartable at its current
/// offset — a partially visible frame is simply re-read on the next poll.
struct SegReader {
    file: File,
    path: PathBuf,
    first_seq: u64,
    /// File offset of `buf[0]`.
    base: u64,
    buf: Vec<u8>,
    /// Parse position within `buf`.
    pos: usize,
    magic_ok: bool,
}

impl SegReader {
    fn open(info: &SegmentInfo) -> io::Result<SegReader> {
        Ok(SegReader {
            file: File::open(&info.path)?,
            path: info.path.clone(),
            first_seq: info.first_seq,
            base: 0,
            buf: Vec::new(),
            pos: 0,
            magic_ok: false,
        })
    }

    /// Ensure at least `need` unparsed bytes are buffered; false when the
    /// file (currently) ends before that — a live tail may grow later.
    fn ensure(&mut self, need: usize) -> io::Result<bool> {
        while self.buf.len() - self.pos < need {
            let read_at = self.base + self.buf.len() as u64;
            self.file.seek(SeekFrom::Start(read_at))?;
            let mut chunk = [0u8; CURSOR_READ_CHUNK];
            let n = self.file.read(&mut chunk)?;
            if n == 0 {
                return Ok(false);
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
        Ok(true)
    }

    fn consume(&mut self, n: usize) {
        self.pos += n;
        // Cap retained memory: drop the parsed prefix once it grows.
        if self.pos >= 1 << 20 {
            self.base += self.pos as u64;
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Unparsed bytes currently visible past the last valid frame.
    fn unparsed(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// One step of [`WalCursor::poll`] inside the current segment.
enum Step {
    Record(u64, codec::WalOp),
    /// The file ends mid-frame (for now): retryable on a live tail.
    NeedMore,
    /// Bytes are present but don't form the expected frame (bad magic/CRC/
    /// seq). Also retryable on a live tail — a reader can observe a frame's
    /// length header before its payload bytes land.
    Bad,
    /// Not a torn tail: the bytes are complete but wrong in a way only a
    /// writer (or a format change) produces — a full 8-byte magic that
    /// isn't ours (v1 segment, foreign file), or a CRC-valid frame whose
    /// payload does not decode (unknown record kind). Skipping either
    /// would silently drop every durable record behind it. Hard error.
    Poison(String),
}

/// Streaming reader over one shard's segmented log: yields records with
/// `seq > cut` in sequence order, *without* materialising segments or the
/// whole tail in memory. Built once, used twice (DESIGN.md §5): recovery
/// drains it in a single pass ([`replay_dir`]), and the leader-side
/// replication tailer keeps polling it as the live segment grows —
/// `poll() == Ok(None)` means "caught up for now", and a later poll picks
/// up newly appended frames or follows a rotation into the next segment.
pub struct WalCursor {
    dir: PathBuf,
    cut: u64,
    /// Sequence number the next valid frame must carry.
    expected: u64,
    seg: Option<SegReader>,
    started: bool,
    last_seq: u64,
    torn: bool,
}

enum Advance {
    Moved,
    End,
}

impl WalCursor {
    /// Cursor over `dir`, positioned to yield `cut + 1` first. Records up
    /// to the cut are still frame-validated while being skipped.
    pub fn new(dir: PathBuf, cut: u64) -> WalCursor {
        WalCursor {
            dir,
            cut,
            expected: cut.saturating_add(1),
            seg: None,
            started: false,
            last_seq: 0,
            torn: false,
        }
    }

    /// Highest valid sequence number seen so far (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.last_seq
    }

    /// Sequence number the next yielded record will carry.
    pub fn next_seq(&self) -> u64 {
        self.expected.max(self.cut.saturating_add(1))
    }

    /// Sticky: true once the cursor has observed a torn/corrupt frame at
    /// some write position (recovery's "torn tail tolerated" flag). A live
    /// tailer may set this transiently on a mid-write read.
    pub fn torn(&self) -> bool {
        self.torn
    }

    /// Next record with `seq > cut`, or `Ok(None)` when the durable log is
    /// exhausted *for now*. Errors are real corruption (sequence gaps,
    /// overlapping segments, WAL holes) — never a torn tail.
    pub fn poll(&mut self) -> Result<Option<(u64, codec::WalOp)>, String> {
        loop {
            if self.seg.is_none() && !self.open_first()? {
                return Ok(None);
            }
            let seg = self.seg.as_mut().expect("segment open");
            let step = read_step(seg, self.expected)
                .map_err(|e| format!("{}: {e}", seg.path.display()))?;
            match step {
                Step::Record(seq, op) => {
                    self.expected = seq + 1;
                    self.last_seq = seq;
                    if seq > self.cut {
                        return Ok(Some((seq, op)));
                    }
                }
                Step::Poison(e) => {
                    let seg = self.seg.as_ref().expect("segment open");
                    return Err(format!("{}: {e}", seg.path.display()));
                }
                Step::NeedMore | Step::Bad => {
                    let seg = self.seg.as_ref().expect("segment open");
                    let trailing = matches!(step, Step::Bad)
                        || seg.unparsed() > 0
                        || !seg.magic_ok;
                    match self.advance()? {
                        Advance::Moved => {
                            // The writer abandoned that tail (torn record,
                            // or a restart continued in a fresh segment).
                            self.torn |= trailing;
                        }
                        Advance::End => {
                            self.torn |= trailing;
                            return Ok(None);
                        }
                    }
                }
            }
        }
    }

    /// Open the starting segment: the newest one whose first seq is `<=
    /// cut + 1` (everything before it is fully covered by the cut). False
    /// while the directory has no segments at all.
    fn open_first(&mut self) -> Result<bool, String> {
        debug_assert!(!self.started || self.seg.is_some());
        let segs =
            scan_segments(&self.dir).map_err(|e| format!("{}: {e}", self.dir.display()))?;
        let Some(first) = segs.first() else {
            return Ok(false);
        };
        // The oldest surviving segment must reach back to the cut, or
        // batches in (cut, first_seq) are unrecoverable — seen when
        // truncation outran the snapshot (or the follower) being caught
        // up. Fail loudly rather than silently skipping acked batches.
        if first.first_seq > self.cut.saturating_add(1) {
            return Err(format!(
                "wal hole in {}: recovering from cut {} but the oldest segment starts at {}",
                self.dir.display(),
                self.cut,
                first.first_seq
            ));
        }
        let start = segs
            .iter()
            .rev()
            .find(|s| s.first_seq <= self.cut.saturating_add(1))
            .expect("checked first above");
        self.expected = start.first_seq;
        self.seg =
            Some(SegReader::open(start).map_err(|e| format!("{}: {e}", start.path.display()))?);
        self.started = true;
        Ok(true)
    }

    /// The current segment is exhausted (cleanly or torn): move to the
    /// successor iff it starts at exactly `expected`; report corruption on
    /// any other successor; otherwise this is the end of the log for now.
    fn advance(&mut self) -> Result<Advance, String> {
        let cur_first = self.seg.as_ref().expect("segment open").first_seq;
        let segs =
            scan_segments(&self.dir).map_err(|e| format!("{}: {e}", self.dir.display()))?;
        let Some(succ) = segs.iter().find(|s| s.first_seq > cur_first) else {
            return Ok(Advance::End);
        };
        if succ.first_seq > self.expected {
            return Err(format!(
                "wal gap in {}: expected seq {}, next segment starts at {}",
                self.dir.display(),
                self.expected,
                succ.first_seq
            ));
        }
        if succ.first_seq < self.expected {
            return Err(format!(
                "overlapping wal segments in {}: seq {} after {}",
                self.dir.display(),
                succ.first_seq,
                self.expected - 1
            ));
        }
        self.seg =
            Some(SegReader::open(succ).map_err(|e| format!("{}: {e}", succ.path.display()))?);
        Ok(Advance::Moved)
    }
}

/// Parse one frame at the reader's position; never consumes bytes unless a
/// complete valid record is decoded, so every failure is retryable.
fn read_step(seg: &mut SegReader, expected: u64) -> io::Result<Step> {
    if !seg.magic_ok {
        if !seg.ensure(SEGMENT_MAGIC.len())? {
            return Ok(Step::NeedMore);
        }
        let magic = &seg.buf[seg.pos..seg.pos + SEGMENT_MAGIC.len()];
        if magic != SEGMENT_MAGIC {
            // A complete wrong magic is never a torn tail (the magic is
            // the first write to a fresh segment): it is an old-format
            // segment or a foreign file. Tolerating it as torn would
            // silently skip the whole segment's durable history.
            return Ok(Step::Poison(format!(
                "bad segment magic {:?} (expected {:?} — old WAL format? \
                 recover with the writing version, checkpoint, then upgrade)",
                String::from_utf8_lossy(magic),
                String::from_utf8_lossy(SEGMENT_MAGIC),
            )));
        }
        seg.consume(SEGMENT_MAGIC.len());
        seg.magic_ok = true;
    }
    if !seg.ensure(FRAME_HEADER)? {
        return Ok(Step::NeedMore);
    }
    let len =
        u32::from_le_bytes(seg.buf[seg.pos..seg.pos + 4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(seg.buf[seg.pos + 4..seg.pos + 8].try_into().unwrap());
    if !seg.ensure(FRAME_HEADER + len)? {
        return Ok(Step::NeedMore);
    }
    let payload = &seg.buf[seg.pos + FRAME_HEADER..seg.pos + FRAME_HEADER + len];
    if codec::crc32(payload) != crc {
        return Ok(Step::Bad);
    }
    let (seq, op) = match codec::decode_record(payload) {
        Ok(r) => r,
        Err(e) => {
            return Ok(Step::Poison(format!(
                "record seq {expected} is CRC-valid but undecodable ({e}); \
                 refusing to skip durable history (wrong binary version?)"
            )))
        }
    };
    if seq != expected {
        return Ok(Step::Bad);
    }
    seg.consume(FRAME_HEADER + len);
    Ok(Step::Record(seq, op))
}
