//! Storage-I/O indirection for the durability plane (DESIGN.md §8).
//!
//! Every *write-side* filesystem operation the WAL and checkpointer
//! perform — segment creation, appends, fsyncs, the atomic tmp→rename
//! commit, truncation deletes — goes through the [`StorageIo`] trait
//! instead of `std::fs` directly. Production uses the zero-cost
//! passthrough [`StdIo`]; tests and the hidden `--fault-plan` CLI flag
//! swap in [`FaultyIo`], which injects deterministic, schedulable faults
//! (fail the Nth fsync, `ENOSPC` after K bytes, a torn rename, added
//! latency) so every durability code path is exercisable without a real
//! failing disk.
//!
//! Read-side replay (`SegReader`, `scan_segments`) deliberately stays on
//! `std::fs`: recovery correctness under *write* faults is the property
//! under test, and a reader that lies is indistinguishable from
//! corruption the CRC framing already covers.
//!
//! Fault plans are strings so they travel through config files, the CLI,
//! and test constructors alike:
//!
//! ```text
//! seed=42;fail_fsync_every=3;enospc_after=65536;enospc_window_ms=500
//! ```
//!
//! Faults are deterministic functions of the plan and the operation
//! count — two runs with the same plan and the same I/O schedule inject
//! identically, which is what makes the differential fault sweeps in
//! `rust/tests/fault_injection.rs` reproducible.

use std::fmt;
use std::fs::{self, File, OpenOptions};
use std::io::{self, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::sync::shim::{AtomicU64, Ordering};

/// One writable file produced by [`StorageIo::create`] (a WAL segment or
/// a checkpoint tmp file). Only the two operations the writers need.
pub trait IoFile: Send {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()>;
    fn sync_data(&mut self) -> io::Result<()>;
}

impl IoFile for File {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        io::Write::write_all(self, buf)
    }

    fn sync_data(&mut self) -> io::Result<()> {
        File::sync_data(self)
    }
}

/// The write-side filesystem surface of the durability plane.
pub trait StorageIo: Send + Sync {
    /// Create (truncating) a file for writing.
    fn create(&self, path: &Path) -> io::Result<Box<dyn IoFile>>;
    /// Read a whole file (checkpoint snapshots/deltas at recovery).
    fn read(&self, path: &Path) -> io::Result<Vec<u8>>;
    /// Atomic replace (the checkpoint commit point).
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Delete (WAL truncation, checkpoint retention).
    fn remove_file(&self, path: &Path) -> io::Result<()>;
    /// Best-effort directory fsync (makes renames/creates durable).
    fn sync_dir(&self, dir: &Path);
}

/// Production passthrough: `std::fs`, nothing else.
#[derive(Debug, Default, Clone, Copy)]
pub struct StdIo;

impl StorageIo for StdIo {
    fn create(&self, path: &Path) -> io::Result<Box<dyn IoFile>> {
        let file = OpenOptions::new().write(true).create(true).truncate(true).open(path)?;
        Ok(Box::new(file))
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        fs::read(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        fs::remove_file(path)
    }

    fn sync_dir(&self, dir: &Path) {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

/// A parsed fault schedule. Every knob is off at its zero value, so the
/// empty plan is the null schedule.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Jitter/derivation seed (reserved for probabilistic schedules; kept
    /// in the grammar so plans are forward-compatible and reproducible).
    pub seed: u64,
    /// Fail exactly the Nth fsync (1-based) with `EIO`.
    pub fail_fsync_at: u64,
    /// Fail every Nth fsync with `EIO`.
    pub fail_fsync_every: u64,
    /// Start failing writes with `ENOSPC` once this many bytes have been
    /// written through the handle.
    pub enospc_after: u64,
    /// The `ENOSPC` condition clears this long after it first fires
    /// (0 = the disk never recovers). This is what lets the chaos smoke
    /// drive the engine degraded *and back*.
    pub enospc_window_ms: u64,
    /// Truncate the source file to half its length immediately before the
    /// Nth rename (1-based): a torn checkpoint commit. The rename itself
    /// still succeeds — the tear is in the data, exactly what a crashed
    /// sync-before-rename leaves behind.
    pub torn_rename_at: u64,
    /// Injected latency per I/O operation.
    pub delay_us: u64,
}

impl FaultPlan {
    /// Parse `key=value;key=value` (empty string = null plan). Unknown
    /// keys are rejected — a typo'd fault plan that silently injects
    /// nothing would green-light an untested code path.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault plan: {part:?} is not key=value"))?;
            let value: u64 = value
                .trim()
                .parse()
                .map_err(|e| format!("fault plan: {key}={value:?}: {e}"))?;
            match key.trim() {
                "seed" => plan.seed = value,
                "fail_fsync_at" => plan.fail_fsync_at = value,
                "fail_fsync_every" => plan.fail_fsync_every = value,
                "enospc_after" => plan.enospc_after = value,
                "enospc_window_ms" => plan.enospc_window_ms = value,
                "torn_rename_at" => plan.torn_rename_at = value,
                "delay_us" => plan.delay_us = value,
                other => return Err(format!("fault plan: unknown key {other:?}")),
            }
        }
        Ok(plan)
    }

    pub fn is_null(&self) -> bool {
        *self == FaultPlan::default()
    }
}

fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared mutable schedule state: operation counters and the ENOSPC
/// window clock. Files hold an `Arc` back to it so faults fire across
/// every file the handle ever created.
#[derive(Debug)]
struct FaultState {
    plan: FaultPlan,
    fsyncs: AtomicU64,
    renames: AtomicU64,
    written: AtomicU64,
    injected: AtomicU64,
    /// Set when ENOSPC first fires; the condition clears
    /// `enospc_window_ms` later (see [`FaultPlan::enospc_window_ms`]).
    enospc_since: Mutex<Option<Instant>>,
}

impl FaultState {
    fn delay(&self) {
        if self.plan.delay_us > 0 {
            std::thread::sleep(Duration::from_micros(self.plan.delay_us));
        }
    }

    /// Is the simulated disk out of space right now? Counts `len` toward
    /// the budget on success.
    fn check_space(&self, len: u64) -> io::Result<()> {
        if self.plan.enospc_after == 0 {
            self.written.fetch_add(len, Ordering::Relaxed);
            return Ok(());
        }
        let before = self.written.fetch_add(len, Ordering::Relaxed);
        if before + len <= self.plan.enospc_after {
            return Ok(());
        }
        let mut since = lock_clean(&self.enospc_since);
        let started = *since.get_or_insert_with(Instant::now);
        if self.plan.enospc_window_ms > 0
            && started.elapsed() >= Duration::from_millis(self.plan.enospc_window_ms)
        {
            // The window elapsed: space was "freed", the fault is over.
            return Ok(());
        }
        self.injected.fetch_add(1, Ordering::Relaxed);
        Err(io::Error::new(
            io::ErrorKind::StorageFull,
            format!("injected ENOSPC (after {} bytes)", self.plan.enospc_after),
        ))
    }

    fn check_fsync(&self) -> io::Result<()> {
        let n = self.fsyncs.fetch_add(1, Ordering::Relaxed) + 1;
        let hit = self.plan.fail_fsync_at == n
            || (self.plan.fail_fsync_every > 0 && n % self.plan.fail_fsync_every == 0);
        if hit {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return Err(io::Error::other(format!("injected EIO on fsync #{n}")));
        }
        Ok(())
    }
}

/// [`StorageIo`] impl driven by a [`FaultPlan`]. Cheap to clone (shared
/// state); tests keep a clone to read the counters after the run.
#[derive(Debug, Clone)]
pub struct FaultyIo {
    state: Arc<FaultState>,
}

impl FaultyIo {
    pub fn new(plan: FaultPlan) -> FaultyIo {
        FaultyIo {
            state: Arc::new(FaultState {
                plan,
                fsyncs: AtomicU64::new(0),
                renames: AtomicU64::new(0),
                written: AtomicU64::new(0),
                injected: AtomicU64::new(0),
                enospc_since: Mutex::new(None),
            }),
        }
    }

    /// Total faults injected so far (any kind).
    pub fn injected(&self) -> u64 {
        self.state.injected.load(Ordering::Relaxed)
    }

    /// fsyncs attempted through this handle.
    pub fn fsyncs(&self) -> u64 {
        self.state.fsyncs.load(Ordering::Relaxed)
    }

    /// Bytes the writers attempted to write through this handle.
    pub fn written(&self) -> u64 {
        self.state.written.load(Ordering::Relaxed)
    }
}

struct FaultyFile {
    file: File,
    state: Arc<FaultState>,
}

impl IoFile for FaultyFile {
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        self.state.delay();
        self.state.check_space(buf.len() as u64)?;
        io::Write::write_all(&mut self.file, buf)
    }

    fn sync_data(&mut self) -> io::Result<()> {
        self.state.delay();
        self.state.check_fsync()?;
        self.file.sync_data()
    }
}

impl StorageIo for FaultyIo {
    fn create(&self, path: &Path) -> io::Result<Box<dyn IoFile>> {
        self.state.delay();
        let file = OpenOptions::new().write(true).create(true).truncate(true).open(path)?;
        Ok(Box::new(FaultyFile { file, state: Arc::clone(&self.state) }))
    }

    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        self.state.delay();
        fs::read(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.state.delay();
        let n = self.state.renames.fetch_add(1, Ordering::Relaxed) + 1;
        if self.state.plan.torn_rename_at == n {
            // Tear the payload, not the rename: halve the source so the
            // committed file is CRC-broken, the way a crash between
            // write-back and rename durability manifests after restart.
            self.state.injected.fetch_add(1, Ordering::Relaxed);
            let len = fs::metadata(from)?.len();
            let f = OpenOptions::new().write(true).open(from)?;
            f.set_len(len / 2)?;
            let _ = f.sync_data();
        }
        fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        self.state.delay();
        fs::remove_file(path)
    }

    fn sync_dir(&self, dir: &Path) {
        self.state.delay();
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

/// Shared, cloneable handle the durability plane threads everywhere a
/// `std::fs` write used to be. Derefs to the trait object.
#[derive(Clone)]
pub struct IoHandle(Arc<dyn StorageIo>);

impl IoHandle {
    /// The production passthrough.
    pub fn std() -> IoHandle {
        IoHandle(Arc::new(StdIo))
    }

    pub fn new(io: Arc<dyn StorageIo>) -> IoHandle {
        IoHandle(io)
    }

    pub fn faulty(plan: FaultPlan) -> (IoHandle, FaultyIo) {
        let io = FaultyIo::new(plan);
        (IoHandle(Arc::new(io.clone())), io)
    }

    /// Build from a plan string (`""` = passthrough) — the `[persist]
    /// fault_plan` / `--fault-plan` entry point.
    pub fn from_plan(plan: &str) -> Result<IoHandle, String> {
        let parsed = FaultPlan::parse(plan)?;
        if parsed.is_null() {
            Ok(IoHandle::std())
        } else {
            Ok(IoHandle(Arc::new(FaultyIo::new(parsed))))
        }
    }
}

impl std::ops::Deref for IoHandle {
    type Target = dyn StorageIo;

    fn deref(&self) -> &(dyn StorageIo + 'static) {
        &*self.0
    }
}

impl fmt::Debug for IoHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("IoHandle(..)")
    }
}

impl Default for IoHandle {
    fn default() -> IoHandle {
        IoHandle::std()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    #[test]
    fn plan_parse_roundtrip() {
        let p = FaultPlan::parse(
            "seed=7;fail_fsync_at=2;fail_fsync_every=5;enospc_after=1024;\
             enospc_window_ms=250;torn_rename_at=1;delay_us=3",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.fail_fsync_at, 2);
        assert_eq!(p.fail_fsync_every, 5);
        assert_eq!(p.enospc_after, 1024);
        assert_eq!(p.enospc_window_ms, 250);
        assert_eq!(p.torn_rename_at, 1);
        assert_eq!(p.delay_us, 3);
        assert!(!p.is_null());
        assert!(FaultPlan::parse("").unwrap().is_null());
        assert!(FaultPlan::parse("  ; ;").unwrap().is_null());
    }

    #[test]
    fn plan_parse_rejects_garbage() {
        assert!(FaultPlan::parse("bogus_key=1").is_err());
        assert!(FaultPlan::parse("fail_fsync_at").is_err());
        assert!(FaultPlan::parse("enospc_after=lots").is_err());
    }

    #[test]
    fn nth_fsync_fails() {
        let dir = TempDir::new("io-fsync");
        let (io, probe) = IoHandle::faulty(FaultPlan {
            fail_fsync_at: 2,
            ..FaultPlan::default()
        });
        let mut f = io.create(&dir.path().join("a")).unwrap();
        f.write_all(b"x").unwrap();
        assert!(f.sync_data().is_ok());
        assert!(f.sync_data().is_err(), "second fsync must fail");
        assert!(f.sync_data().is_ok(), "third fsync succeeds again");
        assert_eq!(probe.injected(), 1);
        assert_eq!(probe.fsyncs(), 3);
    }

    #[test]
    fn every_nth_fsync_fails() {
        let dir = TempDir::new("io-fsync-every");
        let (io, probe) = IoHandle::faulty(FaultPlan {
            fail_fsync_every: 2,
            ..FaultPlan::default()
        });
        let mut f = io.create(&dir.path().join("a")).unwrap();
        let results: Vec<bool> = (0..6).map(|_| f.sync_data().is_ok()).collect();
        assert_eq!(results, vec![true, false, true, false, true, false]);
        assert_eq!(probe.injected(), 3);
    }

    #[test]
    fn enospc_after_budget_then_window_clears() {
        let dir = TempDir::new("io-enospc");
        let (io, probe) = IoHandle::faulty(FaultPlan {
            enospc_after: 8,
            enospc_window_ms: 50,
            ..FaultPlan::default()
        });
        let mut f = io.create(&dir.path().join("a")).unwrap();
        assert!(f.write_all(b"12345678").is_ok(), "within budget");
        let err = f.write_all(b"x").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::StorageFull);
        std::thread::sleep(Duration::from_millis(60));
        assert!(f.write_all(b"x").is_ok(), "window elapsed, space freed");
        assert_eq!(probe.injected(), 1);
    }

    #[test]
    fn permanent_enospc_without_window() {
        let dir = TempDir::new("io-enospc-perm");
        let (io, _probe) = IoHandle::faulty(FaultPlan {
            enospc_after: 1,
            ..FaultPlan::default()
        });
        let mut f = io.create(&dir.path().join("a")).unwrap();
        assert!(f.write_all(b"ab").is_err());
        std::thread::sleep(Duration::from_millis(20));
        assert!(f.write_all(b"c").is_err(), "no window: never recovers");
    }

    #[test]
    fn torn_rename_halves_source() {
        let dir = TempDir::new("io-torn");
        let (io, probe) = IoHandle::faulty(FaultPlan {
            torn_rename_at: 1,
            ..FaultPlan::default()
        });
        let src = dir.path().join("tmp");
        let dst = dir.path().join("final");
        let mut f = io.create(&src).unwrap();
        f.write_all(&[7u8; 100]).unwrap();
        drop(f);
        io.rename(&src, &dst).unwrap();
        assert_eq!(fs::metadata(&dst).unwrap().len(), 50, "torn to half");
        assert_eq!(probe.injected(), 1);
        // Later renames are clean.
        let src2 = dir.path().join("tmp2");
        let dst2 = dir.path().join("final2");
        let mut f = io.create(&src2).unwrap();
        f.write_all(&[7u8; 100]).unwrap();
        drop(f);
        io.rename(&src2, &dst2).unwrap();
        assert_eq!(fs::metadata(&dst2).unwrap().len(), 100);
    }

    #[test]
    fn from_plan_null_is_std() {
        assert!(IoHandle::from_plan("").is_ok());
        assert!(IoHandle::from_plan("enospc_after=1").is_ok());
        assert!(IoHandle::from_plan("nope=1").is_err());
    }
}
