//! Durability subsystem: segmented write-ahead logs, atomic checkpoints,
//! and crash recovery for the online model (DESIGN.md §4).
//!
//! The paper's whole point is *online and continuous* learning; without
//! this layer the learned chain lives only in RAM and a restart discards
//! it. The subsystem has four parts:
//!
//! * [`codec`] — compact varint+CRC32 binary encoding shared by the
//!   checkpoint snapshot and the WAL record payload.
//! * [`wal`] — per-shard segmented append-only logs, written by the
//!   existing shard-affine ingest workers (one writer per shard, batch
//!   framed, fsync policy knob, size-bounded rotation).
//! * [`checkpoint`] — pauses ingest at a batch boundary, encodes
//!   `Engine::export()` to `tmp` + `rename`, commits a manifest recording
//!   the per-shard WAL cut points, then truncates sealed segments.
//! * [`recover`] — startup path: newest valid checkpoint via
//!   `Engine::import_snapshot`, then WAL tail replay through
//!   `observe_batch_direct`, tolerating a torn final record.
//!
//! Guarantee (relaxed, MultiQueues-style): a batch is *acked durable* once
//! its WAL record is written (WAL-append happens before the batch is
//! applied); recovery restores exactly the acked prefix per shard — no
//! acked batch is lost, no batch is applied twice (cut points are exact
//! batch boundaries). `fsync = batch | always` extends the guarantee to
//! power loss; `never` covers process crashes only (the page cache
//! survives SIGKILL). Decay/repair maintenance is logged too (DESIGN.md
//! §6): `Engine::decay` appends a `DecayRecord` per shard under the same
//! ingest gate as batches, so recovery replays maintenance in exactly its
//! sequence position instead of restoring conservatively-larger pre-decay
//! counts, and followers decay in lockstep with the leader.
//!
//! Checkpoints are *incremental*: a generation is either a full snapshot
//! (`ckpt-<gen>.snap`) or a differential (`ckpt-<gen>.delta`) holding only
//! the nodes dirtied since the previous generation; the manifest chains
//! base → delta → delta and recovery folds the chain. Compaction back to
//! a full snapshot triggers on `[persist] delta_chain_max` /
//! `delta_dirty_ratio`, so steady-state checkpoint cost scales with the
//! write working set, not the model size.

mod checkpoint;
pub mod codec;
pub mod io;
mod recover;
pub mod wal;

pub use checkpoint::{install_snapshot, run_checkpoint, CheckpointScheduler, CheckpointSummary};
pub use io::{FaultPlan, FaultyIo, IoHandle, StdIo, StorageIo};
pub use recover::{open_engine, RecoveryReport};

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::metrics::Counter;
use crate::sync::shim::{AtomicU64, Ordering};

use wal::ShardWal;

/// When the WAL fsyncs (`[persist] fsync` / `--fsync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Never fsync: the OS page cache decides. Survives SIGKILL, not
    /// power loss.
    Never,
    /// Group commit: at most one fsync per `fsync_interval` of appends
    /// (plus every segment seal). The steady-state durability knob.
    Batch,
    /// fsync after every appended batch record.
    Always,
}

impl FsyncPolicy {
    pub fn parse(s: &str) -> Result<FsyncPolicy, String> {
        match s {
            "never" => Ok(FsyncPolicy::Never),
            "batch" => Ok(FsyncPolicy::Batch),
            "always" => Ok(FsyncPolicy::Always),
            other => Err(format!("bad fsync policy {other:?} (never|batch|always)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            FsyncPolicy::Never => "never",
            FsyncPolicy::Batch => "batch",
            FsyncPolicy::Always => "always",
        }
    }
}

/// Resolved durability configuration (`ServerConfig::persist_config`).
#[derive(Debug, Clone)]
pub struct PersistConfig {
    pub data_dir: PathBuf,
    pub fsync: FsyncPolicy,
    /// Group-commit window for [`FsyncPolicy::Batch`].
    pub fsync_interval: Duration,
    /// WAL segment rotation bound.
    pub segment_bytes: u64,
    /// Periodic checkpoint cadence (None = only explicit `SAVE`s).
    pub checkpoint_interval: Option<Duration>,
    /// Checkpoint early once live WAL bytes exceed this.
    pub checkpoint_wal_bytes: u64,
    /// Max differential generations on a checkpoint chain before the next
    /// checkpoint compacts to a full snapshot (0 = always full).
    pub delta_chain_max: usize,
    /// Compact to a full snapshot when at least this fraction of nodes is
    /// dirty — past that, a delta would approach full-snapshot size while
    /// still lengthening the recovery fold.
    pub delta_dirty_ratio: f64,
    /// Storage-I/O surface every durability write goes through: the
    /// production passthrough, or a fault-plan-driven [`FaultyIo`]
    /// (`[persist] fault_plan` / the hidden `--fault-plan` flag).
    pub io: IoHandle,
}

impl PersistConfig {
    pub fn wal_root(&self) -> PathBuf {
        self.data_dir.join("wal")
    }

    pub fn epoch_dir(&self, epoch: u64) -> PathBuf {
        self.wal_root().join(format!("e{epoch}"))
    }

    pub fn shard_dir(&self, epoch: u64, shard: usize) -> PathBuf {
        self.epoch_dir(epoch).join(format!("shard-{shard:04}"))
    }

    pub fn checkpoint_dir(&self) -> PathBuf {
        self.data_dir.join("checkpoint")
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.checkpoint_dir().join("MANIFEST")
    }

    /// Sidecar recording the checkpoint mark (dirty-epoch floor) committed
    /// with the newest generation, so a restarted engine can resume
    /// *differential* checkpoints instead of forcing a full base (the mark
    /// itself lives only in memory). Best-effort: a missing or stale
    /// sidecar merely makes the next checkpoint conservative (full, or a
    /// superset delta) — never incorrect.
    pub fn ckpt_mark_path(&self) -> PathBuf {
        self.checkpoint_dir().join("CKPT_MARK")
    }
}

/// Non-poisoning lock: an ingest worker that panicked mid-batch must not
/// wedge checkpoints (and vice versa) — the WAL structures stay valid
/// because every append is a single buffered frame write.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Where the committed checkpoint chain stands: the newest full snapshot
/// and the differential generations committed on top of it (DESIGN.md §6).
/// Mutated only under the checkpoint serial lock; the mutex exists for
/// the STATS reader.
#[derive(Debug, Clone, Default)]
pub struct DeltaChain {
    /// Generation of the newest full snapshot (0 = none committed yet).
    pub base: u64,
    /// Differential generations on top of it (`base+1 ..= base+len`).
    pub len: usize,
    /// Dirty-mark floor of the next differential: a node stamped at or
    /// above this mark changed since the last committed generation.
    pub floor: u64,
}

/// How one log attempt on the write path ended (DESIGN.md §8). The caller
/// applies the op to memory only for the first two outcomes.
#[derive(Debug)]
pub enum LogOutcome {
    /// Record logged, durable per policy: apply it.
    Logged,
    /// Record logged, but its policy-driven fsync failed — the bytes are
    /// framed in the segment (they replay after SIGKILL) without the
    /// power-loss guarantee. Apply it, then degrade until a sync lands.
    SyncDegraded(String),
    /// The append itself failed: the op is *parked* in the shard's
    /// quarantine, unapplied, and will be re-logged + applied in order by
    /// the heal task. Degrade; do NOT apply now.
    Parked(String),
}

/// Per-shard degraded-write state: once an append fails, the shard's WAL
/// writer is quarantined and acked-at-enqueue ops park here (in order)
/// instead of being applied unlogged. Bounded in practice because the
/// server stops admitting writes the moment the engine degrades — the
/// queue absorbs only the in-flight window from before the fault.
#[derive(Debug, Default)]
struct ShardQuarantine {
    quarantined: bool,
    pending: VecDeque<codec::WalOp>,
}

/// Shared durability state, owned by the `Engine` (one per process).
/// Ingest workers call [`PersistState::log_batch`] on the apply path; the
/// checkpointer reads cut points and truncates through the same per-shard
/// locks (uncontended outside checkpoint windows — one writer per shard).
pub struct PersistState {
    cfg: PersistConfig,
    /// WAL epoch these writers append into. Bumped (by recovery) only when
    /// the shard layout changes, so cut points always index the layout
    /// that wrote them.
    epoch: u64,
    wals: Vec<Mutex<ShardWal>>,
    /// The cut points of the *previous* retained checkpoint generation.
    /// Truncation lags one generation behind commits: segments are deleted
    /// only once covered by BOTH retained snapshots, so falling back to
    /// the previous generation (a torn current snapshot) still finds every
    /// WAL record it needs.
    prev_cuts: Mutex<Vec<u64>>,
    /// Last committed checkpoint generation.
    generation: AtomicU64,
    /// The committed base→delta chain the next checkpoint extends.
    chain: Mutex<DeltaChain>,
    last_checkpoint: Mutex<Instant>,
    /// Serializes concurrent checkpoints (scheduler vs wire `SAVE`).
    ckpt_serial: Mutex<()>,
    /// Per-shard quarantine (degraded-write parking) — see
    /// [`ShardQuarantine`].
    quarantine: Vec<Mutex<ShardQuarantine>>,
    appends: Counter,
    errors: Counter,
    /// Batches replayed from the WAL at startup (recovery report, STATS).
    recovered_batches: u64,
    /// Replication retention pins: per live follower stream, the per-shard
    /// sequence number streamed so far. Checkpoint truncation never
    /// deletes a segment a pinned follower still needs (DESIGN.md §5).
    repl_pins: Mutex<Vec<(u64, Vec<u64>)>>,
    next_pin: AtomicU64,
}

impl PersistState {
    /// Open WAL writers for every shard. `last_seqs[i]` is shard `i`'s
    /// highest on-disk (or checkpointed) sequence number; `prev_cuts` is
    /// the cut vector of the checkpoint generation recovery loaded (what
    /// lag-one truncation must keep the WAL reachable for).
    pub(crate) fn create(
        cfg: PersistConfig,
        epoch: u64,
        generation: u64,
        chain: DeltaChain,
        last_seqs: &[u64],
        prev_cuts: Vec<u64>,
        recovered_batches: u64,
    ) -> std::io::Result<PersistState> {
        std::fs::create_dir_all(cfg.checkpoint_dir())?;
        let mut wals = Vec::with_capacity(last_seqs.len());
        for (shard, &last) in last_seqs.iter().enumerate() {
            wals.push(Mutex::new(ShardWal::open(
                cfg.shard_dir(epoch, shard),
                cfg.io.clone(),
                last,
                cfg.fsync,
                cfg.fsync_interval,
                cfg.segment_bytes,
            )?));
        }
        let quarantine = (0..last_seqs.len())
            .map(|_| Mutex::new(ShardQuarantine::default()))
            .collect();
        Ok(PersistState {
            cfg,
            epoch,
            wals,
            quarantine,
            prev_cuts: Mutex::new(prev_cuts),
            generation: AtomicU64::new(generation),
            chain: Mutex::new(chain),
            last_checkpoint: Mutex::new(Instant::now()),
            ckpt_serial: Mutex::new(()),
            appends: Counter::new(),
            errors: Counter::new(),
            recovered_batches,
            repl_pins: Mutex::new(Vec::new()),
            next_pin: AtomicU64::new(1),
        })
    }

    pub fn config(&self) -> &PersistConfig {
        &self.cfg
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn shard_count(&self) -> usize {
        self.wals.len()
    }

    /// Log one same-shard batch ahead of applying it. Called by the
    /// shard's single ingest worker.
    pub fn append(&self, shard: usize, batch: &[(u64, u64)]) -> std::io::Result<u64> {
        let seq = lock_clean(&self.wals[shard]).append(batch)?;
        self.appends.inc();
        Ok(seq)
    }

    /// Log one record of any kind (maintenance records and the follower's
    /// replicated-op path).
    pub fn append_op(&self, shard: usize, op: &codec::WalOp) -> std::io::Result<u64> {
        let seq = lock_clean(&self.wals[shard]).append_op(op)?;
        self.appends.inc();
        Ok(seq)
    }

    /// The ingest worker's degradation-aware log step (DESIGN.md §8): try
    /// to log `batch`; on an append failure quarantine the shard and park
    /// the op (unapplied) instead of applying it unlogged — applying an
    /// unlogged batch is exactly the recovery-divergence the fault sweeps
    /// catch. The caller applies the batch only for non-`Parked` outcomes.
    pub fn log_batch(&self, shard: usize, batch: &[(u64, u64)]) -> LogOutcome {
        {
            let mut q = lock_clean(&self.quarantine[shard]);
            if q.quarantined {
                q.pending.push_back(codec::WalOp::Batch(batch.to_vec()));
                return LogOutcome::Parked(format!("shard {shard} wal quarantined"));
            }
        }
        match self.append(shard, batch) {
            Ok(_) => match self.take_sync_error(shard) {
                None => LogOutcome::Logged,
                Some(e) => {
                    self.errors.inc();
                    LogOutcome::SyncDegraded(format!("shard {shard} fsync failed: {e}"))
                }
            },
            Err(e) => {
                self.errors.inc();
                let mut q = lock_clean(&self.quarantine[shard]);
                q.quarantined = true;
                q.pending.push_back(codec::WalOp::Batch(batch.to_vec()));
                LogOutcome::Parked(format!("shard {shard} wal append failed: {e}"))
            }
        }
    }

    /// The maintenance log step: decay/repair records are *dropped* (not
    /// parked) when they cannot be logged — skipping a periodic pass
    /// keeps memory and WAL consistent, while applying it unlogged would
    /// diverge recovery. Quarantines the shard on failure so batch
    /// traffic parks instead of re-probing a broken disk.
    pub fn log_maintenance(&self, shard: usize, op: &codec::WalOp) -> LogOutcome {
        if lock_clean(&self.quarantine[shard]).quarantined {
            return LogOutcome::Parked(format!("shard {shard} wal quarantined"));
        }
        match self.append_op(shard, op) {
            Ok(_) => match self.take_sync_error(shard) {
                None => LogOutcome::Logged,
                Some(e) => {
                    self.errors.inc();
                    LogOutcome::SyncDegraded(format!("shard {shard} fsync failed: {e}"))
                }
            },
            Err(e) => {
                self.errors.inc();
                lock_clean(&self.quarantine[shard]).quarantined = true;
                LogOutcome::Parked(format!("shard {shard} wal append failed: {e}"))
            }
        }
    }

    /// Heal step for one shard: re-log every parked op in arrival order
    /// (the abandoned segment left their sequence numbers unconsumed, so
    /// re-appending stays contiguous — the crash-safe seq re-arming), and
    /// hand each successfully logged op to `apply`. Stops at the first
    /// failure, leaving the rest parked and the shard quarantined.
    /// Returns the ops drained.
    pub fn drain_quarantine(
        &self,
        shard: usize,
        mut apply: impl FnMut(&codec::WalOp),
    ) -> std::io::Result<usize> {
        let mut q = lock_clean(&self.quarantine[shard]);
        let mut drained = 0usize;
        while let Some(op) = q.pending.front() {
            self.append_op(shard, op)?;
            apply(op);
            q.pending.pop_front();
            drained += 1;
        }
        q.quarantined = false;
        Ok(drained)
    }

    /// Take the shard's deferred fsync error, if its newest policy-driven
    /// sync failed after the record was framed.
    pub fn take_sync_error(&self, shard: usize) -> Option<std::io::Error> {
        lock_clean(&self.wals[shard]).take_sync_error()
    }

    /// Force an fsync of one shard's open segment (the heal task's probe
    /// that a sync-degraded disk is writable again).
    pub fn sync_shard(&self, shard: usize) -> std::io::Result<()> {
        lock_clean(&self.wals[shard]).sync()
    }

    /// True if any shard currently holds a quarantined WAL writer.
    pub fn any_quarantined(&self) -> bool {
        self.quarantine.iter().any(|q| lock_clean(q).quarantined)
    }

    /// Updates (pairs) *currently* parked in quarantines — the engine's
    /// quiesce accounting: an enqueued update is settled once applied,
    /// rejected, or parked. Live (not cumulative) on purpose: the heal
    /// drain moves each parked update into `applied` *before* unparking
    /// it, so the settled sum never dips and never double-counts.
    pub fn parked_updates(&self) -> u64 {
        self.quarantine
            .iter()
            .map(|q| {
                lock_clean(q)
                    .pending
                    .iter()
                    .map(|op| match op {
                        codec::WalOp::Batch(b) => b.len() as u64,
                        _ => 0,
                    })
                    .sum::<u64>()
            })
            .sum()
    }

    pub(crate) fn wal(&self, shard: usize) -> MutexGuard<'_, ShardWal> {
        lock_clean(&self.wals[shard])
    }

    /// Per-shard highest sequence number handed out so far (the WAL heads
    /// replication streams toward, and the `last_seqs=` STATS gauge).
    pub fn last_seqs(&self) -> Vec<u64> {
        self.wals.iter().map(|w| lock_clean(w).last_seq()).collect()
    }

    /// Register a follower stream positioned at `seqs` (per shard, records
    /// `<= seqs[i]` already streamed). Returns the pin id.
    pub fn pin_create(&self, seqs: Vec<u64>) -> u64 {
        let id = self.next_pin.fetch_add(1, Ordering::Relaxed);
        lock_clean(&self.repl_pins).push((id, seqs));
        id
    }

    /// Advance one shard of a pin as records are streamed.
    pub fn pin_advance(&self, id: u64, shard: usize, seq: u64) {
        let mut pins = lock_clean(&self.repl_pins);
        if let Some((_, seqs)) = pins.iter_mut().find(|(p, _)| *p == id) {
            if let Some(s) = seqs.get_mut(shard) {
                *s = (*s).max(seq);
            }
        }
    }

    /// Drop a pin (follower disconnected). A disconnected follower's WAL
    /// position is no longer protected; if truncation passes it before the
    /// reconnect, the next handshake falls back to a snapshot bootstrap.
    pub fn pin_drop(&self, id: u64) {
        lock_clean(&self.repl_pins).retain(|(p, _)| *p != id);
    }

    /// Lowest pinned sequence for `shard` across live follower streams
    /// (None = no followers; truncation is unconstrained).
    pub fn pin_floor(&self, shard: usize) -> Option<u64> {
        lock_clean(&self.repl_pins)
            .iter()
            .map(|(_, seqs)| seqs.get(shard).copied().unwrap_or(0))
            .min()
    }

    /// Number of live follower streams (the `repl_followers=` gauge).
    pub fn pin_count(&self) -> usize {
        lock_clean(&self.repl_pins).len()
    }

    /// Live WAL bytes across all shards (appends minus truncations).
    pub fn wal_bytes(&self) -> u64 {
        self.wals.iter().map(|w| lock_clean(w).live_bytes()).sum()
    }

    pub fn wal_appends(&self) -> u64 {
        self.appends.get()
    }

    pub fn wal_errors(&self) -> u64 {
        self.errors.get()
    }

    /// Successful fsyncs across all shard logs.
    pub fn wal_fsyncs(&self) -> u64 {
        self.wals.iter().map(|w| lock_clean(w).fsync_count()).sum()
    }

    /// Register the durability families with the engine's telemetry
    /// registry (DESIGN.md §9) — sampled closures over this state, called
    /// once from `Engine::attach_persist`. The closures hold a strong
    /// `Arc<PersistState>`: the registry and the persist state share the
    /// engine's lifetime and neither points back at it, so no cycle.
    pub fn register_metrics(self: &Arc<PersistState>, reg: &crate::metrics::Registry) {
        let fam: [(&str, &str, Box<dyn Fn(&PersistState) -> u64 + Send + Sync>); 7] = [
            ("mcprioq_wal_bytes", "Live WAL bytes on disk.", Box::new(|p| p.wal_bytes())),
            (
                "mcprioq_wal_appends_total",
                "WAL records appended.",
                Box::new(|p| p.wal_appends()),
            ),
            (
                "mcprioq_wal_errors_total",
                "Failed WAL appends or fsyncs.",
                Box::new(|p| p.wal_errors()),
            ),
            ("mcprioq_wal_fsyncs_total", "Successful WAL fsyncs.", Box::new(|p| p.wal_fsyncs())),
            (
                "mcprioq_checkpoint_generation",
                "Last committed checkpoint generation.",
                Box::new(|p| p.generation()),
            ),
            (
                "mcprioq_delta_chain_len",
                "Differential checkpoints on the committed chain.",
                Box::new(|p| p.delta_chain().len as u64),
            ),
            (
                "mcprioq_recovered_batches_total",
                "Batches replayed from the WAL at startup.",
                Box::new(|p| p.recovered_batches()),
            ),
        ];
        for (name, help, f) in fam {
            let p = Arc::clone(self);
            // Counters and point-in-time values share the u64 shape; the
            // monotonic ones register as counters below by name suffix.
            if name.ends_with("_total") {
                reg.counter_fn(name, help, &[], move || f(&p));
            } else {
                reg.gauge_fn(name, help, &[], move || f(&p) as f64);
            }
        }
        let p = Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_checkpoint_age_seconds",
            "Seconds since the last committed checkpoint.",
            &[],
            move || p.checkpoint_age().as_secs_f64(),
        );
        let p = Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_repl_followers",
            "Live follower replication streams (retention pins).",
            &[],
            move || p.pin_count() as f64,
        );
        let p = Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_parked_updates",
            "Updates parked in WAL quarantines (degraded writes).",
            &[],
            move || p.parked_updates() as f64,
        );
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// The committed checkpoint chain (cloned; the `ckpt_chain=` gauge and
    /// the checkpointer's decision input).
    pub fn delta_chain(&self) -> DeltaChain {
        lock_clean(&self.chain).clone()
    }

    pub(crate) fn set_delta_chain(&self, chain: DeltaChain) {
        *lock_clean(&self.chain) = chain;
    }

    pub(crate) fn set_generation(&self, generation: u64) {
        self.generation.store(generation, Ordering::Relaxed);
        *lock_clean(&self.last_checkpoint) = Instant::now();
    }

    /// Seconds since the last committed checkpoint (or since startup).
    pub fn checkpoint_age(&self) -> Duration {
        lock_clean(&self.last_checkpoint).elapsed()
    }

    pub fn recovered_batches(&self) -> u64 {
        self.recovered_batches
    }

    pub(crate) fn serialize_checkpoints(&self) -> MutexGuard<'_, ()> {
        lock_clean(&self.ckpt_serial)
    }

    /// Swap in the cuts of the generation just committed, returning the
    /// previous generation's cuts — the bound lag-one truncation uses.
    pub(crate) fn rotate_cuts(&self, new_cuts: Vec<u64>) -> Vec<u64> {
        std::mem::replace(&mut *lock_clean(&self.prev_cuts), new_cuts)
    }
}

/// Remove stray temporary files left by a checkpoint that crashed before
/// its rename (best effort; called from recovery).
pub(crate) fn remove_stale_tmp(dir: &Path) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        if entry.path().extension().is_some_and(|e| e == "tmp") {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

#[cfg(test)]
mod tests;
