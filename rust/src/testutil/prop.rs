//! proptest-lite: generate random cases, run a predicate, and on failure
//! greedily shrink toward a minimal counterexample before reporting.

use super::Rng64;

/// A generator produces a value from entropy and knows how to propose
/// smaller candidates for shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng64) -> Self::Value;
    /// Candidate shrinks, largest-step first. Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Seed is fixed for reproducibility; override per-property when
        // exploring. Case count balances coverage vs suite runtime.
        PropConfig { cases: 256, seed: 0x5EED, max_shrink_steps: 2000 }
    }
}

/// Run `prop` against `cases` random values; panic with a (shrunk) minimal
/// counterexample on failure.
pub fn forall<G: Gen>(config: PropConfig, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng64::new(config.seed);
    for case in 0..config.cases {
        let v = gen.generate(&mut rng);
        if prop(&v) {
            continue;
        }
        // Shrink: repeatedly take the first failing shrink candidate.
        let mut cur = v.clone();
        let mut steps = 0;
        'outer: while steps < config.max_shrink_steps {
            for cand in gen.shrink(&cur) {
                steps += 1;
                if !prop(&cand) {
                    cur = cand;
                    continue 'outer;
                }
                if steps >= config.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (case {case}, seed {:#x}):\n  original: {:?}\n  shrunk:   {:?}",
            config.seed, v, cur
        );
    }
}

/// u64 in [lo, hi] with halving shrink toward lo.
pub struct U64Range {
    pub lo: u64,
    pub hi: u64,
}

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng64) -> u64 {
        rng.next_range(self.lo, self.hi)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of values with length in [0, max_len]; shrinks by halving the vector
/// then shrinking elements.
pub struct VecGen<G> {
    pub elem: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng64) -> Self::Value {
        let len = rng.next_below(self.max_len as u64 + 1) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
            out.push(v[..v.len() - 1].to_vec());
            // Shrink the first shrinkable element.
            for (i, e) in v.iter().enumerate() {
                let shrunk = self.elem.shrink(e);
                if let Some(se) = shrunk.into_iter().next() {
                    let mut w = v.clone();
                    w[i] = se;
                    out.push(w);
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_true_property() {
        forall(PropConfig::default(), &U64Range { lo: 0, hi: 100 }, |&v| v <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_fails_and_shrinks() {
        forall(PropConfig::default(), &U64Range { lo: 0, hi: 1000 }, |&v| v < 500);
    }

    #[test]
    fn shrink_reaches_minimal_counterexample() {
        // Catch the panic and verify the shrunk value is minimal (500).
        let res = std::panic::catch_unwind(|| {
            forall(PropConfig::default(), &U64Range { lo: 0, hi: 1000 }, |&v| v < 500);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk:   500"), "unexpected shrink: {msg}");
    }

    #[test]
    fn vec_gen_respects_max_len() {
        let g = VecGen { elem: U64Range { lo: 0, hi: 9 }, max_len: 16 };
        let mut rng = Rng64::new(1);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!(v.len() <= 16);
            assert!(v.iter().all(|&x| x <= 9));
        }
    }
}
