//! Deterministic 64-bit PRNG: splitmix64 seeding + xoshiro256** core.
//! Quality is more than sufficient for workloads/property tests and the
//! sequence is reproducible across runs and platforms.

#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng64 {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// xoshiro256** next.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be > 0. Uses Lemire's
    /// multiply-shift rejection method (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent stream (for per-thread RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng64 {
        Rng64::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.next_below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "not all buckets hit: {seen:?}");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Rng64::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng64::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
