//! Self-cleaning temporary directory (the `tempfile` crate is unavailable
//! offline). Used by the persist tests and the durability benches.

use std::path::{Path, PathBuf};

use crate::sync::shim::{AtomicU64, Ordering};

static NEXT: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp root, removed on drop.
pub struct TempDir(PathBuf);

impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        let path = std::env::temp_dir().join(format!(
            "mcprioq-{tag}-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir(path)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }

    /// A path inside the directory.
    pub fn join(&self, rel: &str) -> PathBuf {
        self.0.join(rel)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
