//! Test substrate: deterministic PRNG and a minimal property-testing
//! harness ("proptest-lite"). No third-party crates are available offline,
//! so this replaces `rand` + `proptest` for the crate's test suite and for
//! the workload generators' entropy source.

mod prop;
mod rng;
mod tempdir;

pub use prop::{forall, Gen, PropConfig, U64Range, VecGen};
pub use rng::Rng64;
pub use tempdir::TempDir;
