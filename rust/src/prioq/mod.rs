//! The Markov-chain priority queue (§II.2) — the paper's core contribution.
//!
//! A *sorted doubly-linked list* of edges, ordered by transition count
//! (head = largest). Three properties the paper demands:
//!
//! 1. **Wait-free readers.** Inference walks `head -> next -> …` inside an
//!    RCU read-side critical section. Elements are reordered by *swapping*
//!    (Fig. 2), never by pop+insert, so a reader can never be left holding a
//!    node that was unlinked-and-freed mid-scan, and — unlike pop+insert —
//!    there is no window in which an element is absent from the list while
//!    a grace period elapses.
//! 2. **Wait-free counter updates.** The common case (§II.A.2) is a plain
//!    `fetch_add` on the edge counter. Order maintenance is *opportunistic*:
//!    if the element now outranks its predecessor, the updater tries to
//!    bubble it toward the head. The attempt is try-lock single-flight per
//!    list: if another thread is restructuring, the update simply skips —
//!    the list stays *approximately* sorted and a later update repairs it.
//!    Updates therefore never block (measured in E4: no-swap is the normal
//!    case for skewed input, exactly as the paper argues).
//! 3. **Lock-free inserts.** New edges are pushed onto a Treiber stack of
//!    pending entries (one CAS, always succeeds in bounded retries); the
//!    next structural operation splices them at the tail. The splice is
//!    performed by whoever holds the single-flight ticket, and the release
//!    protocol re-checks the stack, so a pending edge becomes visible after
//!    at most one ticket hand-over (helping pattern).
//!
//! ## The swap (Fig. 2), concretely
//!
//! To move `E` above its predecessor `P` in the chain `Q → P → E → N`
//! (arrows are `next`, head-to-tail, descending count), the ticket holder
//! stores, in this order:
//!
//! ```text
//!   1. Q.next = E     readers from Q now see  Q → E → N   (P hidden)
//!   2. P.next = N     readers at P      see       P → N
//!   3. E.next = P     readers from Q now see  Q → E → P → N   (done)
//! ```
//!
//! No ordering of single-word stores can keep both nodes visible to a
//! *fresh* traversal at every instant (that would need a DCAS); the scheme
//! above hides only the *demoted* node `P`, for a window of two stores, and
//! never creates a cycle in the `next` chain — readers always terminate and
//! always see the *promoted* (hotter) element. This is the concrete meaning
//! of the paper's "approximately correct results even during concurrent
//! updates"; E7 measures the observable effect (reader recall under write
//! storms).
//!
//! `prev` pointers are consulted and mutated only by the ticket holder (and
//! by `increment`'s heuristic pre-check, which tolerates staleness), so
//! they need no reader-safe ordering discipline.

mod list;

pub use list::{EdgeList, IncrementOutcome, ListStats, Node};

#[cfg(test)]
mod tests;
