//! Implementation of the sorted doubly-linked edge list.

use crate::rcu::{self, Guard};
use crate::sync::shim::{fence, AtomicPtr, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use crate::sync::{Backoff, SpinLock};

/// Link state of a node.
const LINK_PENDING: u8 = 0;
const LINK_LINKED: u8 = 1;
const LINK_UNLINKED: u8 = 2;

/// One edge of the markov chain: destination id + transition counter
/// (§II.3), threaded on the sorted list and on the pending stack.
///
/// Exactly one cache line (`align(64)`, 49 payload bytes padded to 64) and
/// allocated from [`crate::chain::arena`], never the global allocator: a
/// node shares its line with nothing, so the wait-free `count` increments
/// of one edge never false-share with a neighbour, and the list walk's
/// pointer chase lands on arena-packed lines (DESIGN.md §7).
#[repr(align(64))]
pub struct Node {
    /// Destination node id (the "item" returned by inference).
    pub key: u64,
    /// Transition counter; incremented wait-free, halved by decay.
    pub count: AtomicU64,
    /// Order ceiling: a conservative lower bound on the predecessor's
    /// count. Counts are monotone between decays, so while
    /// `count <= ceil` an increment provably cannot create an inversion —
    /// the hot no-swap path (§II.A.2) then skips the dependent-load cache
    /// miss of dereferencing `prev` entirely (see EXPERIMENTS.md §Perf).
    /// Maintained: exact under the ticket (swap/splice/decay), best-effort
    /// from the increment slow path; staleness only causes extra checks or
    /// a bounded missed swap repaired by the maintenance sweep.
    ceil: AtomicU64,
    next: AtomicPtr<Node>,
    prev: AtomicPtr<Node>,
    /// Treiber-stack link while the node waits to be spliced.
    stack: AtomicPtr<Node>,
    link: AtomicU8,
}

impl Node {
    /// A fresh, unlinked node value (moved into an arena slot by callers).
    pub(crate) fn new(key: u64, count: u64) -> Node {
        Node {
            key,
            count: AtomicU64::new(count),
            ceil: AtomicU64::new(0),
            next: AtomicPtr::new(std::ptr::null_mut()),
            prev: AtomicPtr::new(std::ptr::null_mut()),
            stack: AtomicPtr::new(std::ptr::null_mut()),
            link: AtomicU8::new(LINK_PENDING),
        }
    }

    fn boxed(key: u64, count: u64) -> *mut Node {
        crate::chain::arena::alloc(Node::new(key, count))
    }

    #[inline]
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn is_linked(&self) -> bool {
        self.link.load(Ordering::Acquire) == LINK_LINKED
    }
}

/// Outcome of [`EdgeList::increment`], used by E4 (swap-rate experiment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IncrementOutcome {
    /// New value of the edge counter.
    pub count: u64,
    /// Number of adjacent swaps performed to restore order.
    pub swaps: u32,
    /// True if a reorder was warranted but skipped because another thread
    /// held the structural ticket (the list stays approximately sorted).
    pub skipped: bool,
}

/// Counters exposed for tests/metrics (all monotonically increasing).
#[derive(Debug, Default, Clone, Copy)]
pub struct ListStats {
    pub len: usize,
    pub swaps: u64,
    pub swap_skips: u64,
    pub splices: u64,
}

/// The per-src-node priority queue. See module docs for the protocol.
pub struct EdgeList {
    head: AtomicPtr<Node>,
    tail: AtomicPtr<Node>,
    /// Single-flight ticket for structural mutations (splice/swap/unlink).
    ticket: SpinLock<()>,
    /// Treiber stack of freshly inserted nodes awaiting splice.
    pending: AtomicPtr<Node>,
    len: AtomicUsize,
    swaps: AtomicU64,
    swap_skips: AtomicU64,
    splices: AtomicU64,
    /// Monotone mutation epoch: advanced by every counter increment,
    /// splice, swap, unlink, and decay step. The chain's read-path
    /// snapshots use it as their staleness clock — a snapshot built at
    /// epoch `e` is considered fresh while `mutations() - e` stays under
    /// the configured bound.
    mutations: AtomicU64,
}

// SAFETY: all fields are atomics or a SpinLock; the raw node pointers are
// only dereferenced under the RCU guard / structural-ticket protocol that
// every method documents, so the list may be shared and sent freely.
unsafe impl Send for EdgeList {}
// SAFETY: see the `Send` justification above.
unsafe impl Sync for EdgeList {}

impl Default for EdgeList {
    fn default() -> Self {
        Self::new()
    }
}

impl EdgeList {
    pub fn new() -> Self {
        EdgeList {
            head: AtomicPtr::new(std::ptr::null_mut()),
            tail: AtomicPtr::new(std::ptr::null_mut()),
            ticket: SpinLock::new(()),
            pending: AtomicPtr::new(std::ptr::null_mut()),
            len: AtomicUsize::new(0),
            swaps: AtomicU64::new(0),
            swap_skips: AtomicU64::new(0),
            splices: AtomicU64::new(0),
            mutations: AtomicU64::new(0),
        }
    }

    /// Current mutation epoch (see the field docs). Relaxed: callers only
    /// compare epochs for an approximate staleness bound.
    #[inline]
    pub fn mutations(&self) -> u64 {
        self.mutations.load(Ordering::Relaxed)
    }

    /// Number of *linked* nodes (pending nodes are counted once spliced).
    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Allocate a node for `key` with an initial count and enqueue it for
    /// splicing at the tail. Lock-free (one CAS loop on the pending stack).
    /// Returns the node pointer, which the caller typically publishes in the
    /// dst hash table; the node becomes visible to list readers after at
    /// most one ticket hand-over.
    pub fn insert(&self, _guard: &Guard, key: u64, count: u64) -> *mut Node {
        let node = Node::boxed(key, count);
        self.push_pending(node);
        self.try_maintain();
        node
    }

    /// Enqueue an externally allocated node (used by the chain when it wins
    /// the dst-table race and must link the node it already published).
    pub fn insert_node(&self, _guard: &Guard, node: *mut Node) {
        self.push_pending(node);
        self.try_maintain();
    }

    /// Allocate a node without linking it anywhere (the chain uses this to
    /// race on the dst table; losers are freed without ever being shared).
    pub fn alloc_node(key: u64, count: u64) -> *mut Node {
        Node::boxed(key, count)
    }

    /// Find `key` in the list or insert it with `count`, deduplicating
    /// *within the list itself*. Used when the optional dst hash table
    /// (§II.2) is disabled: the list is then the only index, so uniqueness
    /// must be enforced under the structural ticket (this path blocks —
    /// the measured cost of dropping the optimization, see bench E6/E2
    /// ablations). Returns `(node, inserted)`.
    pub fn find_or_insert(&self, _guard: &Guard, key: u64, count: u64) -> (*mut Node, bool) {
        let t = self.ticket.lock();
        self.drain_pending();
        // Writer-side scan (ticket held, so the chain is stable).
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() {
            // SAFETY: ticket held — no node can be unlinked/retired under us.
            let n = unsafe { &*cur };
            if n.key == key {
                drop(t);
                return (cur, false);
            }
            cur = n.next.load(Ordering::Acquire);
        }
        let node = Node::boxed(key, count);
        self.splice_tail(node);
        self.splices.fetch_add(1, Ordering::Relaxed);
        self.bubble_up_ptr(node);
        drop(t);
        self.try_maintain();
        (node, true)
    }

    /// Free a node that was never shared (lost a publish race).
    ///
    /// # Safety
    /// The node must have come from [`EdgeList::alloc_node`] and must never
    /// have been passed to [`EdgeList::insert_node`] or published anywhere.
    pub unsafe fn free_unshared(node: *mut Node) {
        // SAFETY: per this function's contract the node was never shared,
        // so no reader can hold it and it is released exactly once.
        unsafe { crate::chain::arena::release(node) };
    }

    fn push_pending(&self, node: *mut Node) {
        let mut backoff = Backoff::new();
        let mut head = self.pending.load(Ordering::Acquire);
        loop {
            // SAFETY: `node` is not yet published (our exclusive allocation
            // or, on retry, still only reachable through this loop).
            unsafe { (*node).stack.store(head, Ordering::Relaxed) };
            match self
                .pending
                .compare_exchange_weak(head, node, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => break,
                Err(h) => {
                    head = h;
                    backoff.spin();
                }
            }
        }
        // Helping-protocol handshake, part 1 of 2 (part 2 is in
        // `try_maintain`). Our pending push must become visible before we
        // probe the ticket, and the holder's ticket release must become
        // visible before it re-probes `pending` — otherwise both sides can
        // read stale values (store-buffering): we see the ticket still held
        // and leave, the holder sees `pending` empty and leaves, and the
        // node is stranded until an unrelated mutation drains it. The
        // paired SeqCst fences put both stores in one total order, so at
        // least one side must observe the other. Regression model:
        // `loom_models::pending_handoff_never_strands`.
        fence(Ordering::SeqCst);
        // Callers follow up with `try_maintain()`, which performs the probe.
    }

    /// Try to acquire the ticket and drain pending inserts. Never blocks.
    fn try_maintain(&self) {
        loop {
            let Some(t) = self.ticket.try_lock() else { return };
            self.drain_pending();
            drop(t);
            // Helping-protocol handshake, part 2 of 2: order our ticket
            // release before the `pending` re-probe (pairs with the fence
            // in `push_pending`; see the comment there).
            fence(Ordering::SeqCst);
            // Close the push-after-drain race: if new nodes arrived while we
            // held the ticket's tail end, loop and try again (helping).
            if self.pending.load(Ordering::Acquire).is_null() {
                return;
            }
        }
    }

    /// Splice every pending node at the tail. Caller holds the ticket.
    fn drain_pending(&self) {
        let mut top = self.pending.swap(std::ptr::null_mut(), Ordering::AcqRel);
        // The stack yields newest-first; reverse so earlier inserts land
        // closer to the head (stable FIFO splice order).
        let mut nodes: Vec<*mut Node> = Vec::new();
        while !top.is_null() {
            nodes.push(top);
            // SAFETY: nodes on the pending stack are unpublished to readers
            // and only the ticket holder (us) pops them.
            top = unsafe { &*top }.stack.load(Ordering::Acquire);
        }
        for &node in nodes.iter().rev() {
            self.splice_tail(node);
            self.splices.fetch_add(1, Ordering::Relaxed);
            // New edges normally start at count 1 and belong at the tail,
            // but the API allows arbitrary initial counts (and the count may
            // have been incremented while the node waited on the stack) —
            // restore order immediately. Free when already sorted.
            self.bubble_up_ptr(node);
        }
    }

    /// Append `node` at the tail. Caller holds the ticket.
    fn splice_tail(&self, node: *mut Node) {
        // SAFETY: `node` came off the pending stack (or was just allocated
        // under the ticket) — not yet reachable by readers.
        let n = unsafe { &*node };
        n.next.store(std::ptr::null_mut(), Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Relaxed);
        n.ceil.store(
            // SAFETY: linked nodes stay alive while the ticket is held.
            if tail.is_null() { u64::MAX } else { unsafe { &*tail }.count.load(Ordering::Acquire) },
            Ordering::Relaxed,
        );
        n.prev.store(tail, Ordering::Relaxed);
        n.link.store(LINK_LINKED, Ordering::Release);
        if tail.is_null() {
            // Empty list: publish as head; readers acquire through `head`.
            self.head.store(node, Ordering::Release);
        } else {
            // SAFETY: the tail node is linked and alive under the ticket.
            unsafe { &*tail }.next.store(node, Ordering::Release);
        }
        self.tail.store(node, Ordering::Release);
        self.len.fetch_add(1, Ordering::Relaxed);
        self.mutations.fetch_add(1, Ordering::Relaxed);
    }

    /// Wait-free counter increment plus opportunistic reorder (§II.A.2).
    ///
    /// # Safety
    /// `node` must be a node of *this* list, protected by `guard`.
    pub unsafe fn increment(&self, guard: &Guard, node: *mut Node, delta: u64) -> IncrementOutcome {
        // SAFETY: per this function's contract, `node` belongs to this list
        // and the caller's guard keeps it alive.
        let n = unsafe { &*node };
        let count = n.count.fetch_add(delta, Ordering::AcqRel) + delta;
        self.mutations.fetch_add(1, Ordering::Relaxed);

        // Fast path: under the order ceiling we provably cannot have
        // overtaken the predecessor — no pointer chase at all.
        if count <= n.ceil.load(Ordering::Relaxed) {
            return IncrementOutcome { count, swaps: 0, skipped: false };
        }

        // Heuristic pre-check (racy by design; revalidated under ticket).
        let prev = n.prev.load(Ordering::Acquire);
        if prev.is_null() {
            n.ceil.store(u64::MAX, Ordering::Relaxed); // at head
            return IncrementOutcome { count, swaps: 0, skipped: false };
        }
        // SAFETY: `prev` was a linked neighbour of `node`; even if it has
        // been unlinked since the load, the guard delays its reclamation.
        let pc = unsafe { &*prev }.count.load(Ordering::Acquire);
        if pc >= count {
            // Refresh the ceiling so future increments up to `pc` stay on
            // the fast path.
            n.ceil.store(pc, Ordering::Relaxed);
            return IncrementOutcome { count, swaps: 0, skipped: false };
        }
        match self.ticket.try_lock() {
            Some(t) => {
                let swaps = self.bubble_up(guard, node);
                self.drain_pending();
                drop(t);
                // Close the push-after-drain window (helping protocol).
                self.try_maintain();
                IncrementOutcome { count, swaps, skipped: false }
            }
            None => {
                self.swap_skips.fetch_add(1, Ordering::Relaxed);
                IncrementOutcome { count, swaps: 0, skipped: true }
            }
        }
    }

    /// Force a reorder of `node` (used by tests and by repair sweeps).
    /// Blocks on the ticket.
    ///
    /// # Safety
    /// `node` must be a node of this list, protected by `guard`.
    pub unsafe fn reorder(&self, guard: &Guard, node: *mut Node) -> u32 {
        let t = self.ticket.lock();
        let swaps = self.bubble_up(guard, node);
        self.drain_pending();
        drop(t);
        self.try_maintain();
        swaps
    }

    /// Guard-less variant for internal use while holding the ticket.
    fn bubble_up_ptr(&self, node: *mut Node) -> u32 {
        // SAFETY: caller holds the ticket, so no node can be unlinked or
        // retired while we restructure.
        let n = unsafe { &*node };
        if n.link.load(Ordering::Acquire) != LINK_LINKED {
            return 0;
        }
        let mut swaps = 0u32;
        loop {
            let prev = n.prev.load(Ordering::Relaxed);
            if prev.is_null() {
                break;
            }
            // SAFETY: linked predecessor, stable under the ticket.
            let p = unsafe { &*prev };
            if p.count.load(Ordering::Acquire) >= n.count.load(Ordering::Acquire) {
                break;
            }
            self.swap_with_prev(node, prev);
            swaps += 1;
        }
        if swaps > 0 {
            self.swaps.fetch_add(swaps as u64, Ordering::Relaxed);
        }
        swaps
    }

    /// Bubble `node` toward the head while it outranks its predecessor
    /// (ties keep arrival order — stable). Caller holds the ticket.
    /// Returns the number of swaps performed.
    fn bubble_up(&self, _guard: &Guard, node: *mut Node) -> u32 {
        self.bubble_up_ptr(node)
    }

    /// The Fig.-2 swap: move `node` (E) above its predecessor `prev` (P).
    /// Chain before: Q → P → E → N. After: Q → E → P → N.
    /// Caller holds the ticket; store order is the reader-safe sequence
    /// proven in the module docs (hides only P, never cycles).
    fn swap_with_prev(&self, node: *mut Node, prev: *mut Node) {
        self.mutations.fetch_add(1, Ordering::Relaxed);
        // SAFETY: applies to every deref in this function — the caller
        // holds the structural ticket, so E, P, Q and N are linked nodes
        // that cannot be unlinked or retired until we return.
        let e = unsafe { &*node };
        // SAFETY: see above.
        let p = unsafe { &*prev };
        let q = p.prev.load(Ordering::Relaxed);
        let next = e.next.load(Ordering::Relaxed);

        // --- reader-visible `next` chain, in the safe order ---
        // 1. Q.next = E   (or head = E if P was the head)
        if q.is_null() {
            self.head.store(node, Ordering::Release);
        } else {
            // SAFETY: see the function-level comment above.
            unsafe { &*q }.next.store(node, Ordering::Release);
        }
        // 2. P.next = N
        p.next.store(next, Ordering::Release);
        // 3. E.next = P
        e.next.store(prev, Ordering::Release);

        // --- writer-side `prev` chain (ticket holder only) ---
        e.prev.store(q, Ordering::Relaxed);
        p.prev.store(node, Ordering::Relaxed);
        if next.is_null() {
            // E was the tail; P is now.
            self.tail.store(prev, Ordering::Release);
        } else {
            // SAFETY: see the function-level comment above.
            unsafe { &*next }.prev.store(prev, Ordering::Relaxed);
        }

        // --- order ceilings (see Node::ceil) ---
        e.ceil.store(
            // SAFETY: see the function-level comment above.
            if q.is_null() { u64::MAX } else { unsafe { &*q }.count.load(Ordering::Acquire) },
            Ordering::Relaxed,
        );
        p.ceil.store(e.count.load(Ordering::Acquire), Ordering::Relaxed);
        if !next.is_null() {
            // N's predecessor weakened from E to P: the ceiling must drop.
            // SAFETY: see the function-level comment above.
            unsafe { &*next }.ceil.store(p.count.load(Ordering::Acquire), Ordering::Relaxed);
        }
    }

    /// Unlink `node` from the list and retire it through RCU. Blocks on the
    /// ticket (cold path: decay/prune). The caller must already have removed
    /// every *other* route to the node (e.g. the dst hash table) — readers
    /// inside the current grace period may still traverse it.
    ///
    /// # Safety
    /// `node` must be a linked node of this list, not retired twice.
    pub unsafe fn unlink(&self, guard: &Guard, node: *mut Node) {
        let t = self.ticket.lock();
        self.unlink_locked(node);
        self.drain_pending();
        drop(t);
        self.try_maintain();
        // Arena nodes are not Boxes: retire through a deferred closure that
        // returns the slot to its block after the grace period.
        let p = node as usize;
        // SAFETY: the node was unlinked above and (per this function's
        // contract) every other route to it is gone, so after the grace
        // period no reader can hold it; it is released exactly once.
        rcu::defer(guard, move || unsafe { crate::chain::arena::release(p as *mut Node) });
    }

    fn unlink_locked(&self, node: *mut Node) {
        // SAFETY: ticket held; `node` is linked (debug-asserted below) and
        // cannot be retired before the unlink completes.
        let n = unsafe { &*node };
        debug_assert_eq!(n.link.load(Ordering::Acquire), LINK_LINKED);
        let prev = n.prev.load(Ordering::Relaxed);
        let next = n.next.load(Ordering::Relaxed);
        // Readers parked on `node` keep following `node.next` (unchanged),
        // so the unlink is invisible to them — classic RCU list removal.
        if prev.is_null() {
            self.head.store(next, Ordering::Release);
        } else {
            // SAFETY: linked neighbour, stable under the ticket.
            unsafe { &*prev }.next.store(next, Ordering::Release);
        }
        if next.is_null() {
            self.tail.store(prev, Ordering::Release);
        } else {
            // SAFETY: linked neighbour, stable under the ticket.
            let nx = unsafe { &*next };
            nx.prev.store(prev, Ordering::Relaxed);
            nx.ceil.store(
                if prev.is_null() {
                    u64::MAX
                } else {
                    // SAFETY: linked neighbour, stable under the ticket.
                    unsafe { &*prev }.count.load(Ordering::Acquire)
                },
                Ordering::Relaxed,
            );
        }
        n.link.store(LINK_UNLINKED, Ordering::Release);
        self.len.fetch_sub(1, Ordering::Relaxed);
        self.mutations.fetch_add(1, Ordering::Relaxed);
    }

    /// Halve every counter (model decay, §II.C); unlink nodes that reach 0
    /// and pass them to `on_prune` *before* they are retired (the chain
    /// removes them from the dst table inside the callback). Blocks on the
    /// ticket. Returns (surviving_sum, pruned_count).
    pub fn decay<F: FnMut(u64, *mut Node)>(
        &self,
        guard: &Guard,
        factor_num: u64,
        factor_den: u64,
        mut on_prune: F,
    ) -> (u64, usize) {
        assert!(factor_num < factor_den && factor_den > 0);
        let t = self.ticket.lock();
        self.drain_pending();
        let mut sum = 0u64;
        let mut pruned = 0usize;
        let mut prev_new_count = u64::MAX; // head has no predecessor
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() {
            // SAFETY: ticket held — the chain is stable for the walk.
            let n = unsafe { &*cur };
            let next = n.next.load(Ordering::Acquire);
            // fetch_update so racing increments are not lost (they may be
            // scaled along with the old value — acceptable approximation).
            let new = n
                .count
                .fetch_update(Ordering::AcqRel, Ordering::Acquire, |c| {
                    Some(c * factor_num / factor_den)
                })
                .map(|old| old * factor_num / factor_den)
                .unwrap_or(0);
            if new == 0 {
                self.unlink_locked(cur);
                on_prune(n.key, cur);
                let p = cur as usize;
                // SAFETY: unlinked above; `on_prune` removed the dst-table
                // route before we retire, so the grace period covers every
                // remaining reader and the node is released exactly once.
                rcu::defer(guard, move || unsafe {
                    crate::chain::arena::release(p as *mut Node)
                });
                pruned += 1;
            } else {
                // Counts shrank: re-anchor the ceiling to the new
                // predecessor value (stale-high ceilings would mask swaps).
                n.ceil.store(prev_new_count, Ordering::Relaxed);
                prev_new_count = new;
                sum += new;
                self.mutations.fetch_add(1, Ordering::Relaxed);
            }
            cur = next;
        }
        // Splice inserts that arrived during the walk before releasing.
        self.drain_pending();
        drop(t);
        self.try_maintain();
        (sum, pruned)
    }

    /// Repair sweep: one insertion-sort pass that bubbles every out-of-order
    /// node into place. Blocks on the ticket; O(n + inversions).
    ///
    /// Needed because order maintenance is opportunistic: an increment may
    /// *skip* its reorder when the ticket is busy, and a rare race (the
    /// increment's pre-check reading `prev` just before a concurrent swap
    /// demotes a hotter node above it) can leave a residual inversion that
    /// no later update repairs. Both are bounded, local inversions — the
    /// paper's "approximately correct" state. The chain piggybacks this
    /// sweep on model decay (§II.C), its periodic maintenance pass, making
    /// the order *eventually exact* at quiescence.
    ///
    /// Returns `(swaps performed, edge-count sum)`: every node is visited
    /// exactly once anyway (bubbling moves `cur` toward the head, never
    /// past its saved successor), so the sum the chain needs to rebase the
    /// node total rides along for free instead of a second full scan.
    pub fn repair(&self, _guard: &Guard) -> (u64, u64) {
        let t = self.ticket.lock();
        self.drain_pending();
        let mut swaps = 0u64;
        let mut sum = 0u64;
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() {
            // Save the successor before bubbling (bubbling moves `cur`
            // toward the head, never past its old successor).
            // SAFETY: ticket held — the chain is stable for the walk.
            let n = unsafe { &*cur };
            let next = n.next.load(Ordering::Acquire);
            sum += n.count.load(Ordering::Acquire);
            swaps += self.bubble_up_ptr(cur) as u64;
            cur = next;
        }
        self.drain_pending();
        drop(t);
        self.try_maintain();
        (swaps, sum)
    }

    /// Collect `each(key, count)` for every node under the structural
    /// ticket (pending inserts drained first), so membership and order are
    /// *stable* for the duration — counts may still move concurrently
    /// (increments are wait-free and never take the ticket). While the
    /// ticket is still held, `commit` takes ownership of the collected
    /// entries (exact-capacity, one pass, one allocation); the chain
    /// publishes its read snapshot there, which is what makes a
    /// publication never straddle a concurrent decay/repair sweep (those
    /// block on the same ticket). Non-blocking: returns `None` if the
    /// ticket is busy, and the caller falls back to a live scan.
    pub fn try_collect_stable<T, R>(
        &self,
        _guard: &Guard,
        mut each: impl FnMut(u64, u64) -> T,
        commit: impl FnOnce(Vec<T>) -> R,
    ) -> Option<R> {
        let t = self.ticket.try_lock()?;
        self.drain_pending();
        let mut out = Vec::with_capacity(self.len());
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() {
            // SAFETY: ticket held — the chain is stable for the walk.
            let n = unsafe { &*cur };
            out.push(each(n.key, n.count.load(Ordering::Acquire)));
            cur = n.next.load(Ordering::Acquire);
        }
        let r = commit(out);
        drop(t);
        self.try_maintain();
        Some(r)
    }

    /// Walk the list head→tail under the guard, calling `f(key, count)`;
    /// stop when `f` returns false. Wait-free; sees an approximately
    /// correct snapshot during concurrent restructuring.
    pub fn scan<F: FnMut(u64, u64) -> bool>(&self, _guard: &Guard, mut f: F) -> usize {
        let mut visited = 0usize;
        // Safety bound: the no-cycle proof makes unbounded walks impossible,
        // but a bound costs nothing and turns a hypothetical bug into a
        // truncated (approximately correct) answer instead of a hang.
        let bound = 4 * self.len.load(Ordering::Relaxed) + 64;
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() && visited < bound {
            // SAFETY: the caller's guard keeps every reachable node alive
            // (unlinked nodes are retired only after the grace period).
            let n = unsafe { &*cur };
            visited += 1;
            if !f(n.key, n.count.load(Ordering::Acquire)) {
                break;
            }
            cur = n.next.load(Ordering::Acquire);
        }
        visited
    }

    /// Collect up to `limit` `(key, count)` pairs from the head.
    pub fn top(&self, guard: &Guard, limit: usize) -> Vec<(u64, u64)> {
        if limit == 0 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(limit.min(64));
        self.scan(guard, |k, c| {
            out.push((k, c));
            out.len() < limit
        });
        out
    }

    pub fn stats(&self) -> ListStats {
        ListStats {
            len: self.len(),
            swaps: self.swaps.load(Ordering::Relaxed),
            swap_skips: self.swap_skips.load(Ordering::Relaxed),
            splices: self.splices.load(Ordering::Relaxed),
        }
    }

    /// Check the writer-side invariants (P1): descending counts and
    /// consistent prev links. Only meaningful when quiesced; takes the
    /// ticket to exclude mutators.
    pub fn check_sorted(&self) -> Result<(), String> {
        let _t = self.ticket.lock();
        let mut cur = self.head.load(Ordering::Acquire);
        let mut prev: *mut Node = std::ptr::null_mut();
        let mut last = u64::MAX;
        let mut n_seen = 0usize;
        while !cur.is_null() {
            // SAFETY: ticket held — the chain is stable for the walk.
            let n = unsafe { &*cur };
            let c = n.count.load(Ordering::Acquire);
            if c > last {
                return Err(format!("inversion at key {}: {} > {}", n.key, c, last));
            }
            if n.prev.load(Ordering::Relaxed) != prev {
                return Err(format!("broken prev link at key {}", n.key));
            }
            last = c;
            prev = cur;
            cur = n.next.load(Ordering::Acquire);
            n_seen += 1;
            if n_seen > self.len() + 1 {
                return Err("cycle detected".into());
            }
        }
        if prev != self.tail.load(Ordering::Acquire) {
            return Err("tail pointer stale".into());
        }
        if n_seen != self.len() {
            return Err(format!("len {} but saw {}", self.len(), n_seen));
        }
        Ok(())
    }
}

impl Drop for EdgeList {
    fn drop(&mut self) {
        // Exclusive access: free linked chain and pending stack directly.
        let mut cur = *self.head.get_mut();
        while !cur.is_null() {
            // SAFETY: `&mut self` proves no reader or mutator exists; each
            // node is reachable from exactly one chain link, so it is
            // released exactly once.
            let next = unsafe { &*cur }.next.load(Ordering::Relaxed);
            // SAFETY: see above.
            unsafe { crate::chain::arena::release(cur) };
            cur = next;
        }
        let mut cur = *self.pending.get_mut();
        while !cur.is_null() {
            // SAFETY: same exclusivity argument as the linked chain above.
            let next = unsafe { &*cur }.stack.load(Ordering::Relaxed);
            // SAFETY: see above.
            unsafe { crate::chain::arena::release(cur) };
            cur = next;
        }
    }
}
