//! Tests for the priority-queue list: invariants P1 (sorted when quiesced),
//! P2 (no missing elements for persistent readers), plus stress.

use super::*;
use crate::rcu;
use crate::sync::shim::{AtomicBool, Ordering};
use std::collections::HashSet;
use std::sync::Arc;

fn drain_all(l: &EdgeList, g: &rcu::Guard) -> Vec<(u64, u64)> {
    l.top(g, usize::MAX)
}

#[test]
fn insert_appends_at_tail_in_fifo_order() {
    let l = EdgeList::new();
    let g = rcu::pin();
    for k in 0..5u64 {
        l.insert(&g, k, 1);
    }
    let items = drain_all(&l, &g);
    assert_eq!(items.iter().map(|&(k, _)| k).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    assert_eq!(l.len(), 5);
    l.check_sorted().unwrap();
}

#[test]
fn increment_bubbles_to_correct_position() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let a = l.insert(&g, 10, 5);
    let b = l.insert(&g, 20, 3);
    let c = l.insert(&g, 30, 1);
    let _ = (a, b);
    // c: 1 -> 6, must bubble above both.
    // SAFETY: `c` is a node of `l`, protected by `g` (same for every
    // increment/unlink call in this file).
    let out = unsafe { l.increment(&g, c, 5) };
    assert_eq!(out.count, 6);
    assert_eq!(out.swaps, 2);
    assert!(!out.skipped);
    let items = drain_all(&l, &g);
    assert_eq!(items, vec![(30, 6), (10, 5), (20, 3)]);
    l.check_sorted().unwrap();
}

#[test]
fn increment_no_swap_when_order_kept() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let a = l.insert(&g, 1, 10);
    let b = l.insert(&g, 2, 5);
    let _ = a;
    // SAFETY: node of `l` under `g`.
    let out = unsafe { l.increment(&g, b, 1) }; // 6 < 10: no swap
    assert_eq!(out.swaps, 0);
    l.check_sorted().unwrap();
}

#[test]
fn ties_are_stable_no_swap() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let _a = l.insert(&g, 1, 5);
    let b = l.insert(&g, 2, 4);
    // SAFETY: node of `l` under `g`.
    let out = unsafe { l.increment(&g, b, 1) }; // equal counts: stay put
    assert_eq!(out.swaps, 0);
    assert_eq!(drain_all(&l, &g), vec![(1, 5), (2, 5)]);
}

#[test]
fn swap_at_head_and_tail_updates_ends() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let _a = l.insert(&g, 1, 2);
    let b = l.insert(&g, 2, 1);
    // b is the tail; bubbling to head exercises both end fixups.
    // SAFETY: node of `l` under `g`.
    unsafe { l.increment(&g, b, 10) };
    assert_eq!(drain_all(&l, &g), vec![(2, 11), (1, 2)]);
    l.check_sorted().unwrap();
    // Now the old head (key 1) is the tail; bubble it back.
    let items = l.top(&g, 2);
    assert_eq!(items[1].0, 1);
}

#[test]
fn unlink_middle_head_tail() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let a = l.insert(&g, 1, 30);
    let b = l.insert(&g, 2, 20);
    let c = l.insert(&g, 3, 10);
    // SAFETY: linked nodes of `l` under `g`, each unlinked exactly once
    // and never reachable through any other index.
    unsafe { l.unlink(&g, b) };
    assert_eq!(drain_all(&l, &g), vec![(1, 30), (3, 10)]);
    l.check_sorted().unwrap();
    // SAFETY: see above.
    unsafe { l.unlink(&g, a) };
    assert_eq!(drain_all(&l, &g), vec![(3, 10)]);
    l.check_sorted().unwrap();
    // SAFETY: see above.
    unsafe { l.unlink(&g, c) };
    assert!(l.is_empty());
    assert_eq!(drain_all(&l, &g), vec![]);
    l.check_sorted().unwrap();
}

#[test]
fn decay_halves_and_prunes() {
    let l = EdgeList::new();
    let g = rcu::pin();
    l.insert(&g, 1, 8);
    l.insert(&g, 2, 3);
    l.insert(&g, 3, 1); // halves to 0 -> pruned
    let mut pruned_keys = Vec::new();
    let (sum, pruned) = l.decay(&g, 1, 2, |k, _| pruned_keys.push(k));
    assert_eq!(pruned, 1);
    assert_eq!(pruned_keys, vec![3]);
    assert_eq!(sum, 4 + 1);
    assert_eq!(drain_all(&l, &g), vec![(1, 4), (2, 1)]);
    l.check_sorted().unwrap();
}

#[test]
fn decay_preserves_order() {
    let l = EdgeList::new();
    let g = rcu::pin();
    for (k, c) in [(1u64, 100u64), (2, 57), (3, 13), (4, 5), (5, 2)] {
        l.insert(&g, k, c);
    }
    l.decay(&g, 1, 2, |_, _| {});
    l.check_sorted().unwrap();
    let items = drain_all(&l, &g);
    assert_eq!(items, vec![(1, 50), (2, 28), (3, 6), (4, 2), (5, 1)]);
}

#[test]
fn top_limit_zero_and_over_len() {
    let l = EdgeList::new();
    let g = rcu::pin();
    l.insert(&g, 1, 1);
    assert!(l.top(&g, 0).is_empty());
    assert_eq!(l.top(&g, 100).len(), 1);
}

#[test]
fn scan_early_stop() {
    let l = EdgeList::new();
    let g = rcu::pin();
    for k in 0..10u64 {
        l.insert(&g, k, 10 - k);
    }
    let mut seen = 0;
    let visited = l.scan(&g, |_, _| {
        seen += 1;
        seen < 3
    });
    assert_eq!(seen, 3);
    assert_eq!(visited, 3);
}

#[test]
fn stats_track_swaps_and_splices() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let _a = l.insert(&g, 1, 2);
    let b = l.insert(&g, 2, 1);
    // SAFETY: node of `l` under `g`.
    unsafe { l.increment(&g, b, 5) };
    let s = l.stats();
    assert_eq!(s.len, 2);
    assert_eq!(s.splices, 2);
    assert_eq!(s.swaps, 1);
}

/// P1 under a single-threaded random workload: after quiescing, the list is
/// exactly sorted (our increments always repair immediately when
/// uncontended).
#[test]
fn random_ops_stay_sorted_single_thread() {
    use crate::testutil::Rng64;
    let mut rng = Rng64::new(0xfeed);
    let l = EdgeList::new();
    let g = rcu::pin();
    let mut nodes = Vec::new();
    for i in 0..if cfg!(miri) { 300 } else { 2000 } {
        if nodes.is_empty() || rng.next_below(10) == 0 {
            nodes.push(l.insert(&g, i, 1 + rng.next_below(4)));
        } else {
            let n = nodes[rng.next_below(nodes.len() as u64) as usize];
            // SAFETY: node of `l` under `g`.
            unsafe { l.increment(&g, n, 1 + rng.next_below(3)) };
        }
    }
    l.check_sorted().unwrap();
}

/// P2 ("approximately correct"): readers scanning during a write storm
/// always terminate, never see phantom keys, and — with the skewed update
/// distribution the paper assumes — retain high recall. (The uniform
/// worst case, where counts stay tied and churn is maximal, is measured
/// rather than asserted, in E7.)
#[test]
fn concurrent_swaps_readers_terminate_and_see_hot_keys() {
    const KEYS: u64 = 64;
    let l = Arc::new(EdgeList::new());
    let nodes: Vec<usize> = {
        let g = rcu::pin();
        (0..KEYS).map(|k| l.insert(&g, k, 1) as usize).collect()
    };
    let stop = Arc::new(AtomicBool::new(false));
    let nodes = Arc::new(nodes);

    let writers: Vec<_> = (0..4)
        .map(|w| {
            let l = Arc::clone(&l);
            let stop = Arc::clone(&stop);
            let nodes = Arc::clone(&nodes);
            std::thread::spawn(move || {
                use crate::testutil::Rng64;
                let mut rng = Rng64::new(0xbeef ^ w as u64);
                while !stop.load(Ordering::Relaxed) {
                    let g = rcu::pin();
                    // Zipf-ish skew (cube of a uniform): low keys get the
                    // bulk of the increments, as the paper assumes.
                    let u = rng.next_f64();
                    let k = ((u * u * u) * KEYS as f64) as u64;
                    let n = nodes[k.min(KEYS - 1) as usize] as *mut Node;
                    // SAFETY: node of `l`, never unlinked, under `g`.
                    unsafe { l.increment(&g, n, 1) };
                }
            })
        })
        .collect();

    let mut total_seen = 0u64;
    let mut total_scans = 0u64;
    let mut complete_scans = 0u64;
    for _ in 0..if cfg!(miri) { 40 } else { 2_000 } {
        let g = rcu::pin();
        let mut seen = HashSet::new();
        l.scan(&g, |k, _| {
            seen.insert(k);
            true
        });
        total_scans += 1;
        total_seen += seen.len() as u64;
        if seen.len() == KEYS as usize {
            complete_scans += 1;
        }
        // Even a partial view must never contain phantom keys.
        assert!(seen.iter().all(|&k| k < KEYS));
    }
    stop.store(true, Ordering::SeqCst);
    for w in writers {
        w.join().unwrap();
    }
    // Aggregate recall must be high and most scans complete.
    let mean_recall = total_seen as f64 / (total_scans * KEYS) as f64;
    assert!(mean_recall > 0.95, "mean recall {mean_recall}");
    assert!(
        complete_scans * 2 >= total_scans,
        "only {complete_scans}/{total_scans} scans were complete"
    );
    let g = rcu::pin();
    l.repair(&g);
    l.check_sorted().unwrap();
}

/// Multi-threaded mixed insert/increment storm: afterwards the structure is
/// intact, contains every inserted key exactly once, and total count equals
/// the sum of all increments.
#[test]
fn stress_insert_increment_consistency() {
    const THREADS: u64 = if cfg!(miri) { 3 } else { 6 };
    const OPS: u64 = if cfg!(miri) { 200 } else { 5_000 };
    let l = Arc::new(EdgeList::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let l = Arc::clone(&l);
            std::thread::spawn(move || {
                use crate::testutil::Rng64;
                let mut rng = Rng64::new(t + 1);
                let mut mine = Vec::new();
                let mut delta_sum = 0u64;
                for i in 0..OPS {
                    let g = rcu::pin();
                    if mine.is_empty() || rng.next_below(8) == 0 {
                        let key = t * OPS + i;
                        mine.push(l.insert(&g, key, 1));
                        delta_sum += 1;
                    } else {
                        let n = mine[rng.next_below(mine.len() as u64) as usize];
                        let d = 1 + rng.next_below(4);
                        // SAFETY: node this thread inserted into `l`, under
                        // `g`; nothing ever unlinks it.
                        unsafe { l.increment(&g, n, d) };
                        delta_sum += d;
                    }
                }
                (mine.len() as u64, delta_sum)
            })
        })
        .collect();
    let mut expect_nodes = 0u64;
    let mut expect_sum = 0u64;
    for h in handles {
        let (n, s) = h.join().unwrap();
        expect_nodes += n;
        expect_sum += s;
    }
    let g = rcu::pin();
    let items = drain_all(&l, &g);
    assert_eq!(items.len() as u64, expect_nodes);
    let keys: HashSet<u64> = items.iter().map(|&(k, _)| k).collect();
    assert_eq!(keys.len() as u64, expect_nodes, "duplicate keys in list");
    let total: u64 = items.iter().map(|&(_, c)| c).sum();
    assert_eq!(total, expect_sum, "lost or duplicated increments");
    // Concurrent skips/races may leave bounded residual inversions; the
    // maintenance sweep must restore exact order at quiescence.
    l.repair(&g);
    l.check_sorted().unwrap();
}

/// Decay racing with increments must neither corrupt the list nor lose
/// nodes whose count stays positive.
#[test]
fn decay_races_with_increments() {
    const KEYS: u64 = 32;
    let l = Arc::new(EdgeList::new());
    let nodes: Vec<usize> = {
        let g = rcu::pin();
        (0..KEYS).map(|k| l.insert(&g, k, 1000) as usize).collect()
    };
    let stop = Arc::new(AtomicBool::new(false));
    let nodes = Arc::new(nodes);
    let writers: Vec<_> = (0..3)
        .map(|w| {
            let l = Arc::clone(&l);
            let stop = Arc::clone(&stop);
            let nodes = Arc::clone(&nodes);
            std::thread::spawn(move || {
                use crate::testutil::Rng64;
                let mut rng = Rng64::new(w + 77);
                while !stop.load(Ordering::Relaxed) {
                    let g = rcu::pin();
                    let n = nodes[rng.next_below(KEYS) as usize] as *mut Node;
                    // SAFETY: node of `l`, never pruned (counts stay
                    // positive), under `g`.
                    unsafe { l.increment(&g, n, 1) };
                }
            })
        })
        .collect();
    for _ in 0..if cfg!(miri) { 5 } else { 20 } {
        let g = rcu::pin();
        // Gentle decay: counts stay >> 0 so no node is pruned while writers
        // still hold raw pointers to them.
        l.decay(&g, 3, 4, |_, _| panic!("unexpected prune"));
        std::thread::yield_now();
    }
    stop.store(true, Ordering::SeqCst);
    for w in writers {
        w.join().unwrap();
    }
    assert_eq!(l.len(), KEYS as usize);
    let g = rcu::pin();
    l.repair(&g);
    l.check_sorted().unwrap();
}

/// The repair sweep turns an arbitrarily shuffled list into exact order.
#[test]
fn repair_fixes_arbitrary_disorder() {
    use crate::testutil::{forall, PropConfig, VecGen, U64Range};
    forall(
        PropConfig { cases: if cfg!(miri) { 12 } else { 64 }, ..Default::default() },
        &VecGen { elem: U64Range { lo: 0, hi: 50 }, max_len: 40 },
        |counts| {
            let l = EdgeList::new();
            let g = rcu::pin();
            // Insert in given (arbitrary) count order; splice bubbles each,
            // so the list is sorted even before repair — then increment a
            // few nodes *without* reordering by using raw count stores.
            let nodes: Vec<_> =
                counts.iter().enumerate().map(|(i, &c)| l.insert(&g, i as u64, c + 1)).collect();
            // Manufacture disorder: bump counts behind the queue's back.
            for (i, &n) in nodes.iter().enumerate() {
                if i % 3 == 0 {
                    // SAFETY: node of `l` under `g`.
                    unsafe { &*n }.count.fetch_add(17, Ordering::Relaxed);
                }
            }
            l.repair(&g);
            l.check_sorted().is_ok()
        },
    );
}

#[test]
fn alloc_free_unshared_roundtrip() {
    let n = EdgeList::alloc_node(9, 3);
    // SAFETY: freshly allocated, exclusively ours.
    assert_eq!(unsafe { &*n }.key, 9);
    // SAFETY: from alloc_node, never shared or inserted.
    unsafe { EdgeList::free_unshared(n) };
}

/// `repair` folds the edge-count sum into the sweep (one pass instead of
/// repair + rebase scan).
#[test]
fn repair_returns_swaps_and_sum() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let nodes: Vec<_> = (0..4u64).map(|k| l.insert(&g, k, 10 - k)).collect();
    // Disorder behind the queue's back: last node becomes the hottest.
    // SAFETY: node of `l` under `g`.
    unsafe { &*nodes[3] }.count.store(100, Ordering::Relaxed);
    let (swaps, sum) = l.repair(&g);
    assert_eq!(swaps, 3, "tail node must bubble to the head");
    assert_eq!(sum, 10 + 9 + 8 + 100);
    l.check_sorted().unwrap();
}

#[test]
fn try_collect_stable_sees_pending_and_order() {
    let l = EdgeList::new();
    let g = rcu::pin();
    l.insert(&g, 1, 5);
    l.insert(&g, 2, 9); // bubbles above 1 on splice
    let got = l.try_collect_stable(&g, |k, c| (k, c), |entries| entries);
    assert_eq!(got.unwrap(), vec![(2, 9), (1, 5)]);
    // Empty list: the collect succeeds with an empty Vec.
    let empty = EdgeList::new();
    let got = empty.try_collect_stable(&g, |k, c| (k, c), |entries| entries.len());
    assert_eq!(got.unwrap(), 0);
}

/// The mutation epoch advances on every class of list change — it is the
/// staleness clock the chain's read snapshots compare against.
#[test]
fn mutation_epoch_advances_on_every_change() {
    let l = EdgeList::new();
    let g = rcu::pin();
    let e0 = l.mutations();
    let a = l.insert(&g, 1, 3);
    let e1 = l.mutations();
    assert!(e1 > e0, "splice must advance the epoch");
    // SAFETY: node of `l` under `g`.
    unsafe { l.increment(&g, a, 1) };
    let e2 = l.mutations();
    assert!(e2 > e1, "increment must advance the epoch");
    let b = l.insert(&g, 2, 1);
    let e3 = l.mutations();
    // SAFETY: node of `l` under `g`.
    unsafe { l.increment(&g, b, 10) }; // bubbles above a: swap
    let e4 = l.mutations();
    assert!(e4 > e3 + 1, "increment + swap must advance the epoch twice");
    l.decay(&g, 1, 2, |_, _| {});
    assert!(l.mutations() > e4, "decay must advance the epoch");
}
