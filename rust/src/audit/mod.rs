//! Correctness observatory (DESIGN.md §10).
//!
//! The paper's contract is *approximate correctness*: reads served from a
//! bounded-staleness snapshot may be slightly stale or rank-inverted
//! mid-swap, but never wrong-by-construction. The telemetry plane
//! (DESIGN.md §9) measures how fast every stage is; this module measures
//! how *right* the answers are, continuously and cheaply, and turns the
//! result into registry families, events, and health escalations:
//!
//! - **Approximation-error auditor** ([`Auditor::error_round`]): for
//!   sampled snapshot-bearing (hot) nodes, compare the snapshot-served
//!   top-k against a fresh exact walk ([`McPrioQ::audit_samples`]) and
//!   record rank inversions + Kendall-tau-style displacement
//!   (`mcprioq_audit_rank_error{stat=...}`), probability-mass error in
//!   ppm (`mcprioq_audit_mass_error`), and the snapshot staleness each
//!   sample was taken at (`mcprioq_audit_staleness`) — the correlation
//!   bench plots as a staleness-vs-error curve.
//! - **Invariant watchdog** ([`Auditor::watchdog_round`]): a rotating
//!   schedule of cheap structural checks, each surfaced as
//!   `mcprioq_invariant_violations_total{check=...}`. A violation is a
//!   contract breach, not load: the engine escalates the health ladder
//!   and the event ring captures it.
//!
//! The watchdog checks are designed to be *sound under full concurrency*
//! — a check that cannot distinguish a racing writer from corruption
//! skips (counted in `mcprioq_audit_unstable_skips_total`) rather than
//! cry wolf, because the chaos CI gate asserts zero violations while
//! faults fly.

use std::sync::Arc;

use crate::chain::{AuditSample, McPrioQ};
use crate::metrics::events::{self, Level};
use crate::metrics::{Counter, Histogram, Registry};

/// Invariant catalog (the `{check=...}` label values, DESIGN.md §10).
pub const CHECK_CUM: &str = "cum_monotone";
pub const CHECK_EDGE_SUM: &str = "edge_sum";
pub const CHECK_ARENA: &str = "arena_refcount";
pub const CHECK_WAL_SEQ: &str = "wal_seq_continuity";
pub const CHECK_CKPT_CHAIN: &str = "ckpt_chain";
pub const CHECK_REPL_LAG: &str = "repl_lag";

pub const CHECKS: [&str; 6] = [
    CHECK_CUM,
    CHECK_EDGE_SUM,
    CHECK_ARENA,
    CHECK_WAL_SEQ,
    CHECK_CKPT_CHAIN,
    CHECK_REPL_LAG,
];

/// `[audit]` knobs (config/mod.rs). Defaults keep the armed auditor well
/// under the bench gate's 2% read-throughput budget: one round touches
/// `sample_nodes` probes (each a bounded walk of one hot node) plus a
/// `check_nodes`-node structural window, every `interval_ms`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditConfig {
    /// Arm the background audit thread (`[audit] enabled`).
    pub enabled: bool,
    /// Pause between audit rounds, milliseconds.
    pub interval_ms: u64,
    /// Snapshot-bearing nodes probed for approximation error per round.
    pub sample_nodes: usize,
    /// Top-k depth each error probe compares.
    pub topk: usize,
    /// Nodes per structural-watchdog window (cum + edge-sum checks).
    pub check_nodes: usize,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig {
            enabled: true,
            interval_ms: 200,
            sample_nodes: 8,
            topk: 16,
            check_nodes: 64,
        }
    }
}

/// Persistence coordinates the watchdog's WAL/checkpoint checks read —
/// assembled by the engine from [`crate::persist::PersistState`] so the
/// audit plane needs no storage handle of its own.
#[derive(Debug, Clone, Default)]
pub struct PersistView {
    /// WAL epoch; an epoch change legitimately resets per-shard seqs.
    pub epoch: u64,
    /// Per-shard last appended WAL seq (monotone within an epoch).
    pub last_seqs: Vec<u64>,
    /// Current checkpoint generation (0 = none yet).
    pub generation: u64,
    /// Delta-chain base generation.
    pub chain_base: u64,
    /// Delta-chain length (deltas on top of the base).
    pub chain_len: u64,
}

/// Summary of one error-audit round (logging, tests, bench rows).
#[derive(Debug, Default, Clone, Copy)]
pub struct ErrorRound {
    pub probed: usize,
    pub max_staleness: u64,
    pub max_mass_error: f64,
    pub rank_inversions: u64,
    pub displacement: u64,
}

/// The observatory's state machine: registry handles plus rotating
/// cursors. One `Auditor` per engine, owned by the audit thread (rounds
/// take `&mut self`; all recording sinks are internally thread-safe).
pub struct Auditor {
    cfg: AuditConfig,
    rank_inversions: Arc<Histogram>,
    rank_displacement: Arc<Histogram>,
    mass_error_ppm: Arc<Histogram>,
    staleness: Arc<Histogram>,
    samples_total: Arc<Counter>,
    rounds_total: Arc<Counter>,
    unstable_skips: Arc<Counter>,
    /// One counter per catalog entry, index-aligned with [`CHECKS`].
    violations: Vec<Arc<Counter>>,
    /// Rotating cursor into the snapshot-bearing node walk (error audit).
    sample_cursor: usize,
    /// Rotating cursor into the full node walk (structural window).
    check_cursor: usize,
    /// Which non-chain check runs this watchdog round.
    rotation: usize,
    /// WAL continuity memory: (epoch, per-shard seqs) from the last round.
    wal_state: Option<(u64, Vec<u64>)>,
}

impl Auditor {
    pub fn new(reg: &Registry, cfg: AuditConfig) -> Auditor {
        let violations = CHECKS
            .iter()
            .map(|&check| {
                reg.counter(
                    "mcprioq_invariant_violations_total",
                    "Structural invariant violations detected by the audit watchdog",
                    &[("check", check)],
                )
            })
            .collect();
        Auditor {
            rank_inversions: reg.histogram(
                "mcprioq_audit_rank_error",
                "Snapshot-vs-exact top-k rank error per audit probe \
                 (stat=inversions: strict out-of-order pairs; \
                 stat=displacement: Spearman-footrule rank distance)",
                &[("stat", "inversions")],
            ),
            rank_displacement: reg.histogram(
                "mcprioq_audit_rank_error",
                "Snapshot-vs-exact top-k rank error per audit probe \
                 (stat=inversions: strict out-of-order pairs; \
                 stat=displacement: Spearman-footrule rank distance)",
                &[("stat", "displacement")],
            ),
            mass_error_ppm: reg.histogram(
                "mcprioq_audit_mass_error",
                "Probability mass the snapshot-served top-k misses vs the \
                 exact top-k, parts per million of live mass",
                &[],
            ),
            staleness: reg.histogram(
                "mcprioq_audit_staleness",
                "Snapshot staleness (mutation epochs behind the live list) \
                 at each audit probe",
                &[],
            ),
            samples_total: reg.counter(
                "mcprioq_audit_samples_total",
                "Approximation-error probes taken by the auditor",
                &[],
            ),
            rounds_total: reg.counter(
                "mcprioq_audit_rounds_total",
                "Audit rounds completed (error sampling + watchdog)",
                &[],
            ),
            unstable_skips: reg.counter(
                "mcprioq_audit_unstable_skips_total",
                "Watchdog node checks skipped because the node mutated \
                 mid-scan (retried on a later round)",
                &[],
            ),
            violations,
            cfg,
            sample_cursor: 0,
            check_cursor: 0,
            rotation: 0,
            wal_state: None,
        }
    }

    pub fn config(&self) -> &AuditConfig {
        &self.cfg
    }

    fn violate(&self, idx: usize, n: u64, a: u64, b: u64) {
        if n == 0 {
            return;
        }
        self.violations[idx].add(n);
        events::emit(Level::Error, "audit", CHECKS[idx], a, b);
    }

    /// One approximation-error round over the given chains (one per
    /// shard): probe up to `sample_nodes` hot nodes, feed the registry
    /// histograms, return the round summary.
    pub fn error_round(&mut self, chains: &[&McPrioQ]) -> ErrorRound {
        let mut samples: Vec<AuditSample> = Vec::with_capacity(self.cfg.sample_nodes);
        let per_chain = self.cfg.sample_nodes.div_ceil(chains.len().max(1)).max(1);
        let mut eligible_total = 0usize;
        for chain in chains {
            let before = samples.len();
            let eligible =
                chain.audit_samples(self.sample_cursor, per_chain, self.cfg.topk, &mut samples);
            eligible_total += eligible;
            // Wrapped past this chain's hot set: restart the window so the
            // next round begins at its head again.
            if eligible > 0 && samples.len() == before && self.sample_cursor >= eligible {
                chain.audit_samples(0, per_chain, self.cfg.topk, &mut samples);
            }
        }
        self.sample_cursor = if eligible_total == 0 {
            0
        } else {
            (self.sample_cursor + samples.len()) % eligible_total.max(1)
        };
        let mut round = ErrorRound { probed: samples.len(), ..ErrorRound::default() };
        for s in &samples {
            self.staleness.record(s.staleness);
            self.rank_inversions.record(s.rank_inversions);
            self.rank_displacement.record(s.displacement);
            self.mass_error_ppm.record((s.mass_error * 1e6).round() as u64);
            round.max_staleness = round.max_staleness.max(s.staleness);
            round.max_mass_error = round.max_mass_error.max(s.mass_error);
            round.rank_inversions += s.rank_inversions;
            round.displacement += s.displacement;
        }
        self.samples_total.add(samples.len() as u64);
        round
    }

    /// One watchdog round: a structural window over the chains (snapshot
    /// `cum` monotonicity + tolerant edge-sum) every round, plus one
    /// rotating non-chain check (arena refcounts, WAL seq continuity,
    /// checkpoint chain, replication lag). Returns the escalation-worthy
    /// violations detected this round (replication lag is counted and
    /// event-logged but never escalates the health ladder).
    pub fn watchdog_round(
        &mut self,
        chains: &[&McPrioQ],
        persist: Option<&PersistView>,
        repl_lag: Option<(u64, u64)>,
    ) -> u64 {
        self.rounds_total.inc();
        let mut violations = 0u64;
        // Chain structural window, rotating over all nodes of all shards.
        let total_nodes: usize = chains.iter().map(|c| c.node_count()).sum();
        let mut skip = if total_nodes == 0 { 0 } else { self.check_cursor % total_nodes };
        let mut budget = self.cfg.check_nodes;
        for chain in chains {
            if budget == 0 {
                break;
            }
            let nodes = chain.node_count();
            if skip >= nodes {
                skip -= nodes;
                continue;
            }
            let rep = chain.audit_structural(skip, budget);
            skip = 0;
            budget = budget.saturating_sub(rep.checked);
            self.unstable_skips.add(rep.unstable_skips);
            self.violate(0, rep.cum_violations, rep.cum_violations, 0);
            self.violate(1, rep.edge_sum_violations, rep.edge_sum_violations, 0);
            violations += rep.cum_violations + rep.edge_sum_violations;
        }
        self.check_cursor =
            (self.check_cursor + self.cfg.check_nodes.min(total_nodes.max(1))) % total_nodes.max(1);
        // One rotating non-chain check per round: each is a handful of
        // atomic loads, but rotation keeps the schedule honest as the
        // catalog grows.
        match self.rotation % 4 {
            0 => {
                let s = crate::chain::arena::stats();
                violations += self.check_arena(s.blocks_allocated, s.blocks_freed);
            }
            1 => {
                if let Some(p) = persist {
                    violations += self.check_wal_seqs(p.epoch, &p.last_seqs);
                }
            }
            2 => {
                if let Some(p) = persist {
                    violations += self.check_ckpt_chain(p.generation, p.chain_base, p.chain_len);
                }
            }
            _ => {
                if let Some((lag, bound)) = repl_lag {
                    // Counted and event-logged, but deliberately excluded
                    // from the escalation total: lag is an operating
                    // condition the HEALTH verb already widens for, not
                    // structural corruption.
                    let _ = self.check_repl_lag(lag, bound);
                }
            }
        }
        self.rotation = self.rotation.wrapping_add(1);
        violations
    }

    /// Arena refcount sanity: more blocks freed than allocated means a
    /// double release. The stats are relaxed-read gauges, so only the
    /// direction that racy skew cannot produce is flagged (allocations
    /// are counted before frees ever see the block).
    pub fn check_arena(&self, blocks_allocated: u64, blocks_freed: u64) -> u64 {
        let bad = u64::from(blocks_freed > blocks_allocated);
        self.violate(2, bad, blocks_allocated, blocks_freed);
        bad
    }

    /// Per-shard WAL seq continuity: within one epoch, a shard's last
    /// appended seq never regresses between rounds. An epoch change
    /// (recovery, follower re-bootstrap) legitimately resets the seqs.
    pub fn check_wal_seqs(&mut self, epoch: u64, last_seqs: &[u64]) -> u64 {
        let mut bad = 0u64;
        match &self.wal_state {
            Some((prev_epoch, prev)) if *prev_epoch == epoch && prev.len() == last_seqs.len() => {
                for (shard, (&now, &before)) in last_seqs.iter().zip(prev.iter()).enumerate() {
                    if now < before {
                        self.violate(3, 1, shard as u64, now);
                        bad += 1;
                    }
                }
            }
            _ => {}
        }
        self.wal_state = Some((epoch, last_seqs.to_vec()));
        bad
    }

    /// Checkpoint chain well-formedness: once a checkpoint exists, the
    /// current generation must equal the chain's base + delta count —
    /// anything else means the manifest and the chain disagree.
    pub fn check_ckpt_chain(&self, generation: u64, chain_base: u64, chain_len: u64) -> u64 {
        let bad = u64::from(generation > 0 && generation != chain_base + chain_len);
        self.violate(4, bad, generation, chain_base + chain_len);
        bad
    }

    /// Replication lag bound (`[replicate] max_lag_records`): counted and
    /// event-logged, but this is a *condition*, not corruption — the
    /// HEALTH verb already widens the rung for it, so the engine does not
    /// escalate on this check (DESIGN.md §10).
    pub fn check_repl_lag(&self, lag_records: u64, bound: u64) -> u64 {
        let bad = u64::from(bound > 0 && lag_records > bound);
        self.violate(5, bad, lag_records, bound);
        bad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::ChainConfig;

    fn hot_chain() -> McPrioQ {
        let chain = McPrioQ::new(ChainConfig::default());
        // One hot src with 32 distinct-count edges, then a read to build
        // and publish the snapshot the auditor probes.
        for dst in 0..32u64 {
            for _ in 0..(64 - dst) {
                chain.observe(1, dst);
            }
        }
        let _ = chain.infer_topk(1, 8);
        chain
    }

    #[test]
    fn error_round_is_exact_at_quiescence() {
        let reg = Registry::new();
        let mut auditor = Auditor::new(&reg, AuditConfig::default());
        let chain = hot_chain();
        let round = auditor.error_round(&[&chain]);
        assert_eq!(round.probed, 1);
        assert_eq!(round.rank_inversions, 0, "quiesced snapshot must be exact");
        assert_eq!(round.displacement, 0);
        assert_eq!(round.max_mass_error, 0.0);
    }

    #[test]
    fn error_round_sees_staleness_after_writes() {
        let reg = Registry::new();
        let mut auditor = Auditor::new(&reg, AuditConfig::default());
        let chain = hot_chain();
        // Age the snapshot under its staleness bound: reads still serve
        // it, and the audit must attribute the drift to it.
        for _ in 0..100 {
            chain.observe(1, 31);
        }
        let round = auditor.error_round(&[&chain]);
        assert_eq!(round.probed, 1);
        assert!(round.max_staleness >= 100, "staleness {}", round.max_staleness);
        // dst 31 rose from rank 31 to a top rank; the served snapshot
        // still shows the old order, so displacement must be nonzero.
        assert!(round.displacement > 0);
    }

    #[test]
    fn watchdog_clean_chain_no_violations() {
        let reg = Registry::new();
        let mut auditor = Auditor::new(&reg, AuditConfig::default());
        let chain = hot_chain();
        // Several rounds so the rotation covers every catalog entry.
        let mut total = 0;
        for _ in 0..8 {
            total += auditor.watchdog_round(&[&chain], None, None);
        }
        assert_eq!(total, 0);
        let text = reg.render();
        assert!(text.contains("mcprioq_invariant_violations_total"), "{text}");
        assert!(text.contains("mcprioq_audit_rank_error"), "{text}");
    }

    #[test]
    fn wal_seq_regression_detected_and_epoch_reset_forgiven() {
        let reg = Registry::new();
        let mut auditor = Auditor::new(&reg, AuditConfig::default());
        assert_eq!(auditor.check_wal_seqs(1, &[5, 7]), 0, "first round only records");
        assert_eq!(auditor.check_wal_seqs(1, &[6, 7]), 0, "monotone is clean");
        assert_eq!(auditor.check_wal_seqs(1, &[4, 7]), 1, "shard 0 regressed");
        // Epoch bump: seqs legitimately restart from anywhere.
        assert_eq!(auditor.check_wal_seqs(2, &[0, 0]), 0);
        assert_eq!(auditor.check_wal_seqs(2, &[1, 1]), 0);
    }

    #[test]
    fn ckpt_chain_and_arena_and_lag_checks() {
        let reg = Registry::new();
        let auditor = Auditor::new(&reg, AuditConfig::default());
        assert_eq!(auditor.check_ckpt_chain(0, 0, 0), 0, "no checkpoint yet");
        assert_eq!(auditor.check_ckpt_chain(5, 3, 2), 0);
        assert_eq!(auditor.check_ckpt_chain(5, 3, 1), 1);
        assert_eq!(auditor.check_arena(10, 10), 0);
        assert_eq!(auditor.check_arena(10, 11), 1);
        assert_eq!(auditor.check_repl_lag(100, 0), 0, "bound off");
        assert_eq!(auditor.check_repl_lag(100, 1000), 0);
        assert_eq!(auditor.check_repl_lag(1001, 1000), 1);
        let text = reg.render();
        assert!(text.contains("check=\"ckpt_chain\""), "{text}");
    }
}
