//! Replication unit tests: stream grammar round trips and the replica
//! lag/promotion bookkeeping. The cross-process differentials (follower ==
//! leader, kill-the-leader, snapshot bootstrap) live in
//! `rust/tests/replication.rs`.

use super::wire::{self, StreamMsg};
use super::ReplicaState;
use crate::persist::codec::WalOp;

#[test]
fn stream_grammar_roundtrip() {
    let mut line = String::new();
    wire::write_record(&mut line, 3, 42, &WalOp::Batch(vec![(1, 2), (9, 7)]));
    assert_eq!(line, "RREC 3 42 2 1 2 9 7");
    assert_eq!(
        wire::parse(&line).unwrap(),
        StreamMsg::Record { shard: 3, seq: 42, op: WalOp::Batch(vec![(1, 2), (9, 7)]) }
    );

    // Maintenance records ride the same line grammar (DESIGN.md §6).
    line.clear();
    wire::write_record(&mut line, 1, 7, &WalOp::Decay { num: 1, den: 2 });
    assert_eq!(line, "RDEC 1 7 1 2");
    assert_eq!(
        wire::parse(&line).unwrap(),
        StreamMsg::Record { shard: 1, seq: 7, op: WalOp::Decay { num: 1, den: 2 } }
    );
    line.clear();
    wire::write_record(&mut line, 0, 8, &WalOp::Repair);
    assert_eq!(line, "RREP 0 8");
    assert_eq!(
        wire::parse(&line).unwrap(),
        StreamMsg::Record { shard: 0, seq: 8, op: WalOp::Repair }
    );

    line.clear();
    wire::write_heartbeat(&mut line, &[5, 0, 17]);
    assert_eq!(line, "RHB 3 5 0 17");
    assert_eq!(wire::parse(&line).unwrap(), StreamMsg::Heartbeat { heads: vec![5, 0, 17] });

    line.clear();
    wire::write_stream_header(&mut line, 2, 8);
    assert_eq!(wire::parse(&line).unwrap(), StreamMsg::Stream { epoch: 2, shards: 8 });

    line.clear();
    wire::write_snapshot_header(&mut line, 7, 4096);
    assert_eq!(
        wire::parse(&line).unwrap(),
        StreamMsg::Snapshot { generation: 7, bytes: 4096 }
    );

    assert_eq!(wire::parse("ERR wal hole somewhere").unwrap(),
               StreamMsg::Err("wal hole somewhere".to_string()));
}

#[test]
fn stream_grammar_rejects_malformed() {
    assert!(wire::parse("").is_err());
    assert!(wire::parse("RREC 0 1").is_err()); // missing count
    assert!(wire::parse("RREC 0 1 2 5 6").is_err()); // truncated pair list
    assert!(wire::parse("RREC 0 1 1 5 6 7").is_err()); // trailing args
    assert!(wire::parse("RHB 2 1").is_err()); // short head list
    assert!(wire::parse("RREC 0 1 99999999 1 2").is_err()); // count over cap
    assert!(wire::parse("RDEC 0 1 1").is_err()); // missing denominator
    assert!(wire::parse("RDEC 0 1 1 0").is_err()); // zero denominator
    assert!(wire::parse("RREP 0 1 9").is_err()); // trailing args
    assert!(wire::parse("WAT 1 2").is_err());
}

#[test]
fn replica_state_lag_accounting() {
    let state = ReplicaState::new("127.0.0.1:1".into(), 1, &[10, 20]);
    assert_eq!(state.lag_records(), 0);
    assert_eq!(state.applied_seqs(), vec![10, 20]);

    // Leader runs ahead: heads move, applied lags.
    state.note_head(0, 13);
    state.note_head(1, 20);
    assert_eq!(state.lag_records(), 3);
    // Heads never regress (an old heartbeat can arrive after a record).
    state.note_head(0, 11);
    assert_eq!(state.lag_records(), 3);

    state.note_applied(0, 11, 4);
    state.note_applied(0, 12, 1);
    assert_eq!(state.lag_records(), 1);
    assert_eq!(state.applied_records(), 2);
    assert_eq!(state.applied_updates(), 5);
    state.note_applied(0, 13, 1);
    assert_eq!(state.lag_records(), 0);
    assert_eq!(state.lag_seconds(), 0, "caught up => no staleness");
    assert_eq!(state.applied_seqs(), vec![13, 20]);
}

#[test]
fn replica_state_promotion_and_fault_latch() {
    let state = ReplicaState::new("x".into(), 1, &[0]);
    assert!(!state.promoted());
    state.worker_started();
    state.promote();
    state.promote(); // idempotent
    assert!(state.promoted());
    // The write gate opens only once the apply plane drains: a local
    // write must not race a queued replicated record for a WAL seq.
    assert!(!state.writable(), "apply worker still active");
    state.worker_finished();
    assert!(state.writable());

    assert!(state.fault().is_none());
    state.set_fault("first".into());
    state.set_fault("second".into());
    // First fault wins: it is the root cause.
    assert_eq!(state.fault().as_deref(), Some("first"));
}
