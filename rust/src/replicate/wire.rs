//! The replication stream grammar (leader → follower, one message per
//! line, after the follower's `REPL HELLO` request):
//!
//! ```text
//! RSTREAM <epoch> <nshards>        log catch-up granted: records follow,
//!                                  starting at the HELLO seqs + 1
//! RSNAP <generation> <nbytes>      snapshot bootstrap: <nbytes> of raw
//!                                  checkpoint-codec bytes follow on the
//!                                  wire, then records from the embedded
//!                                  cut points
//! RREC <shard> <seq> <n> <s1> <d1> ... <sn> <dn>
//!                                  one WAL batch record of shard <shard>
//! RDEC <shard> <seq> <num> <den>   one WAL decay record: the leader ran
//!                                  §II.C decay at this sequence position
//!                                  with multiplier num/den — the follower
//!                                  replays it in lockstep (DESIGN.md §6)
//! RREP <shard> <seq>               one WAL order-repair record
//! RHB <nshards> <h1> ... <hn>      heartbeat: the leader's current WAL
//!                                  head per shard (lag = head - applied)
//! ERR <message>                    stream abort (connection closes)
//! ```
//!
//! The record payload reuses the line-protocol conventions (`OBSERVEB`
//! pair lists, `MAX_WIRE_BATCH` cap) so the follower's parser hardening is
//! identical to the server's.

use std::fmt::Write as _;

use crate::coordinator::MAX_WIRE_BATCH;
use crate::persist::codec::WalOp;

/// One parsed stream line.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamMsg {
    Stream { epoch: u64, shards: usize },
    Snapshot { generation: u64, bytes: u64 },
    Record { shard: usize, seq: u64, op: WalOp },
    Heartbeat { heads: Vec<u64> },
    Err(String),
}

/// Append one record line (`RREC`/`RDEC`/`RREP`, no trailing newline) to
/// `out` — the wire image of one WAL record, whatever its kind.
pub fn write_record(out: &mut String, shard: usize, seq: u64, op: &WalOp) {
    match op {
        WalOp::Batch(pairs) => {
            let _ = write!(out, "RREC {shard} {seq} {}", pairs.len());
            for (src, dst) in pairs {
                let _ = write!(out, " {src} {dst}");
            }
        }
        WalOp::Decay { num, den } => {
            let _ = write!(out, "RDEC {shard} {seq} {num} {den}");
        }
        WalOp::Repair => {
            let _ = write!(out, "RREP {shard} {seq}");
        }
    }
}

/// Append one `RHB` line (no trailing newline) to `out`.
pub fn write_heartbeat(out: &mut String, heads: &[u64]) {
    let _ = write!(out, "RHB {}", heads.len());
    for h in heads {
        let _ = write!(out, " {h}");
    }
}

pub fn write_stream_header(out: &mut String, epoch: u64, shards: usize) {
    let _ = write!(out, "RSTREAM {epoch} {shards}");
}

pub fn write_snapshot_header(out: &mut String, generation: u64, bytes: u64) {
    let _ = write!(out, "RSNAP {generation} {bytes}");
}

/// Parse one stream line. Counts are capped at [`MAX_WIRE_BATCH`] so a
/// corrupt or hostile leader can't make the follower allocate unboundedly
/// from one header token.
pub fn parse(line: &str) -> Result<StreamMsg, String> {
    let mut it = line.split_ascii_whitespace();
    let cmd = it.next().ok_or("empty stream line")?;
    let mut num = |name: &str| -> Result<u64, String> {
        it.next()
            .ok_or(format!("{cmd}: missing {name}"))?
            .parse::<u64>()
            .map_err(|_| format!("{cmd}: bad {name}"))
    };
    let count = |n: u64| -> Result<usize, String> {
        if n > MAX_WIRE_BATCH as u64 {
            return Err(format!("count {n} exceeds max {MAX_WIRE_BATCH}"));
        }
        Ok(n as usize)
    };
    let msg = match cmd {
        "RSTREAM" => StreamMsg::Stream {
            epoch: num("epoch")?,
            shards: count(num("shards")?).map_err(|e| format!("RSTREAM: {e}"))?,
        },
        "RSNAP" => StreamMsg::Snapshot { generation: num("generation")?, bytes: num("bytes")? },
        "RREC" => {
            let shard = num("shard")? as usize;
            let seq = num("seq")?;
            let n = count(num("count")?).map_err(|e| format!("RREC: {e}"))?;
            let mut pairs = Vec::with_capacity(n);
            for _ in 0..n {
                pairs.push((num("src")?, num("dst")?));
            }
            StreamMsg::Record { shard, seq, op: WalOp::Batch(pairs) }
        }
        "RDEC" => {
            let shard = num("shard")? as usize;
            let seq = num("seq")?;
            let dnum = num("num")?;
            let den = num("den")?;
            if den == 0 {
                return Err("RDEC: zero denominator".to_string());
            }
            StreamMsg::Record { shard, seq, op: WalOp::Decay { num: dnum, den } }
        }
        "RREP" => StreamMsg::Record {
            shard: num("shard")? as usize,
            seq: num("seq")?,
            op: WalOp::Repair,
        },
        "RHB" => {
            let n = count(num("count")?).map_err(|e| format!("RHB: {e}"))?;
            let mut heads = Vec::with_capacity(n);
            for _ in 0..n {
                heads.push(num("head")?);
            }
            StreamMsg::Heartbeat { heads }
        }
        "ERR" => {
            return Ok(StreamMsg::Err(
                line.strip_prefix("ERR").unwrap_or("").trim().to_string(),
            ))
        }
        other => return Err(format!("unknown stream message {other:?}")),
    };
    if it.next().is_some() {
        return Err(format!("{cmd}: trailing arguments"));
    }
    Ok(msg)
}
