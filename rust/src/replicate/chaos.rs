//! Replication link chaos: an injectable fault schedule for the
//! leader↔follower stream (DESIGN.md §8).
//!
//! The shim sits in exactly one place — the follower's `consume_stream`
//! record arm — and models the faults a real WAN link produces:
//!
//! * **delay** — fixed added latency per record (a slow link).
//! * **duplicate** — a record delivered twice (leader retransmit after a
//!   lost ack); exercises the apply plane's `seq <= applied` dedup.
//! * **drop** — the connection is severed mid-record, as if the TCP
//!   session died with the record in flight. The record is *not* lost
//!   from the system: the reconnect handshake resumes from the
//!   follower's applied seqs, so the leader re-streams it. (Silently
//!   swallowing a record would be a fault TCP cannot produce — the
//!   stream is ordered and reliable; what reality loses is
//!   *connections*.)
//! * **partition** — a severed link whose redial is suppressed for a
//!   window (switch outage): exercises the link's backoff, the
//!   `lag_exceeded` health state, and catch-up on heal.
//!
//! Schedules are counter-based and deterministic (same plan, same
//! stream, same faults) — the same reproducibility discipline as
//! `persist::io::FaultPlan`. The plan is not reachable from TOML: only
//! tests and the bench harness construct one, so a production config
//! cannot ship with a chaotic link.

use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::sync::shim::{AtomicU64, Ordering};

/// Counter-scheduled link-fault plan. The default plan is null (no
/// faults); `Option<ChaosPlan>::None` in the config means the same.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosPlan {
    /// Sever the link on every Nth record (0 = never).
    pub drop_every: u64,
    /// Deliver every Nth record twice (0 = never).
    pub dup_every: u64,
    /// Added delivery latency per record, in milliseconds.
    pub delay_ms: u64,
    /// After this many records, partition the link… (0 = never)
    pub partition_after: u64,
    /// …for this long: the severed link's redial is suppressed until the
    /// window elapses.
    pub partition_ms: u64,
}

impl ChaosPlan {
    pub fn is_null(&self) -> bool {
        *self == ChaosPlan::default()
    }
}

/// What the link should do with the record it just read.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosVerdict {
    Deliver,
    /// Deliver the record twice (retransmit).
    Duplicate,
    /// Sever the connection; the record is re-streamed after reconnect.
    Sever,
    /// Sever and suppress redial for the partition window.
    Partition,
}

/// Live schedule state: survives reconnects (the record counter keeps
/// counting across link incarnations, so "drop every 100th" doesn't
/// reset to zero each time it fires and sever the link forever).
#[derive(Debug)]
pub struct ChaosState {
    plan: ChaosPlan,
    records: AtomicU64,
    blocked_until: Mutex<Option<Instant>>,
}

impl ChaosState {
    pub fn new(plan: ChaosPlan) -> ChaosState {
        ChaosState { plan, records: AtomicU64::new(0), blocked_until: Mutex::new(None) }
    }

    /// Consult the schedule for the next record (applies the configured
    /// delay inline). Partition wins over drop wins over duplicate when
    /// several fire on the same record.
    pub fn on_record(&self) -> ChaosVerdict {
        if self.plan.delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.plan.delay_ms));
        }
        let n = self.records.fetch_add(1, Ordering::Relaxed) + 1;
        if self.plan.partition_after > 0 && n == self.plan.partition_after {
            let until = Instant::now() + Duration::from_millis(self.plan.partition_ms);
            *self.blocked_until.lock().unwrap_or_else(PoisonError::into_inner) = Some(until);
            return ChaosVerdict::Partition;
        }
        if self.plan.drop_every > 0 && n % self.plan.drop_every == 0 {
            return ChaosVerdict::Sever;
        }
        if self.plan.dup_every > 0 && n % self.plan.dup_every == 0 {
            return ChaosVerdict::Duplicate;
        }
        ChaosVerdict::Deliver
    }

    /// Time left in a partition window (`None` = dialing is allowed).
    /// Clears the window once elapsed.
    pub fn dial_blocked(&self) -> Option<Duration> {
        let mut blocked = self.blocked_until.lock().unwrap_or_else(PoisonError::into_inner);
        match *blocked {
            Some(until) => {
                let now = Instant::now();
                if now >= until {
                    *blocked = None;
                    None
                } else {
                    Some(until - now)
                }
            }
            None => None,
        }
    }

    /// Records the schedule has seen (test probe).
    pub fn records_seen(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_plan_always_delivers() {
        let s = ChaosState::new(ChaosPlan::default());
        for _ in 0..1000 {
            assert_eq!(s.on_record(), ChaosVerdict::Deliver);
        }
        assert!(s.dial_blocked().is_none());
        assert_eq!(s.records_seen(), 1000);
    }

    #[test]
    fn drop_and_dup_schedules_fire() {
        let s = ChaosState::new(ChaosPlan { drop_every: 4, dup_every: 3, ..Default::default() });
        let verdicts: Vec<ChaosVerdict> = (0..12).map(|_| s.on_record()).collect();
        // Record 12 is both a 4th and a 3rd: drop wins.
        assert_eq!(verdicts[11], ChaosVerdict::Sever);
        assert_eq!(verdicts[3], ChaosVerdict::Sever);
        assert_eq!(verdicts[2], ChaosVerdict::Duplicate);
        assert_eq!(verdicts[0], ChaosVerdict::Deliver);
    }

    #[test]
    fn partition_blocks_dialing_for_the_window() {
        let s = ChaosState::new(ChaosPlan {
            partition_after: 2,
            partition_ms: 50,
            ..Default::default()
        });
        assert_eq!(s.on_record(), ChaosVerdict::Deliver);
        assert_eq!(s.on_record(), ChaosVerdict::Partition);
        assert!(s.dial_blocked().is_some(), "redial suppressed inside the window");
        std::thread::sleep(Duration::from_millis(60));
        assert!(s.dial_blocked().is_none(), "window elapsed, dialing allowed");
        // The schedule fires once, not on every later record.
        assert_eq!(s.on_record(), ChaosVerdict::Deliver);
    }
}
