//! Follower side: the leader link and the per-shard apply workers behind
//! `mcprioq serve --follow <addr>`.
//!
//! Startup ([`start_follower`], blocking):
//!
//! 1. Recover locally (normal `persist::open_engine` when a data dir is
//!    configured) to learn the durable WAL epoch + per-shard last seqs.
//! 2. Dial the leader (reconnect-with-backoff) and send `REPL HELLO`.
//! 3. `RSTREAM` → keep the recovered engine and tail from where it is.
//!    `RSNAP` → install the leader's snapshot as the local committed
//!    checkpoint ([`crate::persist::install_snapshot`]), adopt the
//!    leader's shard layout, and re-open the engine from it — bootstrap
//!    is just recovery from a checkpoint that happened to arrive over the
//!    wire, so there is exactly one restore path.
//! 4. Spawn one apply worker per shard (record queue each) and the link
//!    thread that feeds them.
//!
//! The link reconnects forever with backoff; every reconnect re-sends
//! HELLO from the *durable* per-shard seqs, so records already queued but
//! not yet applied are simply received twice and deduplicated by the
//! worker's sequence check. A mid-life `RSNAP` (the leader truncated past
//! us while we were gone) is a terminal fault — the engine is shared with
//! the read path and cannot be swapped live; the operator restarts the
//! follower and startup takes the snapshot path. Promotion (wire
//! `PROMOTE`, or leader-loss auto-promotion when configured) latches
//! [`ReplicaState::promoted`]; the link closes, the workers drain and
//! exit, and the server starts accepting writes.

use std::io::{self, BufRead, BufReader, Read, Write as _};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{ReplicateConfig, ServerConfig};
use crate::coordinator::{connect_backoff, BoundedQueue, Engine, Request};
use crate::persist::codec::WalOp;
use crate::persist::{codec, install_snapshot, open_engine};
use crate::runtime::RetryPolicy;
use crate::sync::shim::{AtomicBool, Ordering};

use super::chaos::{ChaosState, ChaosVerdict};
use super::{wire, ReplicaState};

/// One streamed WAL record (batch or maintenance) queued for its shard's
/// apply worker.
type ReplRecord = (u64, WalOp);

/// Records buffered per shard between the link and its apply worker
/// (records are whole leader batches, so this is a deep buffer; a full
/// queue backpressures the link and, through TCP, the leader's tailer).
const APPLY_QUEUE_RECORDS: usize = 1024;

/// Read timeout of the link's stream socket: the poll cadence for stop /
/// promotion / auto-promotion checks while the leader is quiet.
const LINK_POLL: Duration = Duration::from_millis(100);

/// How long a reconnect attempt dials before the outer loop re-checks
/// promotion and tries again.
const RECONNECT_DIAL: Duration = Duration::from_millis(500);

/// A running follower: the engine serving reads, the shared replica
/// state, and the replication machinery. Dropping it stops the link and
/// the apply workers (the engine is left to its other owners).
pub struct FollowerHandle {
    pub engine: Arc<Engine>,
    pub state: Arc<ReplicaState>,
    stop: Arc<AtomicBool>,
    queues: Vec<Arc<BoundedQueue<ReplRecord>>>,
    link: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl FollowerHandle {
    /// Ask the replication plane to stop (link + workers wind down).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for q in &self.queues {
            q.close();
        }
    }

    /// Promote this follower: stop following, accept writes. Idempotent.
    pub fn promote(&self) {
        self.state.promote();
    }

    /// Wait until every shard's applied seq reaches `target[shard]`
    /// (false on timeout or a replication fault) — the tests' and smoke
    /// jobs' "lag is 0 relative to a known leader position" barrier.
    pub fn wait_caught_up(&self, target: &[u64], timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            let applied = self.state.applied_seqs();
            let done = target
                .iter()
                .enumerate()
                .all(|(i, &t)| applied.get(i).copied().unwrap_or(0) >= t);
            if done {
                return true;
            }
            if self.state.fault().is_some() || Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for FollowerHandle {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.link.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Start a follower against `leader`: bootstrap (possibly via snapshot),
/// then stream. Blocks until the initial handshake succeeds or
/// `replicate.connect_timeout` elapses. `config.shards` is adopted from
/// the leader when a snapshot bootstrap replaces the local state.
pub fn start_follower(
    mut config: ServerConfig,
    workers: usize,
    leader: &str,
) -> Result<FollowerHandle, String> {
    let rcfg = config.replicate_config();

    // --- 1. local recovery: what do we already have on disk? ---
    let durable = config.persist_config()?.is_some();
    let (mut engine, mut epoch, mut seqs) = if durable {
        let (engine, _report) = open_engine(&config, workers)?;
        let persist = engine.persist_state().expect("open_engine arms persistence");
        let (e, s) = (persist.epoch(), persist.last_seqs());
        (engine, e, s)
    } else {
        let engine = Engine::new(&config, workers);
        let n = engine.shard_count();
        (engine, 0, vec![0u64; n])
    };

    // --- 2. handshake ---
    let stream = connect_backoff(leader, rcfg.connect_timeout)
        .map_err(|e| format!("connecting to leader {leader}: {e}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(rcfg.connect_timeout)).ok();
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("cloning leader stream: {e}"))?,
    );
    send_hello(&stream, epoch, &seqs).map_err(|e| format!("sending HELLO: {e}"))?;
    let header = read_stream_line(&mut reader, rcfg.connect_timeout)
        .map_err(|e| format!("reading handshake reply: {e}"))?
        .ok_or("leader closed the connection during the handshake")?;
    let mut snapshot_bootstrap = false;
    match wire::parse(&header)? {
        wire::StreamMsg::Stream { epoch: lepoch, shards } => {
            // The leader only grants log catch-up when epoch and layout
            // already match; anything else here is a protocol bug.
            if lepoch != epoch || shards != engine.shard_count() {
                return Err(format!(
                    "leader granted a stream for epoch {lepoch}/{shards} shards, \
                     follower is at epoch {epoch}/{} shards",
                    engine.shard_count()
                ));
            }
        }
        wire::StreamMsg::Snapshot { generation, bytes } => {
            snapshot_bootstrap = true;
            let blob =
                read_blob_timeout(&mut reader, bytes, Instant::now() + rcfg.connect_timeout)
                    .map_err(|e| format!("reading leader snapshot ({bytes} bytes): {e}"))?;
            if durable {
                // The divergent/stale local state is superseded: shut the
                // engine down (releases its WAL writers), install the
                // snapshot as the committed checkpoint, and recover from
                // it — the one restore path, at its usual front door.
                engine.shutdown();
                drop(engine);
                let pcfg = config
                    .persist_config()?
                    .expect("durable follower has a persist config");
                let (snap_epoch, cuts) = install_snapshot(&pcfg, generation, &blob)?;
                config.shards = cuts.len();
                let (reopened, _report) = open_engine(&config, workers)?;
                epoch = snap_epoch;
                seqs = cuts;
                engine = reopened;
            } else {
                let (snap_epoch, cuts, snap) = codec::decode_snapshot(&blob)
                    .map_err(|e| format!("leader snapshot: {e}"))?;
                if engine.shard_count() != cuts.len() {
                    engine.shutdown();
                    drop(engine);
                    config.shards = cuts.len();
                    engine = Engine::new(&config, workers);
                }
                engine.import_snapshot(&snap);
                epoch = snap_epoch;
                seqs = cuts;
            }
        }
        wire::StreamMsg::Err(e) => return Err(format!("leader rejected HELLO: {e}")),
        other => return Err(format!("unexpected handshake reply {other:?}")),
    }

    // --- 3. replication machinery ---
    let state = Arc::new(ReplicaState::new(leader.to_string(), epoch, &seqs));
    if snapshot_bootstrap {
        state.set_snapshot_bootstrap();
    }
    // Follower-link telemetry joins the engine's registry so METRICS /
    // the HTTP sidecar expose lag and link state (DESIGN.md §9).
    state.register_metrics(engine.telemetry());
    let stop = Arc::new(AtomicBool::new(false));
    let queues: Vec<Arc<BoundedQueue<ReplRecord>>> = (0..engine.shard_count())
        .map(|_| Arc::new(BoundedQueue::new(APPLY_QUEUE_RECORDS)))
        .collect();
    let mut worker_handles = Vec::with_capacity(queues.len());
    for (shard, queue) in queues.iter().enumerate() {
        let queue = Arc::clone(queue);
        let engine = Arc::clone(&engine);
        let state = Arc::clone(&state);
        // Counted before the spawn so `writable()` can never observe a
        // half-started apply plane as "drained".
        state.worker_started();
        worker_handles
            .push(std::thread::spawn(move || apply_loop(shard, queue, engine, state)));
    }
    stream.set_read_timeout(Some(LINK_POLL)).ok();
    let link = {
        let engine = Arc::clone(&engine);
        let state = Arc::clone(&state);
        let stop = Arc::clone(&stop);
        let queues = queues.clone();
        let leader = leader.to_string();
        std::thread::spawn(move || {
            link_loop(leader, engine, state, queues, stop, rcfg, Some(reader))
        })
    };

    Ok(FollowerHandle {
        engine,
        state,
        stop,
        queues,
        link: Some(link),
        workers: worker_handles,
    })
}

fn send_hello(mut stream: &TcpStream, epoch: u64, seqs: &[u64]) -> io::Result<()> {
    let mut line = Request::ReplHello { epoch, last_seqs: seqs.to_vec() }.encode();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()
}

/// Decrements [`ReplicaState`]'s worker count however the apply loop
/// exits (drain, fault, even panic) so promotion's write gate opens.
struct WorkerGuard<'a>(&'a ReplicaState);

impl Drop for WorkerGuard<'_> {
    fn drop(&mut self) {
        self.0.worker_finished();
    }
}

/// One shard's apply worker: dequeue records in order, verify sequence
/// contiguity (duplicates from reconnect overlap are skipped), and apply
/// through the engine's replicated-apply path. Any divergence is a
/// terminal fault — applying past it would corrupt the replica.
fn apply_loop(
    shard: usize,
    queue: Arc<BoundedQueue<ReplRecord>>,
    engine: Arc<Engine>,
    state: Arc<ReplicaState>,
) {
    let _done = WorkerGuard(&state);
    loop {
        let records = queue.pop_batch_timeout(32, Duration::from_millis(20));
        if records.is_empty() {
            if queue.is_closed() {
                return;
            }
            continue;
        }
        for (seq, op) in records {
            let applied = state.applied(shard);
            if seq <= applied {
                continue; // reconnect overlap: already applied (and logged)
            }
            if seq != applied + 1 {
                state.set_fault(format!(
                    "shard {shard}: expected replicated seq {}, got {seq}",
                    applied + 1
                ));
                return;
            }
            if let Err(e) = engine.apply_replicated(shard, seq, &op) {
                state.set_fault(e);
                return;
            }
            let updates = match &op {
                WalOp::Batch(batch) => batch.len(),
                WalOp::Decay { .. } | WalOp::Repair => 0,
            };
            state.note_applied(shard, seq, updates);
        }
    }
}

/// The leader link: consume the stream, fan records out to the shard
/// queues, reconnect (with fresh HELLO negotiation) on any disconnect.
fn link_loop(
    leader: String,
    engine: Arc<Engine>,
    state: Arc<ReplicaState>,
    queues: Vec<Arc<BoundedQueue<ReplRecord>>>,
    stop: Arc<AtomicBool>,
    rcfg: ReplicateConfig,
    mut conn: Option<BufReader<TcpStream>>,
) {
    let finished = |state: &ReplicaState| {
        stop.load(Ordering::SeqCst) || state.promoted() || state.fault().is_some()
    };
    // Link-fault schedule (tests/bench only; None in production). Lives
    // here — not per-connection — so the record counter and any partition
    // window survive reconnects.
    let chaos = rcfg.chaos.filter(|p| !p.is_null()).map(ChaosState::new);
    // Unified reconnect pacing (DESIGN.md §8): capped exponential with
    // deterministic jitter instead of a flat retry-hammer; the attempt
    // counter resets every time a connection is actually established.
    let retry = RetryPolicy::connect(0xF0_110_3E6);
    let mut attempts: u32 = 0;
    while !finished(&state) {
        let reader = match conn.take() {
            Some(r) => r,
            None => {
                if let Some(grace) = rcfg.auto_promote {
                    if state.contact_age() >= grace {
                        eprintln!(
                            "[replicate] no leader contact for {:.1?}; auto-promoting",
                            state.contact_age()
                        );
                        state.promote();
                        break;
                    }
                }
                if let Some(left) = chaos.as_ref().and_then(|c| c.dial_blocked()) {
                    // Injected partition: redial suppressed for the window.
                    std::thread::sleep(left.min(Duration::from_millis(50)));
                    continue;
                }
                match reconnect(&leader, &engine, &state) {
                    Ok(r) => r,
                    Err(_) => {
                        // Transient (leader still down) — unless reconnect
                        // latched a fault (snapshot resync required).
                        if state.fault().is_some() {
                            break;
                        }
                        attempts += 1;
                        retry.sleep(attempts);
                        continue;
                    }
                }
            }
        };
        attempts = 0;
        state.set_connected(true);
        state.note_contact();
        consume_stream(reader, &state, &queues, rcfg.auto_promote, chaos.as_ref(), &finished);
        state.set_connected(false);
    }
    state.set_connected(false);
    for q in &queues {
        q.close();
    }
}

/// Read stream lines until disconnect or shutdown. Partial lines survive
/// read timeouts (the buffer is only cleared after a full line), so the
/// poll cadence never tears a record.
fn consume_stream(
    mut reader: BufReader<TcpStream>,
    state: &ReplicaState,
    queues: &[Arc<BoundedQueue<ReplRecord>>],
    auto_promote: Option<Duration>,
    chaos: Option<&ChaosState>,
    finished: &dyn Fn(&ReplicaState) -> bool,
) {
    let mut line = String::with_capacity(4096);
    loop {
        if finished(state) {
            return;
        }
        match reader.read_line(&mut line) {
            Ok(0) => return, // leader closed
            Ok(_) => {
                if !line.ends_with('\n') {
                    return; // EOF mid-line
                }
                let msg = wire::parse(line.trim_end());
                line.clear();
                match msg {
                    Ok(wire::StreamMsg::Record { shard, seq, op }) => {
                        state.note_contact();
                        if shard >= queues.len() {
                            state.set_fault(format!(
                                "leader streamed shard {shard}, follower has {}",
                                queues.len()
                            ));
                            return;
                        }
                        // Chaos shim: sever/partition drop the *connection*
                        // (the reconnect handshake re-streams the record),
                        // never the record itself — see `chaos` module docs.
                        let verdict =
                            chaos.map(ChaosState::on_record).unwrap_or(ChaosVerdict::Deliver);
                        if matches!(verdict, ChaosVerdict::Sever | ChaosVerdict::Partition) {
                            return;
                        }
                        state.note_head(shard, seq);
                        let dup = verdict == ChaosVerdict::Duplicate;
                        let record = (seq, op);
                        if dup
                            && !push_with_backpressure(
                                &queues[shard],
                                record.clone(),
                                state,
                                finished,
                            )
                        {
                            return;
                        }
                        if !push_with_backpressure(&queues[shard], record, state, finished) {
                            return;
                        }
                    }
                    Ok(wire::StreamMsg::Heartbeat { heads }) => {
                        state.note_contact();
                        for (shard, head) in heads.iter().enumerate() {
                            if shard < queues.len() {
                                state.note_head(shard, *head);
                            }
                        }
                    }
                    Ok(wire::StreamMsg::Err(e)) => {
                        // Stream aborted server-side (e.g. WAL truncated
                        // under the tailer): reconnect renegotiates.
                        eprintln!("[replicate] leader aborted stream: {e}");
                        return;
                    }
                    Ok(other) => {
                        state.set_fault(format!("unexpected mid-stream message {other:?}"));
                        return;
                    }
                    Err(e) => {
                        eprintln!("[replicate] unparseable stream line: {e}");
                        return;
                    }
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                // Poll tick; partial line (if any) is preserved. The
                // auto-promotion clock must run here too: a partitioned
                // or wedged leader can leave the socket open but silent
                // for far longer than any failover budget.
                if let Some(grace) = auto_promote {
                    if state.contact_age() >= grace {
                        eprintln!(
                            "[replicate] no leader contact for {:.1?}; auto-promoting",
                            state.contact_age()
                        );
                        state.promote();
                        return;
                    }
                }
                continue;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Blocking push with an escape hatch: applies backpressure to the link
/// (and through TCP to the leader) while still honouring shutdown,
/// promotion, and faults.
fn push_with_backpressure(
    queue: &BoundedQueue<ReplRecord>,
    mut record: ReplRecord,
    state: &ReplicaState,
    finished: &dyn Fn(&ReplicaState) -> bool,
) -> bool {
    loop {
        match queue.try_push(record) {
            Ok(()) => return true,
            Err(back) => {
                if finished(state) || queue.is_closed() {
                    return false;
                }
                record = back;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Redo the handshake after a disconnect, from the *durable* positions.
/// A snapshot demand here is terminal (see the module docs).
fn reconnect(
    leader: &str,
    engine: &Arc<Engine>,
    state: &ReplicaState,
) -> Result<BufReader<TcpStream>, String> {
    let stream =
        connect_backoff(leader, RECONNECT_DIAL).map_err(|e| format!("dial: {e}"))?;
    stream.set_nodelay(true).ok();
    let seqs = match engine.persist_state() {
        Some(p) => p.last_seqs(),
        None => state.applied_seqs(),
    };
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    send_hello(&stream, state.epoch(), &seqs).map_err(|e| format!("HELLO: {e}"))?;
    let mut reader =
        BufReader::new(stream.try_clone().map_err(|e| format!("clone: {e}"))?);
    let header = read_stream_line(&mut reader, Duration::from_secs(5))
        .map_err(|e| format!("handshake reply: {e}"))?
        .ok_or("leader closed during handshake")?;
    match wire::parse(&header).map_err(|e| format!("handshake reply: {e}"))? {
        wire::StreamMsg::Stream { .. } => {
            stream.set_read_timeout(Some(LINK_POLL)).ok();
            Ok(reader)
        }
        wire::StreamMsg::Snapshot { .. } => {
            state.set_fault(
                "leader requires a snapshot resync (WAL truncated past this \
                 follower); restart the follower to bootstrap"
                    .to_string(),
            );
            Err("snapshot resync required".to_string())
        }
        wire::StreamMsg::Err(e) => Err(format!("leader rejected HELLO: {e}")),
        other => Err(format!("unexpected handshake reply {other:?}")),
    }
}

/// Read one `\n`-terminated line, tolerating read-timeout ticks until
/// `timeout` elapses. `Ok(None)` = orderly EOF before any byte.
fn read_stream_line(
    reader: &mut BufReader<TcpStream>,
    timeout: Duration,
) -> io::Result<Option<String>> {
    let deadline = Instant::now() + timeout;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(if line.is_empty() { None } else { Some(line) }),
            Ok(_) => {
                if line.ends_with('\n') {
                    line.truncate(line.trim_end().len());
                    return Ok(Some(line));
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted =>
            {
                if Instant::now() >= deadline {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "timed out waiting for the leader's reply",
                    ));
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Read exactly `len` bytes in bounded chunks, tolerating read-timeout
/// ticks until `deadline`. The buffer grows only as data actually
/// arrives, so a corrupt or hostile `RSNAP` length header cannot force a
/// huge up-front allocation (the `wire` module's cap invariant, extended
/// to the one length field that is legitimately unbounded).
fn read_blob_timeout(
    reader: &mut impl Read,
    len: u64,
    deadline: Instant,
) -> io::Result<Vec<u8>> {
    const CHUNK: usize = 1 << 20;
    let mut blob = Vec::with_capacity(len.min(16 << 20) as usize);
    let mut chunk = vec![0u8; (len as usize).clamp(1, CHUNK)];
    while (blob.len() as u64) < len {
        let want = ((len - blob.len() as u64) as usize).min(chunk.len());
        match reader.read(&mut chunk[..want]) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "leader closed mid-snapshot",
                ))
            }
            Ok(n) => blob.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut
                    || e.kind() == io::ErrorKind::Interrupted =>
            {
                if Instant::now() >= deadline {
                    return Err(e);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(blob)
}
