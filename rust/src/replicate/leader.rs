//! Leader side: turn one server connection into a replication stream.
//!
//! The server's connection handler calls [`serve_follower`] when it parses
//! a `REPL HELLO`; from then on the connection is push-only until the
//! follower disconnects (detected via write failure) or the server stops.
//!
//! Negotiation (DESIGN.md §5 bootstrap state machine):
//!
//! ```text
//! HELLO(epoch, seqs) ──▶ epoch/shard-count match AND every shard's WAL
//!                        reaches back to seqs[i]+1 AND total lag within
//!                        replicate.snapshot_records?
//!        │ yes                                │ no
//!        ▼                                    ▼
//! RSTREAM, tail from seqs          RSNAP + checkpoint-codec bytes of a
//!                                  freshly paused export, tail from its
//!                                  embedded cut points
//! ```
//!
//! The stream itself is `wal::WalCursor` polling per shard: sealed
//! segments first, then the live tail as the ingest workers grow it. A
//! retention pin registered with [`PersistState`] keeps checkpoints from
//! truncating segments this follower hasn't received yet; the pin dies
//! with the connection.

use std::io::{self, BufWriter, Write as _};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::Engine;
use crate::persist::wal::WalCursor;
use crate::persist::{codec, PersistState};
use crate::sync::shim::{AtomicBool, Ordering};

use super::wire;

/// Records drained per shard per scheduling round (fairness bound: one
/// hot shard can't starve the others' cursors).
const RECORDS_PER_ROUND: usize = 64;

/// Idle poll cadence when every cursor is caught up — the floor of an
/// exponential backoff (each empty round doubles the sleep up to
/// [`IDLE_POLL_MAX`], reset by traffic), so a quiet stream costs a
/// handful of directory rescans per second instead of hundreds.
const IDLE_POLL: Duration = Duration::from_millis(2);

/// Idle backoff ceiling: worst-case extra delivery latency after a quiet
/// spell, well under the heartbeat cadence.
const IDLE_POLL_MAX: Duration = Duration::from_millis(64);

/// Floor of the per-write stall timeout on the follower socket. A
/// SIGKILLed follower surfaces as an immediate write error, but a
/// *half-open* peer (cable pull, frozen VM) accepts nothing while TCP
/// keeps buffering: without a bound the streamer thread blocks for the
/// kernel's multi-minute retry horizon with the retention pin held, and
/// one dead follower stalls WAL truncation indefinitely. A stalled write
/// ends the stream, the `PinGuard` drops the pin, and the follower
/// renegotiates (log catch-up or snapshot) when it actually returns.
const WRITE_STALL_FLOOR: Duration = Duration::from_secs(5);

/// Drops the follower's WAL retention pin when the stream ends, however
/// it ends.
struct PinGuard {
    persist: Arc<PersistState>,
    id: u64,
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        self.persist.pin_drop(self.id);
    }
}

/// Serve one follower on an accepted connection. Returns when the
/// follower disconnects, the stream hits unrecoverable WAL corruption
/// (reported as an `ERR` line), or the server stops. I/O errors are the
/// normal "follower went away" exit and are returned to the caller.
pub fn serve_follower(
    engine: &Arc<Engine>,
    writer: &mut BufWriter<TcpStream>,
    hello_epoch: u64,
    hello_seqs: Vec<u64>,
    stop: &AtomicBool,
) -> io::Result<()> {
    let mut line = String::with_capacity(4096);
    let Some(persist) = engine.persist_state().map(Arc::clone) else {
        writer.write_all(b"ERR replication requires a data dir on the leader\n")?;
        writer.flush()?;
        return Ok(());
    };
    // Bound every write so a half-open follower cannot hold the retention
    // pin forever (see WRITE_STALL_FLOOR). Generous relative to the
    // heartbeat so an alive-but-slow follower backpressures (TCP window)
    // without being cut off by one congested interval.
    let stall = engine.replicate_config().heartbeat.saturating_mul(20).max(WRITE_STALL_FLOOR);
    writer.get_ref().set_write_timeout(Some(stall)).ok();
    let nshards = engine.shard_count();
    let epoch = persist.epoch();

    // Pin first, then decide: the pin blocks truncation from racing the
    // availability check below. (A checkpoint already mid-truncation can
    // still win that race; the cursor then reports a WAL hole, the stream
    // ends with ERR, and the follower's reconnect handshake lands in the
    // snapshot path — self-healing, just slower.)
    let pin = PinGuard {
        id: persist.pin_create(if hello_seqs.len() == nshards {
            hello_seqs.clone()
        } else {
            vec![0; nshards]
        }),
        persist: Arc::clone(&persist),
    };

    let heads = persist.last_seqs();
    let mut snapshot = hello_epoch != epoch || hello_seqs.len() != nshards;
    if !snapshot {
        let lag: u64 = heads
            .iter()
            .zip(&hello_seqs)
            .map(|(h, s)| h.saturating_sub(*s))
            .sum();
        let threshold = engine.replicate_config().snapshot_records;
        snapshot = threshold > 0 && lag > threshold;
    }
    if !snapshot {
        // Log catch-up needs every shard's WAL to reach back to the
        // follower's position (truncation may have passed a follower that
        // was disconnected for a while).
        for (shard, (&head, &seq)) in heads.iter().zip(&hello_seqs).enumerate() {
            if head == seq {
                continue; // nothing to stream; availability is irrelevant
            }
            let dir = persist.config().shard_dir(epoch, shard);
            let segs = crate::persist::wal::scan_segments(&dir)
                .map_err(|e| io::Error::other(format!("{}: {e}", dir.display())))?;
            match segs.first() {
                Some(first) if first.first_seq <= seq + 1 => {}
                _ => {
                    snapshot = true;
                    break;
                }
            }
        }
    }

    let start_seqs = if snapshot {
        // A freshly paused export is self-consistent with its cut points:
        // streaming resumes at exactly cuts + 1, no matter how far the
        // last durable checkpoint lags.
        let (cuts, export) = engine.with_ingest_paused(|| {
            (persist.last_seqs(), engine.export())
        });
        let bytes = codec::encode_snapshot(epoch, &cuts, &export);
        line.clear();
        wire::write_snapshot_header(&mut line, persist.generation(), bytes.len() as u64);
        line.push('\n');
        writer.write_all(line.as_bytes())?;
        writer.write_all(&bytes)?;
        writer.flush()?;
        for (shard, &seq) in cuts.iter().enumerate() {
            pin.persist.pin_advance(pin.id, shard, seq);
        }
        cuts
    } else {
        line.clear();
        wire::write_stream_header(&mut line, epoch, nshards);
        line.push('\n');
        writer.write_all(line.as_bytes())?;
        writer.flush()?;
        hello_seqs
    };

    let mut cursors: Vec<WalCursor> = start_seqs
        .iter()
        .enumerate()
        .map(|(shard, &seq)| WalCursor::new(persist.config().shard_dir(epoch, shard), seq))
        .collect();

    let heartbeat = engine.replicate_config().heartbeat;
    // First heartbeat goes out immediately: it carries the heads a just-
    // bootstrapped follower needs to report lag before any record lands.
    line.clear();
    wire::write_heartbeat(&mut line, &persist.last_seqs());
    line.push('\n');
    writer.write_all(line.as_bytes())?;
    writer.flush()?;
    let mut last_hb = Instant::now();
    let mut idle = IDLE_POLL;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let mut sent = 0usize;
        for (shard, cursor) in cursors.iter_mut().enumerate() {
            for _ in 0..RECORDS_PER_ROUND {
                match cursor.poll() {
                    Ok(Some((seq, op))) => {
                        line.clear();
                        wire::write_record(&mut line, shard, seq, &op);
                        line.push('\n');
                        writer.write_all(line.as_bytes())?;
                        pin.persist.pin_advance(pin.id, shard, seq);
                        sent += 1;
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // Real corruption (or truncation won the pin race):
                        // abort the stream; the follower renegotiates.
                        let _ = writer.write_all(format!("ERR {e}\n").as_bytes());
                        let _ = writer.flush();
                        return Ok(());
                    }
                }
            }
        }
        if sent > 0 {
            writer.flush()?;
        }
        if last_hb.elapsed() >= heartbeat {
            line.clear();
            wire::write_heartbeat(&mut line, &persist.last_seqs());
            line.push('\n');
            writer.write_all(line.as_bytes())?;
            writer.flush()?;
            last_hb = Instant::now();
        }
        if sent == 0 {
            std::thread::sleep(idle);
            idle = (idle * 2).min(IDLE_POLL_MAX);
        } else {
            idle = IDLE_POLL;
        }
    }
}
