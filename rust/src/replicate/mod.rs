//! Replication: stream the per-shard WAL to followers for warm standby
//! and scale-out replica reads (DESIGN.md §5).
//!
//! PR 3's segmented per-shard WAL is a ready-made replication log: every
//! acked batch is already a framed, CRC'd, sequence-numbered record on
//! disk. This subsystem adds the two halves that turn it into a
//! leader/follower plane:
//!
//! * [`leader`] — per-follower streaming, driven by `wal::WalCursor`
//!   (sealed segments + the live tail of each shard). The handshake
//!   (`REPL HELLO` with wal-epoch + per-shard last seqs) decides between
//!   log catch-up and a snapshot bootstrap via the checkpoint codec;
//!   connected followers pin WAL truncation so a slow follower lags
//!   instead of being forced into a resync.
//! * [`follower`] — `mcprioq serve --follow <addr>`: per-shard apply
//!   workers feed each streamed record through
//!   `Engine::apply_replicated` (local WAL append, then in-memory apply,
//!   both under the ingest gate), so a follower with a data dir is
//!   itself durable and a promoted follower recovers like any leader.
//!   Reads (TOPK/MTOPK/REC/STATS) are served throughout; writes are
//!   rejected until `PROMOTE` (or leader-loss auto-promotion).
//!
//! Correctness model: MCPrioQ's lookups are approximately correct under
//! concurrent updates by design (§II of the paper) — a reader may observe
//! any recent prefix of the update stream. A follower lagging by `k` WAL
//! records serves answers from exactly such a prefix, so replica reads
//! carry the *same* relaxed semantics as leader reads, just with a larger
//! (bounded, observable) staleness window: `lag_records`/`lag_s` in
//! STATS. At quiescence (leader idle, lag 0) follower and leader are
//! byte-identical — the differential tests in `rust/tests/replication.rs`
//! assert exactly that.

pub mod chaos;
mod follower;
mod leader;
pub mod wire;

pub use chaos::{ChaosPlan, ChaosState, ChaosVerdict};
pub use follower::{start_follower, FollowerHandle};
pub use leader::serve_follower;

use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::metrics::Counter;
use crate::sync::shim::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared state of one follower process: per-shard replication positions,
/// leader heads, link liveness, and the promotion latch. The server's
/// dispatch reads it for read-only enforcement and the STATS role block;
/// the link and apply workers write it.
pub struct ReplicaState {
    leader: String,
    /// Leader WAL epoch this follower tracks (HELLO argument).
    epoch: AtomicU64,
    /// Per-shard last applied (and locally logged) sequence number.
    applied: Vec<AtomicU64>,
    /// Per-shard leader head, from heartbeats and streamed records.
    heads: Vec<AtomicU64>,
    /// Per-shard instant the shard was last fully caught up — the basis of
    /// the `lag_s` bounded-staleness gauge.
    caught_up_at: Vec<Mutex<Instant>>,
    last_contact: Mutex<Instant>,
    connected: AtomicBool,
    promoted: AtomicBool,
    /// Apply workers still running. Writes are admitted only once this
    /// drains after promotion: a local write must not race a queued
    /// replicated record for the next WAL sequence number.
    active_workers: AtomicUsize,
    /// True when this follower bootstrapped via snapshot (vs pure log).
    snapshot_bootstrap: AtomicBool,
    /// Fatal apply/stream fault (sequence divergence, local WAL failure):
    /// the link stops and the operator must restart the follower.
    fault: Mutex<Option<String>>,
    records: Counter,
    updates: Counter,
}

impl ReplicaState {
    pub fn new(leader: String, epoch: u64, start_seqs: &[u64]) -> ReplicaState {
        ReplicaState {
            leader,
            epoch: AtomicU64::new(epoch),
            applied: start_seqs.iter().map(|&s| AtomicU64::new(s)).collect(),
            heads: start_seqs.iter().map(|&s| AtomicU64::new(s)).collect(),
            caught_up_at: start_seqs.iter().map(|_| Mutex::new(Instant::now())).collect(),
            last_contact: Mutex::new(Instant::now()),
            connected: AtomicBool::new(false),
            promoted: AtomicBool::new(false),
            active_workers: AtomicUsize::new(0),
            snapshot_bootstrap: AtomicBool::new(false),
            fault: Mutex::new(None),
            records: Counter::new(),
            updates: Counter::new(),
        }
    }

    pub fn leader(&self) -> &str {
        &self.leader
    }

    pub fn shard_count(&self) -> usize {
        self.applied.len()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    pub fn applied_seqs(&self) -> Vec<u64> {
        self.applied.iter().map(|a| a.load(Ordering::Acquire)).collect()
    }

    pub fn applied(&self, shard: usize) -> u64 {
        self.applied[shard].load(Ordering::Acquire)
    }

    /// Records/updates applied through the replication link so far.
    pub fn applied_records(&self) -> u64 {
        self.records.get()
    }

    pub fn applied_updates(&self) -> u64 {
        self.updates.get()
    }

    /// An apply worker finished record `seq` on `shard`.
    pub(crate) fn note_applied(&self, shard: usize, seq: u64, updates: usize) {
        self.applied[shard].store(seq, Ordering::Release);
        self.records.inc();
        self.updates.add(updates as u64);
        if seq >= self.heads[shard].load(Ordering::Acquire) {
            *lock_clean(&self.caught_up_at[shard]) = Instant::now();
        }
    }

    /// The link learned the leader's current head for `shard`. Heads never
    /// regress — an old heartbeat can arrive after a newer record.
    pub(crate) fn note_head(&self, shard: usize, head: u64) {
        self.heads[shard].fetch_max(head, Ordering::AcqRel);
        if self.applied(shard) >= self.heads[shard].load(Ordering::Acquire) {
            *lock_clean(&self.caught_up_at[shard]) = Instant::now();
        }
    }

    pub(crate) fn note_contact(&self) {
        *lock_clean(&self.last_contact) = Instant::now();
    }

    pub(crate) fn set_connected(&self, up: bool) {
        self.connected.store(up, Ordering::Release);
    }

    pub fn connected(&self) -> bool {
        self.connected.load(Ordering::Acquire)
    }

    /// Seconds since the link last heard from the leader (records or
    /// heartbeats) — the auto-promotion clock.
    pub fn contact_age(&self) -> std::time::Duration {
        lock_clean(&self.last_contact).elapsed()
    }

    /// Total records this follower still trails the leader by.
    pub fn lag_records(&self) -> u64 {
        self.heads
            .iter()
            .zip(&self.applied)
            .map(|(h, a)| {
                h.load(Ordering::Acquire).saturating_sub(a.load(Ordering::Acquire))
            })
            .sum()
    }

    /// Worst-shard staleness in seconds: 0 while caught up, else how long
    /// the most-behind shard has been behind. Together with `lag_records`
    /// this is the bounded-staleness statement replica reads carry.
    pub fn lag_seconds(&self) -> u64 {
        let mut worst = 0u64;
        for (i, (h, a)) in self.heads.iter().zip(&self.applied).enumerate() {
            if h.load(Ordering::Acquire) > a.load(Ordering::Acquire) {
                worst = worst.max(lock_clean(&self.caught_up_at[i]).elapsed().as_secs());
            }
        }
        worst
    }

    pub(crate) fn set_snapshot_bootstrap(&self) {
        self.snapshot_bootstrap.store(true, Ordering::Release);
    }

    pub fn snapshot_bootstrap(&self) -> bool {
        self.snapshot_bootstrap.load(Ordering::Acquire)
    }

    /// Latch promotion. Idempotent; the link and apply workers observe the
    /// latch and wind down (the link closes the leader connection, workers
    /// drain their queues and exit). Writes are admitted only once that
    /// wind-down completes — see [`ReplicaState::writable`].
    pub fn promote(&self) {
        if !self.promoted.swap(true, Ordering::AcqRel) {
            crate::metrics::events::emit(
                crate::metrics::events::Level::Warn,
                "replicate",
                "promoted",
                self.lag_records(),
                0,
            );
        }
    }

    pub fn promoted(&self) -> bool {
        self.promoted.load(Ordering::Acquire)
    }

    pub(crate) fn worker_started(&self) {
        self.active_workers.fetch_add(1, Ordering::AcqRel);
    }

    pub(crate) fn worker_finished(&self) {
        self.active_workers.fetch_sub(1, Ordering::AcqRel);
    }

    /// True once this node may accept writes: promotion latched AND the
    /// apply plane fully drained. Gating on the drain (not just the
    /// latch) keeps a just-promoted node's first local write from
    /// stealing the WAL sequence number of a still-queued replicated
    /// record, which would fault the apply worker and drop the rest of
    /// the received history.
    pub fn writable(&self) -> bool {
        self.promoted() && self.active_workers.load(Ordering::Acquire) == 0
    }

    pub(crate) fn set_fault(&self, msg: String) {
        eprintln!("[replicate] follower fault: {msg}");
        let mut fault = lock_clean(&self.fault);
        if fault.is_none() {
            // First fault wins (and is the one event-logged), matching the
            // sticky message the HEALTH verb reports.
            *fault = Some(msg);
            drop(fault);
            crate::metrics::events::emit(
                crate::metrics::events::Level::Error,
                "replicate",
                "fault",
                self.lag_records(),
                0,
            );
        }
    }

    pub fn fault(&self) -> Option<String> {
        lock_clean(&self.fault).clone()
    }

    /// Register follower-link telemetry into `reg` (DESIGN.md §9). The
    /// closures hold a strong `Arc<ReplicaState>` — the replica state does
    /// not point back at the engine or registry, so there is no cycle.
    pub fn register_metrics(self: &std::sync::Arc<ReplicaState>, reg: &crate::metrics::Registry) {
        let r = std::sync::Arc::clone(self);
        reg.counter_fn(
            "mcprioq_repl_records_total",
            "WAL records applied through the replication link.",
            &[],
            move || r.applied_records(),
        );
        let r = std::sync::Arc::clone(self);
        reg.counter_fn(
            "mcprioq_repl_updates_total",
            "Individual updates applied through the replication link.",
            &[],
            move || r.applied_updates(),
        );
        let r = std::sync::Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_repl_lag_records",
            "WAL records this follower trails the leader by (all shards).",
            &[],
            move || r.lag_records() as f64,
        );
        let r = std::sync::Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_repl_lag_seconds",
            "Worst-shard staleness in seconds (0 while caught up).",
            &[],
            move || r.lag_seconds() as f64,
        );
        let r = std::sync::Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_repl_connected",
            "1 while the leader link is up.",
            &[],
            move || if r.connected() { 1.0 } else { 0.0 },
        );
        let r = std::sync::Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_repl_promoted",
            "1 once promotion has been latched on this node.",
            &[],
            move || if r.promoted() { 1.0 } else { 0.0 },
        );
        let r = std::sync::Arc::clone(self);
        reg.gauge_fn(
            "mcprioq_repl_fault",
            "1 when the replication link latched a fatal fault.",
            &[],
            move || if r.fault().is_some() { 1.0 } else { 0.0 },
        );
    }
}

#[cfg(test)]
mod tests;
