//! Unit, stress and invariant tests for the RCU hash table (invariant P6).

use super::*;
use crate::rcu;
use crate::sync::shim::{AtomicBool, Ordering};
use std::sync::Arc;

#[test]
fn insert_then_get() {
    let t = HashTable::with_capacity(8);
    let g = rcu::pin();
    assert_eq!(t.get(&g, 42), None);
    assert_eq!(t.insert_or_get(&g, 42, 1000), (1000, true));
    assert_eq!(t.get(&g, 42), Some(1000));
    assert_eq!(t.len(), 1);
}

#[test]
fn insert_or_get_dedups() {
    let t = HashTable::with_capacity(8);
    let g = rcu::pin();
    assert_eq!(t.insert_or_get(&g, 7, 100), (100, true));
    assert_eq!(t.insert_or_get(&g, 7, 200), (100, false));
    assert_eq!(t.len(), 1);
}

#[test]
fn remove_returns_value_and_unlinks() {
    let t = HashTable::with_capacity(8);
    let g = rcu::pin();
    for k in 0..20u64 {
        t.insert_or_get(&g, k, k * 10);
    }
    assert_eq!(t.remove(&g, 13), Some(130));
    assert_eq!(t.get(&g, 13), None);
    assert_eq!(t.remove(&g, 13), None);
    assert_eq!(t.len(), 19);
    // Every other key survives the unlink (P6).
    for k in 0..20u64 {
        if k != 13 {
            assert_eq!(t.get(&g, k), Some(k * 10), "key {k} lost");
        }
    }
}

#[test]
fn resize_preserves_all_entries() {
    let t = HashTable::with_capacity(8);
    let g = rcu::pin();
    const N: u64 = if cfg!(miri) { 400 } else { 10_000 };
    for k in 0..N {
        t.insert_or_get(&g, k, !k);
    }
    let s = t.stats();
    assert!(s.resizes >= 1, "expected at least one resize, got {s:?}");
    assert!(s.capacity >= (N as usize * LOAD_NUM_TEST / LOAD_DEN_TEST));
    for k in 0..N {
        assert_eq!(t.get(&g, k), Some(!k), "key {k} lost across resize");
    }
    assert_eq!(t.len(), N as usize);
}
const LOAD_NUM_TEST: usize = 1; // capacity must at least exceed len
const LOAD_DEN_TEST: usize = 1;

#[test]
fn for_each_sees_every_entry() {
    let t = HashTable::with_capacity(8);
    let g = rcu::pin();
    for k in 0..100u64 {
        t.insert_or_get(&g, k, k + 1);
    }
    let mut seen = vec![false; 100];
    t.for_each(&g, |k, v| {
        assert_eq!(v, k + 1);
        seen[k as usize] = true;
    });
    assert!(seen.iter().all(|&s| s));
}

#[test]
fn keys_with_extreme_values() {
    let t = HashTable::with_capacity(8);
    let g = rcu::pin();
    for k in [0u64, 1, u64::MAX, u64::MAX - 1, 1 << 63, 0xFFFF_FFFF] {
        assert_eq!(t.insert_or_get(&g, k, k ^ 0xABCD), (k ^ 0xABCD, true));
    }
    for k in [0u64, 1, u64::MAX, u64::MAX - 1, 1 << 63, 0xFFFF_FFFF] {
        assert_eq!(t.get(&g, k), Some(k ^ 0xABCD));
    }
}

#[test]
fn concurrent_inserts_no_loss_no_dup() {
    const THREADS: u64 = if cfg!(miri) { 4 } else { 8 };
    const PER: u64 = if cfg!(miri) { 100 } else { 4_000 };
    let t = Arc::new(HashTable::with_capacity(8));
    let handles: Vec<_> = (0..THREADS)
        .map(|tid| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let g = rcu::pin();
                for i in 0..PER {
                    let k = tid * PER + i;
                    let (v, ins) = t.insert_or_get(&g, k, k + 1);
                    assert!(ins, "disjoint key {k} already present");
                    assert_eq!(v, k + 1);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let g = rcu::pin();
    assert_eq!(t.len(), (THREADS * PER) as usize);
    for k in 0..THREADS * PER {
        assert_eq!(t.get(&g, k), Some(k + 1), "key {k} lost");
    }
}

#[test]
fn concurrent_same_key_single_winner() {
    const THREADS: usize = if cfg!(miri) { 4 } else { 8 };
    for round in 0..if cfg!(miri) { 5 } else { 50u64 } {
        let t = Arc::new(HashTable::with_capacity(8));
        let winners: Vec<u64> = {
            let handles: Vec<_> = (0..THREADS)
                .map(|tid| {
                    let t = Arc::clone(&t);
                    std::thread::spawn(move || {
                        let g = rcu::pin();
                        t.insert_or_get(&g, round, 1000 + tid as u64).0
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };
        // All participants must agree on one canonical value.
        assert!(winners.windows(2).all(|w| w[0] == w[1]), "split winners: {winners:?}");
        assert_eq!(t.len(), 1);
    }
}

#[test]
fn readers_survive_concurrent_resize() {
    let t = Arc::new(HashTable::with_capacity(8));
    {
        let g = rcu::pin();
        for k in 0..64u64 {
            t.insert_or_get(&g, k, k);
        }
    }
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..3)
        .map(|_| {
            let t = Arc::clone(&t);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut reads = 0u64;
                while reads == 0 || !stop.load(Ordering::Relaxed) {
                    let g = rcu::pin();
                    for k in 0..64u64 {
                        // Keys inserted before the readers started must
                        // always be visible, across any number of resizes.
                        assert_eq!(t.get(&g, k), Some(k), "pre-existing key {k} vanished");
                    }
                    reads += 1;
                }
            })
        })
        .collect();
    // Writer: grow the table through several resizes.
    {
        let g = rcu::pin();
        let top = if cfg!(miri) { 1_024 } else { 20_000u64 };
        for k in 64..top {
            t.insert_or_get(&g, k, k);
        }
    }
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        r.join().unwrap();
    }
    assert!(t.stats().resizes >= 3);
}

#[test]
fn ptr_table_roundtrip() {
    let t: PtrTable<String> = PtrTable::with_capacity(8);
    let g = rcu::pin();
    let p = Box::into_raw(Box::new("hello".to_string()));
    let (w, ins) = t.insert_or_get(&g, 5, p);
    assert!(ins);
    assert_eq!(w, p);
    assert_eq!(t.get(&g, 5), Some(p));
    let r = t.remove(&g, 5).unwrap();
    assert_eq!(r, p);
    // The table retired the Entry; the value itself is ours to free.
    // SAFETY: `p` came from Box::into_raw and `remove` returned it exactly
    // once; no reader can still hold it (single-threaded test).
    drop(unsafe { Box::from_raw(p) });
    assert!(t.is_empty());
}

#[test]
fn stats_shape() {
    let t = HashTable::with_capacity(64);
    let g = rcu::pin();
    for k in 0..32u64 {
        t.insert_or_get(&g, k, 0);
    }
    drop(g);
    let s = t.stats();
    assert_eq!(s.len, 32);
    assert!(s.capacity >= 64);
    assert!(s.max_chain >= 1);
}
