//! The untyped (u64 -> u64) RCU hash table.
//!
//! Concurrency protocol (see mod.rs for the guarantee summary):
//!
//! * Buckets are singly-linked chains; inserts always CAS onto the bucket
//!   head. A successful head-CAS proves the chain gained no entries since
//!   the duplicate-check walk began (inserts only land at the head), so the
//!   insert-if-absent check cannot be defeated by a racing insert.
//! * Removal (decay path only) takes the table lock — this enforces the
//!   single-remover discipline that makes mid-chain unlink safe without
//!   Harris-style marked pointers — then unlinks with a CAS (racing only
//!   against head inserts) and retires the entry through RCU.
//! * Resize uses a seqlock around the array pointer: the migrating thread
//!   bumps `seq` to odd, copies every entry into fresh shells in a 2× array,
//!   publishes the new array, bumps `seq` to even, and defer-frees the old
//!   array *and* its shells wholesale. Writers re-validate `seq` after their
//!   CAS and redo the operation against the new array if a migration raced;
//!   readers are oblivious (the old array stays intact until the grace
//!   period expires — they merely miss entries inserted after migration,
//!   which is the paper's "approximately correct" contract).

use crate::rcu::{self, Guard};
use crate::sync::shim::{fence, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use crate::sync::{Backoff, SpinLock};

const FIB: u64 = 0x9E37_79B9_7F4A_7C15;
const MIN_CAP: usize = 8;
/// Resize when len * 4 > cap * 3 (load factor 0.75).
const LOAD_NUM: usize = 3;
const LOAD_DEN: usize = 4;

struct Entry {
    key: u64,
    value: AtomicU64,
    next: AtomicPtr<Entry>,
}

struct Array {
    shift: u32,
    buckets: Box<[AtomicPtr<Entry>]>,
}

impl Array {
    fn new(cap: usize) -> Box<Array> {
        debug_assert!(cap.is_power_of_two());
        let buckets: Vec<AtomicPtr<Entry>> =
            (0..cap).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect();
        Box::new(Array { shift: 64 - cap.trailing_zeros(), buckets: buckets.into_boxed_slice() })
    }

    #[inline]
    fn bucket(&self, key: u64) -> &AtomicPtr<Entry> {
        let idx = (key.wrapping_mul(FIB) >> self.shift) as usize;
        &self.buckets[idx]
    }

    fn cap(&self) -> usize {
        self.buckets.len()
    }
}

pub struct HashTable {
    array: AtomicPtr<Array>,
    len: AtomicUsize,
    /// Even = stable; odd = migration in progress.
    seq: AtomicU64,
    /// Serializes resize and remove (cold paths only).
    lock: SpinLock<()>,
    resizes: AtomicUsize,
}

// SAFETY: the raw Entry/Array pointers are only dereferenced under an RCU
// guard (reads) or the table spinlock (remove/resize); keys and values are
// plain u64s, so entries are freely sendable between threads.
unsafe impl Send for HashTable {}
// SAFETY: all mutation goes through atomics or the internal spinlock.
unsafe impl Sync for HashTable {}

/// Counters exposed for tests and the metrics endpoint.
#[derive(Debug, Clone, Copy)]
pub struct TableStats {
    pub capacity: usize,
    pub len: usize,
    pub resizes: usize,
    pub max_chain: usize,
}

impl HashTable {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(MIN_CAP).next_power_of_two();
        HashTable {
            array: AtomicPtr::new(Box::into_raw(Array::new(cap))),
            len: AtomicUsize::new(0),
            seq: AtomicU64::new(0),
            lock: SpinLock::new(()),
            resizes: AtomicUsize::new(0),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Wait-free lookup under the RCU guard.
    #[inline]
    pub fn get(&self, _guard: &Guard, key: u64) -> Option<u64> {
        // SAFETY: the guard keeps both the array and the entry shells alive
        // (resize/remove only free them after a grace period), and the
        // array pointer is never null after construction.
        let arr = unsafe { &*self.array.load(Ordering::Acquire) };
        let mut cur = arr.bucket(key).load(Ordering::Acquire);
        while !cur.is_null() {
            // SAFETY: non-null chain pointer read under the guard; entries
            // are retired through RCU, never freed in place.
            let e = unsafe { &*cur };
            if e.key == key {
                return Some(e.value.load(Ordering::Acquire));
            }
            cur = e.next.load(Ordering::Acquire);
        }
        None
    }

    /// Insert `key -> value` if absent. Returns `(winning_value, inserted)`.
    pub fn insert_or_get(&self, guard: &Guard, key: u64, value: u64) -> (u64, bool) {
        let mut shell: *mut Entry = std::ptr::null_mut();
        let mut backoff = Backoff::new();
        loop {
            // Wait out any in-flight migration so we operate on a stable array.
            let s1 = self.stable_seq(&mut backoff);
            // SAFETY: guard held by the caller keeps the array alive.
            let arr = unsafe { &*self.array.load(Ordering::Acquire) };
            let bucket = arr.bucket(key);
            let head = bucket.load(Ordering::Acquire);

            // Duplicate check: walk the chain as of `head`.
            let mut cur = head;
            let mut found = None;
            while !cur.is_null() {
                // SAFETY: non-null chain pointer, alive under the guard.
                let e = unsafe { &*cur };
                if e.key == key {
                    found = Some(e.value.load(Ordering::Acquire));
                    break;
                }
                cur = e.next.load(Ordering::Acquire);
            }
            if let Some(v) = found {
                // Racing migration can't invalidate a *positive* result: the
                // entry existed, so its copy (same key/value) exists after
                // migration too.
                if !shell.is_null() {
                    // SAFETY: we allocated the shell on a previous iteration
                    // and its CAS never succeeded — nobody else has seen it.
                    drop(unsafe { Box::from_raw(shell) });
                }
                return (v, false);
            }

            if shell.is_null() {
                shell = Box::into_raw(Box::new(Entry {
                    key,
                    value: AtomicU64::new(value),
                    next: AtomicPtr::new(head),
                }));
            } else {
                // SAFETY: the shell is ours until the CAS below succeeds.
                unsafe { (*shell).next.store(head, Ordering::Relaxed) };
            }
            if bucket
                .compare_exchange(head, shell, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                backoff.spin();
                continue; // head changed under us: re-walk
            }

            // CAS landed. If no migration raced, we're done. The SeqCst
            // fence is load-bearing: the CAS is a release store, and a
            // release store followed by a load of a *different* location
            // may be reordered (StoreLoad) — without the fence, a migrator
            // could bump `seq` to odd, scan this bucket *before* our CAS
            // drains, and miss the shell, while we still read the old even
            // `seq` and conclude no migration raced: the key would silently
            // vanish from the new array. The fence pairs with the
            // migrator's SeqCst `seq` RMW (single total order): either our
            // store is visible to the scan, or its bump is visible to `s2`.
            fence(Ordering::SeqCst);
            let s2 = self.seq.load(Ordering::SeqCst);
            if s1 == s2 {
                self.len.fetch_add(1, Ordering::Relaxed);
                self.maybe_resize(guard);
                return (value, true);
            }

            // A migration raced with our CAS: our shell may or may not have
            // been copied into the new array. Re-resolve against the new
            // array; the old array (and our orphaned shell, if missed) is
            // freed wholesale by the migrator's deferred closure.
            loop {
                let s1b = self.stable_seq(&mut backoff);
                // SAFETY: guard held by the caller keeps the array alive.
                let arr2 = unsafe { &*self.array.load(Ordering::Acquire) };
                let mut cur = arr2.bucket(key).load(Ordering::Acquire);
                let mut winner = None;
                while !cur.is_null() {
                    // SAFETY: non-null chain pointer, alive under the guard.
                    let e = unsafe { &*cur };
                    if e.key == key {
                        winner = Some(e.value.load(Ordering::Acquire));
                        break;
                    }
                    cur = e.next.load(Ordering::Acquire);
                }
                if self.seq.load(Ordering::SeqCst) != s1b {
                    continue; // another migration; re-walk
                }
                match winner {
                    // Our value was migrated (or another thread won with the
                    // same key). Either way `w` is the canonical value now.
                    Some(w) => {
                        if w == value {
                            self.len.fetch_add(1, Ordering::Relaxed);
                        }
                        return (w, w == value);
                    }
                    // Migrator scanned our bucket before our CAS landed: the
                    // shell exists only in the doomed old array. Retry from
                    // scratch with a fresh shell.
                    None => {
                        shell = std::ptr::null_mut();
                        break;
                    }
                }
            }
        }
    }

    /// Remove `key`, retiring its entry through RCU. Takes the table lock
    /// (cold path: decay/prune only).
    pub fn remove(&self, guard: &Guard, key: u64) -> Option<u64> {
        let _l = self.lock.lock();
        // SAFETY: holding the lock excludes resize, so the array is stable;
        // the guard keeps entries alive.
        let arr = unsafe { &*self.array.load(Ordering::Acquire) };
        let bucket = arr.bucket(key);
        'retry: loop {
            let mut prev: Option<&Entry> = None;
            let mut cur = bucket.load(Ordering::Acquire);
            while !cur.is_null() {
                // SAFETY: non-null chain pointer, alive under the guard.
                let e = unsafe { &*cur };
                if e.key == key {
                    let next = e.next.load(Ordering::Acquire);
                    let cas_target = match prev {
                        Some(p) => &p.next,
                        None => bucket,
                    };
                    if cas_target
                        .compare_exchange(cur, next, Ordering::AcqRel, Ordering::Acquire)
                        .is_err()
                    {
                        // Only head inserts race with us; re-walk.
                        continue 'retry;
                    }
                    let v = e.value.load(Ordering::Acquire);
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    // SAFETY: `cur` was unlinked by the successful CAS above
                    // under the single-remover lock, so it is retired
                    // exactly once; readers that still hold it are covered
                    // by the grace period.
                    unsafe { rcu::defer_free(guard, cur) };
                    return Some(v);
                }
                prev = Some(e);
                cur = e.next.load(Ordering::Acquire);
            }
            return None;
        }
    }

    /// Iterate all live entries (approximately-correct snapshot).
    pub fn for_each<F: FnMut(u64, u64)>(&self, _guard: &Guard, mut f: F) {
        // SAFETY: the guard keeps the array and entries alive.
        let arr = unsafe { &*self.array.load(Ordering::Acquire) };
        for b in arr.buckets.iter() {
            let mut cur = b.load(Ordering::Acquire);
            while !cur.is_null() {
                // SAFETY: non-null chain pointer, alive under the guard.
                let e = unsafe { &*cur };
                f(e.key, e.value.load(Ordering::Acquire));
                cur = e.next.load(Ordering::Acquire);
            }
        }
    }

    pub fn stats(&self) -> TableStats {
        let guard = rcu::pin();
        // SAFETY: `guard` (pinned above, dropped after the scan) keeps the
        // array and every chain entry alive.
        let arr = unsafe { &*self.array.load(Ordering::Acquire) };
        let mut max_chain = 0;
        for b in arr.buckets.iter() {
            let mut n = 0;
            let mut cur = b.load(Ordering::Acquire);
            while !cur.is_null() {
                n += 1;
                // SAFETY: non-null chain pointer, alive under `guard`.
                cur = unsafe { &*cur }.next.load(Ordering::Acquire);
            }
            max_chain = max_chain.max(n);
        }
        drop(guard);
        TableStats {
            capacity: arr.cap(),
            len: self.len(),
            resizes: self.resizes.load(Ordering::Relaxed),
            max_chain,
        }
    }

    /// Spin until `seq` is even; returns the observed stable value.
    #[inline]
    fn stable_seq(&self, backoff: &mut Backoff) -> u64 {
        loop {
            let s = self.seq.load(Ordering::SeqCst);
            if s % 2 == 0 {
                return s;
            }
            backoff.snooze();
        }
    }

    fn maybe_resize(&self, guard: &Guard) {
        // SAFETY: guard held by the caller keeps the array alive.
        let arr = unsafe { &*self.array.load(Ordering::Acquire) };
        if self.len() * LOAD_DEN <= arr.cap() * LOAD_NUM {
            return;
        }
        let Some(_l) = self.lock.try_lock() else {
            return; // someone else is resizing or removing; they'll get to it
        };
        // Re-check under the lock.
        let old_ptr = self.array.load(Ordering::Acquire);
        // SAFETY: under the lock no other thread can retire the array, and
        // the caller's guard covers it besides.
        let old = unsafe { &*old_ptr };
        if self.len() * LOAD_DEN <= old.cap() * LOAD_NUM {
            return;
        }

        // Begin migration: writers observing odd `seq` hold off.
        self.seq.fetch_add(1, Ordering::SeqCst);
        let new = Array::new(old.cap() * 2);
        let mut migrated = 0usize;
        for b in old.buckets.iter() {
            let mut cur = b.load(Ordering::Acquire);
            while !cur.is_null() {
                // SAFETY: entries can only be removed under the lock we
                // hold, so every chain pointer stays valid during the scan.
                let e = unsafe { &*cur };
                // Fresh shell: readers keep traversing the intact old chains.
                let shell = Box::into_raw(Box::new(Entry {
                    key: e.key,
                    value: AtomicU64::new(e.value.load(Ordering::Acquire)),
                    next: AtomicPtr::new(std::ptr::null_mut()),
                }));
                let nb = new.bucket(e.key);
                // SAFETY: the shell is ours; the new array is unpublished.
                unsafe { (*shell).next.store(nb.load(Ordering::Relaxed), Ordering::Relaxed) };
                nb.store(shell, Ordering::Relaxed);
                migrated += 1;
                cur = e.next.load(Ordering::Acquire);
            }
        }
        let new_ptr = Box::into_raw(new);
        self.array.store(new_ptr, Ordering::Release);
        self.seq.fetch_add(1, Ordering::SeqCst);
        self.resizes.fetch_add(1, Ordering::Relaxed);
        let _ = migrated;

        // Retire the old array and every shell it owns after a grace period.
        let old_addr = old_ptr as usize;
        rcu::defer(guard, move || {
            // SAFETY: the old array was unpublished by the `array.store`
            // above and the grace period has expired, so no reader can
            // still traverse it; the array and its shells are freed
            // exactly once (entries were *copied*, not moved, into the new
            // array). Late-CAS orphan shells that landed in these chains
            // after the migration scan are freed here too — that is the
            // only reference to them.
            unsafe {
                let old = Box::from_raw(old_addr as *mut Array);
                for b in old.buckets.iter() {
                    let mut cur = b.load(Ordering::Relaxed);
                    while !cur.is_null() {
                        let e = Box::from_raw(cur);
                        cur = e.next.load(Ordering::Relaxed);
                    }
                }
            }
        });
    }
}

impl Drop for HashTable {
    fn drop(&mut self) {
        // Exclusive access: free the current array and chains directly.
        let arr_ptr = *self.array.get_mut();
        if arr_ptr.is_null() {
            return;
        }
        // SAFETY: `&mut self` proves no concurrent readers exist, so the
        // array and all chain entries can be freed eagerly; each is owned
        // by exactly one chain link.
        unsafe {
            let arr = Box::from_raw(arr_ptr);
            for b in arr.buckets.iter() {
                let mut cur = b.load(Ordering::Relaxed);
                while !cur.is_null() {
                    let e = Box::from_raw(cur);
                    cur = e.next.load(Ordering::Relaxed);
                }
            }
        }
    }
}
