//! RCU hash table — the src-node / dst-node lookup tables of §II.1.
//!
//! Requirements from the paper:
//! * O(1) lock-free lookups that share the RCU grace period with the
//!   priority-queue list (readers traverse table + list under one guard);
//! * inserts for *new* edges/nodes (the rare path);
//! * removals driven by model decay (§II.C), reclaimed after a grace period.
//!
//! Design: chained buckets (`AtomicPtr<Entry>` heads), power-of-two sizing,
//! fibonacci hashing of the caller-supplied 64-bit key hash.
//!
//! Progress guarantees (documented deviation from liburcu's `cds_lfht`):
//! * `get` — wait-free: a bounded walk of one chain under the guard.
//! * `insert` — lock-free via CAS on the bucket head with duplicate
//!   re-check; resize is cooperative: the thread that trips the load factor
//!   takes a spinlock and migrates, while concurrent inserts CAS into the
//!   *new* array (entries are re-checked against both arrays during the
//!   migration window).
//! * `remove` — single-remover discipline (enforced by the caller: only the
//!   decay/maintenance path removes), unlinks with plain CAS and retires the
//!   entry through [`crate::rcu`].
//!
//! Values are `u64` (the chain stores raw pointers cast to u64); a thin
//! typed wrapper [`PtrTable`] provides a pointer-typed view.

mod raw;

pub use raw::{HashTable, TableStats};

use crate::rcu::Guard;

/// Typed convenience wrapper storing `*mut T` values.
pub struct PtrTable<T> {
    inner: HashTable,
    _marker: std::marker::PhantomData<*mut T>,
}

// SAFETY: the table never dereferences the stored pointers — it only moves
// the bits — so sharing/sending the wrapper is as safe as sharing the
// underlying `HashTable` of u64 values. Dereferencing is the caller's
// responsibility at the call site.
unsafe impl<T> Send for PtrTable<T> {}
// SAFETY: see the `Send` justification above.
unsafe impl<T> Sync for PtrTable<T> {}

impl<T> PtrTable<T> {
    pub fn with_capacity(cap: usize) -> Self {
        PtrTable { inner: HashTable::with_capacity(cap), _marker: std::marker::PhantomData }
    }

    #[inline]
    pub fn get(&self, guard: &Guard, key: u64) -> Option<*mut T> {
        self.inner.get(guard, key).map(|v| v as *mut T)
    }

    /// Insert `key -> ptr` if absent; returns the winning pointer (either
    /// `ptr` or the pre-existing one).
    #[inline]
    pub fn insert_or_get(&self, guard: &Guard, key: u64, ptr: *mut T) -> (*mut T, bool) {
        let (v, inserted) = self.inner.insert_or_get(guard, key, ptr as u64);
        (v as *mut T, inserted)
    }

    #[inline]
    pub fn remove(&self, guard: &Guard, key: u64) -> Option<*mut T> {
        self.inner.remove(guard, key).map(|v| v as *mut T)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.inner.len() == 0
    }

    pub fn stats(&self) -> TableStats {
        self.inner.stats()
    }

    /// Iterate over all `(key, value)` pairs under the guard. Concurrent
    /// inserts may or may not be observed (approximately-correct snapshot).
    pub fn for_each<F: FnMut(u64, *mut T)>(&self, guard: &Guard, mut f: F) {
        self.inner.for_each(guard, |k, v| f(k, v as *mut T));
    }
}

#[cfg(test)]
mod tests;
