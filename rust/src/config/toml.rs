//! Minimal TOML-subset parser (see mod.rs for the supported grammar).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(format!("expected string, got {other:?}")),
        }
    }

    pub fn as_u64(&self) -> Result<u64, String> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => Err(format!("expected non-negative integer, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, String> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => Err(format!("expected integer, got {other:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => Err(format!("expected float, got {other:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {other:?}")),
        }
    }

    pub fn as_array(&self) -> Result<&[TomlValue], String> {
        match self {
            TomlValue::Array(a) => Ok(a),
            other => Err(format!("expected array, got {other:?}")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: flat map from `section.key` to value.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    return Err(ParseError { line: lineno, message: "unterminated section".into() });
                };
                let name = name.trim();
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
                {
                    return Err(ParseError {
                        line: lineno,
                        message: format!("bad section name {name:?}"),
                    });
                }
                section = name.to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(ParseError { line: lineno, message: format!("expected key = value, got {line:?}") });
            };
            let key = line[..eq].trim();
            if key.is_empty()
                || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(ParseError { line: lineno, message: format!("bad key {key:?}") });
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|message| ParseError { line: lineno, message })?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            if entries.insert(full.clone(), value).is_some() {
                return Err(ParseError { line: lineno, message: format!("duplicate key {full}") });
            }
        }
        Ok(TomlDoc { entries })
    }

    pub fn entries(&self) -> impl Iterator<Item = (&String, &TomlValue)> {
        self.entries.iter()
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return Err(format!("unterminated string {s:?}"));
        };
        if inner.contains('"') {
            return Err("escaped quotes are not supported".into());
        }
        // Minimal escapes.
        let unescaped = inner.replace("\\n", "\n").replace("\\t", "\t").replace("\\\\", "\\");
        return Ok(TomlValue::Str(unescaped));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            return Err(format!("unterminated array {s:?}"));
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        if s.contains('.') || s.contains('e') || s.contains('E') {
            return Ok(TomlValue::Float(f));
        }
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = TomlDoc::parse(
            "a = 1\nb = \"two\"\nc = 3.5\nd = true\ne = -7\nf = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Str("two".into())));
        assert_eq!(doc.get("c"), Some(&TomlValue::Float(3.5)));
        assert_eq!(doc.get("d"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("e"), Some(&TomlValue::Int(-7)));
        assert_eq!(doc.get("f"), Some(&TomlValue::Int(1000)));
    }

    #[test]
    fn sections_prefix_keys() {
        let doc = TomlDoc::parse("[x.y]\nk = 2\n[z]\nk = 3\n").unwrap();
        assert_eq!(doc.get("x.y.k"), Some(&TomlValue::Int(2)));
        assert_eq!(doc.get("z.k"), Some(&TomlValue::Int(3)));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = TomlDoc::parse("# hi\n\na = 1 # trailing\nb = \"x # not a comment\"\n").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Str("x # not a comment".into())));
    }

    #[test]
    fn arrays() {
        let doc = TomlDoc::parse("a = [1, 2, 3]\nb = []\nc = [\"x\", \"y\"]\n").unwrap();
        assert_eq!(
            doc.get("a"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
        assert_eq!(doc.get("b").unwrap().as_array().unwrap().len(), 0);
        assert_eq!(doc.get("c").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = TomlDoc::parse("a = 1\na = 2\n").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn value_accessors_enforce_types() {
        let v = TomlValue::Int(-1);
        assert!(v.as_u64().is_err());
        assert_eq!(v.as_i64().unwrap(), -1);
        assert_eq!(TomlValue::Int(3).as_f64().unwrap(), 3.0);
        assert!(TomlValue::Bool(true).as_str().is_err());
    }
}
