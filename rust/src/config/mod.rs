//! Configuration substrate: a TOML-subset parser plus the typed configs of
//! the serving system (serde is unavailable offline; built from scratch).
//!
//! Supported syntax: `[section.sub]` headers, `key = value` with string
//! (`"..."`), integer, float, boolean and flat-array (`[1, 2, 3]`) values,
//! `#` comments, and blank lines.

mod toml;

pub use toml::{ParseError, TomlValue, TomlDoc};

use std::time::Duration;

/// Top-level configuration of the `mcprioq` server binary.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// TCP bind address for the line protocol front-end.
    pub listen: String,
    /// Bind address for the HTTP metrics sidecar (`GET /metrics` serving
    /// Prometheus text exposition, DESIGN.md §9); "" = sidecar off. The
    /// same exposition is always reachable in-band via the `METRICS`
    /// wire verb.
    pub metrics_addr: String,
    /// Slow-query capture threshold in microseconds: any TOPK/MTOPK/REC
    /// whose total service time beats this lands in the slow-query log
    /// with stage-level timing (`TRACE dump`). 0 = off.
    pub slow_query_us: u64,
    /// Number of chain shards (0 = number of CPUs).
    pub shards: usize,
    /// Update-ingestion queue capacity per shard (backpressure bound).
    pub queue_capacity: usize,
    /// Ingress admission control (DESIGN.md §8): sustained write-op
    /// budget per client connection, in ops/sec (OBSERVEB costs its pair
    /// count). 0 = admission control off (the default) — writes block on
    /// queue backpressure exactly as before.
    pub rate_limit_ops: u64,
    /// Token-bucket burst capacity on top of `rate_limit_ops`
    /// (0 = one second of the sustained rate).
    pub rate_limit_burst: u64,
    /// Decay cadence; None disables the decay scheduler.
    pub decay_interval: Option<Duration>,
    /// Chain parameters.
    pub chain: ChainSection,
    /// Durability parameters (WAL + checkpoints); disabled while
    /// `data_dir` is empty.
    pub persist: PersistSection,
    /// Replication parameters (leader streaming + follower link); inert
    /// unless the process serves a follower or runs with `--follow`.
    pub replicate: ReplicateSection,
    /// Thread-placement parameters (DESIGN.md §7).
    pub runtime: RuntimeSection,
    /// Correctness-observatory parameters (DESIGN.md §10).
    pub audit: AuditSection,
}

/// `[audit]` — the correctness observatory (DESIGN.md §10): background
/// approximation-error sampling plus the invariant watchdog. On by
/// default because every check is bounded (a few dozen nodes per round);
/// `enabled = false` removes the thread entirely.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditSection {
    /// Arm the background audit thread at serve time.
    pub enabled: bool,
    /// Pause between observatory rounds.
    pub interval_ms: u64,
    /// Snapshot-bearing nodes sampled per error round (across all shards).
    pub sample_nodes: usize,
    /// Top-k depth compared between the snapshot read path and the exact
    /// list walk.
    pub topk: usize,
    /// Nodes structurally checked per watchdog round (rotating cursor).
    pub check_nodes: usize,
}

impl Default for AuditSection {
    fn default() -> Self {
        let d = crate::audit::AuditConfig::default();
        AuditSection {
            enabled: d.enabled,
            interval_ms: d.interval_ms,
            sample_nodes: d.sample_nodes,
            topk: d.topk,
            check_nodes: d.check_nodes,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ChainSection {
    pub src_capacity: usize,
    pub dst_capacity: usize,
    pub use_dst_table: bool,
    pub decay_num: u64,
    pub decay_den: u64,
    /// Serve reads from per-node prefix-sum snapshots (DESIGN.md § Read
    /// pipeline); off = the paper's plain list-walk read path.
    pub snap_enabled: bool,
    /// Mutations a snapshot may trail the live edge list by before reads
    /// rebuild it (the read path's approximate-correctness bound).
    pub snap_staleness: u64,
    /// Minimum edge count before a node gets a snapshot at all.
    pub snap_min_edges: usize,
    /// Read-snapshot memory layout: "eytzinger" (branchless BFS search +
    /// SIMD prefix copy, DESIGN.md §7) or "sorted" (PR 2 binary search).
    pub snap_layout: String,
    /// Standalone order-repair cadence in seconds; 0 = repair only
    /// piggybacks on decay (the original behavior).
    pub repair_interval_s: u64,
}

/// `[runtime]` — thread placement (DESIGN.md §7). Pinning is best-effort:
/// a restricted cpuset logs and leaves workers floating.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RuntimeSection {
    /// Pin shard-affine ingest workers to cores.
    pub pin_workers: bool,
    /// First core index used when pinning (worker w → core
    /// `(core_offset + w) % ncpus`) — lets an operator reserve low cores
    /// for the accept loop / OS.
    pub core_offset: usize,
}

/// `[persist]` — the durability subsystem (DESIGN.md §4). All knobs are
/// inert until `data_dir` is set (`--data-dir` on the CLI overrides).
#[derive(Debug, Clone, PartialEq)]
pub struct PersistSection {
    /// Root directory for WAL segments and checkpoints; "" = disabled.
    pub data_dir: String,
    /// WAL fsync policy: "never" | "batch" (group commit) | "always".
    pub fsync: String,
    /// Group-commit window for `fsync = "batch"`.
    pub fsync_interval_ms: u64,
    /// WAL segment rotation bound in bytes.
    pub segment_bytes: u64,
    /// Periodic checkpoint cadence; 0 = only explicit `SAVE`s.
    pub checkpoint_interval_ms: u64,
    /// Checkpoint early once live WAL bytes exceed this.
    pub checkpoint_wal_bytes: u64,
    /// Max differential checkpoints chained on one full snapshot before
    /// the next checkpoint compacts to a full one (0 = incremental
    /// checkpoints off, every generation is full).
    pub delta_chain_max: u64,
    /// Compact to a full snapshot when at least this fraction of nodes is
    /// dirty since the last generation (in (0, 1]).
    pub delta_dirty_ratio: f64,
    /// Storage-fault injection plan ("" = none): a seeded, schedulable
    /// `persist::FaultPlan` grammar like
    /// `fail_fsync_every=3;enospc_after=65536;enospc_window_ms=500`.
    /// Every durability write then goes through `FaultyIo`. For the
    /// fault-injection suites and the CI chaos smoke (the hidden
    /// `--fault-plan` serve flag); never set this in production.
    pub fault_plan: String,
}

/// `[replicate]` — WAL streaming to followers (DESIGN.md §5). The same
/// section configures both roles: the leader reads `heartbeat_ms` and
/// `snapshot_records`, the follower reads the rest.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicateSection {
    /// Leader: cadence of `RHB` heartbeats (also the follower's liveness
    /// signal and lag-head refresh).
    pub heartbeat_ms: u64,
    /// Leader: a follower whose total record lag exceeds this bootstraps
    /// from a snapshot instead of log catch-up (0 = snapshot only when the
    /// WAL no longer reaches back to the follower's position).
    pub snapshot_records: u64,
    /// Lag bound for `lag_ok=` in the follower's STATS (0 = unbounded).
    pub max_lag_records: u64,
    /// Follower: self-promote after this long without leader contact
    /// (0 = promotion only via the explicit `PROMOTE` command).
    pub auto_promote_ms: u64,
    /// Follower: give up the initial bootstrap handshake after this long.
    pub connect_timeout_ms: u64,
    /// Leader: cap on the WAL bytes (per shard) a follower retention pin
    /// may hold back from checkpoint truncation. Past it the pin is
    /// overridden — the lagging/dead follower renegotiates a snapshot
    /// bootstrap when it returns — so one dead follower can never pin WAL
    /// (and delta-chain compaction) forever. 0 = unlimited.
    pub max_pin_lag_bytes: u64,
    /// Link-chaos schedule for the follower's stream (DESIGN.md §8).
    /// Deliberately unreachable from TOML — only tests and the bench
    /// harness inject one — so a production config cannot ship with a
    /// chaotic link.
    pub chaos: Option<crate::replicate::ChaosPlan>,
}

impl Default for ReplicateSection {
    fn default() -> Self {
        ReplicateSection {
            heartbeat_ms: 500,
            snapshot_records: 262_144,
            max_lag_records: 0,
            auto_promote_ms: 0,
            connect_timeout_ms: 30_000,
            max_pin_lag_bytes: 256 * 1024 * 1024,
            chaos: None,
        }
    }
}

/// Resolved replication configuration (`ServerConfig::replicate_config`).
#[derive(Debug, Clone)]
pub struct ReplicateConfig {
    pub heartbeat: Duration,
    pub snapshot_records: u64,
    pub max_lag_records: u64,
    /// None = manual promotion only.
    pub auto_promote: Option<Duration>,
    pub connect_timeout: Duration,
    /// 0 = a pinned follower may hold back unlimited WAL.
    pub max_pin_lag_bytes: u64,
    /// Link-chaos schedule (tests only; `None` in production).
    pub chaos: Option<crate::replicate::ChaosPlan>,
}

impl Default for PersistSection {
    fn default() -> Self {
        PersistSection {
            data_dir: String::new(),
            fsync: "batch".to_string(),
            fsync_interval_ms: 50,
            segment_bytes: 64 * 1024 * 1024,
            checkpoint_interval_ms: 60_000,
            checkpoint_wal_bytes: 256 * 1024 * 1024,
            delta_chain_max: 8,
            delta_dirty_ratio: 0.5,
            fault_plan: String::new(),
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:7171".to_string(),
            metrics_addr: String::new(),
            slow_query_us: 0,
            shards: 0,
            queue_capacity: 65_536,
            rate_limit_ops: 0,
            rate_limit_burst: 0,
            decay_interval: Some(Duration::from_secs(60)),
            chain: ChainSection {
                src_capacity: 1024,
                dst_capacity: 8,
                use_dst_table: true,
                decay_num: 1,
                decay_den: 2,
                snap_enabled: true,
                snap_staleness: 128,
                snap_min_edges: 8,
                snap_layout: "eytzinger".to_string(),
                repair_interval_s: 0,
            },
            persist: PersistSection::default(),
            replicate: ReplicateSection::default(),
            runtime: RuntimeSection::default(),
            audit: AuditSection::default(),
        }
    }
}

impl ServerConfig {
    /// Parse from TOML text; unknown keys are an error (typo protection).
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = ServerConfig::default();
        for (key, value) in doc.entries() {
            match key.as_str() {
                "server.listen" => cfg.listen = value.as_str()?.to_string(),
                "server.metrics_addr" => cfg.metrics_addr = value.as_str()?.to_string(),
                "server.slow_query_us" => cfg.slow_query_us = value.as_u64()?,
                "server.shards" => cfg.shards = value.as_usize()?,
                "server.queue_capacity" => cfg.queue_capacity = value.as_usize()?,
                "server.rate_limit_ops" => cfg.rate_limit_ops = value.as_u64()?,
                "server.rate_limit_burst" => cfg.rate_limit_burst = value.as_u64()?,
                "server.decay_interval_ms" => {
                    let ms = value.as_u64()?;
                    cfg.decay_interval =
                        (ms > 0).then(|| Duration::from_millis(ms));
                }
                "chain.src_capacity" => cfg.chain.src_capacity = value.as_usize()?,
                "chain.dst_capacity" => cfg.chain.dst_capacity = value.as_usize()?,
                "chain.use_dst_table" => cfg.chain.use_dst_table = value.as_bool()?,
                "chain.decay_num" => cfg.chain.decay_num = value.as_u64()?,
                "chain.decay_den" => cfg.chain.decay_den = value.as_u64()?,
                "chain.snap_enabled" => cfg.chain.snap_enabled = value.as_bool()?,
                "chain.snap_staleness" => cfg.chain.snap_staleness = value.as_u64()?,
                "chain.snap_min_edges" => cfg.chain.snap_min_edges = value.as_usize()?,
                "chain.snap_layout" => {
                    cfg.chain.snap_layout = value.as_str()?.to_string()
                }
                "chain.repair_interval_s" => {
                    cfg.chain.repair_interval_s = value.as_u64()?
                }
                "runtime.pin_workers" => cfg.runtime.pin_workers = value.as_bool()?,
                "runtime.core_offset" => cfg.runtime.core_offset = value.as_usize()?,
                "persist.data_dir" => cfg.persist.data_dir = value.as_str()?.to_string(),
                "persist.fsync" => cfg.persist.fsync = value.as_str()?.to_string(),
                "persist.fsync_interval_ms" => {
                    cfg.persist.fsync_interval_ms = value.as_u64()?
                }
                "persist.segment_bytes" => cfg.persist.segment_bytes = value.as_u64()?,
                "persist.checkpoint_interval_ms" => {
                    cfg.persist.checkpoint_interval_ms = value.as_u64()?
                }
                "persist.checkpoint_wal_bytes" => {
                    cfg.persist.checkpoint_wal_bytes = value.as_u64()?
                }
                "persist.delta_chain_max" => {
                    cfg.persist.delta_chain_max = value.as_u64()?
                }
                "persist.delta_dirty_ratio" => {
                    cfg.persist.delta_dirty_ratio = value.as_f64()?
                }
                "persist.fault_plan" => {
                    cfg.persist.fault_plan = value.as_str()?.to_string()
                }
                "replicate.heartbeat_ms" => cfg.replicate.heartbeat_ms = value.as_u64()?,
                "replicate.snapshot_records" => {
                    cfg.replicate.snapshot_records = value.as_u64()?
                }
                "replicate.max_lag_records" => {
                    cfg.replicate.max_lag_records = value.as_u64()?
                }
                "replicate.auto_promote_ms" => {
                    cfg.replicate.auto_promote_ms = value.as_u64()?
                }
                "replicate.connect_timeout_ms" => {
                    cfg.replicate.connect_timeout_ms = value.as_u64()?
                }
                "replicate.max_pin_lag_bytes" => {
                    cfg.replicate.max_pin_lag_bytes = value.as_u64()?
                }
                "audit.enabled" => cfg.audit.enabled = value.as_bool()?,
                "audit.interval_ms" => cfg.audit.interval_ms = value.as_u64()?,
                "audit.sample_nodes" => cfg.audit.sample_nodes = value.as_usize()?,
                "audit.topk" => cfg.audit.topk = value.as_usize()?,
                "audit.check_nodes" => cfg.audit.check_nodes = value.as_usize()?,
                other => return Err(format!("unknown config key: {other}")),
            }
        }
        if cfg.chain.decay_num >= cfg.chain.decay_den {
            return Err("chain.decay_num must be < chain.decay_den".to_string());
        }
        crate::chain::SnapLayout::parse(&cfg.chain.snap_layout)
            .map_err(|e| format!("chain.snap_layout: {e}"))?;
        crate::persist::FsyncPolicy::parse(&cfg.persist.fsync)?;
        if cfg.persist.segment_bytes == 0 {
            return Err("persist.segment_bytes must be positive".to_string());
        }
        if cfg.replicate.heartbeat_ms == 0 {
            return Err("replicate.heartbeat_ms must be positive".to_string());
        }
        if !(cfg.persist.delta_dirty_ratio > 0.0 && cfg.persist.delta_dirty_ratio <= 1.0) {
            return Err("persist.delta_dirty_ratio must be in (0, 1]".to_string());
        }
        if cfg.audit.interval_ms == 0 {
            return Err("audit.interval_ms must be positive".to_string());
        }
        if !cfg.persist.fault_plan.is_empty() {
            crate::persist::FaultPlan::parse(&cfg.persist.fault_plan)
                .map_err(|e| format!("persist.fault_plan: {e}"))?;
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_toml(&text)
    }

    /// Resolve the `[persist]` section: `Ok(None)` while durability is
    /// disabled (empty `data_dir`), `Err` on an invalid fsync policy.
    pub fn persist_config(&self) -> Result<Option<crate::persist::PersistConfig>, String> {
        if self.persist.data_dir.is_empty() {
            return Ok(None);
        }
        Ok(Some(crate::persist::PersistConfig {
            data_dir: std::path::PathBuf::from(&self.persist.data_dir),
            fsync: crate::persist::FsyncPolicy::parse(&self.persist.fsync)?,
            fsync_interval: Duration::from_millis(self.persist.fsync_interval_ms),
            segment_bytes: self.persist.segment_bytes.max(1),
            checkpoint_interval: (self.persist.checkpoint_interval_ms > 0)
                .then(|| Duration::from_millis(self.persist.checkpoint_interval_ms)),
            checkpoint_wal_bytes: self.persist.checkpoint_wal_bytes.max(1),
            delta_chain_max: self.persist.delta_chain_max as usize,
            delta_dirty_ratio: self.persist.delta_dirty_ratio.clamp(f64::MIN_POSITIVE, 1.0),
            io: crate::persist::IoHandle::from_plan(&self.persist.fault_plan)
                .map_err(|e| format!("persist.fault_plan: {e}"))?,
        }))
    }

    /// Resolve the `[replicate]` section (always valid after parsing).
    pub fn replicate_config(&self) -> ReplicateConfig {
        ReplicateConfig {
            heartbeat: Duration::from_millis(self.replicate.heartbeat_ms.max(1)),
            snapshot_records: self.replicate.snapshot_records,
            max_lag_records: self.replicate.max_lag_records,
            auto_promote: (self.replicate.auto_promote_ms > 0)
                .then(|| Duration::from_millis(self.replicate.auto_promote_ms)),
            connect_timeout: Duration::from_millis(
                self.replicate.connect_timeout_ms.max(1),
            ),
            max_pin_lag_bytes: self.replicate.max_pin_lag_bytes,
            chaos: self.replicate.chaos,
        }
    }

    /// Resolve the `[audit]` section (always valid after parsing).
    pub fn audit_config(&self) -> crate::audit::AuditConfig {
        crate::audit::AuditConfig {
            enabled: self.audit.enabled,
            interval_ms: self.audit.interval_ms.max(1),
            sample_nodes: self.audit.sample_nodes,
            topk: self.audit.topk,
            check_nodes: self.audit.check_nodes,
        }
    }

    pub fn to_chain_config(&self) -> crate::chain::ChainConfig {
        crate::chain::ChainConfig {
            src_capacity: self.chain.src_capacity,
            dst_capacity: self.chain.dst_capacity,
            use_dst_table: self.chain.use_dst_table,
            decay_num: self.chain.decay_num,
            decay_den: self.chain.decay_den,
            snap_enabled: self.chain.snap_enabled,
            snap_staleness: self.chain.snap_staleness,
            snap_min_edges: self.chain.snap_min_edges,
            // Validated at parse time; unparsed strings (hand-built
            // configs) fall back to the default layout.
            snap_layout: crate::chain::SnapLayout::parse(&self.chain.snap_layout)
                .unwrap_or_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip_empty_toml() {
        let cfg = ServerConfig::from_toml("").unwrap();
        assert_eq!(cfg, ServerConfig::default());
    }

    #[test]
    fn full_config_parses() {
        let text = r#"
# serving config
[server]
listen = "0.0.0.0:9999"
shards = 4
queue_capacity = 1024
decay_interval_ms = 5000

[chain]
src_capacity = 2048
dst_capacity = 16
use_dst_table = false
decay_num = 3
decay_den = 4
"#;
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert_eq!(cfg.listen, "0.0.0.0:9999");
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.queue_capacity, 1024);
        assert_eq!(cfg.decay_interval, Some(Duration::from_millis(5000)));
        assert!(!cfg.chain.use_dst_table);
        assert_eq!(cfg.chain.decay_num, 3);
    }

    #[test]
    fn snapshot_knobs_parse() {
        let text = "[chain]\nsnap_enabled = false\nsnap_staleness = 512\nsnap_min_edges = 4\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert!(!cfg.chain.snap_enabled);
        assert_eq!(cfg.chain.snap_staleness, 512);
        assert_eq!(cfg.chain.snap_min_edges, 4);
        // Defaults: snapshots on, as the chain defaults.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert!(cfg.chain.snap_enabled);
        let cc = cfg.to_chain_config();
        assert_eq!(cc.snap_staleness, crate::chain::ChainConfig::default().snap_staleness);
    }

    #[test]
    fn layout_and_runtime_knobs_parse() {
        let text = "[chain]\nsnap_layout = \"sorted\"\nrepair_interval_s = 30\n\
                    [runtime]\npin_workers = true\ncore_offset = 2\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert_eq!(cfg.chain.snap_layout, "sorted");
        assert_eq!(cfg.chain.repair_interval_s, 30);
        assert!(cfg.runtime.pin_workers);
        assert_eq!(cfg.runtime.core_offset, 2);
        assert_eq!(cfg.to_chain_config().snap_layout, crate::chain::SnapLayout::Sorted);
        // Defaults: Eytzinger layout, standalone repair off, no pinning.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert_eq!(cfg.to_chain_config().snap_layout, crate::chain::SnapLayout::Eytzinger);
        assert_eq!(cfg.chain.repair_interval_s, 0);
        assert!(!cfg.runtime.pin_workers);
        // Unknown layouts are a parse-time error, not a silent default.
        assert!(ServerConfig::from_toml("[chain]\nsnap_layout = \"btree\"\n").is_err());
    }

    #[test]
    fn persist_knobs_parse() {
        let text = "[persist]\ndata_dir = \"/tmp/mc\"\nfsync = \"always\"\n\
                    fsync_interval_ms = 10\nsegment_bytes = 4096\n\
                    checkpoint_interval_ms = 0\ncheckpoint_wal_bytes = 8192\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert_eq!(cfg.persist.data_dir, "/tmp/mc");
        let p = cfg.persist_config().unwrap().unwrap();
        assert_eq!(p.fsync, crate::persist::FsyncPolicy::Always);
        assert_eq!(p.segment_bytes, 4096);
        assert_eq!(p.checkpoint_interval, None); // 0 disables periodic
        assert_eq!(p.checkpoint_wal_bytes, 8192);
        // Defaults: disabled until a data dir is set.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert_eq!(cfg.persist, PersistSection::default());
        assert!(cfg.persist_config().unwrap().is_none());
    }

    #[test]
    fn persist_invalid_rejected() {
        assert!(ServerConfig::from_toml("[persist]\nfsync = \"sometimes\"\n").is_err());
        assert!(ServerConfig::from_toml("[persist]\nsegment_bytes = 0\n").is_err());
        assert!(ServerConfig::from_toml("[persist]\nwal_dir = \"x\"\n").is_err());
        assert!(ServerConfig::from_toml("[persist]\ndelta_dirty_ratio = 0.0\n").is_err());
        assert!(ServerConfig::from_toml("[persist]\ndelta_dirty_ratio = 1.5\n").is_err());
    }

    #[test]
    fn delta_knobs_parse() {
        let text = "[persist]\ndata_dir = \"/tmp/mc\"\ndelta_chain_max = 3\n\
                    delta_dirty_ratio = 0.25\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        let p = cfg.persist_config().unwrap().unwrap();
        assert_eq!(p.delta_chain_max, 3);
        assert_eq!(p.delta_dirty_ratio, 0.25);
        // Defaults: incremental checkpoints on.
        let p = ServerConfig::from_toml("[persist]\ndata_dir = \"/tmp/mc\"\n")
            .unwrap()
            .persist_config()
            .unwrap()
            .unwrap();
        assert_eq!(p.delta_chain_max, 8);
        assert_eq!(p.delta_dirty_ratio, 0.5);
        // 0 disables: every checkpoint is a full snapshot.
        let p = ServerConfig::from_toml(
            "[persist]\ndata_dir = \"/tmp/mc\"\ndelta_chain_max = 0\n",
        )
        .unwrap()
        .persist_config()
        .unwrap()
        .unwrap();
        assert_eq!(p.delta_chain_max, 0);
    }

    #[test]
    fn replicate_knobs_parse() {
        let text = "[replicate]\nheartbeat_ms = 100\nsnapshot_records = 1000\n\
                    max_lag_records = 50\nauto_promote_ms = 2000\nconnect_timeout_ms = 500\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        let r = cfg.replicate_config();
        assert_eq!(r.heartbeat, Duration::from_millis(100));
        assert_eq!(r.snapshot_records, 1000);
        assert_eq!(r.max_lag_records, 50);
        assert_eq!(r.auto_promote, Some(Duration::from_millis(2000)));
        assert_eq!(r.connect_timeout, Duration::from_millis(500));
        // Defaults: manual promotion only, heartbeats on.
        let r = ServerConfig::from_toml("").unwrap().replicate_config();
        assert_eq!(r.auto_promote, None);
        assert_eq!(r.heartbeat, Duration::from_millis(500));
        // A dead heartbeat would starve the follower's liveness signal.
        assert!(ServerConfig::from_toml("[replicate]\nheartbeat_ms = 0\n").is_err());
        // Pin-lag escape hatch: bounded by default, 0 opts out.
        assert_eq!(r.max_pin_lag_bytes, 256 * 1024 * 1024);
        let r = ServerConfig::from_toml("[replicate]\nmax_pin_lag_bytes = 0\n")
            .unwrap()
            .replicate_config();
        assert_eq!(r.max_pin_lag_bytes, 0);
    }

    #[test]
    fn admission_knobs_parse() {
        let text = "[server]\nrate_limit_ops = 5000\nrate_limit_burst = 100\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert_eq!(cfg.rate_limit_ops, 5000);
        assert_eq!(cfg.rate_limit_burst, 100);
        // Default: admission control off.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert_eq!(cfg.rate_limit_ops, 0);
        assert_eq!(cfg.rate_limit_burst, 0);
    }

    #[test]
    fn fault_plan_parses_and_reaches_persist_config() {
        let text = "[persist]\ndata_dir = \"/tmp/mc\"\n\
                    fault_plan = \"seed=7;fail_fsync_every=3\"\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert_eq!(cfg.persist.fault_plan, "seed=7;fail_fsync_every=3");
        // The resolved PersistConfig carries a faulty IoHandle (no panic,
        // no silent fallback to StdIo).
        assert!(cfg.persist_config().unwrap().is_some());
        // Default: empty plan, passthrough I/O.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert!(cfg.persist.fault_plan.is_empty());
        // A malformed plan is a parse-time error, not a surprise at boot.
        assert!(
            ServerConfig::from_toml("[persist]\nfault_plan = \"explode=1\"\n").is_err(),
            "unknown fault-plan key must be rejected"
        );
        // Chaos plans are not TOML-reachable by design.
        assert!(ServerConfig::from_toml("[replicate]\nchaos = \"x\"\n").is_err());
    }

    #[test]
    fn telemetry_knobs_parse() {
        let text = "[server]\nmetrics_addr = \"127.0.0.1:9100\"\nslow_query_us = 250\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert_eq!(cfg.metrics_addr, "127.0.0.1:9100");
        assert_eq!(cfg.slow_query_us, 250);
        // Defaults: sidecar off, slow-query capture off.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert!(cfg.metrics_addr.is_empty());
        assert_eq!(cfg.slow_query_us, 0);
    }

    #[test]
    fn audit_knobs_parse() {
        let text = "[audit]\nenabled = false\ninterval_ms = 50\nsample_nodes = 32\n\
                    topk = 4\ncheck_nodes = 128\n";
        let cfg = ServerConfig::from_toml(text).unwrap();
        assert!(!cfg.audit.enabled);
        let a = cfg.audit_config();
        assert!(!a.enabled);
        assert_eq!(a.interval_ms, 50);
        assert_eq!(a.sample_nodes, 32);
        assert_eq!(a.topk, 4);
        assert_eq!(a.check_nodes, 128);
        // Defaults: observatory armed, matching the library defaults.
        let cfg = ServerConfig::from_toml("").unwrap();
        assert!(cfg.audit.enabled);
        assert_eq!(cfg.audit_config(), crate::audit::AuditConfig::default());
        // A zero cadence would spin the audit thread; reject at parse time.
        assert!(ServerConfig::from_toml("[audit]\ninterval_ms = 0\n").is_err());
    }

    #[test]
    fn decay_zero_disables() {
        let cfg = ServerConfig::from_toml("[server]\ndecay_interval_ms = 0\n").unwrap();
        assert_eq!(cfg.decay_interval, None);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ServerConfig::from_toml("[server]\nlisten_addr = \"x\"\n").is_err());
    }

    #[test]
    fn invalid_decay_rejected() {
        let e = ServerConfig::from_toml("[chain]\ndecay_num = 2\ndecay_den = 2\n");
        assert!(e.is_err());
    }

    #[test]
    fn type_mismatch_rejected() {
        assert!(ServerConfig::from_toml("[server]\nshards = \"four\"\n").is_err());
    }
}
