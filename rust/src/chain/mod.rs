//! The MCPrioQ markov chain (Fig. 1): src-node hash table → per-node state
//! (total counter + dst hash table + priority-queue edge list).
//!
//! Public API (all operations are safe, concurrent, and run under internal
//! RCU guards):
//!
//! ```
//! use mcprioq::chain::{ChainConfig, McPrioQ};
//! let chain = McPrioQ::new(ChainConfig::default());
//! chain.observe(1, 2);                       // user moved 1 -> 2
//! chain.observe_batch(&[(1, 3), (1, 2)]);    // hot path: one guard, n updates
//! let rec = chain.infer_threshold(1, 0.9);   // items until cum-prob >= 0.9
//! assert_eq!(rec.items[0].0, 2);             // most likely next node
//! let (sum, pruned) = chain.decay();         // §II.C maintenance
//! # let _ = (sum, pruned);
//! ```
//!
//! Complexity (paper §II.A/§II.B): `observe` of an existing edge is two O(1)
//! hash lookups + one wait-free increment (+ rare bubble swaps); `observe`
//! of a new edge additionally takes the lock-free pending-insert path;
//! `infer_threshold` is O(1) to the queue head plus O(CDF⁻¹(t)) scanned
//! items. Probabilities are computed at read time from the two counters
//! (§II.3), so updates never touch sibling edges.

pub(crate) mod arena;
mod snapshot;
mod state;

pub use state::NodeStats;

use crate::metrics::{Counter, Histogram, StripedCounter};
use crate::sync::shim::{AtomicU64, AtomicUsize, Ordering};

use crate::hashtable::PtrTable;
use crate::prioq::IncrementOutcome;
use crate::rcu;
use crate::rcu::Guard;
use state::NodeState;

/// Memory layout of the per-node read snapshot's threshold-search array
/// (DESIGN.md §7). Both layouts serve bit-identical answers — the knob
/// trades build cost for search locality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SnapLayout {
    /// Plain sorted prefix-sum array; threshold search is a classic binary
    /// search (`partition_point`) — the PR 2 baseline.
    Sorted,
    /// BFS (Eytzinger) permutation of the prefix sums plus split SoA
    /// `dst`/`count` columns: branchless root-to-leaf threshold search and
    /// a vectorized bounded prefix copy.
    #[default]
    Eytzinger,
}

impl SnapLayout {
    pub fn parse(s: &str) -> Result<SnapLayout, String> {
        match s {
            "sorted" => Ok(SnapLayout::Sorted),
            "eytzinger" => Ok(SnapLayout::Eytzinger),
            other => Err(format!("bad snap_layout {other:?} (sorted|eytzinger)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SnapLayout::Sorted => "sorted",
            SnapLayout::Eytzinger => "eytzinger",
        }
    }
}

/// Configuration for a [`McPrioQ`] chain.
#[derive(Debug, Clone)]
pub struct ChainConfig {
    /// Initial capacity of the src-node table.
    pub src_capacity: usize,
    /// Initial capacity of each per-node dst table.
    pub dst_capacity: usize,
    /// §II.2: the dst-node hash table is an *optional optimization* — with
    /// it, edge updates are O(1); without it, updates search the edge list
    /// (cost = the edge probability distribution's lookup depth). Keep it
    /// on in production; turn it off to reproduce the paper's ablation.
    pub use_dst_table: bool,
    /// Decay multiplier as (numerator, denominator); the paper suggests 1/2.
    pub decay_num: u64,
    pub decay_den: u64,
    /// Serve reads from per-node RCU-published prefix-sum snapshots when
    /// fresh enough (see DESIGN.md § Read pipeline). Off reproduces the
    /// paper's plain list-walk read path (the ablation baseline).
    pub snap_enabled: bool,
    /// How many edge-list mutations (increments/splices/swaps/unlinks) a
    /// snapshot may trail the live list by before reads rebuild it. The
    /// approximate-correctness bound of the snapshot path: counts served
    /// from a fresh-enough snapshot differ from the live list by at most
    /// this many updates.
    pub snap_staleness: u64,
    /// Nodes with fewer edges than this are always served by the live
    /// list walk: a handful of pointer chases beats a rebuild.
    pub snap_min_edges: usize,
    /// Snapshot search/copy memory layout (see [`SnapLayout`]).
    pub snap_layout: SnapLayout,
}

impl Default for ChainConfig {
    fn default() -> Self {
        ChainConfig {
            src_capacity: 1024,
            dst_capacity: 8,
            use_dst_table: true,
            decay_num: 1,
            decay_den: 2,
            snap_enabled: true,
            snap_staleness: 128,
            snap_min_edges: 8,
            snap_layout: SnapLayout::default(),
        }
    }
}

/// Read-path effectiveness counters (surfaced in [`ChainStats`] / STATS).
/// Hits are striped — they ride the hottest read path; rebuilds and
/// fallbacks are comparatively rare transitions.
#[derive(Default)]
struct ReadMetrics {
    snap_hits: StripedCounter,
    snap_rebuilds: Counter,
    snap_fallbacks: Counter,
    /// Nanoseconds per successful snapshot rebuild (the read-tail stage
    /// the telemetry plane attributes separately — DESIGN.md §9).
    snap_rebuild_ns: Histogram,
}

/// Result of one `observe` call (consumed by E4's swap-rate experiment).
#[derive(Debug, Clone, Copy)]
pub struct ObserveOutcome {
    /// True if this was the first observation out of `src`.
    pub new_src: bool,
    /// True if the edge `src -> dst` was created by this call.
    pub new_edge: bool,
    /// Counter/reorder outcome for existing-edge updates.
    pub increment: IncrementOutcome,
}

/// Aggregate result of one `observe_batch` call: per-transition outcomes
/// folded into counters (the per-op detail stays available via `observe`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BatchOutcome {
    /// Transitions applied (= batch length).
    pub applied: usize,
    /// Src nodes created by this batch.
    pub new_srcs: usize,
    /// Edges created by this batch.
    pub new_edges: usize,
    /// Adjacent bubble swaps performed across the batch.
    pub swaps: u64,
    /// Reorders skipped because another thread held the ticket.
    pub swap_skips: u64,
}

impl BatchOutcome {
    fn absorb(&mut self, o: ObserveOutcome) {
        self.applied += 1;
        self.new_srcs += o.new_src as usize;
        self.new_edges += o.new_edge as usize;
        self.swaps += o.increment.swaps as u64;
        self.swap_skips += o.increment.skipped as u64;
    }
}

/// An inference answer: items in (approximately) descending probability.
#[derive(Debug, Clone, PartialEq)]
pub struct Recommendation {
    /// `(dst, probability)` pairs, head of the queue first.
    pub items: Vec<(u64, f64)>,
    /// Cumulative probability covered by `items`.
    pub cumulative: f64,
    /// Queue elements visited to produce the answer — the paper's
    /// O(CDF⁻¹(t)) inference cost, measured (E2).
    pub scanned: usize,
    /// Total transition count out of the src node at read time.
    pub total: u64,
}

impl Recommendation {
    fn empty() -> Self {
        Recommendation { items: Vec::new(), cumulative: 0.0, scanned: 0, total: 0 }
    }

    /// Reset to the empty answer, keeping the `items` allocation — the
    /// heart of the buffer-reuse (`infer_*_into`) query pipeline.
    fn reset(&mut self) {
        self.items.clear();
        self.cumulative = 0.0;
        self.scanned = 0;
        self.total = 0;
    }
}

impl Default for Recommendation {
    fn default() -> Self {
        Recommendation::empty()
    }
}

/// One audited node (DESIGN.md §10): how far the snapshot-served top-k
/// strayed from a fresh exact walk, correlated with the snapshot's
/// staleness so bench can plot a staleness-vs-error curve.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct AuditSample {
    pub src: u64,
    /// Mutations the served snapshot trails the live list by (the quantity
    /// `snap_staleness` bounds).
    pub staleness: u64,
    /// Entries the snapshot actually served (`min(k, snapshot len)`).
    pub served_k: usize,
    /// Served pairs ordered against their live counts (strict inversions;
    /// equal counts are interchangeable).
    pub rank_inversions: u64,
    /// Kendall-tau-style (Spearman-footrule) displacement: summed distance
    /// of each served position from its count's exact rank class.
    pub displacement: u64,
    /// Probability mass the served top-k misses vs the exact top-k, as a
    /// fraction of live mass. Exactly 0 at quiescence.
    pub mass_error: f64,
}

/// One structural-watchdog sweep over a bounded node window (DESIGN.md
/// §10): per-snapshot `cum` monotonicity and tolerant edge-sum == total.
#[derive(Debug, Default, Clone, Copy)]
pub struct StructuralAudit {
    /// Nodes the sweep examined.
    pub checked: usize,
    /// Snapshot prefix-sum entries violating monotone/closing invariants.
    pub cum_violations: u64,
    /// Nodes whose stable edge sum grossly mismatched their total.
    pub edge_sum_violations: u64,
    /// Nodes skipped because they mutated mid-scan (retried next round).
    pub unstable_skips: u64,
}

/// Aggregate structure statistics (metrics endpoint, EXPERIMENTS.md).
#[derive(Debug, Default, Clone, Copy)]
pub struct ChainStats {
    pub nodes: usize,
    pub edges: usize,
    pub observes: u64,
    pub swaps: u64,
    pub swap_skips: u64,
    pub decays: u64,
    pub pruned_edges: u64,
    /// Approximate resident bytes of all nodes/edges/tables/snapshots.
    pub approx_bytes: usize,
    /// Queries answered from a fresh prefix-sum snapshot.
    pub snap_hits: u64,
    /// Snapshot rebuilds performed on the read path.
    pub snap_rebuilds: u64,
    /// Queries that wanted a snapshot but fell back to the live list walk
    /// (ticket busy, or the collected list was empty).
    pub snap_fallbacks: u64,
}

/// The lock-free online sparse markov chain.
///
/// Thread-safe: share it via `Arc` (or plain references with scoped
/// threads); every method takes `&self`.
pub struct McPrioQ {
    src: PtrTable<NodeState>,
    config: ChainConfig,
    /// Striped: `observe` is the hottest path in the system; a single
    /// global counter line would serialize writers (§Perf).
    observes: StripedCounter,
    decays: AtomicU64,
    pruned: AtomicU64,
    edges: AtomicUsize,
    reads: ReadMetrics,
    /// Checkpoint mark: every mutation stamps the current value into its
    /// node's dirty epoch; a differential checkpoint collects the nodes
    /// stamped at or above its floor, then advances the mark (inside the
    /// engine's ingest pause, so stamps never straddle a checkpoint cut).
    ckpt_mark: AtomicU64,
}

impl McPrioQ {
    pub fn new(config: ChainConfig) -> Self {
        McPrioQ {
            src: PtrTable::with_capacity(config.src_capacity),
            config,
            observes: StripedCounter::new(),
            decays: AtomicU64::new(0),
            pruned: AtomicU64::new(0),
            edges: AtomicUsize::new(0),
            reads: ReadMetrics::default(),
            ckpt_mark: AtomicU64::new(1),
        }
    }

    pub fn config(&self) -> &ChainConfig {
        &self.config
    }

    /// Record one transition `src -> dst` with weight 1.
    #[inline]
    pub fn observe(&self, src: u64, dst: u64) -> ObserveOutcome {
        self.observe_weighted(src, dst, 1)
    }

    /// Record a transition with an arbitrary positive weight (§II.3: "the
    /// counter could be anything").
    pub fn observe_weighted(&self, src: u64, dst: u64, weight: u64) -> ObserveOutcome {
        assert!(weight > 0, "weight must be positive");
        self.observes.inc();
        let guard = rcu::pin();
        self.observe_pinned(&guard, src, dst, weight, &mut None)
    }

    /// Record a batch of weight-1 transitions under a single RCU guard.
    ///
    /// This is the batch-first hot path: one `rcu::pin()` amortized over
    /// the whole slice, and the src-node `NodeState` lookup is reused for
    /// runs of consecutive same-src transitions (shard-affine ingest feeds
    /// exactly such runs). Semantically identical to calling [`observe`]
    /// per element, in order — the differential tests assert byte-identical
    /// `export()` snapshots.
    pub fn observe_batch(&self, batch: &[(u64, u64)]) -> BatchOutcome {
        let mut out = BatchOutcome::default();
        if batch.is_empty() {
            return out;
        }
        self.observes.add(batch.len() as u64);
        let guard = rcu::pin();
        let mut cached = None;
        for &(src, dst) in batch {
            out.absorb(self.observe_pinned(&guard, src, dst, 1, &mut cached));
        }
        out
    }

    /// Weighted variant of [`observe_batch`]: `(src, dst, weight)` triples,
    /// every weight must be positive.
    pub fn observe_batch_weighted(&self, batch: &[(u64, u64, u64)]) -> BatchOutcome {
        let mut out = BatchOutcome::default();
        if batch.is_empty() {
            return out;
        }
        // Validate before touching any state: a mid-batch panic would leave
        // the observes counter inflated relative to the applied mass.
        for &(_, _, weight) in batch {
            assert!(weight > 0, "weight must be positive");
        }
        self.observes.add(batch.len() as u64);
        let guard = rcu::pin();
        let mut cached = None;
        for &(src, dst, weight) in batch {
            out.absorb(self.observe_pinned(&guard, src, dst, weight, &mut cached));
        }
        out
    }

    /// One transition under a caller-held guard. `cached` carries the
    /// previous iteration's `(src, NodeState)` so batch runs with repeated
    /// srcs skip the table lookup entirely; node states are never removed
    /// from the src table (decay prunes edges, not nodes), so a cached
    /// pointer stays valid for the guard's lifetime.
    fn observe_pinned<'g>(
        &self,
        guard: &'g rcu::Guard,
        src: u64,
        dst: u64,
        weight: u64,
        cached: &mut Option<(u64, &'g NodeState)>,
    ) -> ObserveOutcome {
        let mut new_src = false;
        let state = match cached {
            Some((cached_src, state)) if *cached_src == src => *state,
            _ => {
                // --- src-node lookup / creation (O(1) common case) ---
                let state_ptr = match self.src.get(guard, src) {
                    Some(p) => p,
                    None => {
                        let fresh = NodeState::boxed(src, &self.config);
                        let (winner, inserted) = self.src.insert_or_get(guard, src, fresh);
                        if inserted {
                            new_src = true;
                        } else {
                            // SAFETY: we lost the publish race; the fresh
                            // state was never shared, so this is the only
                            // reference to it.
                            unsafe { NodeState::free_unshared(fresh) };
                        }
                        winner
                    }
                };
                // SAFETY: node states are never removed from the src table
                // (decay prunes edges, not nodes), so a published pointer
                // stays valid until `McPrioQ::drop` — which requires `&mut
                // self`, excluded by the `&self` we hold.
                let state = unsafe { &*state_ptr };
                *cached = Some((src, state));
                state
            }
        };

        // Dirty-epoch stamp (one relaxed load in steady state): this node
        // changes in this checkpoint interval, so the next differential
        // checkpoint must carry it.
        state.mark_dirty(self.ckpt_mark.load(Ordering::Relaxed));

        // --- edge lookup / creation + increment ---
        let (new_edge, increment) = state.observe(guard, dst, weight, &self.config);
        if new_edge {
            self.edges.fetch_add(1, Ordering::Relaxed);
        }
        ObserveOutcome { new_src, new_edge, increment }
    }

    /// Items in descending probability until the cumulative probability
    /// reaches `threshold` (§II.B). `threshold` in `[0, 1]`.
    pub fn infer_threshold(&self, src: u64, threshold: f64) -> Recommendation {
        let mut out = Recommendation::empty();
        self.infer_threshold_into(src, threshold, &mut out);
        out
    }

    /// Allocation-free variant of [`infer_threshold`]: the answer is
    /// written into `out`, reusing its `items` buffer.
    pub fn infer_threshold_into(&self, src: u64, threshold: f64, out: &mut Recommendation) {
        let guard = rcu::pin();
        self.infer_threshold_with(&guard, src, threshold, out);
    }

    /// [`infer_threshold_into`] under a caller-held guard, so a batch of
    /// queries (the server's `MTOPK`, mixed read pipelines) pins RCU once.
    pub fn infer_threshold_with(
        &self,
        guard: &Guard,
        src: u64,
        threshold: f64,
        out: &mut Recommendation,
    ) {
        out.reset();
        // SAFETY: node states are never removed from the src table; see
        // `observe_pinned`.
        if let Some(state) = unsafe { self.src.get(guard, src).map(|p| &*p) } {
            state.infer_threshold_into(guard, threshold, &self.config, &self.reads, out);
        }
    }

    /// The `k` most probable next nodes.
    pub fn infer_topk(&self, src: u64, k: usize) -> Recommendation {
        let mut out = Recommendation::empty();
        self.infer_topk_into(src, k, &mut out);
        out
    }

    /// Allocation-free variant of [`infer_topk`]: the answer is written
    /// into `out`, reusing its `items` buffer.
    pub fn infer_topk_into(&self, src: u64, k: usize, out: &mut Recommendation) {
        let guard = rcu::pin();
        self.infer_topk_with(&guard, src, k, out);
    }

    /// [`infer_topk_into`] under a caller-held guard (one pin per batch).
    pub fn infer_topk_with(&self, guard: &Guard, src: u64, k: usize, out: &mut Recommendation) {
        out.reset();
        // SAFETY: see `observe_pinned` — node states are never unpublished.
        if let Some(state) = unsafe { self.src.get(guard, src).map(|p| &*p) } {
            state.infer_topk_into(guard, k, &self.config, &self.reads, out);
        }
    }

    /// Probability of the single transition `src -> dst` (None if the edge
    /// does not exist). O(1) with the dst table enabled.
    pub fn probability(&self, src: u64, dst: u64) -> Option<f64> {
        let guard = rcu::pin();
        // SAFETY: see `observe_pinned` — node states are never unpublished.
        let state = unsafe { self.src.get(&guard, src).map(|p| &*p) }?;
        state.probability(&guard, dst)
    }

    /// Uniform model decay (§II.C): multiply every edge counter by
    /// `decay_num / decay_den`, prune edges that reach zero, and refresh
    /// each node's total. Runs concurrently with observers and readers.
    /// Returns (surviving total count, pruned edge count).
    pub fn decay(&self) -> (u64, usize) {
        self.decay_with(self.config.decay_num, self.config.decay_den)
    }

    /// [`McPrioQ::decay`] with an explicit multiplier — replaying a logged
    /// `DecayRecord` uses the *recorded* numerator/denominator, so a config
    /// change across a restart cannot skew the replayed maintenance.
    pub fn decay_with(&self, num: u64, den: u64) -> (u64, usize) {
        self.decay_where(num, den, |_| true)
    }

    /// Decay restricted to src nodes matching `pred`. Recovery across a
    /// shard-layout change replays each old shard's `DecayRecord` onto
    /// exactly the srcs that old shard owned (the re-routed engine holds
    /// them spread over new shards), instead of decaying bystanders.
    pub fn decay_where(
        &self,
        num: u64,
        den: u64,
        mut pred: impl FnMut(u64) -> bool,
    ) -> (u64, usize) {
        assert!(den > 0, "decay denominator must be positive");
        self.decays.fetch_add(1, Ordering::Relaxed);
        let guard = rcu::pin();
        let mark = self.ckpt_mark.load(Ordering::Relaxed);
        let mut total = 0u64;
        let mut pruned = 0usize;
        self.src.for_each(&guard, |id, state_ptr| {
            if !pred(id) {
                return;
            }
            // SAFETY: see `observe_pinned` — never unpublished.
            let state = unsafe { &*state_ptr };
            let (sum, p) = state.decay(&guard, num, den);
            // Stamp only nodes the sweep actually changed: a node already
            // decayed empty (sum 0, nothing pruned) is untouched, and
            // skipping it keeps long-dead nodes out of every differential
            // checkpoint. Any node with surviving or pruned mass changed.
            if sum > 0 || p > 0 {
                state.mark_dirty(mark);
            }
            total += sum;
            pruned += p;
        });
        self.pruned.fetch_add(pruned as u64, Ordering::Relaxed);
        self.edges.fetch_sub(pruned, Ordering::Relaxed);
        (total, pruned)
    }

    /// Maintenance sweep: restore exact sort order in every edge list
    /// (residual inversions from skipped/raced reorders). Piggybacked on
    /// decay in production; exposed for tests and quiesce points.
    pub fn repair(&self) -> u64 {
        let guard = rcu::pin();
        let mark = self.ckpt_mark.load(Ordering::Relaxed);
        let mut swaps = 0u64;
        self.src.for_each(&guard, |_, state_ptr| {
            // SAFETY: see `observe_pinned` — never unpublished.
            let state = unsafe { &*state_ptr };
            let s = state.repair(&guard);
            // Dirty only on reorder: an already-sorted node serves the
            // same export either way (the total rebase is re-derived by
            // replaying the logged repair record), so a no-op sweep must
            // not inflate the next differential checkpoint to full size.
            if s > 0 {
                state.mark_dirty(mark);
            }
            swaps += s;
        });
        swaps
    }

    /// Current checkpoint mark (see the field docs).
    pub fn ckpt_mark(&self) -> u64 {
        self.ckpt_mark.load(Ordering::Relaxed)
    }

    /// Advance the checkpoint mark; returns the new value. Call only
    /// inside an ingest pause, *after* collecting the dirty set — every
    /// later mutation then stamps the new mark.
    pub fn advance_ckpt_mark(&self) -> u64 {
        self.ckpt_mark.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Set the checkpoint mark outside the fetch-add discipline — the
    /// recovery path restoring a persisted mark (DESIGN.md §6): nodes
    /// imported from the checkpoint chain are stamped *below* the restored
    /// floor, WAL-replayed nodes at it, so the first post-restart
    /// checkpoint can stay differential. Quiesced callers only.
    pub fn set_ckpt_mark(&self, mark: u64) {
        self.ckpt_mark.store(mark, Ordering::Relaxed);
    }

    /// [`McPrioQ::export`] restricted to nodes dirtied at or after
    /// `since` — the payload of a differential checkpoint.
    pub fn export_dirty(&self, since: u64) -> Vec<(u64, u64, Vec<(u64, u64)>)> {
        let guard = rcu::pin();
        let mut out = Vec::new();
        self.src.for_each(&guard, |id, state_ptr| {
            // SAFETY: see `observe_pinned` — never unpublished.
            let state = unsafe { &*state_ptr };
            if state.dirty_mark() >= since {
                out.push((id, state.total(), state.edges_snapshot(&guard)));
            }
        });
        out.sort_unstable_by_key(|&(id, _, _)| id);
        out
    }

    /// Verify P1/P3 on every node (quiesced-only; test helper).
    pub fn check_invariants(&self) -> Result<(), String> {
        let guard = rcu::pin();
        let mut err = None;
        self.src.for_each(&guard, |id, state_ptr| {
            if err.is_some() {
                return;
            }
            // SAFETY: see `observe_pinned` — never unpublished.
            if let Err(e) = unsafe { &*state_ptr }.check_invariants() {
                err = Some(format!("node {id}: {e}"));
            }
        });
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Error-audit sampling hook (DESIGN.md §10): probe up to `max`
    /// snapshot-bearing nodes (the hot set — only snapshots serve
    /// approximate answers), skipping the first `skip` so a rotating
    /// cursor spreads successive rounds across the whole hot set, and
    /// append one [`AuditSample`] per probed node. Returns the total
    /// number of snapshot-bearing nodes seen, for cursor wraparound.
    pub fn audit_samples(
        &self,
        skip: usize,
        max: usize,
        k: usize,
        out: &mut Vec<AuditSample>,
    ) -> usize {
        let guard = rcu::pin();
        let mut eligible = 0usize;
        let mut taken = 0usize;
        self.src.for_each(&guard, |_, state_ptr| {
            // SAFETY: see `observe_pinned` — never unpublished.
            let state = unsafe { &*state_ptr };
            if !state.has_snapshot() {
                return;
            }
            eligible += 1;
            if eligible <= skip || taken >= max {
                return;
            }
            if let Some(s) = state.audit_probe(&guard, k) {
                out.push(s);
                taken += 1;
            }
        });
        eligible
    }

    /// Structural-watchdog sweep (DESIGN.md §10) over up to `max` nodes
    /// starting `skip` nodes into the walk: snapshot `cum` monotonicity
    /// plus the tolerant edge-sum check. Safe under full concurrency —
    /// nodes that mutate mid-scan are skipped, not misjudged.
    pub fn audit_structural(&self, skip: usize, max: usize) -> StructuralAudit {
        let guard = rcu::pin();
        let mut rep = StructuralAudit::default();
        let mut seen = 0usize;
        self.src.for_each(&guard, |_, state_ptr| {
            seen += 1;
            if seen <= skip || rep.checked >= max {
                return;
            }
            // SAFETY: see `observe_pinned` — never unpublished.
            let state = unsafe { &*state_ptr };
            rep.cum_violations += state.audit_cum(&guard);
            match state.audit_edge_sum(&guard) {
                None => rep.unstable_skips += 1,
                Some(true) => {}
                Some(false) => rep.edge_sum_violations += 1,
            }
            rep.checked += 1;
        });
        rep
    }

    /// Per-node statistics (None if the src node is unknown).
    pub fn node_stats(&self, src: u64) -> Option<NodeStats> {
        let guard = rcu::pin();
        // SAFETY: see `observe_pinned` — never unpublished.
        let state = unsafe { self.src.get(&guard, src).map(|p| &*p) }?;
        Some(state.stats(&guard))
    }

    /// Number of distinct src nodes.
    pub fn node_count(&self) -> usize {
        self.src.len()
    }

    /// Number of live edges (approximate under concurrency).
    pub fn edge_count(&self) -> usize {
        self.edges.load(Ordering::Relaxed)
    }

    /// Latency distribution of this chain's snapshot rebuilds — sampled
    /// by the telemetry registry (one summary series per shard).
    pub fn snap_rebuild_lat(&self) -> crate::metrics::Snapshot {
        self.reads.snap_rebuild_ns.snapshot()
    }

    /// Transitions observed by this chain (O(1), unlike `stats`).
    pub fn observe_count(&self) -> u64 {
        self.observes.get()
    }

    /// Read-snapshot effectiveness counters `(hits, rebuilds, fallbacks)`
    /// — the cheap accessors the telemetry closures sample (a full
    /// `stats()` walks every node under an RCU pin).
    pub fn snap_counters(&self) -> (u64, u64, u64) {
        (
            self.reads.snap_hits.get(),
            self.reads.snap_rebuilds.get(),
            self.reads.snap_fallbacks.get(),
        )
    }

    pub fn stats(&self) -> ChainStats {
        let guard = rcu::pin();
        let mut swaps = 0u64;
        let mut skips = 0u64;
        let mut edges = 0usize;
        let mut bytes = std::mem::size_of::<Self>();
        self.src.for_each(&guard, |_, state_ptr| {
            // SAFETY: see `observe_pinned` — never unpublished.
            let s = unsafe { &*state_ptr }.stats(&guard);
            swaps += s.swaps;
            skips += s.swap_skips;
            edges += s.edges;
            bytes += s.approx_bytes;
        });
        ChainStats {
            nodes: self.src.len(),
            edges,
            observes: self.observes.get(),
            swaps,
            swap_skips: skips,
            decays: self.decays.load(Ordering::Relaxed),
            pruned_edges: self.pruned.load(Ordering::Relaxed),
            approx_bytes: bytes,
            snap_hits: self.reads.snap_hits.get(),
            snap_rebuilds: self.reads.snap_rebuilds.get(),
            snap_fallbacks: self.reads.snap_fallbacks.get(),
        }
    }

    /// Export a quiesced snapshot: `(src, total, [(dst, count)])` per node,
    /// edge lists head-first. Used by examples (model save) and by the
    /// dense-engine comparison (E6).
    pub fn export(&self) -> Vec<(u64, u64, Vec<(u64, u64)>)> {
        let guard = rcu::pin();
        let mut out = Vec::with_capacity(self.src.len());
        self.src.for_each(&guard, |id, state_ptr| {
            // SAFETY: see `observe_pinned` — never unpublished.
            let state = unsafe { &*state_ptr };
            out.push((id, state.total(), state.edges_snapshot(&guard)));
        });
        out.sort_unstable_by_key(|&(id, _, _)| id);
        out
    }

    /// Rebuild a chain from an exported snapshot. Each node's edge list is
    /// replayed as one same-src weighted batch (single guard, cached node).
    pub fn import(config: ChainConfig, snapshot: &[(u64, u64, Vec<(u64, u64)>)]) -> Self {
        let chain = McPrioQ::new(config);
        let mut batch = Vec::new();
        for (src, _total, edges) in snapshot {
            batch.clear();
            batch.extend(edges.iter().map(|&(dst, count)| (*src, dst, count)));
            chain.observe_batch_weighted(&batch);
        }
        chain
    }
}

impl Drop for McPrioQ {
    fn drop(&mut self) {
        // Exclusive access: free every NodeState (PtrTable does not own its
        // values). The NodeState drop frees its edge list and dst table.
        let guard = rcu::pin();
        let mut ptrs = Vec::new();
        self.src.for_each(&guard, |_, p| ptrs.push(p));
        drop(guard);
        for p in ptrs {
            // SAFETY: `&mut self` proves no concurrent users; every state
            // was allocated by `NodeState::boxed` and published exactly
            // once, so each pointer is freed exactly once here.
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

#[cfg(test)]
mod tests;
