//! Per-src-node state: total counter + optional dst table + edge list.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{ChainConfig, Recommendation};
use crate::hashtable::PtrTable;
use crate::prioq::{EdgeList, IncrementOutcome, Node};
use crate::rcu::Guard;
use crate::sync::CachePadded;

/// Statistics for one src node.
#[derive(Debug, Clone, Copy)]
pub struct NodeStats {
    pub id: u64,
    pub total: u64,
    pub edges: usize,
    pub swaps: u64,
    pub swap_skips: u64,
    pub approx_bytes: usize,
}

pub(super) struct NodeState {
    id: u64,
    /// Total transitions out of this node (§II.3's second counter).
    /// Cache-padded: it is the hottest write target of the whole structure.
    total: CachePadded<AtomicU64>,
    edges: EdgeList,
    /// dst -> list-node index; `None` reproduces the paper's "skip the
    /// dst-hash-table" ablation (§II.2).
    dst: Option<PtrTable<Node>>,
}

impl NodeState {
    pub(super) fn boxed(id: u64, config: &ChainConfig) -> *mut NodeState {
        Box::into_raw(Box::new(NodeState {
            id,
            total: CachePadded::new(AtomicU64::new(0)),
            edges: EdgeList::new(),
            dst: config.use_dst_table.then(|| PtrTable::with_capacity(config.dst_capacity)),
        }))
    }

    /// # Safety
    /// Only for states that lost the src-table publish race and were never
    /// shared with other threads.
    pub(super) unsafe fn free_unshared(ptr: *mut NodeState) {
        drop(Box::from_raw(ptr));
    }

    pub(super) fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Find-or-create the edge to `dst` and add `weight`. Returns
    /// `(new_edge, increment outcome)`.
    pub(super) fn observe(
        &self,
        guard: &Guard,
        dst: u64,
        weight: u64,
        _config: &ChainConfig,
    ) -> (bool, IncrementOutcome) {
        let result = match &self.dst {
            Some(table) => {
                match table.get(guard, dst) {
                    Some(node) => {
                        // Normal case (§II.A.2): two O(1) lookups + one
                        // wait-free increment, reorder only on inversion.
                        let out = unsafe { self.edges.increment(guard, node, weight) };
                        (false, out)
                    }
                    None => {
                        // New edge (§II.A.1): race to publish in the dst
                        // table; the winner links the node into the queue.
                        let fresh = EdgeList::alloc_node(dst, weight);
                        let (winner, inserted) = table.insert_or_get(guard, dst, fresh);
                        if inserted {
                            self.edges.insert_node(guard, fresh);
                            (true, IncrementOutcome { count: weight, swaps: 0, skipped: false })
                        } else {
                            unsafe { EdgeList::free_unshared(fresh) };
                            let out = unsafe { self.edges.increment(guard, winner, weight) };
                            (false, out)
                        }
                    }
                }
            }
            None => {
                // Ablation: the list is the only index. Existing edges are
                // found by a (ticketed) list search whose expected depth is
                // the edge's probability rank — the tradeoff §II.2 debates.
                let (node, inserted) = self.edges.find_or_insert(guard, dst, weight);
                if inserted {
                    (true, IncrementOutcome { count: weight, swaps: 0, skipped: false })
                } else {
                    let out = unsafe { self.edges.increment(guard, node, weight) };
                    (false, out)
                }
            }
        };
        self.total.fetch_add(weight, Ordering::AcqRel);
        result
    }

    pub(super) fn infer_threshold(&self, guard: &Guard, threshold: f64) -> Recommendation {
        let total = self.total.load(Ordering::Acquire);
        if total == 0 {
            return Recommendation::empty();
        }
        let threshold = threshold.clamp(0.0, 1.0);
        if threshold == 0.0 {
            // The empty prefix already satisfies cum >= 0 (minimality, P4).
            return Recommendation { items: Vec::new(), cumulative: 0.0, scanned: 0, total };
        }
        let totf = total as f64;
        let mut items = Vec::new();
        let mut cum = 0u64;
        let scanned = self.edges.scan(guard, |dst, count| {
            cum += count;
            items.push((dst, count as f64 / totf));
            // Integer comparison: cum/total >= threshold.
            (cum as f64) < threshold * totf
        });
        Recommendation { items, cumulative: cum as f64 / totf, scanned, total }
    }

    pub(super) fn infer_topk(&self, guard: &Guard, k: usize) -> Recommendation {
        let total = self.total.load(Ordering::Acquire);
        if total == 0 || k == 0 {
            return Recommendation::empty();
        }
        let totf = total as f64;
        let mut items = Vec::with_capacity(k.min(64));
        let mut cum = 0u64;
        let scanned = self.edges.scan(guard, |dst, count| {
            cum += count;
            items.push((dst, count as f64 / totf));
            items.len() < k
        });
        Recommendation { items, cumulative: cum as f64 / totf, scanned, total }
    }

    pub(super) fn probability(&self, guard: &Guard, dst: u64) -> Option<f64> {
        let total = self.total.load(Ordering::Acquire);
        if total == 0 {
            return None;
        }
        match &self.dst {
            Some(table) => {
                let node = table.get(guard, dst)?;
                Some(unsafe { &*node }.count() as f64 / total as f64)
            }
            None => {
                let mut found = None;
                self.edges.scan(guard, |k, c| {
                    if k == dst {
                        found = Some(c);
                        false
                    } else {
                        true
                    }
                });
                found.map(|c| c as f64 / total as f64)
            }
        }
    }

    pub(super) fn decay(&self, guard: &Guard, num: u64, den: u64) -> (u64, usize) {
        let (sum, pruned) = self.edges.decay(guard, num, den, |key, _node| {
            // Unpublish before the node is retired: readers inside the
            // current grace period may still see it via either route.
            if let Some(table) = &self.dst {
                table.remove(guard, key);
            }
        });
        // Refresh the total from the surviving mass. Racing observers may
        // add to `total` between the sum and this store; their edge
        // contribution was either halved with the edge or added after — the
        // discrepancy is transient and bounded by in-flight updates
        // (approximately correct; exact at quiescence, invariant P3).
        self.total.store(sum, Ordering::Release);
        // Piggyback the order-repair sweep on the maintenance pass.
        self.edges.repair(guard);
        (sum, pruned)
    }

    pub(super) fn repair(&self, guard: &Guard) -> u64 {
        let swaps = self.edges.repair(guard);
        // Re-base the total from the edge sum: an increment racing a decay
        // can land after the decay summed its edge but before the total was
        // stored, leaving a small permanent skew that no later update
        // corrects. The maintenance sweep is the quiesce point that restores
        // exactness (P3); under concurrency the rebased value is just a
        // fresher approximation.
        let mut sum = 0u64;
        self.edges.scan(guard, |_, c| {
            sum += c;
            true
        });
        self.total.store(sum, Ordering::Release);
        swaps
    }

    pub(super) fn check_invariants(&self) -> Result<(), String> {
        self.edges.check_sorted()?;
        // P3: sum of edge counters == node total (quiesced).
        let guard = crate::rcu::pin();
        let mut sum = 0u64;
        self.edges.scan(&guard, |_, c| {
            sum += c;
            true
        });
        let total = self.total.load(Ordering::Acquire);
        if sum != total {
            return Err(format!("edge sum {sum} != total {total}"));
        }
        // Dst table and list must agree.
        if let Some(table) = &self.dst {
            if table.len() != self.edges.len() {
                return Err(format!(
                    "dst table len {} != list len {}",
                    table.len(),
                    self.edges.len()
                ));
            }
        }
        Ok(())
    }

    pub(super) fn edges_snapshot(&self, guard: &Guard) -> Vec<(u64, u64)> {
        self.edges.top(guard, usize::MAX)
    }

    pub(super) fn stats(&self) -> NodeStats {
        let ls = self.edges.stats();
        let bytes = std::mem::size_of::<NodeState>()
            + ls.len * (std::mem::size_of::<Node>() + 48) // node + table entry
            + self.dst.as_ref().map_or(0, |t| t.stats().capacity * 8);
        NodeStats {
            id: self.id,
            total: self.total.load(Ordering::Relaxed),
            edges: ls.len,
            swaps: ls.swaps,
            swap_skips: ls.swap_skips,
            approx_bytes: bytes,
        }
    }
}

// NodeState owns its EdgeList (which frees the list nodes) and its dst
// table (which frees only its entry shells — the values are the same list
// nodes, freed exactly once by the EdgeList). Default Drop is correct.
