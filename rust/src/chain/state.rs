//! Per-src-node state: total counter + optional dst table + edge list +
//! RCU-published read snapshot (see `snapshot.rs`).

use super::snapshot::{cum_reaches, dyadic, EdgeSnapshot};
use super::{ChainConfig, ReadMetrics, Recommendation};
use crate::hashtable::PtrTable;
use crate::prioq::{EdgeList, IncrementOutcome, Node};
use crate::rcu::{self, Guard};
use crate::sync::shim::{AtomicPtr, AtomicU64, Ordering};
use crate::sync::CachePadded;

/// Statistics for one src node.
#[derive(Debug, Clone, Copy)]
pub struct NodeStats {
    pub id: u64,
    pub total: u64,
    pub edges: usize,
    pub swaps: u64,
    pub swap_skips: u64,
    pub approx_bytes: usize,
}

pub(super) struct NodeState {
    id: u64,
    /// Total transitions out of this node (§II.3's second counter).
    /// Cache-padded: it is the hottest write target of the whole structure.
    total: CachePadded<AtomicU64>,
    edges: EdgeList,
    /// dst -> list-node index; `None` reproduces the paper's "skip the
    /// dst-hash-table" ablation (§II.2).
    dst: Option<PtrTable<Node>>,
    /// The RCU-published prefix-sum read snapshot (null = none). Swapped
    /// whole; the previous array is retired through `rcu::defer_free`.
    snap: AtomicPtr<EdgeSnapshot>,
    /// Checkpoint-mark this node was last mutated in (observe / decay /
    /// repair) — the dirty epoch incremental checkpoints select on
    /// (DESIGN.md §6). Monotone: the chain's mark only advances, and it
    /// advances only inside a checkpoint's ingest pause.
    dirty: AtomicU64,
}

impl NodeState {
    pub(super) fn boxed(id: u64, config: &ChainConfig) -> *mut NodeState {
        Box::into_raw(Box::new(NodeState {
            id,
            total: CachePadded::new(AtomicU64::new(0)),
            edges: EdgeList::new(),
            dst: config.use_dst_table.then(|| PtrTable::with_capacity(config.dst_capacity)),
            snap: AtomicPtr::new(std::ptr::null_mut()),
            // Born dirty at mark 0: whatever the chain's current mark is,
            // the caller stamps it right after the insert.
            dirty: AtomicU64::new(0),
        }))
    }

    /// Stamp this node as mutated in checkpoint-mark `mark`. The
    /// load-check keeps the hot path to one relaxed load in steady state
    /// (the mark changes only at checkpoints).
    #[inline]
    pub(super) fn mark_dirty(&self, mark: u64) {
        if self.dirty.load(Ordering::Relaxed) != mark {
            self.dirty.store(mark, Ordering::Relaxed);
        }
    }

    pub(super) fn dirty_mark(&self) -> u64 {
        self.dirty.load(Ordering::Relaxed)
    }

    /// # Safety
    /// Only for states that lost the src-table publish race and were never
    /// shared with other threads.
    pub(super) unsafe fn free_unshared(ptr: *mut NodeState) {
        // SAFETY: per this function's contract the state was never shared,
        // and it came from `boxed`'s Box::into_raw.
        drop(unsafe { Box::from_raw(ptr) });
    }

    pub(super) fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Find-or-create the edge to `dst` and add `weight`. Returns
    /// `(new_edge, increment outcome)`.
    ///
    /// Snapshot invalidation hook: every path below advances the edge
    /// list's mutation epoch (increment or splice), which is what ages the
    /// published read snapshot toward its staleness bound — observes never
    /// retire it eagerly (that would defeat the read cache).
    pub(super) fn observe(
        &self,
        guard: &Guard,
        dst: u64,
        weight: u64,
        _config: &ChainConfig,
    ) -> (bool, IncrementOutcome) {
        let result = match &self.dst {
            Some(table) => {
                match table.get(guard, dst) {
                    Some(node) => {
                        // Normal case (§II.A.2): two O(1) lookups + one
                        // wait-free increment, reorder only on inversion.
                        // SAFETY: the dst table only holds nodes of this
                        // edge list, alive under `guard`.
                        let out = unsafe { self.edges.increment(guard, node, weight) };
                        (false, out)
                    }
                    None => {
                        // New edge (§II.A.1): race to publish in the dst
                        // table; the winner links the node into the queue.
                        let fresh = EdgeList::alloc_node(dst, weight);
                        let (winner, inserted) = table.insert_or_get(guard, dst, fresh);
                        if inserted {
                            self.edges.insert_node(guard, fresh);
                            (true, IncrementOutcome { count: weight, swaps: 0, skipped: false })
                        } else {
                            // SAFETY: `fresh` lost the publish race — it
                            // was never inserted or shared.
                            unsafe { EdgeList::free_unshared(fresh) };
                            // SAFETY: `winner` is the table's node for this
                            // edge list, alive under `guard`.
                            let out = unsafe { self.edges.increment(guard, winner, weight) };
                            (false, out)
                        }
                    }
                }
            }
            None => {
                // Ablation: the list is the only index. Existing edges are
                // found by a (ticketed) list search whose expected depth is
                // the edge's probability rank — the tradeoff §II.2 debates.
                let (node, inserted) = self.edges.find_or_insert(guard, dst, weight);
                if inserted {
                    (true, IncrementOutcome { count: weight, swaps: 0, skipped: false })
                } else {
                    // SAFETY: `node` came from this list's find_or_insert,
                    // alive under `guard`.
                    let out = unsafe { self.edges.increment(guard, node, weight) };
                    (false, out)
                }
            }
        };
        self.total.fetch_add(weight, Ordering::AcqRel);
        result
    }

    /// The snapshot to serve this read from, if any: fresh → hit; missing
    /// or stale → try a rebuild under the structural ticket (publishing
    /// while it is held, see `snapshot.rs`); ticket busy → fall back to
    /// the live list walk. `None` always means "walk the list".
    fn snapshot_for_read<'g>(
        &self,
        guard: &'g Guard,
        config: &ChainConfig,
        metrics: &ReadMetrics,
    ) -> Option<&'g EdgeSnapshot> {
        if !config.snap_enabled {
            return None;
        }
        let ptr = self.snap.load(Ordering::Acquire);
        if !ptr.is_null() {
            // SAFETY: guard-protected — a swapped-out snapshot is freed
            // only after the current grace period.
            let snap = unsafe { &*ptr };
            if self.edges.mutations().wrapping_sub(snap.epoch) <= config.snap_staleness {
                metrics.snap_hits.inc();
                return Some(snap);
            }
        }
        if self.edges.len() < config.snap_min_edges {
            // Tiny list: the walk is at least as fast as a copy. Not a
            // fallback — snapshots are simply not worth it here.
            return None;
        }
        // Rebuild cost rides the *read* that found the snapshot stale
        // (DESIGN.md §9): time successful rebuilds so the telemetry plane
        // can attribute read-tail latency to rebuild storms instead of
        // averaging them into the query histogram. Busy-ticket fallbacks
        // are not rebuilds and stay out of the distribution.
        let t0 = std::time::Instant::now();
        match self.try_rebuild_snapshot(guard, config) {
            Some(snap) => {
                metrics.snap_rebuild_ns.record(t0.elapsed().as_nanos() as u64);
                metrics.snap_rebuilds.inc();
                Some(snap)
            }
            None => {
                metrics.snap_fallbacks.inc();
                None
            }
        }
    }

    /// Rebuild and publish the snapshot under the edge list's structural
    /// ticket. Publishing inside the ticketed section is what makes the
    /// install ordered against decay/repair sweeps (which hold the same
    /// ticket): a snapshot carrying pre-sweep content is always published
    /// *before* the sweep runs, so the sweep's invalidation retires it.
    /// Returns `None` (no publish) when the ticket is busy or the list
    /// came back empty.
    fn try_rebuild_snapshot<'g>(&self, guard: &'g Guard, config: &ChainConfig) -> Option<&'g EdgeSnapshot> {
        // Epoch first: increments racing the collect re-age the snapshot,
        // they can never make it look fresher than it is.
        let epoch = self.edges.mutations();
        let mut cum = 0u64;
        self.edges
            .try_collect_stable(
                guard,
                // One ticketed pass computes the prefix sums in place; the
                // exact-capacity Vec is boxed without another copy.
                |dst, count| {
                    cum += count;
                    (dst, count, cum)
                },
                |entries| {
                    if entries.is_empty() {
                        return None;
                    }
                    let fresh = Box::into_raw(Box::new(EdgeSnapshot::from_entries(
                        epoch,
                        entries,
                        config.snap_layout,
                    )));
                    let old = self.snap.swap(fresh, Ordering::AcqRel);
                    if !old.is_null() {
                        // SAFETY: `old` was unpublished by the swap and is
                        // retired exactly once; it came from Box::into_raw.
                        unsafe { rcu::defer_free(guard, old) };
                    }
                    // SAFETY: `fresh` is alive at least until the caller's
                    // guard drops (it can only be retired after a swap +
                    // grace period).
                    Some(unsafe { &*fresh })
                },
            )
            .flatten()
    }

    /// Drop the published snapshot (decay/repair hooks): readers inside
    /// the current grace period may still finish serving from it, after
    /// that it is gone — which is exactly the §II.C guarantee that pruned
    /// edges stop being recommended once a grace period elapses.
    fn invalidate_snapshot(&self, guard: &Guard) {
        let old = self.snap.swap(std::ptr::null_mut(), Ordering::AcqRel);
        if !old.is_null() {
            // SAFETY: unpublished by the swap, retired exactly once, from
            // Box::into_raw.
            unsafe { rcu::defer_free(guard, old) };
        }
    }

    pub(super) fn infer_threshold_into(
        &self,
        guard: &Guard,
        threshold: f64,
        config: &ChainConfig,
        metrics: &ReadMetrics,
        out: &mut Recommendation,
    ) {
        let total = self.total.load(Ordering::Acquire);
        if total == 0 {
            return; // `out` was reset by the caller: the empty answer
        }
        let threshold = threshold.clamp(0.0, 1.0);
        out.total = total;
        if threshold == 0.0 || threshold.is_nan() {
            // Zero (or NaN) threshold: the empty prefix already satisfies
            // cum >= 0 (minimality, P4).
            return;
        }
        // Exact dyadic decomposition: the termination test runs in integer
        // arithmetic (`cum * 2^s >= m * total`), immune to the f64
        // rounding that loses ulps once totals approach 2^53.
        let (m, s) = dyadic(threshold);
        if let Some(snap) = self.snapshot_for_read(guard, config, metrics) {
            // O(log E): search the inclusive prefix sums (branchless
            // Eytzinger descent or binary search, per layout) for the
            // minimal covering prefix, then bulk-copy it out (vectorized
            // when the layout carries SoA columns).
            let end = (snap.threshold_prefix(m, s) + 1).min(snap.entries.len());
            snap.copy_prefix_probs(end, &mut out.items);
            out.cumulative = snap.entries[end - 1].2 as f64 / snap.total as f64;
            out.scanned = end;
            out.total = snap.total;
            return;
        }
        let totf = total as f64;
        let items = &mut out.items;
        let mut cum = 0u64;
        out.scanned = self.edges.scan(guard, |dst, count| {
            cum += count;
            items.push((dst, count as f64 / totf));
            !cum_reaches(cum, total, m, s)
        });
        out.cumulative = cum as f64 / totf;
    }

    pub(super) fn infer_topk_into(
        &self,
        guard: &Guard,
        k: usize,
        config: &ChainConfig,
        metrics: &ReadMetrics,
        out: &mut Recommendation,
    ) {
        let total = self.total.load(Ordering::Acquire);
        if total == 0 || k == 0 {
            return; // `out` was reset by the caller: the empty answer
        }
        if let Some(snap) = self.snapshot_for_read(guard, config, metrics) {
            // The bounded-copy fast path: one contiguous prefix, no
            // pointer chase, probabilities against the snapshot's own sum.
            let end = k.min(snap.entries.len());
            snap.copy_prefix_probs(end, &mut out.items);
            out.cumulative = snap.entries[end - 1].2 as f64 / snap.total as f64;
            out.scanned = end;
            out.total = snap.total;
            return;
        }
        let totf = total as f64;
        let items = &mut out.items;
        let mut cum = 0u64;
        out.scanned = self.edges.scan(guard, |dst, count| {
            cum += count;
            items.push((dst, count as f64 / totf));
            items.len() < k
        });
        out.cumulative = cum as f64 / totf;
        out.total = total;
    }

    pub(super) fn probability(&self, guard: &Guard, dst: u64) -> Option<f64> {
        let total = self.total.load(Ordering::Acquire);
        if total == 0 {
            return None;
        }
        match &self.dst {
            Some(table) => {
                let node = table.get(guard, dst)?;
                // SAFETY: table nodes belong to this edge list, alive under
                // `guard`.
                Some(unsafe { &*node }.count() as f64 / total as f64)
            }
            None => {
                let mut found = None;
                self.edges.scan(guard, |k, c| {
                    if k == dst {
                        found = Some(c);
                        false
                    } else {
                        true
                    }
                });
                found.map(|c| c as f64 / total as f64)
            }
        }
    }

    pub(super) fn decay(&self, guard: &Guard, num: u64, den: u64) -> (u64, usize) {
        let (sum, pruned) = self.edges.decay(guard, num, den, |key, _node| {
            // Unpublish before the node is retired: readers inside the
            // current grace period may still see it via either route.
            if let Some(table) = &self.dst {
                table.remove(guard, key);
            }
        });
        // Drop the read snapshot *after* the sweep: any snapshot carrying
        // pre-decay counts (or pruned edges) was published before the
        // sweep could take the ticket, so this retire covers it, and one
        // grace period later no reader can serve a pruned edge.
        self.invalidate_snapshot(guard);
        // Refresh the total from the surviving mass. Racing observers may
        // add to `total` between the sum and this store; their edge
        // contribution was either halved with the edge or added after — the
        // discrepancy is transient and bounded by in-flight updates
        // (approximately correct; exact at quiescence, invariant P3).
        self.total.store(sum, Ordering::Release);
        // Piggyback the order-repair sweep on the maintenance pass; its
        // fused edge sum is a fresher total than the decay sweep's.
        let (_swaps, repaired_sum) = self.edges.repair(guard);
        self.total.store(repaired_sum, Ordering::Release);
        (sum, pruned)
    }

    pub(super) fn repair(&self, guard: &Guard) -> u64 {
        let (swaps, sum) = self.edges.repair(guard);
        // Snapshots predate the exact re-sort; retire them so the next
        // read rebuilds from the repaired (exact-at-quiescence) order.
        self.invalidate_snapshot(guard);
        // Re-base the total from the edge sum (fused into the repair pass —
        // previously a second full list scan): an increment racing a decay
        // can land after the decay summed its edge but before the total was
        // stored, leaving a small permanent skew that no later update
        // corrects. The maintenance sweep is the quiesce point that restores
        // exactness (P3); under concurrency the rebased value is just a
        // fresher approximation.
        self.total.store(sum, Ordering::Release);
        swaps
    }

    pub(super) fn check_invariants(&self) -> Result<(), String> {
        self.edges.check_sorted()?;
        // P3: sum of edge counters == node total (quiesced).
        let guard = crate::rcu::pin();
        let mut sum = 0u64;
        self.edges.scan(&guard, |_, c| {
            sum += c;
            true
        });
        let total = self.total.load(Ordering::Acquire);
        if sum != total {
            return Err(format!("edge sum {sum} != total {total}"));
        }
        // Dst table and list must agree.
        if let Some(table) = &self.dst {
            if table.len() != self.edges.len() {
                return Err(format!(
                    "dst table len {} != list len {}",
                    table.len(),
                    self.edges.len()
                ));
            }
        }
        Ok(())
    }

    pub(super) fn edges_snapshot(&self, guard: &Guard) -> Vec<(u64, u64)> {
        self.edges.top(guard, usize::MAX)
    }

    /// Whether a read snapshot is currently published — the audit plane
    /// probes only these nodes (no snapshot = reads are exact walks, so
    /// there is no approximation to measure).
    pub(super) fn has_snapshot(&self) -> bool {
        !self.snap.load(Ordering::Acquire).is_null()
    }

    /// Approximation-error probe (DESIGN.md §10): compare the top-`k` the
    /// published snapshot *serves* against a fresh exact walk of the live
    /// list, under the caller's guard. Returns `None` when no snapshot is
    /// published. Ties in live counts are rank-classes: a served position
    /// anywhere inside its count's class contributes no error.
    pub(super) fn audit_probe(&self, guard: &Guard, k: usize) -> Option<super::AuditSample> {
        let ptr = self.snap.load(Ordering::Acquire);
        if ptr.is_null() {
            return None;
        }
        // SAFETY: guard-protected — a concurrently swapped-out snapshot
        // stays readable until the grace period ends.
        let snap = unsafe { &*ptr };
        let staleness = self.edges.mutations().wrapping_sub(snap.epoch);
        // Fresh exact reference: live counts, sorted by count (the order
        // the list converges to at quiescence).
        let live = self.edges.top(guard, usize::MAX);
        let live_total: u64 = live.iter().map(|&(_, c)| c).sum();
        let counts: std::collections::HashMap<u64, u64> = live.iter().copied().collect();
        let mut exact = live;
        exact.sort_unstable_by(|a, b| b.1.cmp(&a.1));
        let served_k = k.min(snap.entries.len());
        // Live count of each served dst (0 = pruned since the snapshot).
        let served: Vec<(u64, u64)> = snap.entries[..served_k]
            .iter()
            .map(|&(dst, _, _)| (dst, counts.get(&dst).copied().unwrap_or(0)))
            .collect();
        // Rank inversions: served pairs ordered against their live counts
        // (strict — equal counts are interchangeable). O(k²), k is small.
        let mut rank_inversions = 0u64;
        for i in 0..served.len() {
            for j in (i + 1)..served.len() {
                if served[i].1 < served[j].1 {
                    rank_inversions += 1;
                }
            }
        }
        // Spearman-footrule displacement: distance from each served
        // position to its count's rank class [lo, hi) in the exact order.
        let mut displacement = 0u64;
        for (i, &(_, c)) in served.iter().enumerate() {
            let lo = exact.partition_point(|e| e.1 > c);
            let hi = exact.partition_point(|e| e.1 >= c);
            let target = i.clamp(lo, hi.max(lo + 1) - 1);
            displacement += i.abs_diff(target) as u64;
        }
        // Probability mass the served answer misses against the exact
        // top-k, in live mass. 0 when the served set is the exact set.
        let mass_error = if live_total == 0 {
            0.0
        } else {
            let exact_mass: u64 = exact.iter().take(k).map(|&(_, c)| c).sum();
            let served_mass: u64 = served.iter().map(|&(_, c)| c).sum();
            exact_mass.saturating_sub(served_mass) as f64 / live_total as f64
        };
        Some(super::AuditSample {
            src: self.id,
            staleness,
            served_k,
            rank_inversions,
            displacement,
            mass_error,
        })
    }

    /// Watchdog check (DESIGN.md §10): the published snapshot's inclusive
    /// prefix sums must ascend and close at the snapshot total. Snapshots
    /// are immutable after publish, so any violation is construction
    /// corruption, never a benign race. Returns the violation count.
    pub(super) fn audit_cum(&self, _guard: &Guard) -> u64 {
        let ptr = self.snap.load(Ordering::Acquire);
        if ptr.is_null() {
            return 0;
        }
        // SAFETY: the caller's guard keeps a swapped-out snapshot alive.
        let snap = unsafe { &*ptr };
        let mut violations = 0u64;
        let mut prev = 0u64;
        for &(_, count, cum) in snap.entries.iter() {
            if cum < prev || cum.wrapping_sub(prev) != count {
                violations += 1;
            }
            prev = cum;
        }
        if snap.entries.last().map(|e| e.2) != Some(snap.total) {
            violations += 1;
        }
        violations
    }

    /// Watchdog edge-sum check (DESIGN.md §10). `None`: the node mutated
    /// mid-scan (comparison meaningless; the watchdog retries next round).
    /// `Some(true)`: the stable edge sum matches the total within the
    /// in-flight skew bound. `Some(false)`: a stable gross mismatch —
    /// structural corruption (lost edge, double count), not racing
    /// arithmetic. The bound exists because maintenance racing a writer
    /// legitimately leaves a few increments of skew until the next repair
    /// rebase (see [`NodeState::decay`]); corruption is orders larger.
    pub(super) fn audit_edge_sum(&self, guard: &Guard) -> Option<bool> {
        let m0 = self.edges.mutations();
        let t0 = self.total.load(Ordering::Acquire);
        let mut sum = 0u64;
        self.edges.scan(guard, |_, c| {
            sum += c;
            true
        });
        let t1 = self.total.load(Ordering::Acquire);
        if t0 != t1 || self.edges.mutations() != m0 {
            return None;
        }
        let bound = 64.max(t1 / 256);
        Some(sum.abs_diff(t1) <= bound)
    }

    /// Caller must hold an RCU guard (the published snapshot is
    /// dereferenced to account its bytes).
    pub(super) fn stats(&self, _guard: &Guard) -> NodeStats {
        let ls = self.edges.stats();
        let snap = self.snap.load(Ordering::Acquire);
        // SAFETY: the caller's guard (see doc) keeps the snapshot alive.
        let snap_bytes = if snap.is_null() { 0 } else { unsafe { &*snap }.approx_bytes() };
        let bytes = std::mem::size_of::<NodeState>()
            + ls.len * (std::mem::size_of::<Node>() + 48) // node + table entry
            + self.dst.as_ref().map_or(0, |t| t.stats().capacity * 8)
            + snap_bytes;
        NodeStats {
            id: self.id,
            total: self.total.load(Ordering::Relaxed),
            edges: ls.len,
            swaps: ls.swaps,
            swap_skips: ls.swap_skips,
            approx_bytes: bytes,
        }
    }
}

// NodeState owns its EdgeList (which frees the list nodes) and its dst
// table (which frees only its entry shells — the values are the same list
// nodes, freed exactly once by the EdgeList). The published snapshot is
// the one RCU-managed field: swapped-out snapshots were handed to
// `defer_free`, so only the current pointer is freed here.
impl Drop for NodeState {
    fn drop(&mut self) {
        let snap = *self.snap.get_mut();
        if !snap.is_null() {
            // SAFETY: `&mut self` — no readers; the current snapshot is
            // owned solely by this state (swapped-out ones were deferred).
            drop(unsafe { Box::from_raw(snap) });
        }
    }
}
