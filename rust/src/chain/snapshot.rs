//! RCU-published flat read snapshots of a node's edge list, plus the exact
//! integer threshold predicate both read paths share.
//!
//! The edge list is optimal for *writes* (wait-free increments, local
//! swaps) but pays a dependent-load cache miss per item on *reads*. The
//! paper's "approximately correct during concurrent updates" semantics
//! license serving slightly-stale answers, so the chain caches a contiguous
//! `(dst, count, cum)` array per node — `infer_topk` becomes a bounded copy
//! of the array prefix and `infer_threshold` a binary search over the
//! inclusive prefix sums `cum`, O(log E) instead of the O(CDF⁻¹(t))
//! pointer chase.
//!
//! Lifecycle (see DESIGN.md § Read pipeline):
//!
//! * **Build** — lazily, on the read path, when a query finds no snapshot
//!   or one whose epoch trails the list's mutation counter by more than
//!   `ChainConfig::snap_staleness`. The build collects the list under the
//!   existing structural ticket (`EdgeList::try_collect_stable`) and
//!   *publishes while the ticket is still held*, so a publication can never
//!   straddle a concurrent decay/repair sweep and resurrect pre-sweep
//!   edges. Non-blocking: if the ticket is busy the query falls back to
//!   the live list walk.
//! * **Serve** — readers load the pointer under their RCU guard; the array
//!   is immutable after publish, so a snapshot answer is *internally
//!   consistent*: probabilities and `cumulative` are all ratios against the
//!   snapshot's own edge sum (cumulative never exceeds 1).
//! * **Retire** — the previous snapshot goes through `rcu::defer_free`,
//!   the same retire scheme the hash tables use; decay and repair
//!   invalidate eagerly so a pruned edge can never be served once a grace
//!   period has elapsed.

/// One immutable read snapshot: list order preserved, `cum` is the
/// inclusive prefix sum of `count` (so `entries.last().cum == total`).
pub(super) struct EdgeSnapshot {
    /// `EdgeList::mutations()` observed *before* the build walked the
    /// list: mutations that race the build re-age the snapshot, never
    /// un-age it (conservative staleness accounting).
    pub(super) epoch: u64,
    /// Sum of the snapshot's counts — the denominator for every
    /// probability served from it. Equals the node total at quiescence.
    pub(super) total: u64,
    /// `(dst, count, cum)` in head-first (descending count) list order.
    pub(super) entries: Box<[(u64, u64, u64)]>,
}

impl EdgeSnapshot {
    /// Wrap entries collected in one ticketed pass (non-empty, list order,
    /// `cum` already the inclusive prefix sum). Exact-capacity input, so
    /// boxing is free — the single allocation of a rebuild.
    pub(super) fn from_entries(epoch: u64, entries: Vec<(u64, u64, u64)>) -> EdgeSnapshot {
        debug_assert!(!entries.is_empty());
        let total = entries.last().map_or(0, |&(_, _, cum)| cum);
        EdgeSnapshot { epoch, total, entries: entries.into_boxed_slice() }
    }

    /// Index of the first entry whose cumulative count reaches
    /// `threshold` (as `m/2^s`) of `total` — the minimal prefix length
    /// minus one. `entries.len()` if even the full list falls short
    /// (possible only for a stale snapshot raced by pruning).
    pub(super) fn threshold_prefix(&self, m: u128, s: u32) -> usize {
        self.entries.partition_point(|&(_, _, cum)| !cum_reaches(cum, self.total, m, s))
    }

    /// Resident bytes of the array (for `NodeStats::approx_bytes`).
    pub(super) fn approx_bytes(&self) -> usize {
        std::mem::size_of::<EdgeSnapshot>() + self.entries.len() * std::mem::size_of::<(u64, u64, u64)>()
    }
}

/// Decompose a finite `t` in `(0, 1]` into the exact dyadic rational
/// `m / 2^s` (every finite f64 is one). The pair feeds [`cum_reaches`],
/// which decides `cum/total >= t` in pure integer arithmetic — the f64
/// comparison `(cum as f64) < t * (total as f64)` loses ulps once counts
/// pass 2^53 and can terminate a threshold scan one item early.
pub(super) fn dyadic(t: f64) -> (u128, u32) {
    debug_assert!(t > 0.0 && t <= 1.0 && t.is_finite());
    let bits = t.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as u32;
    let frac = (bits & ((1u64 << 52) - 1)) as u128;
    if exp == 0 {
        // Subnormal: t = frac * 2^-1074.
        (frac, 1074)
    } else {
        // Normal: t = (2^52 + frac) * 2^(exp - 1075).
        (frac | (1u128 << 52), 1075 - exp)
    }
}

/// Exact integer test for `cum >= t * total` where `t = m / 2^s` from
/// [`dyadic`]: compares `cum * 2^s >= m * total` in u128. `m * total`
/// fits (m < 2^53, total < 2^64); if `cum << s` overflows u128 the left
/// side is mathematically >= 2^128 > m * total, i.e. the threshold is
/// reached.
#[inline]
pub(super) fn cum_reaches(cum: u64, total: u64, m: u128, s: u32) -> bool {
    if s >= 128 {
        // t < 2^-127: any scanned mass (cum >= 1) covers it.
        return cum > 0;
    }
    match (cum as u128).checked_mul(1u128 << s) {
        Some(lhs) => lhs >= m * total as u128,
        None => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dyadic_roundtrips_exact_values() {
        for t in [1.0, 0.5, 0.25, 0.75, 0.9, 0.1, 1e-300, f64::MIN_POSITIVE] {
            let (m, s) = dyadic(t);
            if s < 128 {
                // m / 2^s == t exactly (both are the same dyadic rational).
                assert_eq!(m as f64 / 2f64.powi(s as i32), t, "t={t}");
            }
        }
        assert_eq!(dyadic(0.5), (1 << 52, 53));
        assert_eq!(dyadic(1.0), (1 << 52, 52));
    }

    #[test]
    fn cum_reaches_matches_rational_semantics() {
        let (m, s) = dyadic(0.75);
        assert!(!cum_reaches(74, 100, m, s));
        assert!(cum_reaches(75, 100, m, s));
        assert!(cum_reaches(76, 100, m, s));
        // t = 1.0: only the full mass reaches it.
        let (m, s) = dyadic(1.0);
        assert!(!cum_reaches(u64::MAX - 1, u64::MAX, m, s));
        assert!(cum_reaches(u64::MAX, u64::MAX, m, s));
        // Tiny thresholds: one unit of mass suffices.
        let (m, s) = dyadic(f64::MIN_POSITIVE);
        assert!(cum_reaches(1, u64::MAX, m, s));
    }

    #[test]
    fn cum_reaches_is_exact_past_f64_precision() {
        // total = 2^53 + 1 is not representable as f64; the old float
        // predicate rounded it to 2^53 and stopped a t=1.0 scan one item
        // early (cum = 2^53 "reached" the rounded target).
        let total = (1u64 << 53) + 1;
        let (m, s) = dyadic(1.0);
        assert!(!cum_reaches(1 << 53, total, m, s));
        assert!(cum_reaches(total, total, m, s));
    }

    /// Test helper mirroring the rebuild's running-prefix-sum collect.
    fn snap_from_counts(epoch: u64, counts: &[(u64, u64)]) -> EdgeSnapshot {
        let mut cum = 0u64;
        EdgeSnapshot::from_entries(
            epoch,
            counts
                .iter()
                .map(|&(dst, count)| {
                    cum += count;
                    (dst, count, cum)
                })
                .collect(),
        )
    }

    #[test]
    fn snapshot_prefix_sums_and_binary_search() {
        let snap = snap_from_counts(7, &[(10, 5), (20, 3), (30, 2)]);
        assert_eq!(snap.total, 10);
        assert_eq!(&*snap.entries, &[(10, 5, 5), (20, 3, 8), (30, 2, 10)]);
        let (m, s) = dyadic(0.5);
        assert_eq!(snap.threshold_prefix(m, s), 0); // first item covers 0.5
        let (m, s) = dyadic(0.75);
        assert_eq!(snap.threshold_prefix(m, s), 1);
        let (m, s) = dyadic(1.0);
        assert_eq!(snap.threshold_prefix(m, s), 2);
        assert!(snap.approx_bytes() > 3 * 24);
    }
}
