//! RCU-published flat read snapshots of a node's edge list, plus the exact
//! integer threshold predicate both read paths share.
//!
//! The edge list is optimal for *writes* (wait-free increments, local
//! swaps) but pays a dependent-load cache miss per item on *reads*. The
//! paper's "approximately correct during concurrent updates" semantics
//! license serving slightly-stale answers, so the chain caches a contiguous
//! `(dst, count, cum)` array per node — `infer_topk` becomes a bounded copy
//! of the array prefix and `infer_threshold` a search over the inclusive
//! prefix sums `cum`, O(log E) instead of the O(CDF⁻¹(t)) pointer chase.
//!
//! Mechanical sympathy (DESIGN.md §7): behind `ChainConfig::snap_layout`
//! the snapshot optionally carries an [`EytzingerAccel`] — the `cum` array
//! re-laid out in BFS (Eytzinger) order so the threshold search is a
//! branchless root-to-leaf walk touching one cache line per level (the
//! restructuring move of Bhardwaj & Chatterjee's learned lock-free search
//! layouts), plus split `dst`/`count` columns so the bounded prefix copy
//! runs vectorized (SSE2/AVX2/NEON, runtime-detected, scalar fallback).
//! Both accelerated paths are *bit-identical* to the scalar ones: the
//! search evaluates the same exact integer predicate on the same values,
//! and the SIMD copy performs the same correctly-rounded u64→f64 convert
//! and divide lane-wise (guarded to totals < 2^52 where the conversion is
//! provably exact; larger totals fall back to scalar).
//!
//! Lifecycle (see DESIGN.md § Read pipeline):
//!
//! * **Build** — lazily, on the read path, when a query finds no snapshot
//!   or one whose epoch trails the list's mutation counter by more than
//!   `ChainConfig::snap_staleness`. The build collects the list under the
//!   existing structural ticket (`EdgeList::try_collect_stable`) and
//!   *publishes while the ticket is still held*, so a publication can never
//!   straddle a concurrent decay/repair sweep and resurrect pre-sweep
//!   edges. Non-blocking: if the ticket is busy the query falls back to
//!   the live list walk.
//! * **Serve** — readers load the pointer under their RCU guard; the array
//!   is immutable after publish, so a snapshot answer is *internally
//!   consistent*: probabilities and `cumulative` are all ratios against the
//!   snapshot's own edge sum (cumulative never exceeds 1).
//! * **Retire** — the previous snapshot goes through `rcu::defer_free`,
//!   the same retire scheme the hash tables use; decay and repair
//!   invalidate eagerly so a pruned edge can never be served once a grace
//!   period has elapsed.

use super::SnapLayout;

/// Memory layout of one read snapshot's search/copy acceleration arrays
/// (present iff the chain runs with `snap_layout = eytzinger`).
///
/// `eyt`/`rank` are 1-based (index 0 unused) so a node's children are
/// `2k` / `2k+1` — the classic implicit-BFS trick that keeps the top of
/// the tree packed into the first cache lines. `dsts`/`counts` are the
/// snapshot's entries split into contiguous columns: the `(u64, u64, u64)`
/// rows stride 24 bytes, which defeats aligned vector loads, while the
/// split columns feed 2/4-lane SIMD directly.
pub(super) struct EytzingerAccel {
    /// `entries[..].cum` permuted into BFS order; `eyt[k]`'s children are
    /// `eyt[2k]` and `eyt[2k+1]`.
    eyt: Box<[u64]>,
    /// `rank[k]` = the sorted-order index of `eyt[k]` (search result
    /// translation back to entry space).
    rank: Box<[u32]>,
    /// `entries[i].0` — the dst column for the vectorized prefix copy.
    dsts: Box<[u64]>,
    /// `entries[i].1` — the count column for the vectorized prefix copy.
    counts: Box<[u64]>,
}

impl EytzingerAccel {
    fn build(entries: &[(u64, u64, u64)]) -> EytzingerAccel {
        let n = entries.len();
        debug_assert!(n < u32::MAX as usize);
        let mut eyt = vec![0u64; n + 1].into_boxed_slice();
        let mut rank = vec![0u32; n + 1].into_boxed_slice();
        let mut i = 0usize;
        fill(entries, &mut eyt, &mut rank, &mut i, 1);
        debug_assert_eq!(i, n);
        EytzingerAccel {
            eyt,
            rank,
            dsts: entries.iter().map(|&(d, _, _)| d).collect(),
            counts: entries.iter().map(|&(_, c, _)| c).collect(),
        }
    }

    fn approx_bytes(&self) -> usize {
        self.eyt.len() * 8 + self.rank.len() * 4 + self.dsts.len() * 16
    }
}

/// In-order traversal of the implicit tree writes the ascending `cum`
/// sequence into BFS positions — the standard Eytzinger construction.
fn fill(entries: &[(u64, u64, u64)], eyt: &mut [u64], rank: &mut [u32], i: &mut usize, k: usize) {
    if k >= eyt.len() {
        return;
    }
    fill(entries, eyt, rank, i, 2 * k);
    eyt[k] = entries[*i].2;
    rank[k] = *i as u32;
    *i += 1;
    fill(entries, eyt, rank, i, 2 * k + 1);
}

/// One immutable read snapshot: list order preserved, `cum` is the
/// inclusive prefix sum of `count` (so `entries.last().cum == total`).
pub(super) struct EdgeSnapshot {
    /// `EdgeList::mutations()` observed *before* the build walked the
    /// list: mutations that race the build re-age the snapshot, never
    /// un-age it (conservative staleness accounting).
    pub(super) epoch: u64,
    /// Sum of the snapshot's counts — the denominator for every
    /// probability served from it. Equals the node total at quiescence.
    pub(super) total: u64,
    /// `(dst, count, cum)` in head-first (descending count) list order.
    pub(super) entries: Box<[(u64, u64, u64)]>,
    /// Eytzinger search tree + SoA copy columns (layout knob).
    accel: Option<EytzingerAccel>,
}

impl EdgeSnapshot {
    /// Wrap entries collected in one ticketed pass (non-empty, list order,
    /// `cum` already the inclusive prefix sum). The entry array is boxed
    /// from its exact-capacity Vec for free; the Eytzinger layout costs
    /// one extra O(n) pass inside the same (already ticketed) rebuild.
    pub(super) fn from_entries(
        epoch: u64,
        entries: Vec<(u64, u64, u64)>,
        layout: SnapLayout,
    ) -> EdgeSnapshot {
        debug_assert!(!entries.is_empty());
        let total = entries.last().map_or(0, |&(_, _, cum)| cum);
        let accel = match layout {
            SnapLayout::Eytzinger if !entries.is_empty() => Some(EytzingerAccel::build(&entries)),
            _ => None,
        };
        EdgeSnapshot { epoch, total, entries: entries.into_boxed_slice(), accel }
    }

    /// Index of the first entry whose cumulative count reaches
    /// `threshold` (as `m/2^s`) of `total` — the minimal prefix length
    /// minus one. `entries.len()` if even the full list falls short
    /// (possible only for a stale snapshot raced by pruning).
    ///
    /// With the Eytzinger accelerator this is the branchless lower bound:
    /// the child index is *computed* from the predicate (no compare-and-
    /// branch for the predictor to miss), and the final `k` encodes the
    /// whole descent — shifting off the trailing ones recovers the last
    /// left-turn, i.e. the smallest element satisfying the predicate.
    pub(super) fn threshold_prefix(&self, m: u128, s: u32) -> usize {
        let n = self.entries.len();
        if let Some(accel) = &self.accel {
            let mut k = 1usize;
            while k <= n {
                // cum_reaches is monotone over the ascending cum sequence:
                // descend left (candidate found) on true, right on false.
                k = 2 * k + usize::from(!cum_reaches(accel.eyt[k], self.total, m, s));
            }
            k >>= k.trailing_ones() + 1;
            return if k == 0 { n } else { accel.rank[k] as usize };
        }
        self.entries.partition_point(|&(_, _, cum)| !cum_reaches(cum, self.total, m, s))
    }

    /// Append `(dst, count/total)` for the first `end` entries to `out` —
    /// the bounded prefix copy both inference paths share. Vectorized
    /// (2/4 lanes) when the SoA columns are present and every operand is
    /// exactly representable; bit-identical to the scalar loop either way.
    pub(super) fn copy_prefix_probs(&self, end: usize, out: &mut Vec<(u64, f64)>) {
        debug_assert!(end <= self.entries.len());
        let totf = self.total as f64;
        if let Some(accel) = &self.accel {
            // Counts never exceed the total, so `total < 2^52` bounds every
            // lane into the range where the packed u64→f64 conversion is
            // exact; the divide is correctly rounded per IEEE in both the
            // scalar and vector units, hence identical results.
            if self.total < (1u64 << 52) {
                simd::copy_probs(&accel.dsts[..end], &accel.counts[..end], totf, out);
                return;
            }
        }
        for &(dst, count, _) in &self.entries[..end] {
            out.push((dst, count as f64 / totf));
        }
    }

    /// Resident bytes of the arrays (for `NodeStats::approx_bytes`).
    pub(super) fn approx_bytes(&self) -> usize {
        std::mem::size_of::<EdgeSnapshot>()
            + self.entries.len() * std::mem::size_of::<(u64, u64, u64)>()
            + self.accel.as_ref().map_or(0, EytzingerAccel::approx_bytes)
    }
}

/// Runtime-dispatched vectorized `count/total` prefix copy. Every kernel
/// converts a vector of u64 counts to f64 (exact below 2^52) and divides
/// by the splatted total with the *vector divide* (never a reciprocal
/// estimate — those are not correctly rounded). Lane results land in a
/// stack buffer and are paired with their dsts by scalar pushes, because
/// the layout of the Rust tuple `(u64, f64)` is unspecified and must not
/// be raw-written.
mod simd {
    pub(super) fn copy_probs(dsts: &[u64], counts: &[u64], totf: f64, out: &mut Vec<(u64, f64)>) {
        debug_assert_eq!(dsts.len(), counts.len());
        out.reserve(dsts.len());
        // Under Miri the vendor kernels are skipped (the interpreter does
        // not model every intrinsic); the scalar loop is bit-identical.
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        {
            if is_x86_feature_detected!("avx2") {
                // SAFETY: AVX2 presence just checked (std caches the cpuid).
                unsafe { copy_probs_avx2(dsts, counts, totf, out) };
            } else {
                // SAFETY: SSE2 is x86_64 baseline.
                unsafe { copy_probs_sse2(dsts, counts, totf, out) };
            }
            return;
        }
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        {
            // SAFETY: NEON is aarch64 baseline.
            unsafe { copy_probs_neon(dsts, counts, totf, out) };
            return;
        }
        #[allow(unreachable_code)]
        copy_probs_scalar(dsts, counts, totf, out)
    }

    #[cfg_attr(
        all(any(target_arch = "x86_64", target_arch = "aarch64"), not(miri)),
        allow(dead_code)
    )]
    fn copy_probs_scalar(dsts: &[u64], counts: &[u64], totf: f64, out: &mut Vec<(u64, f64)>) {
        for (&dst, &count) in dsts.iter().zip(counts) {
            out.push((dst, count as f64 / totf));
        }
    }

    /// Exponent bits of 2^52: OR-ing them over a sub-2^52 integer yields
    /// the bit pattern of the double `2^52 + v`; subtracting 2^52 strips
    /// the bias exactly (no rounding — the sum is representable).
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    const MAGIC_BITS: i64 = 0x4330_0000_0000_0000;
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    const MAGIC: f64 = 4_503_599_627_370_496.0; // 2^52

    /// # Safety
    ///
    /// SSE2 is the x86_64 baseline, so the target-feature requirement is
    /// met by construction; callers must keep `dsts.len() == counts.len()`
    /// (the in-bounds contract of the lane loads).
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[target_feature(enable = "sse2")]
    #[allow(unused_unsafe)] // non-pointer intrinsics are safe on newer toolchains
    unsafe fn copy_probs_sse2(dsts: &[u64], counts: &[u64], totf: f64, out: &mut Vec<(u64, f64)>) {
        use std::arch::x86_64::*;
        // SAFETY: SSE2 is enabled (target_feature + caller's check); the
        // unaligned load reads `i..i+2 <= n` lanes inside `counts`, and the
        // store targets the 2-lane stack buffer.
        unsafe {
            let tot = _mm_set1_pd(totf);
            let magic_i = _mm_set1_epi64x(MAGIC_BITS);
            let magic_d = _mm_set1_pd(MAGIC);
            let n = counts.len();
            let mut buf = [0f64; 2];
            let mut i = 0usize;
            while i + 2 <= n {
                let v = _mm_loadu_si128(counts.as_ptr().add(i) as *const __m128i);
                let f = _mm_sub_pd(_mm_castsi128_pd(_mm_or_si128(v, magic_i)), magic_d);
                _mm_storeu_pd(buf.as_mut_ptr(), _mm_div_pd(f, tot));
                out.push((dsts[i], buf[0]));
                out.push((dsts[i + 1], buf[1]));
                i += 2;
            }
            while i < n {
                out.push((dsts[i], counts[i] as f64 / totf));
                i += 1;
            }
        }
    }

    /// # Safety
    ///
    /// The caller must have runtime-detected AVX2 (`is_x86_feature_
    /// detected!`) and keep `dsts.len() == counts.len()`.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[target_feature(enable = "avx2")]
    #[allow(unused_unsafe)] // non-pointer intrinsics are safe on newer toolchains
    unsafe fn copy_probs_avx2(dsts: &[u64], counts: &[u64], totf: f64, out: &mut Vec<(u64, f64)>) {
        use std::arch::x86_64::*;
        // SAFETY: AVX2 was runtime-detected by the caller; the unaligned
        // load reads `i..i+4 <= n` lanes inside `counts`, and the store
        // targets the 4-lane stack buffer.
        unsafe {
            let tot = _mm256_set1_pd(totf);
            let magic_i = _mm256_set1_epi64x(MAGIC_BITS);
            let magic_d = _mm256_set1_pd(MAGIC);
            let n = counts.len();
            let mut buf = [0f64; 4];
            let mut i = 0usize;
            while i + 4 <= n {
                let v = _mm256_loadu_si256(counts.as_ptr().add(i) as *const __m256i);
                let f = _mm256_sub_pd(_mm256_castsi256_pd(_mm256_or_si256(v, magic_i)), magic_d);
                _mm256_storeu_pd(buf.as_mut_ptr(), _mm256_div_pd(f, tot));
                for (j, &p) in buf.iter().enumerate() {
                    out.push((dsts[i + j], p));
                }
                i += 4;
            }
            while i < n {
                out.push((dsts[i], counts[i] as f64 / totf));
                i += 1;
            }
        }
    }

    /// # Safety
    ///
    /// NEON is the aarch64 baseline, so the target-feature requirement is
    /// met by construction; callers must keep `dsts.len() == counts.len()`.
    #[cfg(all(target_arch = "aarch64", not(miri)))]
    #[target_feature(enable = "neon")]
    #[allow(unused_unsafe)] // non-pointer intrinsics are safe on newer toolchains
    unsafe fn copy_probs_neon(dsts: &[u64], counts: &[u64], totf: f64, out: &mut Vec<(u64, f64)>) {
        use std::arch::aarch64::*;
        // SAFETY: NEON is aarch64 baseline; the load reads `i..i+2 <= n`
        // lanes inside `counts`, the store targets the 2-lane buffer.
        unsafe {
            let tot = vdupq_n_f64(totf);
            let n = counts.len();
            let mut buf = [0f64; 2];
            let mut i = 0usize;
            while i + 2 <= n {
                let v = vld1q_u64(counts.as_ptr().add(i));
                // ucvtf is exact for sub-2^52 values (and correctly rounded
                // beyond — but the caller's guard keeps us below anyway).
                let f = vcvtq_f64_u64(v);
                vst1q_f64(buf.as_mut_ptr(), vdivq_f64(f, tot));
                out.push((dsts[i], buf[0]));
                out.push((dsts[i + 1], buf[1]));
                i += 2;
            }
            while i < n {
                out.push((dsts[i], counts[i] as f64 / totf));
                i += 1;
            }
        }
    }
}

/// Decompose a finite `t` in `(0, 1]` into the exact dyadic rational
/// `m / 2^s` (every finite f64 is one). The pair feeds [`cum_reaches`],
/// which decides `cum/total >= t` in pure integer arithmetic — the f64
/// comparison `(cum as f64) < t * (total as f64)` loses ulps once counts
/// pass 2^53 and can terminate a threshold scan one item early.
pub(super) fn dyadic(t: f64) -> (u128, u32) {
    debug_assert!(t > 0.0 && t <= 1.0 && t.is_finite());
    let bits = t.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as u32;
    let frac = (bits & ((1u64 << 52) - 1)) as u128;
    if exp == 0 {
        // Subnormal: t = frac * 2^-1074.
        (frac, 1074)
    } else {
        // Normal: t = (2^52 + frac) * 2^(exp - 1075).
        (frac | (1u128 << 52), 1075 - exp)
    }
}

/// Exact integer test for `cum >= t * total` where `t = m / 2^s` from
/// [`dyadic`]: compares `cum * 2^s >= m * total` in u128. `m * total`
/// fits (m < 2^53, total < 2^64); if `cum << s` overflows u128 the left
/// side is mathematically >= 2^128 > m * total, i.e. the threshold is
/// reached.
#[inline]
pub(super) fn cum_reaches(cum: u64, total: u64, m: u128, s: u32) -> bool {
    if s >= 128 {
        // t < 2^-127: any scanned mass (cum >= 1) covers it.
        return cum > 0;
    }
    match (cum as u128).checked_mul(1u128 << s) {
        Some(lhs) => lhs >= m * total as u128,
        None => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dyadic_roundtrips_exact_values() {
        for t in [1.0, 0.5, 0.25, 0.75, 0.9, 0.1, 1e-300, f64::MIN_POSITIVE] {
            let (m, s) = dyadic(t);
            if s < 128 {
                // m / 2^s == t exactly (both are the same dyadic rational).
                assert_eq!(m as f64 / 2f64.powi(s as i32), t, "t={t}");
            }
        }
        assert_eq!(dyadic(0.5), (1 << 52, 53));
        assert_eq!(dyadic(1.0), (1 << 52, 52));
    }

    #[test]
    fn cum_reaches_matches_rational_semantics() {
        let (m, s) = dyadic(0.75);
        assert!(!cum_reaches(74, 100, m, s));
        assert!(cum_reaches(75, 100, m, s));
        assert!(cum_reaches(76, 100, m, s));
        // t = 1.0: only the full mass reaches it.
        let (m, s) = dyadic(1.0);
        assert!(!cum_reaches(u64::MAX - 1, u64::MAX, m, s));
        assert!(cum_reaches(u64::MAX, u64::MAX, m, s));
        // Tiny thresholds: one unit of mass suffices.
        let (m, s) = dyadic(f64::MIN_POSITIVE);
        assert!(cum_reaches(1, u64::MAX, m, s));
    }

    #[test]
    fn cum_reaches_is_exact_past_f64_precision() {
        // total = 2^53 + 1 is not representable as f64; the old float
        // predicate rounded it to 2^53 and stopped a t=1.0 scan one item
        // early (cum = 2^53 "reached" the rounded target).
        let total = (1u64 << 53) + 1;
        let (m, s) = dyadic(1.0);
        assert!(!cum_reaches(1 << 53, total, m, s));
        assert!(cum_reaches(total, total, m, s));
    }

    /// Test helper mirroring the rebuild's running-prefix-sum collect.
    fn snap_from_counts(epoch: u64, counts: &[(u64, u64)], layout: SnapLayout) -> EdgeSnapshot {
        let mut cum = 0u64;
        EdgeSnapshot::from_entries(
            epoch,
            counts
                .iter()
                .map(|&(dst, count)| {
                    cum += count;
                    (dst, count, cum)
                })
                .collect(),
            layout,
        )
    }

    #[test]
    fn snapshot_prefix_sums_and_binary_search() {
        let snap = snap_from_counts(7, &[(10, 5), (20, 3), (30, 2)], SnapLayout::Sorted);
        assert_eq!(snap.total, 10);
        assert_eq!(&*snap.entries, &[(10, 5, 5), (20, 3, 8), (30, 2, 10)]);
        let (m, s) = dyadic(0.5);
        assert_eq!(snap.threshold_prefix(m, s), 0); // first item covers 0.5
        let (m, s) = dyadic(0.75);
        assert_eq!(snap.threshold_prefix(m, s), 1);
        let (m, s) = dyadic(1.0);
        assert_eq!(snap.threshold_prefix(m, s), 2);
        assert!(snap.approx_bytes() > 3 * 24);
    }

    #[test]
    fn eytzinger_search_matches_partition_point() {
        // Zipf-ish descending counts at every size from 1 to a few levels
        // past one full tree, thresholds spanning both tails.
        let thresholds =
            [1e-12, 0.01, 0.1, 0.25, 0.5, 0.5000001, 0.75, 0.9, 0.99, 0.999999, 1.0];
        for n in 1..=130usize {
            let counts: Vec<(u64, u64)> =
                (0..n).map(|i| (i as u64 + 1, (2 * (n - i)) as u64)).collect();
            let sorted = snap_from_counts(1, &counts, SnapLayout::Sorted);
            let eyt = snap_from_counts(1, &counts, SnapLayout::Eytzinger);
            for &t in &thresholds {
                let (m, s) = dyadic(t);
                assert_eq!(
                    sorted.threshold_prefix(m, s),
                    eyt.threshold_prefix(m, s),
                    "n={n} t={t}"
                );
            }
        }
    }

    #[test]
    fn eytzinger_search_full_list_short_is_len() {
        // A stale snapshot raced by pruning can be asked for more mass
        // than it holds (cum_reaches false everywhere after the caller
        // rescales): simulate with t=1.0 against a *larger* total by
        // constructing entries whose last cum understates the denominator.
        let entries = vec![(1u64, 5u64, 5u64), (2, 3, 8)];
        let mut snap = EdgeSnapshot::from_entries(1, entries, SnapLayout::Eytzinger);
        snap.total = 100; // stale denominator: even cum=8 falls short of t=0.5
        let (m, s) = dyadic(0.5);
        assert_eq!(snap.threshold_prefix(m, s), snap.entries.len());
    }

    #[test]
    fn simd_prefix_copy_matches_scalar() {
        for n in [1usize, 2, 3, 4, 5, 7, 8, 15, 16, 33, 64, 127] {
            let counts: Vec<(u64, u64)> =
                (0..n).map(|i| (1000 + i as u64, (3 * (n - i) + 1) as u64)).collect();
            let eyt = snap_from_counts(9, &counts, SnapLayout::Eytzinger);
            let sorted = snap_from_counts(9, &counts, SnapLayout::Sorted);
            for end in [1, n / 2, n] {
                if end == 0 {
                    continue;
                }
                let mut fast = Vec::new();
                let mut slow = Vec::new();
                eyt.copy_prefix_probs(end, &mut fast);
                sorted.copy_prefix_probs(end, &mut slow);
                assert_eq!(fast.len(), end);
                // Bit-identical, not approximately equal.
                for (f, s) in fast.iter().zip(&slow) {
                    assert_eq!(f.0, s.0);
                    assert_eq!(f.1.to_bits(), s.1.to_bits(), "n={n} end={end}");
                }
            }
        }
    }

    #[test]
    fn simd_guard_falls_back_above_2_pow_52() {
        // Totals at/above 2^52 must take the scalar path (the packed
        // conversion trick is only exact below); results still match the
        // plain-layout scalar loop bit for bit.
        let big = 1u64 << 53;
        let counts = [(1u64, big), (2, big), (3, 7)];
        let eyt = snap_from_counts(3, &counts, SnapLayout::Eytzinger);
        let sorted = snap_from_counts(3, &counts, SnapLayout::Sorted);
        assert!(eyt.total >= (1 << 52));
        let mut fast = Vec::new();
        let mut slow = Vec::new();
        eyt.copy_prefix_probs(3, &mut fast);
        sorted.copy_prefix_probs(3, &mut slow);
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!(f.1.to_bits(), s.1.to_bits());
        }
    }

    #[test]
    fn accel_accounted_in_bytes() {
        let counts: Vec<(u64, u64)> = (0..32).map(|i| (i, 32 - i)).collect();
        let plain = snap_from_counts(1, &counts, SnapLayout::Sorted);
        let eyt = snap_from_counts(1, &counts, SnapLayout::Eytzinger);
        assert!(eyt.approx_bytes() > plain.approx_bytes());
    }
}
