//! Cache-line-aligned block arena for edge-list [`Node`]s (DESIGN.md §7).
//!
//! `observe` of a new edge used to `Box` a ~56-byte node: every insert
//! paid a global-allocator round trip, nodes of one shard interleaved
//! with unrelated allocations on shared cache lines (false sharing on the
//! count word), and RCU retired each node through `free()` individually.
//! This arena replaces that with thread-affine 64 KiB blocks carved into
//! 64-byte slots:
//!
//! * **Alignment** — blocks are allocated with `align == size`, so every
//!   slot is 64-byte aligned and a node never straddles a cache line;
//!   the owning block of any node is recoverable by masking its address
//!   (no back-pointer stored per node).
//! * **Affinity** — allocation is thread-local (one open block per
//!   thread). Ingest workers are shard-affine (and optionally core-pinned,
//!   see `runtime::pin_current_thread`), so a shard's edge nodes pack
//!   into the same blocks — the read path's pointer chase walks warm,
//!   co-located lines instead of allocator-scattered ones.
//! * **Block-grained reclamation** — each block header counts its live
//!   nodes plus one "open" reference held while a thread still allocates
//!   from it. RCU retires nodes with a deferred `arena::release` closure;
//!   the block itself returns to the OS only when the last node *and* the
//!   open reference are gone, so reclamation cost amortizes over ~1000
//!   nodes instead of one `free()` per retired edge.
//!
//! The memory cost is slack: partially-filled open blocks and the
//! header slot. [`slack_bytes`] reports it so `EngineStats::approx_bytes`
//! stays honest after the allocator change.

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::cell::RefCell;

use crate::prioq::Node;
use crate::sync::shim::{fence, AtomicU64, AtomicUsize, Ordering};

/// Block size == block alignment: the owning block of any interior
/// pointer is `ptr & !(BLOCK_BYTES - 1)`.
pub(crate) const BLOCK_BYTES: usize = 64 * 1024;
/// One cache line per node (`Node` is `#[repr(align(64))]`, size 64).
pub(crate) const SLOT_BYTES: usize = 64;
/// Slot 0 holds the block header; the rest hold nodes.
const SLOTS_PER_BLOCK: usize = BLOCK_BYTES / SLOT_BYTES;

// The slot math above is only sound while a node is exactly one slot.
const _: () = assert!(std::mem::size_of::<Node>() == SLOT_BYTES);
const _: () = assert!(std::mem::align_of::<Node>() == SLOT_BYTES);
const _: () = assert!(std::mem::size_of::<BlockHeader>() <= SLOT_BYTES);

/// Lives in slot 0 of every block.
#[repr(C, align(64))]
struct BlockHeader {
    /// Live nodes in this block, plus 1 while some thread still allocates
    /// from it (the "open" reference). The block is freed by whoever drops
    /// the count to zero — a releasing RCU callback or the closing thread.
    live: AtomicUsize,
}

static BLOCKS_ALLOCATED: AtomicU64 = AtomicU64::new(0);
static BLOCKS_FREED: AtomicU64 = AtomicU64::new(0);
static NODES_LIVE: AtomicU64 = AtomicU64::new(0);

fn block_layout() -> Layout {
    // size == align, both powers of two: always valid.
    Layout::from_size_align(BLOCK_BYTES, BLOCK_BYTES).unwrap()
}

/// Allocate a block whose header starts at `initial_live`.
fn new_block(initial_live: usize) -> *mut u8 {
    let layout = block_layout();
    // SAFETY: `layout` has non-zero size (BLOCK_BYTES).
    let ptr = unsafe { alloc(layout) };
    if ptr.is_null() {
        handle_alloc_error(layout);
    }
    // SAFETY: `ptr` is a fresh, aligned allocation of BLOCK_BYTES, large
    // enough for the header (static-asserted above).
    unsafe {
        (ptr as *mut BlockHeader).write(BlockHeader { live: AtomicUsize::new(initial_live) })
    };
    BLOCKS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
    ptr
}

/// # Safety
/// `ptr_in_block` must point into a live arena block (header initialized,
/// not yet deallocated).
#[inline]
unsafe fn header<'a>(ptr_in_block: *mut u8) -> &'a BlockHeader {
    let block = (ptr_in_block as usize & !(BLOCK_BYTES - 1)) as *mut BlockHeader;
    // SAFETY: size == align, so masking recovers the block base; the
    // caller guarantees the block (and thus its slot-0 header) is live.
    unsafe { &*block }
}

/// Drop one reference (a node or the open ref) on the block owning
/// `ptr_in_block`; frees the block when it was the last.
///
/// # Safety
/// `ptr_in_block` must point into a live arena block, and the caller must
/// own one reference (node or open ref) that it gives up with this call.
unsafe fn release_ref(ptr_in_block: *mut u8) {
    // SAFETY: the block is live per this function's contract.
    let hdr = unsafe { header(ptr_in_block) };
    if hdr.live.fetch_sub(1, Ordering::Release) == 1 {
        // Acquire the other releasers' writes before the block memory is
        // handed back (classic refcount teardown fence).
        fence(Ordering::Acquire);
        let block = (ptr_in_block as usize & !(BLOCK_BYTES - 1)) as *mut u8;
        // SAFETY: the count hit zero, so we hold the last reference; the
        // block came from `alloc` with this exact layout.
        unsafe { dealloc(block, block_layout()) };
        BLOCKS_FREED.fetch_add(1, Ordering::Relaxed);
    }
}

/// The calling thread's open block and its bump cursor.
struct ThreadArena {
    block: *mut u8,
    next_slot: usize,
}

impl ThreadArena {
    /// Bump-allocate one slot, opening a fresh block when the current one
    /// is full (the old block's open ref is dropped — it is freed once its
    /// last node is released).
    fn alloc_slot(&mut self) -> *mut u8 {
        if self.block.is_null() || self.next_slot >= SLOTS_PER_BLOCK {
            if !self.block.is_null() {
                // SAFETY: dropping this thread's open ref on a live block.
                unsafe { release_ref(self.block) };
            }
            self.block = new_block(1); // 1 = the open ref
            self.next_slot = 1; // slot 0 is the header
        }
        // SAFETY: `next_slot < SLOTS_PER_BLOCK`, so the offset stays inside
        // the block allocation.
        let p = unsafe { self.block.add(self.next_slot * SLOT_BYTES) };
        self.next_slot += 1;
        p
    }
}

impl Drop for ThreadArena {
    fn drop(&mut self) {
        if !self.block.is_null() {
            // SAFETY: dropping this thread's open ref on a live block.
            unsafe { release_ref(self.block) };
        }
    }
}

thread_local! {
    static ARENA: RefCell<ThreadArena> =
        const { RefCell::new(ThreadArena { block: std::ptr::null_mut(), next_slot: 0 }) };
}

/// Allocate a 64-byte-aligned slot and move `init` into it. The returned
/// pointer is released exactly once via [`release`] (directly for
/// never-shared nodes, through an RCU-deferred closure otherwise).
pub(crate) fn alloc(init: Node) -> *mut Node {
    let slot = ARENA.try_with(|a| {
        let mut a = a.borrow_mut();
        let p = a.alloc_slot();
        // Count the node before the pointer escapes this thread.
        // SAFETY: `p` points into this thread's live open block.
        unsafe { header(p) }.live.fetch_add(1, Ordering::Relaxed);
        p
    });
    let p = match slot {
        Ok(p) => p,
        // TLS teardown (a detached thread dropping an EdgeList during its
        // own exit): a one-off block owned solely by this node. live = 1 is
        // the node itself — no open ref, the release frees the block.
        // SAFETY: slot 1 is in bounds (SLOTS_PER_BLOCK > 1).
        Err(_) => unsafe { new_block(1).add(SLOT_BYTES) },
    };
    NODES_LIVE.fetch_add(1, Ordering::Relaxed);
    let node = p as *mut Node;
    // SAFETY: `p` is a fresh, 64-byte-aligned slot sized for one Node.
    unsafe { node.write(init) };
    node
}

/// Release a node previously returned by [`alloc`]: runs its destructor
/// and drops its block reference (freeing the block if it was the last).
/// Never touches TLS — safe from RCU reclamation on any thread and during
/// thread teardown.
///
/// # Safety
/// `node` must come from [`alloc`], be released exactly once, and have no
/// remaining references (outside the RCU grace period that deferred this
/// call).
pub(crate) unsafe fn release(node: *mut Node) {
    // SAFETY: `node` came from `alloc` (initialized, live) and is released
    // exactly once per this function's contract.
    unsafe { std::ptr::drop_in_place(node) }; // no-op today; future-proofs Node fields
    NODES_LIVE.fetch_sub(1, Ordering::Relaxed);
    // SAFETY: `node` holds one block reference, given up here.
    unsafe { release_ref(node as *mut u8) };
}

/// Process-wide arena gauges (STATS / `EngineStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub(crate) struct ArenaStats {
    pub blocks_allocated: u64,
    pub blocks_freed: u64,
    pub nodes_live: u64,
}

impl ArenaStats {
    pub fn blocks_live(&self) -> u64 {
        self.blocks_allocated.saturating_sub(self.blocks_freed)
    }

    /// Resident bytes held by live blocks.
    pub fn resident_bytes(&self) -> u64 {
        self.blocks_live() * BLOCK_BYTES as u64
    }

    /// Resident bytes *not* occupied by live nodes: headers, freed-node
    /// holes awaiting their block's last release, and the unfilled tails
    /// of open blocks. The memory-accounting correction of DESIGN.md §7.
    pub fn slack_bytes(&self) -> u64 {
        self.resident_bytes().saturating_sub(self.nodes_live * SLOT_BYTES as u64)
    }
}

pub(crate) fn stats() -> ArenaStats {
    // Relaxed loads: gauges, not invariants — racy reads may transiently
    // disagree by in-flight allocations.
    ArenaStats {
        blocks_allocated: BLOCKS_ALLOCATED.load(Ordering::Relaxed),
        blocks_freed: BLOCKS_FREED.load(Ordering::Relaxed),
        nodes_live: NODES_LIVE.load(Ordering::Relaxed),
    }
}

/// Process-wide arena slack (see [`ArenaStats::slack_bytes`]).
pub(crate) fn slack_bytes() -> u64 {
    stats().slack_bytes()
}

/// Register the process-wide arena gauges with a telemetry registry
/// (DESIGN.md §9). The arena is global, so these are unlabeled; the
/// per-shard occupancy series come from the engine (edge count × slot).
pub(crate) fn register_metrics(reg: &crate::metrics::Registry) {
    reg.counter_fn(
        "mcprioq_arena_blocks_allocated_total",
        "Edge-arena blocks ever allocated.",
        &[],
        || stats().blocks_allocated,
    );
    reg.counter_fn(
        "mcprioq_arena_blocks_freed_total",
        "Edge-arena blocks returned to the OS.",
        &[],
        || stats().blocks_freed,
    );
    reg.gauge_fn("mcprioq_arena_nodes_live", "Live edge nodes in the arena.", &[], || {
        stats().nodes_live as f64
    });
    reg.gauge_fn(
        "mcprioq_arena_resident_bytes",
        "Bytes held by live arena blocks.",
        &[],
        || stats().resident_bytes() as f64,
    );
    reg.gauge_fn(
        "mcprioq_arena_slack_bytes",
        "Arena bytes not occupied by live nodes (headers, holes, tails).",
        &[],
        || stats().slack_bytes() as f64,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_fills_one_cache_line() {
        assert_eq!(std::mem::size_of::<Node>(), 64);
        assert_eq!(std::mem::align_of::<Node>(), 64);
    }

    #[test]
    fn alloc_release_roundtrip_is_aligned() {
        let mut nodes = Vec::new();
        for i in 0..100u64 {
            let n = alloc(Node::new(i, i + 1));
            assert_eq!(n as usize % SLOT_BYTES, 0, "slot not cache-line aligned");
            assert_ne!(n as usize % BLOCK_BYTES, 0, "node landed on the header slot");
            nodes.push(n);
        }
        for (i, n) in nodes.iter().enumerate() {
            // SAFETY: live nodes from `alloc`, exclusively ours.
            unsafe {
                assert_eq!((**n).key, i as u64);
                assert_eq!((**n).count(), i as u64 + 1);
            }
        }
        for n in nodes {
            // SAFETY: from `alloc`, released exactly once.
            unsafe { release(n) };
        }
        // Gauges are process-global (other tests allocate concurrently);
        // assert only self-consistency, not exact deltas.
        let s = stats();
        assert!(s.blocks_allocated >= s.blocks_freed);
        assert!(s.resident_bytes() >= s.slack_bytes());
    }

    #[test]
    fn blocks_recycle_across_fill_boundary() {
        // Fill past two whole blocks and release everything: the closed
        // blocks must come back. `blocks_freed` is monotone, so the
        // +2 delta holds no matter what other tests do concurrently.
        let n_nodes = SLOTS_PER_BLOCK * 2;
        let mut nodes = Vec::with_capacity(n_nodes);
        for i in 0..n_nodes as u64 {
            nodes.push(alloc(Node::new(i, 1)));
        }
        let held = stats();
        for n in nodes {
            // SAFETY: from `alloc`, released exactly once.
            unsafe { release(n) };
        }
        let after = stats();
        assert!(
            after.blocks_freed >= held.blocks_freed + 2,
            "filled blocks were not reclaimed: held={held:?} after={after:?}"
        );
    }

    #[test]
    fn cross_thread_release_is_safe() {
        // Releases are address-based (header recovered by masking), never
        // TLS-based: a remote thread freeing another thread's nodes — the
        // RCU reclamation shape — must work and keep the gauges sane.
        let nodes: Vec<usize> = (0..200u64).map(|i| alloc(Node::new(i, 1)) as usize).collect();
        std::thread::spawn(move || {
            for n in nodes {
                // SAFETY: from `alloc`, released exactly once (the vec was
                // moved here, so no other reference remains).
                unsafe { release(n as *mut Node) };
            }
        })
        .join()
        .unwrap();
        let s = stats();
        assert!(s.blocks_allocated >= s.blocks_freed);
    }
}
