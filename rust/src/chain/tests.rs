//! Chain-level tests: behaviour of the public API, invariants P1-P5, and
//! concurrent stress over the full structure (tables + queue + counters).

use super::*;
use crate::sync::shim::{AtomicBool, AtomicU64, Ordering};
use crate::testutil::{forall, PropConfig, Rng64, U64Range, VecGen};
use std::sync::Arc;

fn default_chain() -> McPrioQ {
    McPrioQ::new(ChainConfig::default())
}

fn no_dst_chain() -> McPrioQ {
    McPrioQ::new(ChainConfig { use_dst_table: false, ..Default::default() })
}

#[test]
fn observe_creates_nodes_and_edges() {
    let c = default_chain();
    let o1 = c.observe(1, 2);
    assert!(o1.new_src && o1.new_edge);
    let o2 = c.observe(1, 2);
    assert!(!o2.new_src && !o2.new_edge);
    assert_eq!(o2.increment.count, 2);
    let o3 = c.observe(1, 3);
    assert!(!o3.new_src && o3.new_edge);
    assert_eq!(c.node_count(), 1);
    assert_eq!(c.edge_count(), 2);
}

#[test]
fn probability_is_count_over_total() {
    let c = default_chain();
    for _ in 0..3 {
        c.observe(1, 2);
    }
    c.observe(1, 3);
    assert_eq!(c.probability(1, 2), Some(0.75));
    assert_eq!(c.probability(1, 3), Some(0.25));
    assert_eq!(c.probability(1, 4), None);
    assert_eq!(c.probability(9, 2), None);
}

#[test]
fn infer_threshold_returns_minimal_prefix() {
    let c = default_chain();
    // probabilities: 2 -> 0.5, 3 -> 0.3, 4 -> 0.2
    for _ in 0..5 {
        c.observe(1, 2);
    }
    for _ in 0..3 {
        c.observe(1, 3);
    }
    for _ in 0..2 {
        c.observe(1, 4);
    }
    let r = c.infer_threshold(1, 0.5);
    assert_eq!(r.items.len(), 1);
    assert_eq!(r.items[0], (2, 0.5));
    let r = c.infer_threshold(1, 0.75);
    assert_eq!(r.items.len(), 2);
    assert!((r.cumulative - 0.8).abs() < 1e-9);
    let r = c.infer_threshold(1, 1.0);
    assert_eq!(r.items.len(), 3);
    assert!((r.cumulative - 1.0).abs() < 1e-9);
    // P4 minimality: dropping the last item falls below t.
    let r = c.infer_threshold(1, 0.75);
    let without_last: f64 = r.items[..r.items.len() - 1].iter().map(|&(_, p)| p).sum();
    assert!(without_last < 0.75);
}

#[test]
fn infer_threshold_edge_cases() {
    let c = default_chain();
    assert_eq!(c.infer_threshold(1, 0.9), Recommendation::empty()); // unknown src
    c.observe(1, 2);
    assert!(c.infer_threshold(1, 0.0).items.is_empty()); // empty prefix suffices
    let r = c.infer_threshold(1, 1.5); // clamped to 1.0
    assert_eq!(r.items.len(), 1);
    let r = c.infer_threshold(1, -0.5); // clamped to 0.0
    assert!(r.items.is_empty());
}

#[test]
fn infer_topk_orders_by_probability() {
    let c = default_chain();
    for (dst, n) in [(10u64, 7), (20, 3), (30, 9), (40, 1)] {
        for _ in 0..n {
            c.observe(5, dst);
        }
    }
    let r = c.infer_topk(5, 3);
    let keys: Vec<u64> = r.items.iter().map(|&(k, _)| k).collect();
    assert_eq!(keys, vec![30, 10, 20]);
    assert_eq!(r.scanned, 3);
    // k > edges: everything, in order.
    let r = c.infer_topk(5, 100);
    assert_eq!(r.items.len(), 4);
    assert!((r.cumulative - 1.0).abs() < 1e-9);
    assert!(c.infer_topk(5, 0).items.is_empty());
}

#[test]
fn hot_item_bubbles_to_front() {
    let c = default_chain();
    c.observe(1, 100);
    c.observe(1, 200);
    c.observe(1, 300);
    // Make 300 the hottest.
    for _ in 0..10 {
        c.observe(1, 300);
    }
    let r = c.infer_topk(1, 1);
    assert_eq!(r.items[0].0, 300);
    c.check_invariants().unwrap();
}

#[test]
fn decay_halves_and_prunes_and_keeps_distribution() {
    let c = default_chain();
    for _ in 0..8 {
        c.observe(1, 2);
    }
    for _ in 0..4 {
        c.observe(1, 3);
    }
    c.observe(1, 4); // count 1: pruned by first decay
    let p2_before = c.probability(1, 2).unwrap();
    let (total, pruned) = c.decay();
    assert_eq!(pruned, 1);
    assert_eq!(total, 4 + 2);
    assert_eq!(c.edge_count(), 2);
    assert_eq!(c.probability(1, 4), None);
    // Probability ordering (and roughly the values) survive decay (P5).
    let p2_after = c.probability(1, 2).unwrap();
    assert!((p2_before - 8.0 / 13.0).abs() < 1e-9);
    assert!((p2_after - 4.0 / 6.0).abs() < 1e-9);
    c.check_invariants().unwrap();
}

#[test]
fn decay_to_extinction_empties_graph() {
    let c = default_chain();
    for _ in 0..7 {
        c.observe(1, 2);
    }
    for _ in 0..10 {
        c.decay();
    }
    assert_eq!(c.edge_count(), 0);
    assert!(c.infer_threshold(1, 0.9).items.is_empty());
    // The graph still works after extinction.
    c.observe(1, 2);
    assert_eq!(c.probability(1, 2), Some(1.0));
    c.check_invariants().unwrap();
}

#[test]
fn multiple_src_nodes_are_independent() {
    let c = default_chain();
    c.observe(1, 10);
    c.observe(2, 20);
    c.observe(2, 20);
    assert_eq!(c.node_count(), 2);
    assert_eq!(c.probability(1, 10), Some(1.0));
    assert_eq!(c.probability(2, 20), Some(1.0));
    assert_eq!(c.infer_topk(1, 10).items.len(), 1);
    c.check_invariants().unwrap();
}

#[test]
fn no_dst_table_variant_behaves_identically() {
    let with = default_chain();
    let without = no_dst_chain();
    let mut rng = Rng64::new(11);
    for _ in 0..if cfg!(miri) { 300 } else { 2_000 } {
        let src = rng.next_below(5);
        let dst = rng.next_below(20);
        with.observe(src, dst);
        without.observe(src, dst);
    }
    for src in 0..5 {
        let a = with.infer_threshold(src, 0.9);
        let b = without.infer_threshold(src, 0.9);
        assert_eq!(a.total, b.total, "src {src}");
        assert_eq!(a.items.len(), b.items.len(), "src {src}");
        // Same multiset of items (tie order may differ).
        let mut ka: Vec<u64> = a.items.iter().map(|&(k, _)| k).collect();
        let mut kb: Vec<u64> = b.items.iter().map(|&(k, _)| k).collect();
        ka.sort_unstable();
        kb.sort_unstable();
        assert_eq!(ka, kb, "src {src}");
    }
    with.check_invariants().unwrap();
    without.check_invariants().unwrap();
}

#[test]
fn export_import_roundtrip() {
    let c = default_chain();
    let mut rng = Rng64::new(3);
    for _ in 0..if cfg!(miri) { 200 } else { 1_000 } {
        c.observe(rng.next_below(8), rng.next_below(30));
    }
    let snap = c.export();
    let c2 = McPrioQ::import(ChainConfig::default(), &snap);
    assert_eq!(c2.export(), snap);
}

#[test]
fn stats_accumulate() {
    let c = default_chain();
    for i in 0..100 {
        c.observe(i % 3, i % 7);
    }
    let s = c.stats();
    assert_eq!(s.observes, 100);
    assert_eq!(s.nodes, 3);
    assert!(s.edges > 0 && s.edges <= 21);
    assert!(s.approx_bytes > 0);
    c.decay();
    assert_eq!(c.stats().decays, 1);
}

/// P3/P1 under full concurrency: many writers over shared src nodes; after
/// quiescing + repair, totals match edge sums exactly and order is exact.
#[test]
fn concurrent_observe_preserves_counts() {
    const THREADS: u64 = if cfg!(miri) { 4 } else { 8 };
    const OPS: u64 = if cfg!(miri) { 200 } else { 10_000 };
    let c = Arc::new(default_chain());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                let mut rng = Rng64::new(t + 0x99);
                for _ in 0..OPS {
                    // Zipf-ish: skewed dst choice, few srcs — maximal sharing.
                    let src = rng.next_below(4);
                    let u = rng.next_f64();
                    let dst = ((u * u) * 50.0) as u64;
                    c.observe(src, dst);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    c.repair();
    c.check_invariants().unwrap();
    let s = c.stats();
    assert_eq!(s.observes, THREADS * OPS);
    // Total mass across all nodes must equal the number of observations.
    let mass: u64 = c.export().iter().map(|(_, total, _)| *total).sum();
    assert_eq!(mass, THREADS * OPS);
}

/// Readers running during a write+decay storm always get well-formed
/// answers (descending-ish probabilities, cumulative <= 1 + eps).
#[test]
fn concurrent_read_write_decay() {
    let c = Arc::new(default_chain());
    let stop = Arc::new(AtomicBool::new(false));
    // Seed.
    for i in 0..50 {
        c.observe(1, i);
    }
    let writers: Vec<_> = (0..3)
        .map(|t| {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = Rng64::new(t);
                while !stop.load(Ordering::Relaxed) {
                    let u = rng.next_f64();
                    c.observe(1, ((u * u * u) * 50.0) as u64);
                }
            })
        })
        .collect();
    let decayer = {
        let c = Arc::clone(&c);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                c.decay();
                std::thread::yield_now();
            }
        })
    };
    for _ in 0..if cfg!(miri) { 50 } else { 3_000 } {
        let r = c.infer_threshold(1, 0.9);
        // Well-formed: probabilities positive and finite. No numeric bound
        // on the cumulative: a slow reader racing decays and writers sums
        // edge counts that moved after the total was snapshotted, so the
        // ratio is transiently unbounded (approximately correct, §II.B/C);
        // exactness at quiescence is asserted below via check_invariants.
        assert!(r.items.iter().all(|&(_, p)| p > 0.0 && p.is_finite()));
        assert!(r.cumulative.is_finite());
        let rt = c.infer_topk(1, 5);
        assert!(rt.items.len() <= 5);
    }
    stop.store(true, Ordering::SeqCst);
    for w in writers {
        w.join().unwrap();
    }
    decayer.join().unwrap();
    c.repair();
    c.check_invariants().unwrap();
}

#[test]
fn observe_batch_matches_single_path() {
    // Identical stream, three ingestion shapes -> byte-identical exports.
    let single = default_chain();
    let batched = default_chain();
    let one_go = default_chain();
    let mut rng = Rng64::new(0xBA7C);
    let stream: Vec<(u64, u64)> = (0..if cfg!(miri) { 500 } else { 5_000 })
        .map(|_| {
            // Skewed srcs so batches contain same-src runs (the cached-node
            // fast path) as well as src switches.
            let src = rng.next_below(4) * rng.next_below(3);
            (src, rng.next_below(64))
        })
        .collect();
    for &(s, d) in &stream {
        single.observe(s, d);
    }
    let mut folded = BatchOutcome::default();
    for chunk in stream.chunks(97) {
        let out = batched.observe_batch(chunk);
        assert_eq!(out.applied, chunk.len());
        folded.applied += out.applied;
        folded.new_srcs += out.new_srcs;
        folded.new_edges += out.new_edges;
    }
    one_go.observe_batch(&stream);
    assert_eq!(single.export(), batched.export());
    assert_eq!(single.export(), one_go.export());
    assert_eq!(single.stats().observes, batched.stats().observes);
    assert_eq!(folded.applied, stream.len());
    assert_eq!(folded.new_srcs, single.node_count());
    assert_eq!(folded.new_edges, single.edge_count());
    batched.check_invariants().unwrap();
    one_go.check_invariants().unwrap();
}

#[test]
fn observe_batch_weighted_and_empty() {
    let c = default_chain();
    assert_eq!(c.observe_batch(&[]), BatchOutcome::default());
    let out = c.observe_batch_weighted(&[(1, 2, 3), (1, 2, 2), (1, 3, 1), (4, 5, 1)]);
    assert_eq!(out.applied, 4);
    assert_eq!(out.new_srcs, 2);
    assert_eq!(out.new_edges, 3);
    assert_eq!(c.probability(1, 2), Some(5.0 / 6.0));
    assert_eq!(c.probability(1, 3), Some(1.0 / 6.0));
    assert_eq!(c.probability(4, 5), Some(1.0));
    assert_eq!(c.stats().observes, 4);
    c.check_invariants().unwrap();
}

/// Concurrent batch and single writers over shared src nodes: mass is
/// conserved and invariants hold after quiescing (the batch path must not
/// lose or duplicate updates under contention).
#[test]
fn concurrent_batch_and_single_writers() {
    const THREADS: u64 = if cfg!(miri) { 4 } else { 8 };
    const OPS: u64 = if cfg!(miri) { 200 } else { 8_000 };
    let c = Arc::new(default_chain());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                let mut rng = Rng64::new(t + 0xB0B);
                let mut buf = Vec::with_capacity(64);
                for _ in 0..OPS {
                    let src = rng.next_below(4);
                    let u = rng.next_f64();
                    let dst = ((u * u) * 50.0) as u64;
                    if t % 2 == 0 {
                        // Batch writer: flush in runs of 64.
                        buf.push((src, dst));
                        if buf.len() == 64 {
                            c.observe_batch(&buf);
                            buf.clear();
                        }
                    } else {
                        c.observe(src, dst);
                    }
                }
                if !buf.is_empty() {
                    c.observe_batch(&buf);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    c.repair();
    c.check_invariants().unwrap();
    assert_eq!(c.stats().observes, THREADS * OPS);
    let mass: u64 = c.export().iter().map(|(_, total, _)| *total).sum();
    assert_eq!(mass, THREADS * OPS);
}

/// Regression (integer threshold termination): `total = 2^53 + 1` is not
/// representable as f64, so the old float predicate
/// `(cum as f64) < threshold * (total as f64)` rounded the target down to
/// 2^53 and stopped a t=1.0 scan one item early — returning a prefix with
/// cumulative < 1 and breaking P4 (cover the threshold). The termination
/// test now runs in exact integer arithmetic.
#[test]
fn infer_threshold_exact_at_totals_near_2_pow_53() {
    // 2 edges < snap_min_edges: exercises the live list-walk predicate.
    let c = default_chain();
    c.observe_batch_weighted(&[(1, 2, 1u64 << 53), (1, 3, 1)]);
    let r = c.infer_threshold(1, 1.0);
    assert_eq!(r.items.len(), 2, "float rounding dropped the last item");
    assert_eq!(r.total, (1u64 << 53) + 1);
    assert_eq!(r.scanned, 2);

    // >= snap_min_edges: exercises the snapshot binary-search predicate
    // (query twice so the second answer is served from the snapshot).
    let c = default_chain();
    let mut batch = vec![(5u64, 100u64, 1u64 << 53)];
    batch.extend((0..9).map(|d| (5u64, d, 1u64)));
    c.observe_batch_weighted(&batch);
    c.infer_threshold(5, 1.0);
    let r = c.infer_threshold(5, 1.0);
    assert_eq!(r.items.len(), 10, "snapshot path dropped trailing items");
    assert_eq!(r.total, (1u64 << 53) + 9);
    assert!(c.stats().snap_hits >= 1, "second query must hit the snapshot");
}

/// Snapshot reads must be byte-identical to list-walk reads at quiescence:
/// two chains fed the same stream, snapshots on vs off, agree exactly on
/// every query shape (items, probabilities, cumulative, scanned, total).
#[test]
fn snapshot_reads_match_list_walk_at_quiescence() {
    let on = default_chain();
    let off = McPrioQ::new(ChainConfig { snap_enabled: false, ..Default::default() });
    let mut rng = Rng64::new(0x54A9);
    for _ in 0..if cfg!(miri) { 2_000 } else { 20_000 } {
        let src = rng.next_below(4);
        let u = rng.next_f64();
        let dst = ((u * u) * 64.0) as u64;
        on.observe(src, dst);
        off.observe(src, dst);
    }
    on.repair();
    off.repair();
    for src in 0..4 {
        for k in [1, 3, 10, 1_000] {
            on.infer_topk(src, k); // first read rebuilds the snapshot
            assert_eq!(on.infer_topk(src, k), off.infer_topk(src, k), "src {src} k {k}");
        }
        for t in [0.0, 0.3, 0.9, 0.999, 1.0] {
            on.infer_threshold(src, t);
            assert_eq!(on.infer_threshold(src, t), off.infer_threshold(src, t), "src {src} t {t}");
        }
    }
    let s = on.stats();
    assert!(s.snap_rebuilds > 0, "reads never built a snapshot");
    assert!(s.snap_hits > 0, "repeat reads never hit the snapshot");
    assert_eq!(off.stats().snap_hits, 0, "disabled chain must not snapshot");
}

/// §II.C + grace period: once decay has pruned an edge and a grace period
/// has elapsed, neither the snapshot nor the list walk may serve it.
#[test]
fn snapshot_never_serves_pruned_edges_after_grace_period() {
    let c = default_chain();
    for d in 0..16u64 {
        let w = if d < 8 { 10 } else { 1 };
        c.observe_batch_weighted(&[(1, d, w)]);
    }
    c.infer_topk(1, 16);
    c.infer_topk(1, 16); // served from the snapshot
    assert!(c.stats().snap_hits >= 1);
    let (_, pruned) = c.decay(); // weight-1 edges reach 0
    assert_eq!(pruned, 8);
    crate::rcu::synchronize();
    for _ in 0..3 {
        let r = c.infer_topk(1, 16);
        assert!(r.items.iter().all(|&(d, _)| d < 8), "pruned edge served: {:?}", r.items);
        assert_eq!(r.items.len(), 8);
    }
}

/// Concurrent readers during a decay storm (satellite of the read-path
/// overhaul): the hammered node receives *no* concurrent increments (a
/// disjoint src takes the write traffic), so every read — snapshot or
/// list walk — must satisfy `cumulative <= 1 + eps`, and once the first
/// decay's prune has synchronized, no pruned edge may appear.
#[test]
fn concurrent_reads_during_decay_bounded_and_prune_safe() {
    let c = Arc::new(default_chain());
    // Read node 1: heavy edges survive ~20 decays, weight-1 edges are
    // pruned by the first. Inserted in descending weight so the list is
    // born sorted (no swaps => no transient double-visits on this node).
    for d in 0..32u64 {
        let w = if d < 16 { 1 << 20 } else { 1 };
        c.observe_batch_weighted(&[(1, d, w)]);
    }
    let stop = Arc::new(AtomicBool::new(false));
    // After the first decay + grace period, this flips to 1.
    let pruned_gen = Arc::new(AtomicU64::new(0));
    let writer = {
        let c = Arc::clone(&c);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut rng = Rng64::new(0xF00);
            while !stop.load(Ordering::Relaxed) {
                c.observe(2, rng.next_below(40));
            }
        })
    };
    let decayer = {
        let c = Arc::clone(&c);
        let gen = Arc::clone(&pruned_gen);
        std::thread::spawn(move || {
            for i in 0..if cfg!(miri) { 3 } else { 10 } {
                c.decay();
                if i == 0 {
                    crate::rcu::synchronize();
                    gen.store(1, Ordering::SeqCst);
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        })
    };
    let readers: Vec<_> = (0..4)
        .map(|_| {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            let gen = Arc::clone(&pruned_gen);
            std::thread::spawn(move || {
                let mut out = Recommendation::default();
                while !stop.load(Ordering::Relaxed) {
                    let g = gen.load(Ordering::SeqCst);
                    c.infer_topk_into(1, 32, &mut out);
                    assert!(out.cumulative <= 1.0 + 1e-9, "cum {}", out.cumulative);
                    if g >= 1 {
                        assert!(
                            out.items.iter().all(|&(d, _)| d < 16),
                            "pruned edge after grace period: {:?}",
                            out.items
                        );
                    }
                    c.infer_threshold_into(1, 0.9, &mut out);
                    assert!(out.cumulative <= 1.0 + 1e-9, "cum {}", out.cumulative);
                }
            })
        })
        .collect();
    decayer.join().unwrap();
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        r.join().unwrap();
    }
    writer.join().unwrap();
    c.repair();
    c.check_invariants().unwrap();
}

/// The published snapshot array is accounted in `approx_bytes`.
#[test]
fn node_stats_count_snapshot_bytes() {
    let c = default_chain();
    for d in 0..32u64 {
        c.observe(7, d);
    }
    let before = c.node_stats(7).unwrap().approx_bytes;
    c.infer_topk(7, 5); // builds the snapshot
    let after = c.node_stats(7).unwrap().approx_bytes;
    assert!(after >= before + 32 * 24, "snapshot bytes missing: {before} -> {after}");
}

/// Buffer-reuse query API: `infer_*_into` answers equal the allocating
/// API and reuse the caller's `items` allocation across calls.
#[test]
fn infer_into_reuses_buffers_and_matches() {
    let c = default_chain();
    for i in 0..200u64 {
        c.observe(i % 3, i % 17);
    }
    let mut out = Recommendation::default();
    c.infer_topk_into(1, 5, &mut out);
    assert_eq!(out, c.infer_topk(1, 5));
    let cap = out.items.capacity();
    c.infer_topk_into(2, 5, &mut out);
    assert_eq!(out, c.infer_topk(2, 5));
    assert!(out.items.capacity() >= cap.min(5), "buffer reuse lost capacity");
    c.infer_threshold_into(0, 0.8, &mut out);
    assert_eq!(out, c.infer_threshold(0, 0.8));
    // Unknown src resets the buffer to the empty answer.
    c.infer_topk_into(999, 5, &mut out);
    assert_eq!(out, Recommendation::empty());
}

/// Property: for any observation sequence, infer_threshold(t) returns a
/// minimal prefix with cumulative >= t (P4), and the prefix is sorted by
/// descending probability (P1).
#[test]
fn prop_threshold_minimal_sorted_prefix() {
    forall(
        PropConfig { cases: if cfg!(miri) { 16 } else { 128 }, ..Default::default() },
        &VecGen { elem: U64Range { lo: 0, hi: 15 }, max_len: 200 },
        |dsts| {
            let c = default_chain();
            for &d in dsts {
                c.observe(0, d);
            }
            if dsts.is_empty() {
                return c.infer_threshold(0, 0.5).items.is_empty();
            }
            for t in [0.1, 0.5, 0.9, 1.0] {
                let r = c.infer_threshold(0, t);
                // Sorted descending.
                if !r.items.windows(2).all(|w| w[0].1 >= w[1].1 - 1e-12) {
                    return false;
                }
                // Covers t.
                if r.cumulative + 1e-12 < t {
                    return false;
                }
                // Minimal.
                if r.items.len() > 1 {
                    let without: f64 = r.cumulative - r.items.last().unwrap().1;
                    if without >= t + 1e-12 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Property: decay never increases any probability-ordering inversions and
/// preserves relative order of surviving edges (P5).
#[test]
fn prop_decay_preserves_order() {
    forall(
        PropConfig { cases: if cfg!(miri) { 16 } else { 128 }, ..Default::default() },
        &VecGen { elem: U64Range { lo: 0, hi: 9 }, max_len: 300 },
        |dsts| {
            let c = default_chain();
            for &d in dsts {
                c.observe(0, d);
            }
            let before: Vec<u64> =
                c.infer_topk(0, 100).items.iter().map(|&(k, _)| k).collect();
            c.decay();
            if c.check_invariants().is_err() {
                return false;
            }
            let after: Vec<u64> = c.infer_topk(0, 100).items.iter().map(|&(k, _)| k).collect();
            // Surviving edges appear in the same relative order.
            let mut bi = before.iter();
            after.iter().all(|a| bi.any(|b| b == a))
        },
    );
}
